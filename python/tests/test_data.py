"""Synthetic generator tests: shapes, determinism, separability, container."""

import numpy as np
import pytest

from compile import data as d

SHAPES = {"top": (20, 6), "flavor": (15, 6), "quickdraw": (100, 3)}


@pytest.mark.parametrize("name", list(SHAPES))
def test_shapes_and_dtypes(name):
    x, y = d.generate(name, seed=1, n=64)
    seq, feat = SHAPES[name]
    assert x.shape == (64, seq, feat)
    assert x.dtype == np.float32
    assert y.shape == (64,)
    assert y.dtype == np.uint32


@pytest.mark.parametrize("name", list(SHAPES))
def test_deterministic_given_seed(name):
    x1, y1 = d.generate(name, seed=42, n=32)
    x2, y2 = d.generate(name, seed=42, n=32)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = d.generate(name, seed=43, n=32)
    assert not np.array_equal(x1, x3)


@pytest.mark.parametrize("name", list(SHAPES))
def test_labels_cover_all_classes(name):
    _, y = d.generate(name, seed=5, n=400)
    classes = d.N_CLASSES[name]
    n_labels = 2 if classes == 1 else classes
    assert set(np.unique(y)) == set(range(n_labels))


@pytest.mark.parametrize("name", list(SHAPES))
def test_features_bounded(name):
    """top/flavor features are O(1) (int 6 suffices); quickdraw keeps the
    raw ~0-255 coordinate scale that forces >= 10 integer bits (Fig 2c)."""
    x, _ = d.generate(name, seed=7, n=256)
    bound = 512.0 if name == "quickdraw" else 32.0
    assert np.abs(x).max() < bound
    if name == "quickdraw":
        assert np.abs(x[:, :, :2]).max() > 64.0  # raw scale preserved
    assert np.isfinite(x).all()


def test_top_tagging_prong_structure_separates():
    """Tops (3-prong) have wider dR spread than light jets — the feature
    the RNN learns; a crude cut on it must already beat chance."""
    x, y = d.generate("top", seed=11, n=1000)
    dr = x[:, :, 4]  # dR feature
    pt = x[:, :, 0]
    spread = (dr * (pt > 0)).sum(1) / np.maximum((pt > 0).sum(1), 1)
    sig, bkg = spread[y == 1].mean(), spread[y == 0].mean()
    assert sig > bkg * 1.3


def test_flavor_displacement_orders_classes():
    """Mean |S(d0)| of the leading track: b > c > light."""
    x, y = d.generate("flavor", seed=13, n=1500)
    lead_sig = np.abs(x[:, 0, 4])
    means = [lead_sig[y == k].mean() for k in range(3)]
    assert means[2] > means[1] > means[0]


def test_quickdraw_classes_differ_geometrically():
    x, y = d.generate("quickdraw", seed=17, n=500)
    # radial profile variance differs between spiral (4) and rose (1)
    r = np.sqrt(x[:, :, 0] ** 2 + x[:, :, 1] ** 2)
    v_spiral = r[y == 4].std(axis=1).mean()
    v_rose = r[y == 1].std(axis=1).mean()
    assert abs(v_spiral - v_rose) > 0.02
    # timestamps are monotone in [0, 15] (the game's drawing window)
    t = x[:, :, 2]
    assert (np.diff(t, axis=1) >= -1e-4).all()
    assert t.min() >= 0.0 and t.max() <= 15.0 + 1e-4


def test_dataset_container_roundtrip(tmp_path):
    x, y = d.generate("flavor", seed=3, n=20)
    path = str(tmp_path / "t.bin")
    d.write_dataset(path, x, y, d.N_CLASSES["flavor"])
    x2, y2, classes = d.read_dataset(path)
    assert classes == 3
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)


def test_dataset_container_rejects_bad_magic(tmp_path):
    path = str(tmp_path / "bad.bin")
    with open(path, "wb") as f:
        f.write(b"NOTMAGIC" + b"\0" * 32)
    with pytest.raises(ValueError):
        d.read_dataset(path)
