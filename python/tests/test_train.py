"""Training-loop tests: optimizer correctness, loss decrease, AUC metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m
from compile import train as t


def test_binary_auc_perfect_and_chance():
    scores = np.array([0.9, 0.8, 0.7, 0.2, 0.1, 0.0])
    labels = np.array([1, 1, 1, 0, 0, 0])
    assert t.binary_auc(scores, labels) == 1.0
    assert t.binary_auc(1 - scores, labels) == 0.0
    assert t.binary_auc(np.full(6, 0.5), labels) == 0.5


def test_binary_auc_with_ties_is_midrank():
    scores = np.array([0.5, 0.5, 0.5, 0.1])
    labels = np.array([1, 0, 1, 0])
    # one neg tied with both pos (0.5 each), one neg below both (1 each)
    assert abs(t.binary_auc(scores, labels) - 0.75) < 1e-9


def test_binary_auc_degenerate_labels():
    assert t.binary_auc(np.array([0.1, 0.9]), np.array([1, 1])) == 0.5


def test_multiclass_auc_matches_binary_reduction():
    rng = np.random.default_rng(0)
    probs = rng.random((200, 3))
    probs /= probs.sum(1, keepdims=True)
    labels = rng.integers(0, 3, 200)
    per = t.multiclass_auc(probs, labels)
    assert len(per) == 3
    for k in range(3):
        assert per[k] == t.binary_auc(probs[:, k], (labels == k).astype(int))


def test_adam_matches_reference_impl():
    """Hand-rolled Adam vs an independent numpy reference, 10 steps."""
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    state = t.adam_init(params)
    lr, b1, b2, eps = 0.1, 0.9, 0.999, 1e-8
    w = np.array([1.0, -2.0, 3.0])
    m_, v_ = np.zeros(3), np.zeros(3)
    for step in range(1, 11):
        g = 2.0 * w  # grad of sum(w^2)
        grads = {"w": jnp.asarray(g, jnp.float32)}
        params, state = t.adam_step(params, state, grads, lr)
        m_ = b1 * m_ + (1 - b1) * g
        v_ = b2 * v_ + (1 - b2) * g * g
        mh = m_ / (1 - b1**step)
        vh = v_ / (1 - b2**step)
        w = w - lr * mh / (np.sqrt(vh) + eps)
    np.testing.assert_allclose(np.array(params["w"]), w, rtol=2e-4)


def test_loss_fn_binary_stable_at_extremes():
    a = m.arch("top", "lstm")
    params = m.init_params(a, jax.random.PRNGKey(0))
    x = jnp.zeros((4, a.seq_len, a.input_size))
    y = jnp.array([0, 1, 0, 1])
    loss = t._loss_fn(params, x, y, a)
    assert np.isfinite(float(loss))


@pytest.mark.slow
def test_short_training_reduces_loss():
    a = m.arch("top", "gru")
    cfg_backup = dict(t.TRAIN_CFG["top"])
    t.TRAIN_CFG["top"] = dict(n_train=2000, steps=120, batch=128, lr=1e-3)
    try:
        _params, meta = t.train_one(a, verbose=False)
    finally:
        t.TRAIN_CFG["top"] = cfg_backup
    assert meta["loss_curve"][-1] < meta["loss_curve"][0] * 0.8
    assert meta["float_auc"] > 0.85
