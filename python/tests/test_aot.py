"""AOT export tests: HLO text well-formedness and lowering invariants."""

import jax
import pytest

from compile import aot
from compile import model as m


@pytest.fixture(scope="module")
def top_gru_lowered():
    a = m.arch("top", "gru")
    params = m.init_params(a, jax.random.PRNGKey(0))
    return aot.lower_model(a, params, batch=1)


def test_hlo_is_text_module(top_gru_lowered):
    text, _ = top_gru_lowered
    assert text.startswith("HloModule")
    assert "ENTRY" in text


def test_hlo_parameters_are_input_plus_weights(top_gru_lowered):
    """Parameter 0 is the input batch; parameters 1..N are the weight
    tensors in manifest order (weights must NOT be baked in: the HLO text
    printer elides large constants as `{...}`, silently corrupting them)."""
    text, order = top_gru_lowered
    entry = text.split("ENTRY")[1]
    assert entry.count("parameter(") == 1 + len(order)
    assert "f32[1,20,6]" in entry  # (batch, seq, input)
    assert "{...}" not in entry


def test_param_order_covers_all_layers(top_gru_lowered):
    _, order = top_gru_lowered
    layers = {layer for layer, _t in order}
    assert layers == {"rnn", "dense0", "out"}
    # dict flatten order is sorted by key, stable across runs
    assert order == sorted(order)


def test_hlo_batch_shapes():
    a = m.arch("top", "lstm")
    params = m.init_params(a, jax.random.PRNGKey(1))
    for batch in (1, 10):
        text, _ = aot.lower_model(a, params, batch=batch)
        assert f"f32[{batch},20,6]" in text


def test_hlo_no_custom_calls(top_gru_lowered):
    """interpret=True must lower pallas to plain HLO — a Mosaic custom-call
    would be unloadable by the CPU PJRT plugin."""
    text, _ = top_gru_lowered
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_batch_sizes_constant():
    # The rust batcher's bucket list must stay in sync with the manifest.
    assert aot.BATCH_SIZES == (1, 10, 100)
