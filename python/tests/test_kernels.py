"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes; every kernel must match ``ref.py`` to float32
tolerance under interpret=True.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, gru, lstm, ref

ATOL = 2e-5


def _rand(key, shape, scale=0.4):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# LSTM
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 8),
    seq=st.integers(1, 24),
    in_dim=st.integers(1, 12),
    hidden=st.integers(1, 48),
)
def test_lstm_matches_ref(batch, seq, in_dim, hidden):
    x = _rand(0, (batch, seq, in_dim), 1.0)
    w = _rand(1, (in_dim, 4 * hidden))
    u = _rand(2, (hidden, 4 * hidden))
    b = _rand(3, (4 * hidden,), 0.1)
    got = lstm(x, w, u, b)
    want = ref.lstm(x, w, u, b)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=ATOL)


def test_lstm_paper_shapes():
    """The exact recurrent shapes of the three benchmarks (Table 1)."""
    for in_dim, hidden, seq in [(6, 20, 20), (6, 120, 15), (3, 128, 100)]:
        x = _rand(0, (2, seq, in_dim), 1.0)
        w = _rand(1, (in_dim, 4 * hidden))
        u = _rand(2, (hidden, 4 * hidden))
        b = _rand(3, (4 * hidden,), 0.1)
        np.testing.assert_allclose(
            np.array(lstm(x, w, u, b)),
            np.array(ref.lstm(x, w, u, b)),
            atol=ATOL,
        )


def test_lstm_zero_input_keeps_forget_dynamics():
    """With zero inputs the state evolves only through gate biases."""
    hidden = 8
    x = jnp.zeros((1, 5, 4))
    w = jnp.zeros((4, 4 * hidden))
    u = jnp.zeros((hidden, 4 * hidden))
    b = jnp.concatenate(
        [jnp.zeros(hidden), jnp.ones(hidden), jnp.zeros(2 * hidden)]
    )
    got = np.array(lstm(x, w, u, b))
    want = np.array(ref.lstm(x, w, u, b))
    np.testing.assert_allclose(got, want, atol=ATOL)
    # sigmoid(0)=0.5 input gate, tanh(0)=0 candidate -> h stays 0
    np.testing.assert_allclose(got, np.zeros_like(got), atol=ATOL)


def test_lstm_rejects_bad_shapes():
    x = jnp.zeros((1, 3, 4))
    with pytest.raises(ValueError):
        lstm(x, jnp.zeros((4, 12)), jnp.zeros((8, 32)), jnp.zeros(32))
    with pytest.raises(ValueError):
        lstm(x, jnp.zeros((4, 32)), jnp.zeros((8, 32)), jnp.zeros(31))


def test_lstm_under_jit_and_grad_free():
    """The kernel composes with jit (needed for AOT lowering)."""
    x = _rand(0, (2, 6, 5), 1.0)
    w, u, b = _rand(1, (5, 32)), _rand(2, (8, 32)), _rand(3, (32,), 0.1)
    got = jax.jit(lambda xx: lstm(xx, w, u, b))(x)
    np.testing.assert_allclose(
        np.array(got), np.array(ref.lstm(x, w, u, b)), atol=ATOL
    )


def test_lstm_vmem_footprint_model():
    from compile.kernels.lstm import vmem_footprint_bytes

    # quickdraw LSTM at batch 100 must still fit one TensorCore's ~16 MiB.
    assert vmem_footprint_bytes(100, 100, 3, 128) < 16 * 2**20
    assert vmem_footprint_bytes(1, 20, 6, 20) < 64 * 2**10


# ---------------------------------------------------------------------------
# GRU (reset_after)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 8),
    seq=st.integers(1, 24),
    in_dim=st.integers(1, 12),
    hidden=st.integers(1, 48),
)
def test_gru_matches_ref(batch, seq, in_dim, hidden):
    x = _rand(0, (batch, seq, in_dim), 1.0)
    w = _rand(1, (in_dim, 3 * hidden))
    u = _rand(2, (hidden, 3 * hidden))
    b = _rand(3, (2, 3 * hidden), 0.1)
    got = gru(x, w, u, b)
    want = ref.gru(x, w, u, b)
    np.testing.assert_allclose(np.array(got), np.array(want), atol=ATOL)


def test_gru_paper_shapes():
    for in_dim, hidden, seq in [(6, 20, 20), (6, 120, 15), (3, 128, 100)]:
        x = _rand(0, (2, seq, in_dim), 1.0)
        w = _rand(1, (in_dim, 3 * hidden))
        u = _rand(2, (hidden, 3 * hidden))
        b = _rand(3, (2, 3 * hidden), 0.1)
        np.testing.assert_allclose(
            np.array(gru(x, w, u, b)),
            np.array(ref.gru(x, w, u, b)),
            atol=ATOL,
        )


def test_gru_reset_after_bias_split_matters():
    """reset_after uses two bias rows; swapping them must change outputs
    (guards against accidentally collapsing to reset_before semantics)."""
    x = _rand(0, (1, 4, 3), 1.0)
    w = _rand(1, (3, 12))
    u = _rand(2, (4, 12))
    b = jnp.stack([jnp.full(12, 0.5), jnp.full(12, -0.5)])
    got = np.array(gru(x, w, u, b))
    swapped = np.array(gru(x, w, u, b[::-1]))
    assert not np.allclose(got, swapped)


def test_gru_rejects_bad_shapes():
    x = jnp.zeros((1, 3, 4))
    with pytest.raises(ValueError):
        gru(x, jnp.zeros((4, 8)), jnp.zeros((8, 24)), jnp.zeros((2, 24)))
    with pytest.raises(ValueError):
        gru(x, jnp.zeros((4, 24)), jnp.zeros((8, 24)), jnp.zeros((24,)))


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 16),
    in_dim=st.integers(1, 64),
    out_dim=st.integers(1, 64),
    act=st.sampled_from(["linear", "relu", "sigmoid", "tanh"]),
)
def test_dense_matches_ref(batch, in_dim, out_dim, act):
    x = _rand(0, (batch, in_dim), 1.0)
    w = _rand(1, (in_dim, out_dim))
    b = _rand(2, (out_dim,), 0.1)
    got = np.array(dense(x, w, b, activation=act))
    want = np.dot(np.array(x), np.array(w)) + np.array(b)
    if act == "relu":
        want = np.maximum(want, 0)
    elif act == "sigmoid":
        want = 1 / (1 + np.exp(-want))
    elif act == "tanh":
        want = np.tanh(want)
    np.testing.assert_allclose(got, want, atol=ATOL, rtol=1e-5)


@pytest.mark.parametrize("block_out", [1, 2, 4, 8, 16])
def test_dense_tiling_is_invisible(block_out):
    """Output tiling (the reuse-factor analogue) must not change numerics."""
    x = _rand(0, (3, 10), 1.0)
    w = _rand(1, (10, 16))
    b = _rand(2, (16,), 0.1)
    full = np.array(dense(x, w, b))
    tiled = np.array(dense(x, w, b, block_out=block_out))
    np.testing.assert_allclose(full, tiled, atol=ATOL)


def test_dense_rejects_nondividing_block():
    with pytest.raises(ValueError):
        dense(jnp.zeros((1, 4)), jnp.zeros((4, 10)), jnp.zeros(10), block_out=3)


def test_hadamard_ref():
    a = _rand(0, (4, 8), 1.0)
    b = _rand(1, (4, 8), 1.0)
    np.testing.assert_allclose(
        np.array(ref.hadamard(a, b)), np.array(a) * np.array(b)
    )
