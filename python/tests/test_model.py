"""L2 model tests: Table 1 parameter counts, backend agreement, round trips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m

# (name, cell) -> (rnn params, non-rnn params, total) — Table 1 + §4 text.
PAPER_COUNTS = {
    ("top", "lstm"): (2160, 1409, 3569),
    ("top", "gru"): (1680, 1409, 3089),
    ("flavor", "lstm"): (60960, 6593, 67553),
    ("flavor", "gru"): (46080, 6593, 52673),
    ("quickdraw", "lstm"): (67584, 66565, 134149),
    ("quickdraw", "gru"): (51072, 66565, 117637),
}


@pytest.mark.parametrize("name,cell", list(PAPER_COUNTS))
def test_param_counts_match_table1(name, cell):
    a = m.arch(name, cell)
    rnn, non_rnn, total = PAPER_COUNTS[(name, cell)]
    assert a.rnn_param_count() == rnn
    assert a.non_rnn_param_count() == non_rnn
    assert a.param_count() == total


@pytest.mark.parametrize("name,cell", list(PAPER_COUNTS))
def test_init_params_match_arch_count(name, cell):
    a = m.arch(name, cell)
    params = m.init_params(a, jax.random.PRNGKey(0))
    assert m.count_params(params) == a.param_count()


@pytest.mark.parametrize("cell", ["lstm", "gru"])
def test_forward_backends_agree(cell):
    a = m.arch("top", cell)
    params = m.init_params(a, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, a.seq_len, a.input_size))
    y_ref = np.array(m.forward(params, x, a, backend="ref"))
    y_pal = np.array(m.forward(params, x, a, backend="pallas"))
    np.testing.assert_allclose(y_ref, y_pal, atol=3e-6)


def test_forward_backends_agree_multiclass():
    a = m.arch("flavor", "gru")
    params = m.init_params(a, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (3, a.seq_len, a.input_size))
    y_ref = np.array(m.forward(params, x, a, backend="ref"))
    y_pal = np.array(m.forward(params, x, a, backend="pallas"))
    np.testing.assert_allclose(y_ref, y_pal, atol=3e-6)
    np.testing.assert_allclose(y_ref.sum(axis=1), 1.0, atol=1e-5)


def test_output_ranges():
    a = m.arch("top", "lstm")
    params = m.init_params(a, jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (16, a.seq_len, a.input_size))
    y = np.array(m.forward(params, x, a))
    assert y.shape == (16, 1)
    assert (y >= 0).all() and (y <= 1).all()


def test_logits_are_preactivation():
    a = m.arch("top", "gru")
    params = m.init_params(a, jax.random.PRNGKey(5))
    x = jax.random.normal(jax.random.PRNGKey(6), (4, a.seq_len, a.input_size))
    z = np.array(m.logits(params, x, a))
    y = np.array(m.forward(params, x, a))
    np.testing.assert_allclose(1 / (1 + np.exp(-z)), y, atol=1e-6)


def test_unknown_arch_rejected():
    with pytest.raises(KeyError):
        m.arch("nope", "lstm")
    with pytest.raises(KeyError):
        m.arch("top", "rnn")


def test_params_json_roundtrip():
    a = m.arch("top", "gru")
    params = m.init_params(a, jax.random.PRNGKey(7))
    text = m.params_to_json(a, params)
    a2, params2 = m.params_from_json(text)
    assert a2 == a
    for layer, tensors in params.items():
        for pname, val in tensors.items():
            np.testing.assert_allclose(
                np.array(val), np.array(params2[layer][pname]), atol=0
            )


def test_forward_json_roundtrip_preserves_outputs():
    a = m.arch("flavor", "lstm")
    params = m.init_params(a, jax.random.PRNGKey(8))
    a2, params2 = m.params_from_json(m.params_to_json(a, params))
    x = jax.random.normal(jax.random.PRNGKey(9), (2, a.seq_len, a.input_size))
    np.testing.assert_allclose(
        np.array(m.forward(params, x, a)),
        np.array(m.forward(params2, x, a2)),
        atol=1e-7,
    )


def test_lstm_forget_bias_is_one():
    a = m.arch("top", "lstm")
    params = m.init_params(a, jax.random.PRNGKey(0))
    b = np.array(params["rnn"]["b"])
    h = a.hidden_size
    np.testing.assert_allclose(b[h : 2 * h], 1.0)
    np.testing.assert_allclose(b[:h], 0.0)


def test_orthogonal_recurrent_init():
    a = m.arch("top", "gru")
    params = m.init_params(a, jax.random.PRNGKey(0))
    u = np.array(params["rnn"]["u"])  # (H, 3H), each HxH block orthogonal
    h = a.hidden_size
    for g in range(3):
        blk = u[:, g * h : (g + 1) * h]
        np.testing.assert_allclose(blk.T @ blk, np.eye(h), atol=1e-5)
