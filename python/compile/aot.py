"""AOT export: lower every benchmark model to HLO *text* for the rust runtime.

This is the L2→L3 bridge.  Each trained model is lowered with the Pallas
backend (the whole inference graph comes from L1 kernels), weights baked
in as constants, at each serving batch size, and written as HLO **text**:

    jax.jit(fn).lower(spec) → StableHLO → XlaComputation → as_hlo_text()

Text — NOT ``lowered.compile()``/``.serialize()`` — is the interchange
format because jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under ``artifacts/``):

* ``hlo/{bench}_{cell}_b{B}.hlo.txt`` — one module per model × batch size
* ``golden/{bench}_{cell}.json``      — forward outputs on the first 8
  frozen test samples, for rust↔python cross-validation
* ``manifest.json``                   — registry the rust runtime loads
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import data as datamod
from compile import model as modelmod

# Serving batch buckets.  1/10/100 are the batch sizes of the paper's §5.2
# GPU-throughput comparison; the dynamic batcher in rust pads to the next
# bucket.
BATCH_SIZES = (1, 10, 100)
N_GOLDEN = 8


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(a, params, batch: int) -> tuple[str, list[list[str]]]:
    """Lower ``forward(params, ·, a)`` with weights as runtime parameters.

    Weights are NOT baked in as constants: XLA's HLO text printer elides
    large literals as ``constant({...})``, which the rust-side parser
    accepts but fills with garbage — a silent numerical corruption.  The
    weights instead become parameters 1..N (parameter 0 is the input
    batch); the rust runtime builds the weight literals once from
    ``weights/{key}.json`` in the flatten order recorded in the manifest.

    Returns (hlo_text, param_order) where param_order[i] = [layer, tensor]
    for HLO parameter ``i + 1``.
    """
    flat, treedef = jax.tree_util.tree_flatten(params)
    paths, _ = jax.tree_util.tree_flatten_with_path(params)
    order = [[str(p[0].key), str(p[1].key)] for p, _leaf in paths]

    def fn(x, *ws):
        p = jax.tree_util.tree_unflatten(treedef, ws)
        return (modelmod.forward(p, x, a, backend="pallas"),)

    x_spec = jax.ShapeDtypeStruct((batch, a.seq_len, a.input_size), jnp.float32)
    w_specs = [jax.ShapeDtypeStruct(w.shape, w.dtype) for w in flat]
    return to_hlo_text(jax.jit(fn).lower(x_spec, *w_specs)), order


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--only", default=None, help="lower a single arch key")
    args = ap.parse_args()

    hlo_dir = os.path.join(args.out, "hlo")
    golden_dir = os.path.join(args.out, "golden")
    os.makedirs(hlo_dir, exist_ok=True)
    os.makedirs(golden_dir, exist_ok=True)

    manifest: dict = {"format": "hlo-text-v1", "models": []}
    for a in modelmod.all_archs():
        if args.only and a.key != args.only:
            continue
        wpath = os.path.join(args.out, "weights", f"{a.key}.json")
        if not os.path.exists(wpath):
            print(f"skip {a.key}: no weights at {wpath} (run train first)")
            continue
        with open(wpath) as f:
            a2, params = modelmod.params_from_json(f.read())
        assert a2 == a, (a2, a)

        entry = {
            "key": a.key,
            "benchmark": a.name,
            "cell": a.cell,
            "seq_len": a.seq_len,
            "input_size": a.input_size,
            "hidden_size": a.hidden_size,
            "output_size": a.output_size,
            "weights": f"weights/{a.key}.json",
            "dataset": f"data/{a.name}_test.bin",
            "golden": f"golden/{a.key}.json",
            "hlo": {},
        }
        for batch in BATCH_SIZES:
            text, order = lower_model(a, params, batch)
            rel = f"hlo/{a.key}_b{batch}.hlo.txt"
            with open(os.path.join(args.out, rel), "w") as f:
                f.write(text)
            entry["hlo"][str(batch)] = rel
            entry["param_order"] = order
            print(f"wrote {rel} ({len(text)} chars)")

        # Golden outputs on the frozen test set (float path, ref backend —
        # identical numerics to pallas, asserted in pytest).
        xt, _yt, _c = datamod.read_dataset(
            os.path.join(args.out, "data", f"{a.name}_test.bin")
        )
        xg = jnp.asarray(xt[:N_GOLDEN])
        yg = np.asarray(modelmod.forward(params, xg, a, backend="ref"))
        with open(os.path.join(golden_dir, f"{a.key}.json"), "w") as f:
            json.dump(
                {
                    "n": N_GOLDEN,
                    "output_size": a.output_size,
                    "outputs": [[float(v) for v in row] for row in yg],
                },
                f,
            )
        manifest["models"].append(entry)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['models'])} models")


if __name__ == "__main__":
    main()
