"""Build the committed test fixtures for the rust weight-import layer.

Trains the real ``top_gru`` benchmark with :func:`compile.train.train_one`
(the same pipeline ``make artifacts`` runs) and freezes three small files
under ``rust/tests/fixtures/``:

* ``top_gru.json``      — the JSON interchange doc (``params_to_json``)
* ``top_gru.onnx``      — the same checkpoint as an ONNX graph, written in
  ONNX's *native* layouts so the rust reader has real conversion work to
  do: ``GRU`` with ``W (1, 3H, I)`` / ``R (1, 3H, H)`` / ``B (1, 6H)``
  (gate blocks ``[z, r, h]``, ``linear_before_reset=1`` = Keras
  ``reset_after``), and ``Gemm`` head layers with ``transB=1`` (weights
  stored ``(out, in)``).
* ``top_test_slice.bin``— a few hundred events of the frozen top-tagging
  test stream in the ``RNNDAT01`` container (seed ``SEED_TEST``).
* ``top_gru.meta.json`` — training metadata + the float AUC on the slice
  (the reference the rust golden accuracy suite pins against).

The ONNX bytes are a hand-rolled protobuf encoding (no ``onnx`` package
on this image); the subset written here is exactly the subset
``rust/src/model/import/onnx.rs`` reads back.

Reproducibility: ``train_one`` seeds its initializer from
``hash(arch.key)``, so regeneration must run with ``PYTHONHASHSEED=0``:

    cd python && PYTHONHASHSEED=0 python3 -m compile.export_fixtures
"""

from __future__ import annotations

import argparse
import json
import os
import struct

import numpy as np

from compile import data as datamod
from compile import model as modelmod
from compile import train as trainmod

SLICE_N = 400

# ---------------------------------------------------------------------------
# Minimal protobuf wire-format writers (the ONNX subset we emit).
# ---------------------------------------------------------------------------


def _varint(n: int) -> bytes:
    out = bytearray()
    while True:
        byte = n & 0x7F
        n >>= 7
        if n:
            out.append(byte | 0x80)
        else:
            out.append(byte)
            return bytes(out)


def _tag(field: int, wire: int) -> bytes:
    return _varint((field << 3) | wire)


def _p_int(field: int, n: int) -> bytes:
    return _tag(field, 0) + _varint(n)


def _p_bytes(field: int, payload: bytes) -> bytes:
    return _tag(field, 2) + _varint(len(payload)) + payload


def _p_str(field: int, s: str) -> bytes:
    return _p_bytes(field, s.encode("utf-8"))


def _tensor(name: str, dims: tuple[int, ...], data: np.ndarray) -> bytes:
    """TensorProto: dims(1) data_type(2)=FLOAT name(8) raw_data(9)."""
    body = b"".join(_p_int(1, d) for d in dims)
    body += _p_int(2, 1)  # FLOAT
    body += _p_str(8, name)
    body += _p_bytes(9, np.ascontiguousarray(data, "<f4").tobytes())
    return body


def _attr_int(name: str, value: int) -> bytes:
    # AttributeProto: name(1) i(3) type(20)=INT(2)
    return _p_str(1, name) + _p_int(3, value) + _p_int(20, 2)


def _attr_str(name: str, value: str) -> bytes:
    # AttributeProto: name(1) s(4) type(20)=STRING(3)
    return _p_str(1, name) + _p_str(4, value) + _p_int(20, 3)


def _node(
    op_type: str,
    inputs: list[str],
    outputs: list[str],
    name: str,
    attrs: list[bytes] | None = None,
) -> bytes:
    body = b"".join(_p_str(1, i) for i in inputs)
    body += b"".join(_p_str(2, o) for o in outputs)
    body += _p_str(3, name)
    body += _p_str(4, op_type)
    body += b"".join(_p_bytes(5, a) for a in (attrs or []))
    return body


def _value_info(name: str, shape: tuple[int, ...]) -> bytes:
    """ValueInfoProto with a float tensor type of the given static shape."""
    dims = b"".join(_p_bytes(1, _p_int(1, d)) for d in shape)
    tensor_shape = _p_bytes(2, dims)
    tensor_type = _p_int(1, 1) + tensor_shape  # elem_type FLOAT + shape
    type_proto = _p_bytes(1, tensor_type)
    return _p_str(1, name) + _p_bytes(2, type_proto)


def onnx_export(a: modelmod.Arch, params: dict) -> bytes:
    """Serialize a trained checkpoint as an ONNX ModelProto.

    Layout conversions applied (the inverse of what the rust reader does):
    recurrent kernels transpose from Keras ``(I, GH)`` to ONNX
    ``(1, GH, I)``; LSTM gate blocks reorder from Keras ``[i, f, c, o]``
    to ONNX ``[i, o, f, c]``; the single Keras LSTM bias becomes ONNX's
    ``Wb`` half with ``Rb = 0``; GRU keeps ``[z, r, h]`` (identical in
    both conventions) and stacks its two Keras bias rows into ``(1, 6H)``.
    """
    h = a.hidden_size
    w = np.asarray(params["rnn"]["w"], np.float32)  # (I, GH)
    u = np.asarray(params["rnn"]["u"], np.float32)  # (H, GH)
    b = np.asarray(params["rnn"]["b"], np.float32)

    def blocks(mat: np.ndarray, order: list[int]) -> np.ndarray:
        """Transpose (in, G*H) to (G*H, in) with gate blocks reordered."""
        t = mat.T  # (GH, in)
        return np.concatenate([t[g * h : (g + 1) * h] for g in order])

    if a.cell == "lstm":
        order = [0, 3, 1, 2]  # ONNX [i, o, f, c] from Keras [i, f, c, o]
        w_on = blocks(w, order)[None]  # (1, 4H, I)
        r_on = blocks(u, order)[None]  # (1, 4H, H)
        wb = np.concatenate([b[g * h : (g + 1) * h] for g in order])
        b_on = np.concatenate([wb, np.zeros(4 * h, np.float32)])[None]
        op, n_b = "LSTM", 8 * h
    else:
        w_on = blocks(w, [0, 1, 2])[None]  # (1, 3H, I), [z, r, h] kept
        r_on = blocks(u, [0, 1, 2])[None]
        b_on = np.concatenate([b[0], b[1]])[None]  # (1, 6H): Wb | Rb
        op, n_b = "GRU", 6 * h

    initializers = [
        _tensor("rnn.W", w_on.shape, w_on),
        _tensor("rnn.R", r_on.shape, r_on),
        _tensor("rnn.B", (1, n_b), b_on),
    ]
    attrs = [_attr_int("hidden_size", h), _attr_str("direction", "forward")]
    if a.cell == "gru":
        attrs.append(_attr_int("linear_before_reset", 1))
    nodes = [
        _node(op, ["x", "rnn.W", "rnn.R", "rnn.B"], ["rnn_y", "rnn_h"],
              "rnn", attrs),
    ]
    # ONNX LSTM/GRU Y_h output is (num_dirs, B, H); flatten to (B, H) for
    # the head (the rust reader ignores shaping nodes by design).
    nodes.append(_node("Squeeze", ["rnn_h"], ["state"], "squeeze"))

    prev = "state"
    head = [(f"dense{i}", True) for i in range(len(a.dense_sizes))]
    head.append(("out", False))
    for lname, relu in head:
        wl = np.asarray(params[lname]["w"], np.float32)  # (in, out)
        bl = np.asarray(params[lname]["b"], np.float32)
        initializers.append(_tensor(f"{lname}.w", wl.T.shape, wl.T))
        initializers.append(_tensor(f"{lname}.b", bl.shape, bl))
        out_name = f"{lname}_z"
        nodes.append(
            _node("Gemm", [prev, f"{lname}.w", f"{lname}.b"], [out_name],
                  lname, [_attr_int("transB", 1)])
        )
        prev = out_name
        if relu:
            nodes.append(_node("Relu", [prev], [f"{lname}_a"], f"{lname}_relu"))
            prev = f"{lname}_a"
    act = "Sigmoid" if a.output_activation == "sigmoid" else "Softmax"
    nodes.append(_node(act, [prev], ["probs"], "output_activation"))

    graph = b"".join(_p_bytes(1, n) for n in nodes)
    graph += _p_str(2, a.key)
    graph += b"".join(_p_bytes(5, t) for t in initializers)
    graph += _p_bytes(11, _value_info("x", (1, a.seq_len, a.input_size)))
    graph += _p_bytes(12, _value_info("probs", (1, a.output_size)))

    model = _p_int(1, 8)  # ir_version
    model += _p_str(2, "rnn-hls export_fixtures")
    model += _p_bytes(7, graph)
    model += _p_bytes(8, _p_str(1, "") + _p_int(2, 14))  # opset 14
    return model


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../rust/tests/fixtures")
    ap.add_argument("--key", default="top_gru")
    ap.add_argument("--slice", type=int, default=SLICE_N)
    args = ap.parse_args()

    name, cell = args.key.rsplit("_", 1)
    a = modelmod.arch(name, cell)
    os.makedirs(args.out, exist_ok=True)

    print(f"training {a.key} ({a.param_count()} params)")
    params, meta = trainmod.train_one(a)

    with open(os.path.join(args.out, f"{a.key}.json"), "w") as f:
        f.write(modelmod.params_to_json(a, params))
    with open(os.path.join(args.out, f"{a.key}.onnx"), "wb") as f:
        f.write(onnx_export(a, params))

    x, y = datamod.generate(name, trainmod.SEED_TEST, args.slice)
    slice_path = os.path.join(args.out, f"{name}_test_slice.bin")
    datamod.write_dataset(slice_path, x, y, datamod.N_CLASSES[name])

    # Reference float AUC on the *slice* — what the rust golden suite pins.
    import jax.numpy as jnp

    probs = np.asarray(
        modelmod.forward(params, jnp.asarray(x), a)
    )
    slice_auc = trainmod.mean_auc(probs, y, datamod.N_CLASSES[name])
    meta["slice_n"] = args.slice
    meta["slice_float_auc"] = slice_auc
    with open(os.path.join(args.out, f"{a.key}.meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"slice float AUC ({args.slice} events): {slice_auc:.4f}")
    print(f"wrote fixtures to {args.out}")


if __name__ == "__main__":
    main()
