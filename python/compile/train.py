"""Build-time training for the six benchmark models.

The paper trains in Keras/TensorFlow; here we train the same architectures
in JAX (hand-rolled Adam — no optax on this image) on the synthetic
generators of :mod:`compile.data`.  Training happens ONCE during
``make artifacts`` and writes:

* ``artifacts/weights/{bench}_{cell}.json``   — weights for the rust engine
* ``artifacts/data/{bench}_test.bin``         — frozen evaluation set
* ``artifacts/weights/{bench}_{cell}.meta.json`` — float AUC, loss curve

Hyperparameters follow §4 of the paper where stated: Adam, lr 2e-4,
binary cross-entropy with L1(1e-5)/L2(1e-4) weight regularization for top
tagging; categorical cross-entropy for the multi-class models.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as datamod
from compile import model as modelmod
from compile.model import Arch

# Per-benchmark training budget: (train size, steps, batch, lr).
# Sizes chosen so `make artifacts` finishes in a few minutes on CPU while
# reaching the AUC regime the paper's models operate in (≥0.9).
TRAIN_CFG = {
    "top": dict(n_train=20000, steps=900, batch=246, lr=2e-4 * 5),
    "flavor": dict(n_train=15000, steps=700, batch=128, lr=1e-3),
    "quickdraw": dict(n_train=8000, steps=400, batch=96, lr=1.5e-3),
}
N_TEST = 4000
SEED_TRAIN = 20220415  # arXiv submission-ish; arbitrary but frozen
SEED_TEST = 777


def binary_auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """ROC AUC via the rank statistic (Mann-Whitney U)."""
    scores = np.asarray(scores, np.float64).reshape(-1)
    labels = np.asarray(labels).reshape(-1)
    pos = scores[labels == 1]
    neg = scores[labels == 0]
    if len(pos) == 0 or len(neg) == 0:
        return 0.5
    order = np.argsort(np.concatenate([pos, neg]), kind="mergesort")
    ranks = np.empty(len(order), np.float64)
    ranks[order] = np.arange(1, len(order) + 1)
    # midrank correction for ties
    allv = np.concatenate([pos, neg])
    sorted_v = allv[order]
    i = 0
    while i < len(sorted_v):
        j = i
        while j + 1 < len(sorted_v) and sorted_v[j + 1] == sorted_v[i]:
            j += 1
        if j > i:
            ranks[order[i : j + 1]] = 0.5 * (i + 1 + j + 1)
        i = j + 1
    r_pos = ranks[: len(pos)].sum()
    u = r_pos - len(pos) * (len(pos) + 1) / 2.0
    return float(u / (len(pos) * len(neg)))


def multiclass_auc(probs: np.ndarray, labels: np.ndarray) -> list[float]:
    """One-vs-rest AUC per class (the paper's 'top-1 AUC per class')."""
    n_classes = probs.shape[1]
    return [
        binary_auc(probs[:, k], (labels == k).astype(np.int32))
        for k in range(n_classes)
    ]


def mean_auc(probs: np.ndarray, labels: np.ndarray, classes: int) -> float:
    if classes == 1:
        return binary_auc(probs.reshape(-1), labels)
    return float(np.mean(multiclass_auc(probs, labels)))


# --------------------------------------------------------------------------
# Loss / optimizer
# --------------------------------------------------------------------------


def _loss_fn(params: dict, x: jax.Array, y: jax.Array, a: Arch) -> jax.Array:
    z = modelmod.logits(params, x, a)
    if a.output_activation == "sigmoid":
        z = z.reshape(-1)
        yf = y.astype(jnp.float32)
        bce = jnp.mean(
            jnp.maximum(z, 0.0) - z * yf + jnp.log1p(jnp.exp(-jnp.abs(z)))
        )
        # Paper §4.1: L1 1e-5 and L2 1e-4 weight regularization.
        leaves = jax.tree_util.tree_leaves(params)
        l1 = sum(jnp.sum(jnp.abs(leaf)) for leaf in leaves)
        l2 = sum(jnp.sum(leaf**2) for leaf in leaves)
        return bce + 1e-5 * l1 + 1e-4 * l2
    logp = jax.nn.log_softmax(z, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def adam_init(params: dict) -> dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32), "m0": zeros}


def adam_step(params: dict, state: dict, grads: dict, lr: float) -> tuple[dict, dict]:
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads
    )
    v = jax.tree_util.tree_map(
        lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads
    )
    tf = t.astype(jnp.float32)
    scale = lr * jnp.sqrt(1 - b2**tf) / (1 - b1**tf)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - scale * m_ / (jnp.sqrt(v_) + eps), params, m, v
    )
    return new_params, {"m": m, "v": v, "t": t, "m0": state["m0"]}


# --------------------------------------------------------------------------
# Training driver
# --------------------------------------------------------------------------


def train_one(a: Arch, verbose: bool = True) -> tuple[dict, dict[str, Any]]:
    """Train one benchmark variant; returns (params, metadata)."""
    cfg = TRAIN_CFG[a.name]
    x_np, y_np = datamod.generate(a.name, SEED_TRAIN, cfg["n_train"])
    x = jnp.asarray(x_np)
    y = jnp.asarray(y_np.astype(np.int32))

    params = modelmod.init_params(a, jax.random.PRNGKey(hash(a.key) % 2**31))
    if a.name == "quickdraw":
        # Raw-coordinate inputs are O(200); rescale the input kernel so
        # initial pre-activations are O(1) (Keras converges to the same
        # regime, just slower).
        import jax.numpy as _jnp
        params["rnn"]["w"] = params["rnn"]["w"] * 0.008
    opt = adam_init(params)
    lr = cfg["lr"]

    @jax.jit
    def step(params, opt, xb, yb):
        loss, grads = jax.value_and_grad(_loss_fn)(params, xb, yb, a)
        params, opt = adam_step(params, opt, grads, lr)
        return params, opt, loss

    rng = np.random.default_rng(0)
    n = x.shape[0]
    batch = cfg["batch"]
    losses = []
    t0 = time.time()
    for it in range(cfg["steps"]):
        idx = rng.integers(0, n, size=batch)
        params, opt, loss = step(params, opt, x[idx], y[idx])
        if it % 50 == 0:
            losses.append(float(loss))
            if verbose:
                print(f"  [{a.key}] step {it:4d} loss {float(loss):.4f}")

    # Evaluate float AUC on the frozen test set.
    classes = datamod.N_CLASSES[a.name]
    xt_np, yt_np = datamod.generate(a.name, SEED_TEST, N_TEST)
    probs = np.asarray(
        jax.jit(lambda p, xx: modelmod.forward(p, xx, a))(params, jnp.asarray(xt_np))
    )
    auc = mean_auc(probs, yt_np, classes)
    per_class = (
        multiclass_auc(probs, yt_np) if classes > 1 else [auc]
    )
    meta = {
        "arch": a.key,
        "param_count": modelmod.count_params(params),
        "train_steps": cfg["steps"],
        "train_seconds": round(time.time() - t0, 1),
        "loss_curve": losses,
        "float_auc": auc,
        "float_auc_per_class": per_class,
    }
    if verbose:
        print(f"  [{a.key}] float AUC {auc:.4f}  ({meta['train_seconds']}s)")
    return params, meta


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--only", default=None, help="train a single arch key")
    args = ap.parse_args()

    os.makedirs(os.path.join(args.out, "weights"), exist_ok=True)
    os.makedirs(os.path.join(args.out, "data"), exist_ok=True)

    # Frozen evaluation sets, one per benchmark (shared by both cells).
    for name in modelmod.BENCHMARKS:
        path = os.path.join(args.out, "data", f"{name}_test.bin")
        if not os.path.exists(path):
            x, y = datamod.generate(name, SEED_TEST, N_TEST)
            datamod.write_dataset(path, x, y, datamod.N_CLASSES[name])
            print(f"wrote {path}: {x.shape}")

    for a in modelmod.all_archs():
        if args.only and a.key != args.only:
            continue
        wpath = os.path.join(args.out, "weights", f"{a.key}.json")
        if os.path.exists(wpath):
            print(f"skip {a.key}: {wpath} exists")
            continue
        print(f"training {a.key} ({a.param_count()} params)")
        params, meta = train_one(a)
        with open(wpath, "w") as f:
            f.write(modelmod.params_to_json(a, params))
        with open(wpath.replace(".json", ".meta.json"), "w") as f:
            json.dump(meta, f, indent=2)
        print(f"wrote {wpath}")


if __name__ == "__main__":
    main()
