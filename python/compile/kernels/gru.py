"""Fused Pallas GRU sequence kernel (Keras ``reset_after=True`` variant).

Mirrors ``lstm.py``: grid over time steps, hidden state resident in the
output block across steps, gate matmuls packed over the 3H axis.  The
``reset_after`` convention (separate input/recurrent biases, reset gate
applied *after* the recurrent matmul) matches Keras' TF2 default and the
paper's GRU parameter counts (Table 1).

See ``lstm.py`` for the interpret=True requirement and the TPU mapping of
the paper's FPGA design knobs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_kernel(x_ref, w_ref, u_ref, b_ref, h_ref, *, hidden: int):
    """Grid step ``t``: one GRU state update, state resident in the h block."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x_t = x_ref[:, 0, :]  # (B, I)
    h_prev = h_ref[...]

    bias = b_ref[...]  # (2, 3H): row 0 input bias, row 1 recurrent bias
    x_mat = (
        jnp.dot(x_t, w_ref[...], preferred_element_type=jnp.float32)
        + bias[0:1, :]
    )
    h_mat = (
        jnp.dot(h_prev, u_ref[...], preferred_element_type=jnp.float32)
        + bias[1:2, :]
    )

    xz = x_mat[:, 0 * hidden : 1 * hidden]
    xr = x_mat[:, 1 * hidden : 2 * hidden]
    xh = x_mat[:, 2 * hidden : 3 * hidden]
    hz = h_mat[:, 0 * hidden : 1 * hidden]
    hr = h_mat[:, 1 * hidden : 2 * hidden]
    hh = h_mat[:, 2 * hidden : 3 * hidden]

    z = jax.nn.sigmoid(xz + hz)
    r = jax.nn.sigmoid(xr + hr)
    # reset_after: the reset gate multiplies the *post-matmul* recurrent
    # contribution (a Hadamard product, as in the paper's §3).
    g = jnp.tanh(xh + r * hh)
    h_ref[...] = z * h_prev + (1.0 - z) * g


def gru(
    x_seq: jax.Array,
    w: jax.Array,
    u: jax.Array,
    b: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """GRU over a sequence via a fused Pallas kernel.

    Drop-in replacement for :func:`compile.kernels.ref.gru`.

    Args:
      x_seq: inputs ``(B, T, I)``.
      w: kernel ``(I, 3H)``, Keras ``[z, r, h]`` packing.
      u: recurrent kernel ``(H, 3H)``.
      b: bias ``(2, 3H)``.

    Returns:
      final hidden state ``(B, H)``.
    """
    batch, seq_len, in_dim = x_seq.shape
    hidden = u.shape[0]
    if w.shape != (in_dim, 3 * hidden):
        raise ValueError(f"kernel shape {w.shape} != {(in_dim, 3 * hidden)}")
    if b.shape != (2, 3 * hidden):
        raise ValueError(f"bias shape {b.shape} != {(2, 3 * hidden)}")

    h = pl.pallas_call(
        functools.partial(_gru_kernel, hidden=hidden),
        grid=(seq_len,),
        in_specs=[
            pl.BlockSpec((batch, 1, in_dim), lambda t: (0, t, 0)),
            pl.BlockSpec((in_dim, 3 * hidden), lambda t: (0, 0)),
            pl.BlockSpec((hidden, 3 * hidden), lambda t: (0, 0)),
            pl.BlockSpec((2, 3 * hidden), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((batch, hidden), lambda t: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, hidden), x_seq.dtype),
        interpret=interpret,
    )(x_seq, w, u, b)
    return h


def vmem_footprint_bytes(
    batch: int, seq_len: int, in_dim: int, hidden: int, dtype_bytes: int = 4
) -> int:
    """VMEM bytes resident during one grid step (see lstm.py counterpart)."""
    x_slice = batch * in_dim
    weights = in_dim * 3 * hidden + hidden * 3 * hidden + 2 * 3 * hidden
    state = batch * hidden
    gates = 2 * batch * 3 * hidden
    return (x_slice + weights + state + gates) * dtype_bytes
