"""L1: Pallas kernels for the paper's compute hot-spots.

* :mod:`compile.kernels.lstm` — fused LSTM sequence kernel (Eq. 1).
* :mod:`compile.kernels.gru` — fused GRU sequence kernel (reset_after).
* :mod:`compile.kernels.dense` — tiled affine kernel for the MLP heads.
* :mod:`compile.kernels.ref` — pure-jnp oracle for all of the above.
"""

from compile.kernels import ref  # noqa: F401
from compile.kernels.dense import dense  # noqa: F401
from compile.kernels.gru import gru  # noqa: F401
from compile.kernels.lstm import lstm  # noqa: F401
