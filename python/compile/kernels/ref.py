"""Pure-jnp reference oracle for every Pallas kernel in this package.

These functions are the *semantic ground truth*: the Pallas kernels in
``lstm.py`` / ``gru.py`` / ``dense.py`` must match them to float32
tolerance (checked in ``python/tests/test_kernels.py``), and the rust
fixed-point engine (``rust/src/nn``) must match their float path before
quantization.

Conventions follow Keras so that Table 1 of the paper reproduces exactly:

* ``dense``:      ``y = x @ w + b`` with ``w.shape == (in, out)``.
* ``lstm``:       Keras gate packing ``[i, f, c, o]`` along the last axis of
                  the kernel ``w (in, 4H)``, recurrent kernel ``u (H, 4H)``
                  and bias ``b (4H,)``.
* ``gru``:        Keras ``reset_after=True`` variant (the TF2 default — this
                  is what gives the paper's 1680/46080/51072 parameter
                  counts): gate packing ``[z, r, h]``, kernel ``w (in, 3H)``,
                  recurrent kernel ``u (H, 3H)``, bias ``b (2, 3H)`` with
                  row 0 the input bias and row 1 the recurrent bias.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Affine layer, Keras convention: ``x (B, I) @ w (I, O) + b (O,)``."""
    return jnp.dot(x, w) + b


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def sigmoid(x: jax.Array) -> jax.Array:
    return jax.nn.sigmoid(x)


def tanh(x: jax.Array) -> jax.Array:
    return jnp.tanh(x)


def softmax(x: jax.Array) -> jax.Array:
    return jax.nn.softmax(x, axis=-1)


def hadamard(a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise product — the one op the paper had to add to hls4ml."""
    return a * b


def lstm_cell(
    x: jax.Array,
    h: jax.Array,
    c: jax.Array,
    w: jax.Array,
    u: jax.Array,
    b: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """One LSTM state update (Eq. 1 of the paper, Keras packing).

    Args:
      x: input at this step, ``(B, I)``.
      h: previous hidden state, ``(B, H)``.
      c: previous cell state, ``(B, H)``.
      w: kernel ``(I, 4H)`` packed ``[i, f, c, o]``.
      u: recurrent kernel ``(H, 4H)``, same packing.
      b: bias ``(4H,)``.

    Returns:
      ``(h_new, c_new)``, each ``(B, H)``.
    """
    z = jnp.dot(x, w) + jnp.dot(h, u) + b
    zi, zf, zc, zo = jnp.split(z, 4, axis=-1)
    i = jax.nn.sigmoid(zi)
    f = jax.nn.sigmoid(zf)
    g = jnp.tanh(zc)
    o = jax.nn.sigmoid(zo)
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def lstm(
    x_seq: jax.Array, w: jax.Array, u: jax.Array, b: jax.Array
) -> jax.Array:
    """Run an LSTM over a full sequence, returning the final hidden state.

    Args:
      x_seq: ``(B, T, I)``.
    Returns:
      final hidden state ``(B, H)`` (Keras ``return_sequences=False``).
    """
    batch = x_seq.shape[0]
    hidden = u.shape[0]
    h0 = jnp.zeros((batch, hidden), dtype=x_seq.dtype)
    c0 = jnp.zeros((batch, hidden), dtype=x_seq.dtype)

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell(x_t, h, c, w, u, b)
        return (h, c), None

    (h, _c), _ = jax.lax.scan(step, (h0, c0), jnp.swapaxes(x_seq, 0, 1))
    return h


def gru_cell(
    x: jax.Array,
    h: jax.Array,
    w: jax.Array,
    u: jax.Array,
    b: jax.Array,
) -> jax.Array:
    """One GRU state update, Keras ``reset_after=True`` convention.

    Args:
      x: input at this step, ``(B, I)``.
      h: previous hidden state, ``(B, H)``.
      w: kernel ``(I, 3H)`` packed ``[z, r, h]``.
      u: recurrent kernel ``(H, 3H)``, same packing.
      b: bias ``(2, 3H)``; ``b[0]`` input bias, ``b[1]`` recurrent bias.

    Returns:
      ``h_new (B, H)``.
    """
    x_mat = jnp.dot(x, w) + b[0]
    h_mat = jnp.dot(h, u) + b[1]
    xz, xr, xh = jnp.split(x_mat, 3, axis=-1)
    hz, hr, hh = jnp.split(h_mat, 3, axis=-1)
    z = jax.nn.sigmoid(xz + hz)
    r = jax.nn.sigmoid(xr + hr)
    g = jnp.tanh(xh + r * hh)
    return z * h + (1.0 - z) * g


def gru(
    x_seq: jax.Array, w: jax.Array, u: jax.Array, b: jax.Array
) -> jax.Array:
    """Run a GRU over a full sequence, returning the final hidden state."""
    batch = x_seq.shape[0]
    hidden = u.shape[0]
    h0 = jnp.zeros((batch, hidden), dtype=x_seq.dtype)

    def step(h, x_t):
        h = gru_cell(x_t, h, w, u, b)
        return h, None

    h, _ = jax.lax.scan(step, h0, jnp.swapaxes(x_seq, 0, 1))
    return h
