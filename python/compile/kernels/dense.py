"""Pallas dense (affine) kernel with output tiling.

The non-recurrent layers of the benchmark models (Table 1's "Dense layer
sizes" column) run through this kernel so the whole forward pass lowers
from Pallas.  The grid tiles the output dimension — the direct analogue of
hls4ml splitting a matrix multiply across DSPs with a reuse factor: a
smaller ``block_out`` keeps fewer MXU lanes live per step across more grid
steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    y = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "sigmoid":
        y = jax.nn.sigmoid(y)
    elif activation == "tanh":
        y = jnp.tanh(y)
    elif activation != "linear":
        raise ValueError(f"unsupported fused activation: {activation}")
    o_ref[...] = y


def dense(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    activation: str = "linear",
    block_out: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Affine layer ``act(x @ w + b)`` as a Pallas kernel.

    Args:
      x: ``(B, I)``.
      w: ``(I, O)`` (Keras convention).
      b: ``(O,)``.
      activation: fused activation: linear | relu | sigmoid | tanh.
        (softmax is NOT fused: it needs the full row, and hls4ml likewise
        implements it as a separate LUT-based layer.)
      block_out: output-tile width; must divide O. None → whole O.

    Returns:
      ``(B, O)``.
    """
    batch, in_dim = x.shape
    if w.shape[0] != in_dim:
        raise ValueError(f"w rows {w.shape[0]} != input dim {in_dim}")
    out_dim = w.shape[1]
    if b.shape != (out_dim,):
        raise ValueError(f"bias shape {b.shape} != {(out_dim,)}")
    if block_out is None:
        block_out = out_dim
    if out_dim % block_out != 0:
        raise ValueError(f"block_out {block_out} must divide O {out_dim}")
    b2 = b.reshape(1, out_dim)

    return pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        grid=(out_dim // block_out,),
        in_specs=[
            pl.BlockSpec((batch, in_dim), lambda j: (0, 0)),
            pl.BlockSpec((in_dim, block_out), lambda j: (0, j)),
            pl.BlockSpec((1, block_out), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((batch, block_out), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((batch, out_dim), x.dtype),
        interpret=interpret,
    )(x, w, b2)
