"""Fused Pallas LSTM sequence kernel.

The paper's FPGA "static mode" keeps the recurrent state resident inside
the single RNN block while the sequence streams through it.  The TPU
re-think of that insight (DESIGN.md §Hardware-Adaptation) is a *fused
sequence kernel*: one ``pallas_call`` whose grid iterates over time steps,
keeping ``h``/``c`` resident in fast memory (the output block is mapped to
the same tile on every grid step, so it never round-trips to HBM between
steps), and the four gate matmuls of Eq. 1 issued as two packed MXU
contractions per step (``x_t @ W`` and ``h_{t-1} @ U`` over the 4H-packed
gate axis).

``interpret=True`` is mandatory on this CPU image: real-TPU lowering emits
a Mosaic custom-call that the CPU PJRT plugin cannot execute.  The kernel
is still *structured* for TPU: 2-D blocks, gate-packed matmuls, and a
``block_h`` knob (the TPU analogue of hls4ml's reuse factor — smaller
blocks keep fewer multipliers live per step at the cost of more grid
steps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(x_ref, w_ref, u_ref, b_ref, h_ref, c_ref, *, hidden: int):
    """Grid step ``t``: one LSTM state update, state resident in h/c blocks."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    x_t = x_ref[:, 0, :]  # (B, I) — this step's slice of the sequence
    h_prev = h_ref[...]
    c_prev = c_ref[...]

    # Packed gate pre-activations: both contractions hit the full 4H gate
    # axis in one go (the MXU analogue of hls4ml packaging kernel +
    # recurrent kernel into single dense calls).
    z = (
        jnp.dot(x_t, w_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h_prev, u_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    zi = z[:, 0 * hidden : 1 * hidden]
    zf = z[:, 1 * hidden : 2 * hidden]
    zc = z[:, 2 * hidden : 3 * hidden]
    zo = z[:, 3 * hidden : 4 * hidden]

    i = jax.nn.sigmoid(zi)
    f = jax.nn.sigmoid(zf)
    g = jnp.tanh(zc)
    o = jax.nn.sigmoid(zo)

    # Hadamard products — the op the paper added to hls4ml — run on the VPU.
    c_new = f * c_prev + i * g
    h_ref[...] = o * jnp.tanh(c_new)
    c_ref[...] = c_new


def lstm(
    x_seq: jax.Array,
    w: jax.Array,
    u: jax.Array,
    b: jax.Array,
    *,
    interpret: bool = True,
) -> jax.Array:
    """LSTM over a sequence via a fused Pallas kernel.

    Drop-in replacement for :func:`compile.kernels.ref.lstm`.

    Args:
      x_seq: inputs ``(B, T, I)``.
      w: kernel ``(I, 4H)``, Keras ``[i, f, c, o]`` packing.
      u: recurrent kernel ``(H, 4H)``.
      b: bias ``(4H,)``.
      interpret: must stay True on CPU-only PJRT (see module docstring).

    Returns:
      final hidden state ``(B, H)``.
    """
    batch, seq_len, in_dim = x_seq.shape
    hidden = u.shape[0]
    if w.shape != (in_dim, 4 * hidden):
        raise ValueError(f"kernel shape {w.shape} != {(in_dim, 4 * hidden)}")
    if b.shape != (4 * hidden,):
        raise ValueError(f"bias shape {b.shape} != {(4 * hidden,)}")
    b2 = b.reshape(1, 4 * hidden)

    h, _c = pl.pallas_call(
        functools.partial(_lstm_kernel, hidden=hidden),
        grid=(seq_len,),
        in_specs=[
            # One time-slice of the sequence per grid step.
            pl.BlockSpec((batch, 1, in_dim), lambda t: (0, t, 0)),
            # Weights: same full block each step (stay resident).
            pl.BlockSpec((in_dim, 4 * hidden), lambda t: (0, 0)),
            pl.BlockSpec((hidden, 4 * hidden), lambda t: (0, 0)),
            pl.BlockSpec((1, 4 * hidden), lambda t: (0, 0)),
        ],
        out_specs=[
            # State blocks pinned to tile (0, 0) on every step: the VMEM
            # residency that mirrors the FPGA static-mode state registers.
            pl.BlockSpec((batch, hidden), lambda t: (0, 0)),
            pl.BlockSpec((batch, hidden), lambda t: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch, hidden), x_seq.dtype),
            jax.ShapeDtypeStruct((batch, hidden), x_seq.dtype),
        ],
        interpret=interpret,
    )(x_seq, w, u, b2)
    return h


def vmem_footprint_bytes(
    batch: int, seq_len: int, in_dim: int, hidden: int, dtype_bytes: int = 4
) -> int:
    """Bytes resident in VMEM during one grid step of the fused kernel.

    Used by DESIGN.md / EXPERIMENTS.md §Perf to estimate TPU viability:
    one x-slice + both weight matrices + bias + h + c + the packed gate
    buffer.  Must stay under ~16 MiB (one TensorCore's VMEM).
    """
    x_slice = batch * in_dim
    weights = in_dim * 4 * hidden + hidden * 4 * hidden + 4 * hidden
    state = 2 * batch * hidden
    gates = batch * 4 * hidden
    return (x_slice + weights + state + gates) * dtype_bytes
