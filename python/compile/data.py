"""Synthetic dataset generators for the three benchmarks.

The paper trains on MadGraph+Pythia top jets, CMS OpenData tracks, and the
Google QuickDraw strokes — none of which are available here (repro gate).
Per DESIGN.md §Hardware-substitution we build generators that preserve the
*discriminating structure* each RNN has to learn, so that (a) the models
train to a realistic AUC regime and (b) the post-training-quantization
scan of Fig. 2 sees weight/activation dynamic ranges comparable to the
paper's models.

All generators are seeded ``numpy.random.Generator`` based and mirrored
algorithm-for-algorithm in ``rust/src/data/`` (the rust side feeds the
live serving demo; the *evaluation* test sets are generated here once and
stored under ``artifacts/data/`` so Fig. 2 is bit-reproducible).

Binary test-set format (read by ``rust/src/data/dataset.rs``)::

    magic   8 bytes  b"RNNDAT01"
    n       u32 LE   number of samples
    seq     u32 LE   sequence length
    feat    u32 LE   features per step
    classes u32 LE   number of classes (1 => binary, sigmoid output)
    data    n*seq*feat f32 LE, row-major [sample][step][feature]
    labels  n u32 LE
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"RNNDAT01"


# --------------------------------------------------------------------------
# Top quark tagging: 1-prong (light q) vs 3-prong (top) jet substructure toy.
# Features per particle: [log pT, eta_rel, phi_rel, log E, dR, pid]
# --------------------------------------------------------------------------


def top_tagging(
    seed: int, n: int, seq_len: int = 20, n_feat: int = 6
) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` jets, half top (label 1), half light-quark (label 0)."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, seq_len, n_feat), np.float32)
    y = (rng.random(n) < 0.5).astype(np.uint32)

    for i in range(n):
        is_top = bool(y[i])
        # Top jets have 3 hard subjets (b q q' from t→bW→bqq'), light jets 1
        # (occasionally 2 from a hard gluon emission).
        if is_top:
            n_prong = 3
        else:
            n_prong = 1 if rng.random() < 0.8 else 2
        # Subjet axes inside the R=0.8 cone; tops' prongs are wider apart.
        spread = 0.35 if is_top else 0.12
        axes = rng.normal(0.0, spread, size=(n_prong, 2))
        # pT sharing between prongs (Dirichlet) around a ~1 TeV jet.
        frac = rng.dirichlet(np.full(n_prong, 3.0))
        jet_pt = rng.normal(1000.0, 10.0)  # delta pT/pT = 0.01 at 1 TeV

        n_part = int(rng.integers(12, seq_len + 1))
        pts = np.zeros(n_part)
        etas = np.zeros(n_part)
        phis = np.zeros(n_part)
        pids = np.zeros(n_part)
        for p in range(n_part):
            prong = int(rng.choice(n_prong, p=frac))
            # Fragmentation: particle pT exponential within its prong.
            pts[p] = frac[prong] * jet_pt * rng.exponential(0.22)
            width = 0.05 if is_top else 0.08
            etas[p] = axes[prong, 0] + rng.normal(0.0, width)
            phis[p] = axes[prong, 1] + rng.normal(0.0, width)
            pids[p] = rng.integers(-2, 3)

        order = np.argsort(-pts)  # pT-ordered, as in the paper
        pts, etas, phis, pids = pts[order], etas[order], phis[order], pids[order]
        energy = pts * np.cosh(etas)
        dr = np.sqrt(etas**2 + phis**2)
        feats = np.stack(
            [
                np.log1p(pts) / 7.0,
                etas,
                phis,
                np.log1p(energy) / 7.0,
                dr,
                pids / 2.0,
            ],
            axis=-1,
        ).astype(np.float32)
        x[i, :n_part] = feats  # zero-padded tail, as in the paper
    return x, y


# --------------------------------------------------------------------------
# Jet flavor tagging: displaced-track toy (b / c / light).
# Features per track: [pt_rel, dR, d0, dz, S(d0), S(dz)]
# --------------------------------------------------------------------------


def flavor_tagging(
    seed: int, n: int, seq_len: int = 15, n_feat: int = 6
) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` jets with labels 0=light, 1=c, 2=b."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, seq_len, n_feat), np.float32)
    y = rng.integers(0, 3, size=n).astype(np.uint32)

    # (mean displaced multiplicity, d0 scale [cm], significance scale)
    profile = {
        0: (0.25, 0.010, 1.0),  # light: fakes only
        1: (1.8, 0.025, 2.5),  # c hadrons: ~cτ 60-300 µm
        2: (3.5, 0.045, 5.0),  # b hadrons: ~cτ 450 µm + tertiary c
    }
    for i in range(n):
        mult, d0_scale, sig_scale = profile[int(y[i])]
        n_trk = int(rng.integers(6, seq_len + 1))
        n_disp = min(int(rng.poisson(mult)), n_trk)

        d0 = rng.normal(0.0, 0.008, size=n_trk)  # prompt: resolution only
        dz = rng.normal(0.0, 0.015, size=n_trk)
        if n_disp > 0:
            sign = rng.choice([-1.0, 1.0], size=n_disp, p=[0.1, 0.9])
            d0[:n_disp] = sign * rng.exponential(d0_scale, size=n_disp)
            dz[:n_disp] += rng.normal(0.0, d0_scale, size=n_disp)
        sigma_d0 = rng.uniform(0.006, 0.014, size=n_trk)
        sigma_dz = rng.uniform(0.010, 0.025, size=n_trk)
        s_d0 = d0 / sigma_d0 + rng.normal(0, 0.3, size=n_trk)
        s_dz = dz / sigma_dz + rng.normal(0, 0.3, size=n_trk)
        # Heavy-flavor decay tracks are harder and closer to the jet axis.
        pt_rel = rng.beta(1.5, 6.0, size=n_trk)
        dr = rng.exponential(0.12, size=n_trk).clip(max=0.5)

        order = np.argsort(-np.abs(s_d0))  # paper: ordered by S(d0)
        feats = np.stack(
            [
                pt_rel[order],
                dr[order],
                (d0[order] * 10.0).clip(-4, 4),
                (dz[order] * 10.0).clip(-4, 4),
                (s_d0[order] / 4.0).clip(-6, 6),
                (s_dz[order] / 4.0).clip(-6, 6),
            ],
            axis=-1,
        ).astype(np.float32)
        x[i, :n_trk] = feats
    return x, y


# --------------------------------------------------------------------------
# QuickDraw: parametric stroke-curve families standing in for
# {ant, butterfly, bee, mosquito, snail}.  Features per step: [x, y, t]
# --------------------------------------------------------------------------


def _curve(cls: int, s: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    """Return (len(s), 2) points of the class's stroke family at phases s."""
    two_pi = 2.0 * np.pi
    if cls == 0:  # "ant": three body segments drawn as successive circles
        seg = np.floor(s * 3).clip(max=2)
        phase = (s * 3 - seg) * two_pi
        cx = (seg - 1.0) * 0.9
        r = 0.35 + 0.1 * (seg == 1)
        return np.stack([cx + r * np.cos(phase), r * np.sin(phase)], -1)
    if cls == 1:  # "butterfly": four-petal rose curve
        theta = s * two_pi
        r = np.abs(np.cos(2.0 * theta)) + 0.15
        return np.stack([r * np.cos(theta), r * np.sin(theta)], -1)
    if cls == 2:  # "bee": ellipse body with zigzag stripes
        theta = s * two_pi
        x = 1.2 * np.cos(theta)
        y = 0.6 * np.sin(theta) + 0.25 * np.sign(np.sin(theta * 8.0)) * (s > 0.5)
        return np.stack([x, y], -1)
    if cls == 3:  # "mosquito": small body, long radial legs (star rays)
        n_ray = 6
        ray = np.floor(s * n_ray).clip(max=n_ray - 1)
        along = (s * n_ray - ray)
        # out-and-back along each ray
        dist = 0.2 + 1.3 * (1.0 - np.abs(2.0 * along - 1.0))
        ang = ray / n_ray * two_pi + 0.3
        return np.stack([dist * np.cos(ang), dist * np.sin(ang)], -1)
    # cls == 4, "snail": Archimedean spiral
    theta = s * 3.0 * two_pi
    r = 0.08 + 0.10 * theta
    return np.stack([r * np.cos(theta), r * np.sin(theta)], -1)


def quickdraw(
    seed: int, n: int, seq_len: int = 100, n_feat: int = 3
) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` stroke sequences over 5 synthetic drawing classes."""
    rng = np.random.default_rng(seed)
    x = np.zeros((n, seq_len, n_feat), np.float32)
    y = rng.integers(0, 5, size=n).astype(np.uint32)

    for i in range(n):
        s = np.linspace(0.0, 1.0, seq_len)
        pts = _curve(int(y[i]), s, rng)
        # Per-drawing augmentation: rotation, anisotropic scale, offset.
        ang = rng.uniform(0, 2 * np.pi)
        rot = np.array(
            [[np.cos(ang), -np.sin(ang)], [np.sin(ang), np.cos(ang)]]
        )
        scale = rng.uniform(0.7, 1.3, size=2)
        pts = (pts * scale) @ rot.T + rng.normal(0, 0.15, size=2)
        pts += rng.normal(0.0, 0.04, size=pts.shape)  # pen jitter
        # RAW coordinate scale: the real QuickDraw data records pen
        # positions on a ~0-255 canvas, and the paper's Fig. 2c shows the
        # model needs >= 10 integer bits as a result.  We keep that
        # property: coordinates span roughly +-200 (needs int >= 10;
        # int 6 / 8 clip at +-32 / +-128 and lose the drawing).
        pts *= 200.0 / 1.6
        # Timestamp: cumulative arc length with speed noise, scaled to
        # the game's 15-second window.
        seg = np.linalg.norm(np.diff(pts, axis=0), axis=1)
        seg *= rng.uniform(0.7, 1.3, size=seg.shape)
        t = np.concatenate([[0.0], np.cumsum(seg)])
        t = 15.0 * t / max(t[-1], 1e-6)
        x[i] = np.stack([pts[:, 0], pts[:, 1], t], -1).astype(np.float32)
    return x, y


GENERATORS = {
    "top": top_tagging,
    "flavor": flavor_tagging,
    "quickdraw": quickdraw,
}

N_CLASSES = {"top": 1, "flavor": 3, "quickdraw": 5}


def generate(name: str, seed: int, n: int) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` samples of benchmark ``name`` with the given seed."""
    return GENERATORS[name](seed, n)


# --------------------------------------------------------------------------
# Binary test-set container (see module docstring for the layout).
# --------------------------------------------------------------------------


def write_dataset(path: str, x: np.ndarray, y: np.ndarray, classes: int) -> None:
    n, seq, feat = x.shape
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<IIII", n, seq, feat, classes))
        f.write(x.astype("<f4").tobytes())
        f.write(y.astype("<u4").tobytes())


def read_dataset(path: str) -> tuple[np.ndarray, np.ndarray, int]:
    with open(path, "rb") as f:
        magic = f.read(8)
        if magic != MAGIC:
            raise ValueError(f"bad magic {magic!r} in {path}")
        n, seq, feat, classes = struct.unpack("<IIII", f.read(16))
        x = np.frombuffer(f.read(n * seq * feat * 4), "<f4").reshape(n, seq, feat)
        y = np.frombuffer(f.read(n * 4), "<u4")
    return x.copy(), y.copy(), classes
