"""L2: the paper's benchmark models as JAX compute graphs.

Builds the six benchmark variants of Table 1 (three tasks × {LSTM, GRU}),
with parameter shapes/initialization matching Keras so the trainable
parameter counts reproduce the paper exactly:

=============== ===== ==== ====== ========= === ======== ======= =======
benchmark       seq   in   hidden dense     out non-RNN  LSTM    GRU
=============== ===== ==== ====== ========= === ======== ======= =======
top             20    6    20     64        1   1,409    2,160   1,680
flavor          15    6    120    50/10     3   6,593    60,960  46,080
quickdraw       100   3    128    256/128   5   66,565   67,584  51,072
=============== ===== ==== ====== ========= === ======== ======= =======

The forward pass can run through either backend:

* ``backend="ref"``    — pure jnp (:mod:`compile.kernels.ref`), used for
  training (fast under jit) and as the numerical oracle;
* ``backend="pallas"`` — the fused Pallas kernels, used for the AOT
  artifacts so the whole inference graph lowers from L1 kernels.

Both produce identical numerics (pytest asserts allclose).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax
import jax.numpy as jnp

from compile.kernels import dense as dense_pallas
from compile.kernels import gru as gru_pallas
from compile.kernels import lstm as lstm_pallas
from compile.kernels import ref


@dataclasses.dataclass(frozen=True)
class Arch:
    """Hyperparameters of one benchmark model (one row of Table 1)."""

    name: str  # "top" | "flavor" | "quickdraw"
    cell: str  # "lstm" | "gru"
    seq_len: int
    input_size: int
    hidden_size: int
    dense_sizes: tuple[int, ...]
    output_size: int
    # "sigmoid" for binary (top tagging), "softmax" for multi-class.
    output_activation: str

    @property
    def key(self) -> str:
        return f"{self.name}_{self.cell}"

    def rnn_param_count(self) -> int:
        """Trainable parameters in the recurrent layer (Table 1 columns)."""
        i, h = self.input_size, self.hidden_size
        if self.cell == "lstm":
            return 4 * (i * h + h * h + h)
        # GRU with reset_after=True: two bias vectors of size 3H.
        return 3 * (i * h + h * h) + 2 * 3 * h

    def non_rnn_param_count(self) -> int:
        """Trainable parameters in the dense head (Table 1 "Non-RNN")."""
        total = 0
        prev = self.hidden_size
        for size in self.dense_sizes + (self.output_size,):
            total += prev * size + size
            prev = size
        return total

    def param_count(self) -> int:
        return self.rnn_param_count() + self.non_rnn_param_count()


_BASE = {
    "top": dict(
        seq_len=20,
        input_size=6,
        hidden_size=20,
        dense_sizes=(64,),
        output_size=1,
        output_activation="sigmoid",
    ),
    "flavor": dict(
        seq_len=15,
        input_size=6,
        hidden_size=120,
        dense_sizes=(50, 10),
        output_size=3,
        output_activation="softmax",
    ),
    "quickdraw": dict(
        seq_len=100,
        input_size=3,
        hidden_size=128,
        dense_sizes=(256, 128),
        output_size=5,
        output_activation="softmax",
    ),
}

BENCHMARKS = tuple(_BASE)
CELLS = ("lstm", "gru")


def arch(name: str, cell: str) -> Arch:
    """Look up one of the six benchmark architectures."""
    if name not in _BASE:
        raise KeyError(f"unknown benchmark {name!r}; want one of {BENCHMARKS}")
    if cell not in CELLS:
        raise KeyError(f"unknown cell {cell!r}; want one of {CELLS}")
    return Arch(name=name, cell=cell, **_BASE[name])


def all_archs() -> list[Arch]:
    return [arch(n, c) for n in BENCHMARKS for c in CELLS]


# --------------------------------------------------------------------------
# Initialization (Keras defaults: glorot_uniform kernels, orthogonal
# recurrent kernels, zero biases with unit forget-gate bias for LSTM).
# --------------------------------------------------------------------------


def _glorot(key: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    fan_in, fan_out = shape[0], shape[-1]
    limit = jnp.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -limit, limit)


def _orthogonal(key: jax.Array, rows: int, cols: int) -> jax.Array:
    """Orthogonal init for the recurrent kernel, column-stacked per gate."""
    n_stack = cols // rows
    mats = []
    for sub in jax.random.split(key, n_stack):
        a = jax.random.normal(sub, (rows, rows), jnp.float32)
        q, r = jnp.linalg.qr(a)
        q = q * jnp.sign(jnp.diag(r))
        mats.append(q)
    return jnp.concatenate(mats, axis=1)


def init_params(a: Arch, key: jax.Array) -> dict[str, Any]:
    """Initialize a parameter pytree for architecture ``a``.

    Layout (all Keras-shaped):
      ``rnn/w (I, GH)``, ``rnn/u (H, GH)``, ``rnn/b`` (``(4H,)`` LSTM or
      ``(2, 3H)`` GRU), then ``dense{k}/w``, ``dense{k}/b`` for each head
      layer, and ``out/w``, ``out/b``.
    """
    gates = 4 if a.cell == "lstm" else 3
    keys = jax.random.split(key, 3 + 2 * (len(a.dense_sizes) + 1))
    gh = gates * a.hidden_size

    w = _glorot(keys[0], (a.input_size, gh))
    u = _orthogonal(keys[1], a.hidden_size, gh)
    if a.cell == "lstm":
        # unit_forget_bias: ones on the forget-gate quarter.
        b = jnp.concatenate(
            [
                jnp.zeros(a.hidden_size),
                jnp.ones(a.hidden_size),
                jnp.zeros(2 * a.hidden_size),
            ]
        ).astype(jnp.float32)
    else:
        b = jnp.zeros((2, gh), jnp.float32)

    params: dict[str, Any] = {"rnn": {"w": w, "u": u, "b": b}}
    prev = a.hidden_size
    ki = 3
    for idx, size in enumerate(a.dense_sizes):
        params[f"dense{idx}"] = {
            "w": _glorot(keys[ki], (prev, size)),
            "b": jnp.zeros(size, jnp.float32),
        }
        prev = size
        ki += 2
    params["out"] = {
        "w": _glorot(keys[ki], (prev, a.output_size)),
        "b": jnp.zeros(a.output_size, jnp.float32),
    }
    return params


def count_params(params: dict[str, Any]) -> int:
    leaves = jax.tree_util.tree_leaves(params)
    return sum(int(leaf.size) for leaf in leaves)


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------


def forward(
    params: dict[str, Any],
    x_seq: jax.Array,
    a: Arch,
    *,
    backend: str = "ref",
) -> jax.Array:
    """Full model forward: RNN → dense head → output activation.

    Args:
      params: pytree from :func:`init_params` (or loaded weights).
      x_seq: ``(B, T, I)`` float32.
      a: architecture descriptor.
      backend: "ref" (pure jnp) or "pallas" (fused L1 kernels).

    Returns:
      ``(B, output_size)`` probabilities (sigmoid/softmax applied).
    """
    rnn = params["rnn"]
    if backend == "pallas":
        rnn_fn = lstm_pallas if a.cell == "lstm" else gru_pallas
        h = rnn_fn(x_seq, rnn["w"], rnn["u"], rnn["b"])
        for idx in range(len(a.dense_sizes)):
            layer = params[f"dense{idx}"]
            h = dense_pallas(h, layer["w"], layer["b"], activation="relu")
        out = params["out"]
        if a.output_activation == "sigmoid":
            h = dense_pallas(h, out["w"], out["b"], activation="sigmoid")
        else:
            h = dense_pallas(h, out["w"], out["b"], activation="linear")
            h = jax.nn.softmax(h, axis=-1)
        return h
    if backend != "ref":
        raise ValueError(f"unknown backend {backend!r}")

    rnn_fn = ref.lstm if a.cell == "lstm" else ref.gru
    h = rnn_fn(x_seq, rnn["w"], rnn["u"], rnn["b"])
    for idx in range(len(a.dense_sizes)):
        layer = params[f"dense{idx}"]
        h = ref.relu(ref.dense(h, layer["w"], layer["b"]))
    out = params["out"]
    h = ref.dense(h, out["w"], out["b"])
    if a.output_activation == "sigmoid":
        return jax.nn.sigmoid(h)
    return jax.nn.softmax(h, axis=-1)


def logits(
    params: dict[str, Any], x_seq: jax.Array, a: Arch
) -> jax.Array:
    """Pre-activation outputs (for numerically-stable training losses)."""
    rnn = params["rnn"]
    rnn_fn = ref.lstm if a.cell == "lstm" else ref.gru
    h = rnn_fn(x_seq, rnn["w"], rnn["u"], rnn["b"])
    for idx in range(len(a.dense_sizes)):
        layer = params[f"dense{idx}"]
        h = ref.relu(ref.dense(h, layer["w"], layer["b"]))
    out = params["out"]
    return ref.dense(h, out["w"], out["b"])


# --------------------------------------------------------------------------
# Weight (de)serialization — the interchange format the rust engine loads.
# --------------------------------------------------------------------------


def params_to_json(a: Arch, params: dict[str, Any]) -> str:
    """Serialize weights for ``rust/src/model``: flat row-major f32 lists."""
    layers = []
    for name in ["rnn"] + [f"dense{i}" for i in range(len(a.dense_sizes))] + ["out"]:
        entry: dict[str, Any] = {"name": name}
        for pname, val in sorted(params[name].items()):
            arr = jax.device_get(val)
            entry[pname] = {
                "shape": list(arr.shape),
                "data": [float(v) for v in arr.reshape(-1)],
            }
        layers.append(entry)
    doc = {
        "arch": {
            "name": a.name,
            "cell": a.cell,
            "seq_len": a.seq_len,
            "input_size": a.input_size,
            "hidden_size": a.hidden_size,
            "dense_sizes": list(a.dense_sizes),
            "output_size": a.output_size,
            "output_activation": a.output_activation,
        },
        "param_count": count_params(params),
        "layers": layers,
    }
    return json.dumps(doc)


def params_from_json(text: str) -> tuple[Arch, dict[str, Any]]:
    """Inverse of :func:`params_to_json` (round-trip tested)."""
    doc = json.loads(text)
    meta = doc["arch"]
    a = arch(meta["name"], meta["cell"])
    params: dict[str, Any] = {}
    for entry in doc["layers"]:
        tensors = {}
        for pname, val in entry.items():
            if pname == "name":
                continue
            tensors[pname] = jnp.asarray(
                val["data"], jnp.float32
            ).reshape(val["shape"])
        params[entry["name"]] = tensors
    return a, params
