//! Weight-import integration tests.
//!
//! A local protobuf encoder (mirror of `python/compile/export_fixtures.
//! py`) builds ONNX checkpoints from [`Weights`] in memory, so the tests
//! cover bitwise roundtrips through ONNX's native layouts (gate-blocked
//! `(1, G·H, I)` kernels, `iofc` LSTM order, `transB` Gemm weights, the
//! split `Wb | Rb` bias), every typed rejection path with the offending
//! tensor named, and the malformed-bytes-never-panic contract.  The
//! committed fixtures pin the cross-language contract: the JSON and ONNX
//! exports of the same trained checkpoint must import bitwise-identical.

use std::path::PathBuf;

use rnn_hls::model::{
    zoo, Cell, ImportError, OnnxSource, Weights,
};

fn fixtures() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

// ---------------------------------------------------------------------
// Minimal protobuf writers (mirror of the python exporter).
// ---------------------------------------------------------------------

fn varint(mut n: u64) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let byte = (n & 0x7f) as u8;
        n >>= 7;
        if n != 0 {
            out.push(byte | 0x80);
        } else {
            out.push(byte);
            return out;
        }
    }
}

fn tag(field: u32, wire: u8) -> Vec<u8> {
    varint(u64::from(field) << 3 | u64::from(wire))
}

fn p_int(field: u32, n: u64) -> Vec<u8> {
    let mut v = tag(field, 0);
    v.extend(varint(n));
    v
}

fn p_bytes(field: u32, payload: &[u8]) -> Vec<u8> {
    let mut v = tag(field, 2);
    v.extend(varint(payload.len() as u64));
    v.extend_from_slice(payload);
    v
}

fn p_str(field: u32, s: &str) -> Vec<u8> {
    p_bytes(field, s.as_bytes())
}

fn tensor_proto(name: &str, dims: &[usize], data: &[f32], dtype: u64) -> Vec<u8> {
    let mut body = Vec::new();
    for &d in dims {
        body.extend(p_int(1, d as u64));
    }
    body.extend(p_int(2, dtype));
    body.extend(p_str(8, name));
    let mut raw = Vec::with_capacity(data.len() * 4);
    for &f in data {
        raw.extend_from_slice(&f.to_le_bytes());
    }
    body.extend(p_bytes(9, &raw));
    body
}

fn attr_int(name: &str, value: u64) -> Vec<u8> {
    let mut v = p_str(1, name);
    v.extend(p_int(3, value));
    v.extend(p_int(20, 2)); // type = INT
    v
}

fn attr_str(name: &str, value: &str) -> Vec<u8> {
    let mut v = p_str(1, name);
    v.extend(p_str(4, value));
    v.extend(p_int(20, 3)); // type = STRING
    v
}

fn node_proto(
    op: &str,
    inputs: &[&str],
    outputs: &[&str],
    name: &str,
    attrs: &[Vec<u8>],
) -> Vec<u8> {
    let mut body = Vec::new();
    for i in inputs {
        body.extend(p_str(1, i));
    }
    for o in outputs {
        body.extend(p_str(2, o));
    }
    body.extend(p_str(3, name));
    body.extend(p_str(4, op));
    for a in attrs {
        body.extend(p_bytes(5, a));
    }
    body
}

fn model_proto(graph_name: &str, nodes: &[Vec<u8>], inits: &[Vec<u8>]) -> Vec<u8> {
    let mut graph = Vec::new();
    for n in nodes {
        graph.extend(p_bytes(1, n));
    }
    graph.extend(p_str(2, graph_name));
    for t in inits {
        graph.extend(p_bytes(5, t));
    }
    let mut model = p_int(1, 8); // ir_version
    model.extend(p_bytes(7, &graph));
    model
}

// ---------------------------------------------------------------------
// Weights → ONNX export, with corruption knobs for the rejection tests.
// ---------------------------------------------------------------------

#[derive(Clone)]
struct ExportOpts {
    /// Gemm weights stored `(out, in)` with `transB=1` (the common
    /// Keras-export layout) vs plain `(in, out)`.
    transb: bool,
    direction: Option<&'static str>,
    /// GRU `linear_before_reset` attribute (Keras `reset_after`).
    linear_before_reset: bool,
    graph_name: Option<&'static str>,
    hidden_size_attr: Option<u64>,
    w_dtype: u64,
    drop_bias_init: bool,
    /// Swap the W dims to `(1, I, G·H)` — same element count, wrong
    /// layout.
    swap_w_dims: bool,
}

impl Default for ExportOpts {
    fn default() -> Self {
        Self {
            transb: true,
            direction: Some("forward"),
            linear_before_reset: true,
            graph_name: None,
            hidden_size_attr: None,
            w_dtype: 1,
            drop_bias_init: false,
            swap_w_dims: false,
        }
    }
}

/// Keras `(cols, G·H)` → ONNX `(G·H, cols)`: transpose with ONNX gate
/// block `ob` reading Keras block `order[ob]`.
fn to_onnx_blocks(
    data: &[f32],
    cols: usize,
    h: usize,
    order: &[usize],
) -> Vec<f32> {
    let gh = order.len() * h;
    let mut out = vec![0.0f32; gh * cols];
    for (ob, &kb) in order.iter().enumerate() {
        for j in 0..h {
            for c in 0..cols {
                out[(ob * h + j) * cols + c] = data[c * gh + kb * h + j];
            }
        }
    }
    out
}

fn export_onnx(w: &Weights, opts: &ExportOpts) -> Vec<u8> {
    let arch = &w.arch;
    let h = arch.hidden_size;
    let i = arch.input_size;
    let g = arch.cell.gates();
    // Keras → ONNX gate block order: LSTM [i,f,c,o] → [i,o,f,c].
    let order: &[usize] = match arch.cell {
        Cell::Lstm => &[0, 3, 1, 2],
        Cell::Gru => &[0, 1, 2],
    };

    let kw = w.tensor("rnn", "w").unwrap();
    let ku = w.tensor("rnn", "u").unwrap();
    let kb = w.tensor("rnn", "b").unwrap();
    let w_on = to_onnx_blocks(&kw.data, i, h, order);
    let u_on = to_onnx_blocks(&ku.data, h, h, order);
    let b_on: Vec<f32> = match arch.cell {
        Cell::Lstm => {
            // Reorder the single Keras bias into ONNX gate order, then
            // split it across the Wb | Rb halves element-by-element
            // (even indices → Wb, odd → Rb).  The reader sums the
            // halves, and a sum where one addend is 0.0 is bit-exact —
            // so this exercises the sum path, not just Rb = 0.
            let mut reordered = vec![0.0f32; 4 * h];
            for (ob, &kbk) in order.iter().enumerate() {
                for j in 0..h {
                    reordered[ob * h + j] = kb.data[kbk * h + j];
                }
            }
            let mut both = vec![0.0f32; 8 * h];
            for (x, &v) in reordered.iter().enumerate() {
                if x % 2 == 0 {
                    both[x] = v;
                } else {
                    both[4 * h + x] = v;
                }
            }
            both
        }
        // Keras reset_after rows (2, 3H) are already Wb then Rb.
        Cell::Gru => kb.data.clone(),
    };

    let w_dims: &[usize] = if opts.swap_w_dims {
        &[1, i, g * h]
    } else {
        &[1, g * h, i]
    };
    let mut inits = vec![
        tensor_proto("rnn.W", w_dims, &w_on, opts.w_dtype),
        tensor_proto("rnn.R", &[1, g * h, h], &u_on, 1),
    ];
    if !opts.drop_bias_init {
        inits.push(tensor_proto("rnn.B", &[1, 2 * g * h], &b_on, 1));
    }

    let mut attrs = Vec::new();
    if let Some(hs) = opts.hidden_size_attr {
        attrs.push(attr_int("hidden_size", hs));
    } else {
        attrs.push(attr_int("hidden_size", h as u64));
    }
    if let Some(d) = opts.direction {
        attrs.push(attr_str("direction", d));
    }
    if arch.cell == Cell::Gru && opts.linear_before_reset {
        attrs.push(attr_int("linear_before_reset", 1));
    }
    let op = match arch.cell {
        Cell::Lstm => "LSTM",
        Cell::Gru => "GRU",
    };
    let mut nodes = vec![
        node_proto(
            op,
            &["x", "rnn.W", "rnn.R", "rnn.B"],
            &["rnn_y", "rnn_h"],
            "rnn",
            &attrs,
        ),
        node_proto("Squeeze", &["rnn_h"], &["state"], "squeeze", &[]),
    ];

    let mut prev_name = "state".to_string();
    let mut head: Vec<(String, bool)> = (0..arch.dense_sizes.len())
        .map(|k| (format!("dense{k}"), true))
        .collect();
    head.push(("out".into(), false));
    for (lname, relu) in head {
        let wl = w.tensor(&lname, "w").unwrap();
        let bl = w.tensor(&lname, "b").unwrap();
        let (rows, cols) = (wl.shape[0], wl.shape[1]);
        if opts.transb {
            // Store (out, in).
            let mut t = vec![0.0f32; rows * cols];
            for r in 0..rows {
                for c in 0..cols {
                    t[c * rows + r] = wl.data[r * cols + c];
                }
            }
            inits.push(tensor_proto(&format!("{lname}.w"), &[cols, rows], &t, 1));
        } else {
            inits.push(tensor_proto(
                &format!("{lname}.w"),
                &[rows, cols],
                &wl.data,
                1,
            ));
        }
        inits.push(tensor_proto(&format!("{lname}.b"), &[cols], &bl.data, 1));
        let out_name = format!("{lname}_z");
        let wn = format!("{lname}.w");
        let bn = format!("{lname}.b");
        let gemm_attrs = if opts.transb {
            vec![attr_int("transB", 1)]
        } else {
            vec![]
        };
        nodes.push(node_proto(
            "Gemm",
            &[&prev_name, &wn, &bn],
            &[&out_name],
            &lname,
            &gemm_attrs,
        ));
        prev_name = out_name;
        if relu {
            let act_name = format!("{lname}_a");
            nodes.push(node_proto(
                "Relu",
                &[&prev_name],
                &[&act_name],
                &format!("{lname}_relu"),
                &[],
            ));
            prev_name = act_name;
        }
    }
    let act = match arch.output_activation {
        rnn_hls::model::OutputActivation::Sigmoid => "Sigmoid",
        rnn_hls::model::OutputActivation::Softmax => "Softmax",
    };
    nodes.push(node_proto(
        act,
        &[&prev_name],
        &["probs"],
        "output_activation",
        &[],
    ));

    let graph_name = opts.graph_name.map(str::to_string).unwrap_or_else(|| {
        w.arch.key()
    });
    model_proto(&graph_name, &nodes, &inits)
}

/// Bitwise tensor-by-tensor equality of two imported checkpoints.
fn assert_bitwise_eq(a: &Weights, b: &Weights) {
    assert_eq!(a.arch, b.arch);
    let mut layers = vec!["rnn".to_string()];
    layers.extend((0..a.arch.dense_sizes.len()).map(|k| format!("dense{k}")));
    layers.push("out".into());
    for layer in &layers {
        let tensors: &[&str] =
            if layer == "rnn" { &["w", "u", "b"] } else { &["w", "b"] };
        for name in tensors {
            let ta = a.tensor(layer, name).unwrap();
            let tb = b.tensor(layer, name).unwrap();
            assert_eq!(ta.shape, tb.shape, "{layer}.{name} shape");
            let bits_a: Vec<u32> =
                ta.data.iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u32> =
                tb.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "{layer}.{name} data bits");
        }
    }
}

fn parse_and_build(bytes: &[u8]) -> anyhow::Result<Weights> {
    let mut src = OnnxSource::parse(bytes, None)?;
    let arch = src.arch.clone();
    Weights::from_source(&arch, &mut src)
}

fn import_err(bytes: &[u8]) -> ImportError {
    match OnnxSource::parse(bytes, None) {
        Err(e) => e,
        Ok(mut src) => {
            let arch = src.arch.clone();
            let err = Weights::from_source(&arch, &mut src)
                .expect_err("import should fail");
            err.downcast::<ImportError>().expect("typed import error")
        }
    }
}

// ---------------------------------------------------------------------
// Roundtrips
// ---------------------------------------------------------------------

#[test]
fn lstm_roundtrip_is_bitwise_exact() {
    let arch = zoo::arch("top", Cell::Lstm).unwrap();
    let w = Weights::synthetic(&arch, 0xA11CE);
    let bytes = export_onnx(&w, &ExportOpts::default());
    let got = parse_and_build(&bytes).unwrap();
    assert_bitwise_eq(&w, &got);
}

#[test]
fn gru_roundtrip_is_bitwise_exact() {
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let w = Weights::synthetic(&arch, 0xB0B);
    let bytes = export_onnx(&w, &ExportOpts::default());
    let got = parse_and_build(&bytes).unwrap();
    assert_bitwise_eq(&w, &got);
}

#[test]
fn gemm_without_transb_roundtrips() {
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let w = Weights::synthetic(&arch, 7);
    let bytes = export_onnx(
        &w,
        &ExportOpts { transb: false, ..ExportOpts::default() },
    );
    let got = parse_and_build(&bytes).unwrap();
    assert_bitwise_eq(&w, &got);
}

#[test]
fn direction_attribute_is_optional() {
    let arch = zoo::arch("top", Cell::Lstm).unwrap();
    let w = Weights::synthetic(&arch, 3);
    let bytes = export_onnx(
        &w,
        &ExportOpts { direction: None, ..ExportOpts::default() },
    );
    assert_bitwise_eq(&w, &parse_and_build(&bytes).unwrap());
}

#[test]
fn committed_json_and_onnx_fixtures_import_identically() {
    // The cross-language contract: the python exporter wrote the same
    // trained checkpoint in both formats; the two readers must produce
    // bitwise-identical Weights.
    let a = Weights::load_path(fixtures().join("top_gru.json"), None).unwrap();
    let b = Weights::load_path(fixtures().join("top_gru.onnx"), None).unwrap();
    assert_eq!(a.arch.key(), "top_gru");
    assert_eq!(a.param_count(), 3089);
    assert_bitwise_eq(&a, &b);
}

#[test]
fn explicit_arch_hint_is_accepted_when_it_matches() {
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let w = Weights::synthetic(&arch, 5);
    let bytes = export_onnx(
        &w,
        &ExportOpts {
            graph_name: Some("mystery_export"),
            ..ExportOpts::default()
        },
    );
    // Without a hint the graph name resolves nowhere...
    let err = OnnxSource::parse(&bytes, None).unwrap_err();
    assert!(matches!(err, ImportError::Unsupported { .. }), "{err}");
    // ...with the hint the same bytes import exactly.
    let mut src = OnnxSource::parse(&bytes, Some(&arch)).unwrap();
    let got = Weights::from_source(&arch, &mut src).unwrap();
    assert_bitwise_eq(&w, &got);
}

// ---------------------------------------------------------------------
// Typed rejection paths
// ---------------------------------------------------------------------

#[test]
fn missing_initializer_names_the_tensor() {
    let arch = zoo::arch("top", Cell::Lstm).unwrap();
    let w = Weights::synthetic(&arch, 1);
    let bytes = export_onnx(
        &w,
        &ExportOpts { drop_bias_init: true, ..ExportOpts::default() },
    );
    match import_err(&bytes) {
        ImportError::MissingTensor { name } => assert_eq!(name, "rnn.B"),
        other => panic!("want MissingTensor, got {other}"),
    }
}

#[test]
fn wrong_kernel_layout_names_the_tensor() {
    let arch = zoo::arch("top", Cell::Lstm).unwrap();
    let w = Weights::synthetic(&arch, 1);
    let bytes = export_onnx(
        &w,
        &ExportOpts { swap_w_dims: true, ..ExportOpts::default() },
    );
    match import_err(&bytes) {
        ImportError::ShapeMismatch { name, want, got } => {
            assert_eq!(name, "rnn.W");
            assert_eq!(want, vec![1, 80, 6]);
            assert_eq!(got, vec![1, 6, 80]);
        }
        other => panic!("want ShapeMismatch, got {other}"),
    }
}

#[test]
fn non_f32_dtype_names_the_tensor() {
    let arch = zoo::arch("top", Cell::Lstm).unwrap();
    let w = Weights::synthetic(&arch, 1);
    let bytes = export_onnx(
        &w,
        &ExportOpts { w_dtype: 7, ..ExportOpts::default() },
    );
    match import_err(&bytes) {
        ImportError::BadDtype { name, got } => {
            assert_eq!(name, "rnn.W");
            assert_eq!(got, "INT64");
        }
        other => panic!("want BadDtype, got {other}"),
    }
}

#[test]
fn reverse_direction_is_unsupported() {
    let arch = zoo::arch("top", Cell::Lstm).unwrap();
    let w = Weights::synthetic(&arch, 1);
    let bytes = export_onnx(
        &w,
        &ExportOpts {
            direction: Some("bidirectional"),
            ..ExportOpts::default()
        },
    );
    match import_err(&bytes) {
        ImportError::Unsupported { what } => {
            assert!(what.contains("bidirectional"), "{what}");
        }
        other => panic!("want Unsupported, got {other}"),
    }
}

#[test]
fn gru_without_reset_after_is_unsupported() {
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let w = Weights::synthetic(&arch, 1);
    let bytes = export_onnx(
        &w,
        &ExportOpts {
            linear_before_reset: false,
            ..ExportOpts::default()
        },
    );
    match import_err(&bytes) {
        ImportError::Unsupported { what } => {
            assert!(what.contains("linear_before_reset"), "{what}");
        }
        other => panic!("want Unsupported, got {other}"),
    }
}

#[test]
fn hidden_size_contradiction_is_arch_mismatch() {
    let arch = zoo::arch("top", Cell::Lstm).unwrap();
    let w = Weights::synthetic(&arch, 1);
    let bytes = export_onnx(
        &w,
        &ExportOpts {
            hidden_size_attr: Some(99),
            ..ExportOpts::default()
        },
    );
    match import_err(&bytes) {
        ImportError::ArchMismatch { detail } => {
            assert!(detail.contains("99"), "{detail}");
        }
        other => panic!("want ArchMismatch, got {other}"),
    }
}

#[test]
fn wrong_cell_hint_is_arch_mismatch() {
    let lstm = zoo::arch("top", Cell::Lstm).unwrap();
    let gru = zoo::arch("top", Cell::Gru).unwrap();
    let w = Weights::synthetic(&gru, 1);
    let bytes = export_onnx(&w, &ExportOpts::default());
    let err = OnnxSource::parse(&bytes, Some(&lstm)).unwrap_err();
    assert!(matches!(err, ImportError::ArchMismatch { .. }), "{err}");
}

// ---------------------------------------------------------------------
// Malformed bytes must never panic
// ---------------------------------------------------------------------

/// Run the full import pipeline, discarding the outcome: any Result is
/// fine, a panic is the bug.
fn must_not_panic(bytes: &[u8]) {
    if let Ok(mut src) = OnnxSource::parse(bytes, None) {
        let arch = src.arch.clone();
        let _ = Weights::from_source(&arch, &mut src);
    }
}

#[test]
fn truncated_onnx_never_panics() {
    let bytes = std::fs::read(fixtures().join("top_gru.onnx")).unwrap();
    // Every prefix near the start (where headers live), then stepped
    // prefixes through the tensor payloads.
    for end in 0..64.min(bytes.len()) {
        must_not_panic(&bytes[..end]);
    }
    for end in (64..bytes.len()).step_by(97) {
        must_not_panic(&bytes[..end]);
    }
}

#[test]
fn bit_flipped_onnx_never_panics() {
    let bytes = std::fs::read(fixtures().join("top_gru.onnx")).unwrap();
    for (step, mask) in [(211usize, 0x41u8), (137, 0xFF), (59, 0x08)] {
        let mut mutated = bytes.clone();
        for pos in (0..mutated.len()).step_by(step) {
            mutated[pos] ^= mask;
        }
        must_not_panic(&mutated);
    }
}

#[test]
fn garbage_and_wrong_container_never_panic() {
    must_not_panic(&[]);
    must_not_panic(b"not a protobuf at all");
    let json = std::fs::read(fixtures().join("top_gru.json")).unwrap();
    must_not_panic(&json);
    let pattern: Vec<u8> =
        (0..4096u32).map(|x| (x.wrapping_mul(2654435761) >> 13) as u8).collect();
    must_not_panic(&pattern);
}
