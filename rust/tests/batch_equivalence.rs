//! Batched-inference equivalence suite: `forward_batch` (and the packed
//! variant the coordinator uses) must be **bitwise identical** to calling
//! `forward` per sample — for both engines, across LSTM/GRU cells,
//! sigmoid/softmax heads, and worker counts 1/2/8.
//!
//! This is the contract that makes the parallel batch runtime safe to
//! wire into the serving path: batching is a pure throughput lever with
//! zero numerical footprint.

use rnn_hls::data::generators;
use rnn_hls::fixed::{FixedSpec, QuantConfig};
use rnn_hls::model::{zoo, Cell, Weights};
use rnn_hls::nn::{Engine, FixedEngine, FloatEngine};

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];
/// Deliberately not divisible by 2 or 8: exercises uneven chunk splits.
const BATCH: usize = 9;

/// Realistic inputs from the benchmark's own generator.
fn sample_inputs(benchmark: &str, n: usize) -> Vec<Vec<f32>> {
    let mut generator = generators::for_benchmark(benchmark, 0xFEED).unwrap();
    (0..n).map(|_| generator.generate().features).collect()
}

fn refs(samples: &[Vec<f32>]) -> Vec<&[f32]> {
    samples.iter().map(|v| v.as_slice()).collect()
}

/// The four (cell × head) combinations from the paper's model zoo:
/// top = sigmoid head, flavor = softmax head.
fn cases() -> Vec<(&'static str, Cell)> {
    vec![
        ("top", Cell::Lstm),
        ("top", Cell::Gru),
        ("flavor", Cell::Lstm),
        ("flavor", Cell::Gru),
    ]
}

#[test]
fn float_forward_batch_bitwise_identical_across_workers() {
    for (benchmark, cell) in cases() {
        let arch = zoo::arch(benchmark, cell).unwrap();
        let weights = Weights::synthetic(&arch, 0xA11CE);
        let samples = sample_inputs(benchmark, BATCH);
        let xs = refs(&samples);
        let mut engine = FloatEngine::new(&weights).unwrap();
        let want: Vec<Vec<f32>> = xs.iter().map(|x| engine.forward(x)).collect();
        for workers in WORKER_COUNTS {
            engine.set_parallelism(workers);
            let got = engine.forward_batch(&xs);
            assert_eq!(
                got,
                want,
                "{} float: batch output differs at {workers} workers",
                arch.key()
            );
        }
    }
}

#[test]
fn fixed_forward_batch_bitwise_identical_across_workers() {
    for (benchmark, cell) in cases() {
        let arch = zoo::arch(benchmark, cell).unwrap();
        let weights = Weights::synthetic(&arch, 0xB0B);
        let samples = sample_inputs(benchmark, BATCH);
        let xs = refs(&samples);
        for spec in [FixedSpec::new(16, 6), FixedSpec::new(24, 8)] {
            let mut engine =
                FixedEngine::new(&weights, QuantConfig::ptq(spec)).unwrap();
            let want: Vec<Vec<f32>> =
                xs.iter().map(|x| engine.forward(x)).collect();
            for workers in WORKER_COUNTS {
                engine.set_parallelism(workers);
                let got = engine.forward_batch(&xs);
                assert_eq!(
                    got,
                    want,
                    "{} fixed{}: batch output differs at {workers} workers",
                    arch.key(),
                    spec.label()
                );
            }
        }
    }
}

#[test]
fn packed_batch_matches_slice_batch() {
    // The coordinator feeds engines through `forward_packed` on the
    // batcher's flat buffer; it must agree with the slice API (and hence
    // with per-sample `forward`).
    for (benchmark, cell) in [("top", Cell::Gru), ("flavor", Cell::Lstm)] {
        let arch = zoo::arch(benchmark, cell).unwrap();
        let weights = Weights::synthetic(&arch, 0xCAFE);
        let samples = sample_inputs(benchmark, BATCH);
        let xs = refs(&samples);
        let mut packed = Vec::new();
        for s in &samples {
            packed.extend_from_slice(s);
        }
        let engine = FloatEngine::new(&weights).unwrap().with_parallelism(4);
        assert_eq!(
            engine.forward_packed(&packed, BATCH),
            engine.forward_batch(&xs),
            "{}",
            arch.key()
        );
        let fixed = FixedEngine::new(&weights, QuantConfig::ptq(FixedSpec::new(16, 6)))
            .unwrap()
            .with_parallelism(4);
        assert_eq!(
            fixed.forward_packed(&packed, BATCH),
            fixed.forward_batch(&xs),
            "{} fixed",
            arch.key()
        );
    }
}

#[test]
fn empty_and_singleton_batches() {
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let weights = Weights::synthetic(&arch, 1);
    let engine = FloatEngine::new(&weights).unwrap().with_parallelism(8);
    assert!(engine.forward_batch(&[]).is_empty());
    let samples = sample_inputs("top", 1);
    let xs = refs(&samples);
    assert_eq!(engine.forward_batch(&xs), vec![engine.forward(xs[0])]);
}
