//! Golden accuracy suite: the paper's float-vs-fixed AUC contract,
//! pinned on the committed trained checkpoint + frozen test slice.
//!
//! `tests/fixtures/top_gru.meta.json` records the float AUC the python
//! training pipeline measured on the same slice; the rust float engine
//! must reproduce it, and the fixed-point ladder must show the Fig. 2
//! shape — near-float at wide types, degrading as width shrinks.  The
//! floors are far above the ~0.5 a gate-order or layout bug collapses
//! to, so a wrong import is a loud failure, not a tolerance nibble.

use std::path::PathBuf;

use rnn_hls::data::Dataset;
use rnn_hls::report::accuracy;
use rnn_hls::util::json;

fn fixtures() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn reference_slice_auc() -> f64 {
    let text =
        std::fs::read_to_string(fixtures().join("top_gru.meta.json")).unwrap();
    let doc = json::parse(&text).unwrap();
    doc.req("slice_float_auc").unwrap().as_f64().unwrap()
}

fn run_sweep() -> accuracy::AccuracyReport {
    let weights = rnn_hls::model::Weights::load_path(
        fixtures().join("top_gru.json"),
        None,
    )
    .unwrap();
    let ds = Dataset::load(fixtures().join("top_test_slice.bin")).unwrap();
    assert_eq!(ds.n, 400, "fixture slice size changed — regenerate goldens");
    accuracy::run(&weights, &ds, &accuracy::default_specs(), 2).unwrap()
}

#[test]
fn float_engine_reproduces_the_python_auc() {
    let report = run_sweep();
    let reference = reference_slice_auc();
    assert!(
        reference > 0.9,
        "meta.json reference AUC {reference} is implausible"
    );
    // f32 engine vs the python f32 pipeline: same weights, same events.
    // Tolerance covers summation-order differences only.
    assert!(
        (report.auc_float - reference).abs() < 0.01,
        "float AUC {} vs python reference {reference}",
        report.auc_float
    );
}

#[test]
fn fixed_point_ladder_matches_fig2_shape() {
    let report = run_sweep();

    // <16,6> — hls4ml's default type: trained-network accuracy must
    // survive PTQ essentially intact (Fig. 2 plateau).
    let p16 = report.point(16, 6).expect("<16,6> scanned");
    assert!(
        p16.auc_fixed >= 0.92,
        "<16,6> AUC {:.4} — gate-order/layout bugs collapse this to ~0.5",
        p16.auc_fixed
    );
    assert!(
        report.delta(p16).abs() <= 0.06,
        "<16,6> delta {:.4} from float {:.4}",
        report.delta(p16),
        report.auc_float
    );

    // <20,8> — near-float.
    let p20 = report.point(20, 8).expect("<20,8> scanned");
    assert!(p20.auc_fixed >= 0.95, "<20,8> AUC {:.4}", p20.auc_fixed);
    assert!(
        report.delta(p20).abs() <= 0.04,
        "<20,8> delta {:.4}",
        report.delta(p20)
    );

    // <12,6> (6 fractional bits) — visibly degraded but still a
    // classifier; <8,4> — deep in the cliff, only sanity-bounded.
    let p12 = report.point(12, 6).expect("<12,6> scanned");
    assert!(p12.auc_fixed >= 0.70, "<12,6> AUC {:.4}", p12.auc_fixed);
    let p8 = report.point(8, 4).expect("<8,4> scanned");
    assert!(p8.auc_fixed >= 0.30, "<8,4> AUC {:.4}", p8.auc_fixed);

    // Monotone-with-width at the ends (small tolerance for tie noise),
    // plus the packaged shape check the CLI prints as a warning.
    assert!(
        p20.auc_fixed >= p8.auc_fixed - 0.02,
        "widest <20,8> ({:.4}) below narrowest <8,4> ({:.4})",
        p20.auc_fixed,
        p8.auc_fixed
    );
    accuracy::shape_check(&report).unwrap();
}

#[test]
fn bench_json_schema_is_stable() {
    let report = run_sweep();
    let path = std::env::temp_dir().join(format!(
        "bench_accuracy_golden_{}.json",
        std::process::id()
    ));
    accuracy::write_bench_json(&path, std::slice::from_ref(&report)).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();

    for marker in [
        "\"bench\":\"accuracy\"",
        "\"schema_version\":1",
        "\"key\":\"top_gru\"",
        "\"samples\":400",
        "\"auc_float\":",
        "\"width\":16,\"integer\":6,",
        "\"width\":20,\"integer\":8,",
        "\"delta\":",
    ] {
        assert!(text.contains(marker), "missing {marker}");
    }
    // The emitted document parses back, with one row per scanned spec.
    let doc = json::parse(&text).unwrap();
    let models = doc.req("models").unwrap().as_array().unwrap();
    assert_eq!(models.len(), 1);
    let rows = models[0].req("rows").unwrap().as_array().unwrap();
    assert_eq!(rows.len(), accuracy::default_specs().len());
    for row in rows {
        let width = row.req("width").unwrap().as_usize().unwrap();
        let auc = row.req("auc_fixed").unwrap().as_f64().unwrap();
        assert!((1..=26).contains(&width));
        assert!((0.0..=1.0).contains(&auc), "AUC {auc} out of [0,1]");
    }
}
