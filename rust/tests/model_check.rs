//! Model-check scenarios for the serving fabric — compiled only under
//! `--features model-check`, where `util::sync` swaps its std
//! re-exports for instrumented primitives driven by a deterministic
//! scheduler (see `src/util/sync.rs`).
//!
//! Each scenario drives the *production* queue/pool/session code —
//! not a model of it — through adversarial interleavings:
//!
//! * bounded-exhaustive DFS (`explore_exhaustive`) for the small,
//!   spin-free scenarios (queue races, channel shed), where the whole
//!   decision tree is enumerable;
//! * seeded-random schedules (`explore_random`) for the full fabric
//!   (worker pool, live session), whose readiness/settle spin loops
//!   terminate probabilistically but not on every DFS path.
//!
//! On failure the harness panics with a replay line; re-run with
//! `MODEL_CHECK_TRACE=<trace>` (exhaustive) or `MODEL_CHECK_SEED=<seed>`
//! (random) to reproduce that exact interleaving.
#![cfg(feature = "model-check")]

use std::sync::Arc;
use std::time::{Duration, Instant};

use rnn_hls::coordinator::{BatchRunner, BoundedQueue, Request};
use rnn_hls::util::sync::{check, mpsc, thread};
use rnn_hls::util::threads::WorkerPool;
use rnn_hls::{BackendKind, ServingSpec, Session, SubmitError};

/// Scenario 1 — the queue close race.  A push, a timed pop, and a close
/// interleave freely; whenever the push was *admitted* the item must
/// surface exactly once (popped by the consumer or drained after the
/// close) — never lost, never duplicated.
#[test]
fn queue_close_race_never_loses_an_item() {
    check::explore_exhaustive("queue_close_race", 20_000, || {
        let q = Arc::new(BoundedQueue::new(2));
        let producer = {
            let q = q.clone();
            thread::spawn(move || q.push(7u32).is_ok())
        };
        let consumer = {
            let q = q.clone();
            thread::spawn(move || q.pop_timeout(Duration::from_millis(50)))
        };
        q.close();
        let pushed = producer.join().unwrap();
        let popped = consumer.join().unwrap();
        let mut delivered = usize::from(popped.is_some());
        while q.try_pop().is_some() {
            delivered += 1;
        }
        assert_eq!(
            delivered,
            usize::from(pushed),
            "an admitted item must surface exactly once \
             (pushed={pushed}, popped={popped:?})"
        );
    });
}

/// Scenario 2 — no lost wakeup on the queue condvar.  A consumer
/// blocked in `pop_timeout` must always observe a racing push: the
/// model's timeout budget (two scheduler-chosen timeouts per run) means
/// a lost notify would leave the consumer blocked forever, which the
/// scheduler reports as a deadlock instead of hanging the test.
#[test]
fn queue_push_always_wakes_a_timed_wait() {
    check::explore_exhaustive("queue_no_lost_wakeup", 20_000, || {
        let q = Arc::new(BoundedQueue::new(2));
        let consumer = {
            let q = q.clone();
            thread::spawn(move || loop {
                if let Some(v) = q.pop_timeout(Duration::from_millis(50)) {
                    return v;
                }
            })
        };
        let producer = {
            let q = q.clone();
            // Capacity 2, queue open: this push cannot be rejected.
            thread::spawn(move || q.push(9u32).unwrap())
        };
        producer.join().unwrap();
        assert_eq!(consumer.join().unwrap(), 9);
    });
}

/// Scenario 3 — a panicking job in the worker pool.  The panic must
/// surface on the calling thread (after the surviving chunks finish),
/// the pool must stay serviceable for the next call, and `Drop` must
/// join every worker — under schedules where the panic lands before,
/// between, and after the sibling chunks.
#[test]
fn worker_pool_survives_a_panicking_chunk() {
    check::explore_random("worker_pool_panic", 0xA11CE, 25, || {
        let pool = WorkerPool::new(2);
        let caught =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                pool.map_chunks(4, |range| {
                    if range.start == 0 {
                        panic!("chunk boom");
                    }
                    range.map(|i| i * 10).collect::<Vec<_>>()
                })
            }));
        assert!(caught.is_err(), "the chunk panic must reach the caller");
        let ok = pool.map_chunks(4, |range| range.collect::<Vec<usize>>());
        assert_eq!(ok, vec![0, 1, 2, 3], "pool serviceable after a panic");
        drop(pool);
    });
}

/// Minimal runner for the live-session scenario: constant output, no
/// shared state — the accounting identity is what is under test.
struct TinyRunner;

impl BatchRunner for TinyRunner {
    fn max_batch(&self) -> usize {
        1
    }
    fn run(&mut self, _xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        Ok(vec![vec![0.5]; n])
    }
}

fn request(id: u64) -> Request {
    Request {
        id,
        features: vec![0.0; 4],
        label: 0,
        route_key: 0,
        enqueued_at: Instant::now(),
    }
}

/// Scenario 4 — submit vs shutdown linearizability on a live session.
/// Whatever the interleaving, the final report's books balance: every
/// `Ok` admission completes, every `Full` rejection is one counted
/// drop, every `Closed` rejection — including the narrow race where the
/// closed-flag check passes but the push lands on an already-closed
/// queue (the un-count path) — is counted nowhere.
#[test]
fn submit_racing_shutdown_keeps_the_accounting_identity() {
    check::explore_random("submit_vs_shutdown", 0x5E55, 20, || {
        let spec = ServingSpec {
            engine: BackendKind::Float,
            workers: 1,
            queue_capacity: 2,
            completions: false,
            ..ServingSpec::default()
        }
        .with_batcher(1, Duration::ZERO);
        let session = Session::start(&spec, |_shard| {
            Ok(Box::new(TinyRunner) as Box<dyn BatchRunner>)
        })
        .unwrap();
        let handle = session.handle();
        let submitter = thread::spawn(move || {
            let (mut ok, mut full) = (0u64, 0u64);
            for id in 0..3u64 {
                match handle.submit(request(id)) {
                    Ok(()) => ok += 1,
                    Err(SubmitError::Full { .. }) => full += 1,
                    Err(SubmitError::Closed { .. }) => break,
                }
            }
            (ok, full)
        });
        let report = session.shutdown().unwrap();
        let (ok, full) = submitter.join().unwrap();
        assert_eq!(
            report.merged.generated,
            ok + full,
            "every admission attempt that touched a queue counted once"
        );
        assert_eq!(report.merged.dropped, full, "every Full is one drop");
        assert_eq!(report.merged.completed, ok, "every admission drains");
        assert_eq!(
            report.merged.generated,
            report.merged.completed + report.merged.dropped,
            "the accounting identity"
        );
    });
}

/// Scenario 5 — completion-channel shed accounting.  The egress channel
/// is bounded and `try_send` sheds on overflow (a worker never blocks
/// on a slow consumer); whatever the producer/consumer interleaving,
/// `sent == delivered + shed` — here checked as: every successful send
/// is eventually delivered, every attempt is either sent or shed.
#[test]
fn completion_channel_shed_never_miscounts() {
    check::explore_exhaustive("completion_channel_shed", 20_000, || {
        let (tx, rx) = mpsc::sync_channel::<u32>(1);
        let producer = thread::spawn(move || {
            let (mut sent, mut shed) = (0u32, 0u32);
            for i in 0..3u32 {
                match tx.try_send(i) {
                    Ok(()) => sent += 1,
                    Err(_) => shed += 1,
                }
            }
            (sent, shed)
        });
        // Drain concurrently with the producer...
        let mut delivered = 0u32;
        while rx.try_recv().is_ok() {
            delivered += 1;
        }
        let (sent, shed) = producer.join().unwrap();
        // ...then drain what is left once it has finished.
        while rx.try_recv().is_ok() {
            delivered += 1;
        }
        assert_eq!(sent + shed, 3, "every send attempt accounted");
        assert_eq!(sent, delivered, "every successful send is delivered");
    });
}
