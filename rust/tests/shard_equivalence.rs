//! Shard-equivalence suite: the same request stream served with 1 shard
//! vs 2/4 shards (hash and round-robin routing) must produce **identical
//! per-request outputs** and a merged metrics total equal to the
//! single-shard count — sharding is a pure throughput lever with zero
//! semantic footprint, exactly like batching (`batch_equivalence.rs`).
//!
//! Method: a deterministic generator encodes the event index into the
//! features, and a recording runner keys every output it produces by
//! that embedded id.  Whatever the topology, the (id → output) map must
//! come out the same.  Queues are sized so nothing drops: a drop would
//! silently shrink the map and void the comparison, so every run asserts
//! `dropped == 0` first.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use rnn_hls::coordinator::{
    BatchRunner, BatcherConfig, Server, ServerConfig, ShardPolicy,
    ShardedConfig, ShardedServer, SourceConfig, TierMix,
};
use rnn_hls::data::generators::{Event, Generator};
use rnn_hls::util::sync::{lock_or_recover, Mutex};

const N_EVENTS: usize = 2_000;

/// Emits events whose first feature is the event index (exact in f32 for
/// the stream sizes used here) — the source assigns `Request::id` in the
/// same order, so runners can recover the id from the features alone.
struct IdGen {
    next: u64,
}

impl Generator for IdGen {
    fn name(&self) -> &'static str {
        "id"
    }
    fn seq_len(&self) -> usize {
        4
    }
    fn n_feat(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        1
    }
    fn generate(&mut self) -> Event {
        let id = self.next;
        self.next += 1;
        let mut features = vec![0.0f32; self.seq_len() * self.n_feat()];
        features[0] = id as f32;
        // Remaining features depend on the id too, so outputs genuinely
        // vary per request.
        features[1] = (id % 17) as f32 * 0.25;
        Event {
            features,
            label: (id % 2) as u32,
        }
    }
}

/// Records (id → output) for every sample it serves; output is a pure
/// function of the id, and matches the label parity so online accuracy
/// must come out exactly 1.0.
struct RecordingRunner {
    outputs: Arc<Mutex<HashMap<u64, Vec<f32>>>>,
}

impl BatchRunner for RecordingRunner {
    fn max_batch(&self) -> usize {
        8
    }
    fn run(&mut self, xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let stride = xs.len() / n.max(1);
        let mut out = Vec::with_capacity(n);
        let mut map = lock_or_recover(&self.outputs);
        for i in 0..n {
            let row = &xs[i * stride..(i + 1) * stride];
            let id = row[0] as u64;
            // Binary head (single prob, threshold 0.5): parity decides
            // the side, the second feature adds an id-dependent wiggle
            // small enough to never cross it.
            let base = if id % 2 == 1 { 0.9f32 } else { 0.1f32 };
            let probs = vec![base + row[1] * 1e-4];
            anyhow::ensure!(
                map.insert(id, probs.clone()).is_none(),
                "request {id} served twice"
            );
            out.push(probs);
        }
        Ok(out)
    }
}

fn config(workers: usize) -> ServerConfig {
    ServerConfig {
        workers,
        queue_capacity: 16_384, // > N_EVENTS: nothing can drop
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(100),
        },
        source: SourceConfig {
            rate_hz: 5_000_000.0, // saturating: pacing never the bottleneck
            poisson: false,
            n_events: N_EVENTS,
        },
    }
}

/// Serve the stream through a `ShardedServer`, returning the recorded
/// (id → output) map and the report.
fn run_sharded(
    shards: usize,
    policy: ShardPolicy,
) -> (HashMap<u64, Vec<f32>>, rnn_hls::coordinator::ShardedReport) {
    run_sharded_with(shards, policy, Vec::new())
}

/// `run_sharded` with an explicit per-shard batching policy (empty =
/// the shared `ServerConfig` batcher on every shard).
fn run_sharded_with(
    shards: usize,
    policy: ShardPolicy,
    shard_batchers: Vec<BatcherConfig>,
) -> (HashMap<u64, Vec<f32>>, rnn_hls::coordinator::ShardedReport) {
    let outputs = Arc::new(Mutex::new(HashMap::new()));
    let sink = outputs.clone();
    let report = ShardedServer::run(
        ShardedConfig {
            shards,
            policy,
            tier_mix: TierMix::single(),
            shard_backends: Vec::new(),
            shard_batchers,
            server: config(2),
        },
        Box::new(IdGen { next: 0 }),
        move |_shard| {
            Ok(Box::new(RecordingRunner {
                outputs: sink.clone(),
            }) as Box<dyn BatchRunner>)
        },
    )
    .unwrap();
    let map = Arc::try_unwrap(outputs).unwrap().into_inner().unwrap();
    (map, report)
}

/// Baseline: the classic single coordinator.
fn run_single() -> (HashMap<u64, Vec<f32>>, rnn_hls::coordinator::ServerReport)
{
    let outputs = Arc::new(Mutex::new(HashMap::new()));
    let sink = outputs.clone();
    let report = Server::run(config(2), Box::new(IdGen { next: 0 }), move || {
        Ok(Box::new(RecordingRunner {
            outputs: sink.clone(),
        }) as Box<dyn BatchRunner>)
    })
    .unwrap();
    let map = Arc::try_unwrap(outputs).unwrap().into_inner().unwrap();
    (map, report)
}

#[test]
fn one_shard_reproduces_server_exactly() {
    let (single_map, single) = run_single();
    let (sharded_map, sharded) = run_sharded(1, ShardPolicy::HashId);

    // Validity: no drops on either side.
    assert_eq!(single.dropped, 0);
    assert_eq!(sharded.merged.dropped, 0);

    // Deterministic report fields match exactly.
    assert_eq!(sharded.merged.generated, single.generated);
    assert_eq!(sharded.merged.completed, single.completed);
    assert_eq!(sharded.merged.accuracy, single.accuracy);
    assert_eq!(single.accuracy, 1.0);
    assert_eq!(single.completed, N_EVENTS as u64);

    // Per-request outputs are identical.
    assert_eq!(sharded_map, single_map);
    assert_eq!(single_map.len(), N_EVENTS);
}

#[test]
fn multi_shard_outputs_identical_to_single_shard() {
    let (baseline_map, baseline) = run_sharded(1, ShardPolicy::HashId);
    assert_eq!(baseline.merged.dropped, 0);
    assert_eq!(baseline.merged.completed, N_EVENTS as u64);

    for shards in [2usize, 4] {
        for policy in [ShardPolicy::HashId, ShardPolicy::RoundRobin] {
            let (map, report) = run_sharded(shards, policy);
            let label = format!("shards={shards} policy={}", policy.name());

            assert_eq!(report.merged.dropped, 0, "{label}");
            // Merged totals equal the single-shard counts.
            assert_eq!(
                report.merged.generated,
                baseline.merged.generated,
                "{label}"
            );
            assert_eq!(
                report.merged.completed,
                baseline.merged.completed,
                "{label}"
            );
            assert_eq!(report.merged.accuracy, 1.0, "{label}");

            // Identical per-request outputs, request for request.
            assert_eq!(map, baseline_map, "{label}");

            // The roll-up is a true partition: per-shard counts sum to
            // the merged totals and every shard did real work.
            assert_eq!(report.per_shard.len(), shards, "{label}");
            let routed: u64 =
                report.per_shard.iter().map(|s| s.routed).sum();
            let completed: u64 =
                report.per_shard.iter().map(|s| s.completed).sum();
            assert_eq!(routed, report.merged.generated, "{label}");
            assert_eq!(completed, report.merged.completed, "{label}");
            for s in &report.per_shard {
                assert!(
                    s.routed > 0,
                    "{label}: shard {} starved",
                    s.shard
                );
            }
        }
    }
}

/// Tier-aware batching must not perturb a homogeneous session: a
/// 1-shard run with an *explicit* per-shard batcher equal to the shared
/// config — and a multi-shard run with identical per-shard policies —
/// remain bitwise-identical to the pre-tier [`Server`] output, request
/// for request.
#[test]
fn per_shard_batchers_keep_homogeneous_runs_bitwise_identical() {
    let (single_map, single) = run_single();
    assert_eq!(single.dropped, 0);

    let batcher = config(2).batcher;
    let (map, report) =
        run_sharded_with(1, ShardPolicy::HashId, vec![batcher]);
    assert_eq!(report.merged.dropped, 0);
    assert_eq!(report.merged.completed, single.completed);
    assert_eq!(report.merged.accuracy, single.accuracy);
    assert_eq!(map, single_map, "explicit uniform policy changed outputs");
    assert_eq!(report.per_shard[0].batcher.max_batch, batcher.max_batch);

    let (map2, report2) = run_sharded_with(
        2,
        ShardPolicy::RoundRobin,
        vec![batcher, batcher],
    );
    assert_eq!(report2.merged.dropped, 0);
    assert_eq!(map2, single_map, "per-shard policies changed outputs");
}

/// Round-robin must split a steady stream near-perfectly; hash must be
/// sticky (replaying the same stream re-routes every id identically —
/// implied by the output-map equality above, asserted here directly on
/// the per-shard routed counts of two runs).
#[test]
fn routing_is_balanced_and_reproducible() {
    let (_, rr) = run_sharded(4, ShardPolicy::RoundRobin);
    for s in &rr.per_shard {
        assert_eq!(s.routed, (N_EVENTS / 4) as u64, "round-robin balance");
    }
    let (_, hash_a) = run_sharded(4, ShardPolicy::HashId);
    let (_, hash_b) = run_sharded(4, ShardPolicy::HashId);
    for (a, b) in hash_a.per_shard.iter().zip(&hash_b.per_shard) {
        assert_eq!(a.routed, b.routed, "hash routing must be deterministic");
    }
}
