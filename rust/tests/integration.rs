//! Integration tests over the real artifacts (built by `make artifacts`).
//!
//! These exercise the full L1→L2→L3 composition: Pallas-lowered HLO
//! executed via PJRT, cross-checked against the python goldens, the f32
//! rust engine, and the bit-accurate fixed-point engine.
//!
//! If `artifacts/` is missing the tests are skipped (with a note) so
//! `cargo test` stays green on a fresh checkout; CI runs `make artifacts`
//! first.

use std::path::PathBuf;

use rnn_hls::coordinator::{
    BatcherConfig, Server, ServerConfig, SourceConfig,
};
use rnn_hls::data::{generators, metrics, Dataset};
use rnn_hls::fixed::{FixedSpec, QuantConfig};
use rnn_hls::model::Weights;
use rnn_hls::nn::{Engine, FixedEngine, FloatEngine};
use rnn_hls::runtime::Runtime;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts at {}", dir.display());
        None
    }
}

#[test]
fn pjrt_matches_python_goldens() {
    let Some(dir) = artifacts() else { return };
    let runtime = Runtime::new(&dir).unwrap();
    for entry in runtime.manifest().models.clone() {
        let golden_text =
            std::fs::read_to_string(runtime.manifest().path(&entry.golden))
                .unwrap();
        let golden = rnn_hls::util::json::parse(&golden_text).unwrap();
        let n = golden.req("n").unwrap().as_usize().unwrap();
        let expected: Vec<Vec<f32>> = golden
            .req("outputs")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|row| row.as_f32_vec().unwrap())
            .collect();
        let ds = Dataset::load(runtime.manifest().path(&entry.dataset)).unwrap();
        let model = runtime.model(&entry.key, 10).unwrap();
        let mut xs = Vec::new();
        for i in 0..n {
            xs.extend_from_slice(ds.sample(i));
        }
        let got = model.run_batch(&xs, n).unwrap();
        for (g_row, e_row) in got.iter().zip(&expected) {
            for (g, e) in g_row.iter().zip(e_row) {
                assert!(
                    (g - e).abs() < 1e-4,
                    "{}: pjrt {g} vs golden {e}",
                    entry.key
                );
            }
        }
    }
}

#[test]
fn float_engine_matches_pjrt() {
    let Some(dir) = artifacts() else { return };
    let runtime = Runtime::new(&dir).unwrap();
    for key in ["top_gru", "flavor_lstm", "quickdraw_gru"] {
        let entry = runtime.manifest().model(key).unwrap().clone();
        let weights = Weights::load(runtime.manifest().path(&entry.weights)).unwrap();
        let float_engine = FloatEngine::new(&weights).unwrap();
        let ds = Dataset::load(runtime.manifest().path(&entry.dataset)).unwrap();
        let model = runtime.model(key, 1).unwrap();
        for i in 0..5 {
            let x = ds.sample(i);
            let pjrt = &model.run_batch(x, 1).unwrap()[0];
            let float = float_engine.forward(x);
            for (a, b) in pjrt.iter().zip(&float) {
                assert!(
                    (a - b).abs() < 2e-4,
                    "{key} sample {i}: pjrt {a} vs float {b}"
                );
            }
        }
    }
}

#[test]
fn fixed_engine_high_precision_tracks_float_on_real_models() {
    // The right fidelity metric is the paper's own (AUC): per-sample
    // outputs may drift (activation-LUT error compounds across the
    // recurrence — real hls4ml behaviour), but at 16 fractional bits the
    // quantized AUC must match float to well under 1%, and the mean
    // output deviation must stay small.
    let Some(dir) = artifacts() else { return };
    for key in ["top_lstm", "flavor_gru"] {
        let weights =
            Weights::load(dir.join("weights").join(format!("{key}.json"))).unwrap();
        let float_engine = FloatEngine::new(&weights).unwrap();
        let fixed_engine = FixedEngine::new(
            &weights,
            QuantConfig::ptq(FixedSpec::new(24, 8)),
        )
        .unwrap();
        let benchmark = key.split('_').next().unwrap();
        let ds = Dataset::load(dir.join("data").join(format!("{benchmark}_test.bin")))
            .unwrap()
            .truncated(300);
        let mut sum_dev = 0.0f64;
        let mut count = 0usize;
        let mut probs_f = Vec::with_capacity(ds.n);
        let mut probs_q = Vec::with_capacity(ds.n);
        for i in 0..ds.n {
            let yf = float_engine.forward(ds.sample(i));
            let yq = fixed_engine.forward(ds.sample(i));
            for (a, b) in yf.iter().zip(&yq) {
                sum_dev += (a - b).abs() as f64;
                count += 1;
            }
            probs_f.push(yf);
            probs_q.push(yq);
        }
        let mean_dev = sum_dev / count as f64;
        assert!(mean_dev < 0.02, "{key}: mean output deviation {mean_dev}");
        let auc_f = metrics::mean_auc(&probs_f, ds.labels(), ds.n_classes);
        let auc_q = metrics::mean_auc(&probs_q, ds.labels(), ds.n_classes);
        assert!(
            (auc_f - auc_q).abs() < 0.01,
            "{key}: AUC float {auc_f:.4} vs fixed {auc_q:.4}"
        );
    }
}

#[test]
fn quantized_auc_shape_on_real_model() {
    // Fig. 2's mechanism on the real trained top-tagging GRU: AUC ratio
    // low at 2 fractional bits, ≈1 at 12.
    let Some(dir) = artifacts() else { return };
    let weights = Weights::load(dir.join("weights/top_gru.json")).unwrap();
    let ds = Dataset::load(dir.join("data/top_test.bin"))
        .unwrap()
        .truncated(400);
    let float_engine = FloatEngine::new(&weights).unwrap();
    let auc = |engine: &dyn Engine| -> f64 {
        let probs: Vec<Vec<f32>> =
            (0..ds.n).map(|i| engine.forward(ds.sample(i))).collect();
        metrics::mean_auc(&probs, ds.labels(), ds.n_classes)
    };
    let auc_float = auc(&float_engine);
    assert!(auc_float > 0.95, "float AUC {auc_float}");

    let lo_engine = FixedEngine::new(
        &weights,
        QuantConfig::ptq(FixedSpec::new(8, 6)), // 2 fractional bits
    )
    .unwrap();
    let hi_engine = FixedEngine::new(
        &weights,
        QuantConfig::ptq(FixedSpec::new(18, 6)), // 12 fractional bits
    )
    .unwrap();
    let (lo, hi) = (auc(&lo_engine), auc(&hi_engine));
    assert!(hi / auc_float > 0.99, "hi ratio {}", hi / auc_float);
    assert!(lo < hi, "low precision {lo} should trail {hi}");
}

#[test]
fn batch_padding_is_consistent() {
    // Running n samples through a larger bucket (zero-padded) must give
    // the same outputs as the exact-size bucket.
    let Some(dir) = artifacts() else { return };
    let runtime = Runtime::new(&dir).unwrap();
    let ds = Dataset::load(dir.join("data/top_test.bin")).unwrap();
    let m1 = runtime.model("top_gru", 1).unwrap();
    let m10 = runtime.model("top_gru", 10).unwrap();
    let mut xs = Vec::new();
    for i in 0..3 {
        xs.extend_from_slice(ds.sample(i));
    }
    let padded = m10.run_batch(&xs, 3).unwrap();
    assert_eq!(padded.len(), 3);
    for i in 0..3 {
        let single = &m1.run_batch(ds.sample(i), 1).unwrap()[0];
        for (a, b) in single.iter().zip(&padded[i]) {
            assert!((a - b).abs() < 1e-5, "sample {i}: {a} vs {b}");
        }
    }
}

#[test]
fn bucket_selection() {
    let Some(dir) = artifacts() else { return };
    let runtime = Runtime::new(&dir).unwrap();
    assert_eq!(runtime.bucket_for("top_gru", 1).unwrap(), 1);
    assert_eq!(runtime.bucket_for("top_gru", 2).unwrap(), 10);
    assert_eq!(runtime.bucket_for("top_gru", 10).unwrap(), 10);
    assert_eq!(runtime.bucket_for("top_gru", 55).unwrap(), 100);
    // Larger than the largest bucket: clamps to it (caller splits).
    assert_eq!(runtime.bucket_for("top_gru", 500).unwrap(), 100);
}

#[test]
fn serving_e2e_with_fixed_engine() {
    // Full coordinator pipeline with the bit-accurate engine as the
    // backend, consuming whole batches through the parallel
    // `forward_batch` datapath (EngineRunner): no event lost
    // (completed + dropped == generated), online accuracy well above
    // chance.
    let Some(dir) = artifacts() else { return };
    let weights = Weights::load(dir.join("weights/top_gru.json")).unwrap();

    let cfg = ServerConfig {
        workers: 2,
        queue_capacity: 16_384,
        batcher: BatcherConfig {
            max_batch: 10,
            max_wait: std::time::Duration::from_micros(100),
        },
        source: SourceConfig {
            rate_hz: 50_000.0,
            poisson: true,
            n_events: 5_000,
        },
    };
    let generator = generators::for_benchmark("top", 42).unwrap();
    let weights2 = weights.clone();
    let report = Server::run(cfg, generator, move || {
        let engine = FixedEngine::new(
            &weights2,
            QuantConfig::ptq(FixedSpec::new(16, 6)),
        )?
        .with_parallelism(2);
        Ok(Box::new(rnn_hls::coordinator::EngineRunner::new(
            Box::new(engine),
            10,
        )) as Box<dyn rnn_hls::coordinator::BatchRunner>)
    })
    .unwrap();
    assert_eq!(report.generated, 5_000);
    assert_eq!(report.completed + report.dropped, 5_000);
    assert!(report.completed > 1_000, "completed {}", report.completed);
    assert!(report.accuracy > 0.8, "accuracy {}", report.accuracy);
}
