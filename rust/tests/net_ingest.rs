//! Network ingest acceptance suite — the wire protocol and the TCP
//! front-end over the live `Session`:
//!
//! (a) **framing round-trips**: every frame type survives
//!     encode → decode and write_frame → read_frame bitwise, including
//!     non-finite floats (compared by bit pattern);
//! (b) **garbage never panics**: truncation at every byte boundary, bad
//!     magic/version/type, oversized length claims, lying counts, and
//!     seeded random byte soup all land in typed `FrameError`s;
//! (c) **the socket is semantics-free**: a request stream served over
//!     TCP produces outputs bitwise identical to the same stream
//!     submitted in-process, for 1 and 4 shards;
//! (d) **typed backpressure end-to-end**: a full shard queue answers
//!     `SHED` frames, connection admission control answers `BUSY`, and
//!     the client-side ledger balances (`sent == responses + sheds`);
//! (e) **drain-then-close**: shutdown with requests still in flight
//!     writes every deliverable reply before closing the socket;
//! (f) **metrics grammar**: the metrics endpoint emits the documented
//!     line-oriented snapshot, terminated by `end`.

use std::collections::HashMap;
use std::io::Read;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use rnn_hls::api::{BackendKind, ErrorCode, ServingSpec, Session};
use rnn_hls::coordinator::BatchRunner;
use rnn_hls::ingest::wire::{
    read_frame, write_frame, Frame, FrameError, WireError, WireRequest,
    WireResponse, HEADER_LEN, MAX_PAYLOAD, WIRE_MAGIC, WIRE_VERSION,
};
use rnn_hls::util::sync::mpsc::{self, Receiver};
use rnn_hls::util::sync::{lock_or_recover, Mutex};

const FEATURE_LEN: usize = 8;

// ------------------------------------------------------------ test rig

/// Deterministic per-row output: a pure function of the features, so
/// batch composition, shard routing, and transport cannot change it.
fn pure_output(row: &[f32]) -> Vec<f32> {
    let sum: f32 = row.iter().sum();
    vec![row[0] * 0.5 + row[1], sum * 0.125]
}

struct PureRunner;

impl BatchRunner for PureRunner {
    fn max_batch(&self) -> usize {
        8
    }
    fn run(&mut self, xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let stride = xs.len() / n.max(1);
        Ok((0..n)
            .map(|i| pure_output(&xs[i * stride..(i + 1) * stride]))
            .collect())
    }
}

/// Features for event `i` — the index embedded exactly in f32.
fn features_for(i: u64) -> Vec<f32> {
    let mut features = vec![0.0f32; FEATURE_LEN];
    features[0] = i as f32;
    features[1] = (i % 13) as f32 * 0.25;
    features
}

fn listener_spec(shards: usize) -> ServingSpec {
    ServingSpec {
        engine: BackendKind::Float, // factory overrides; field unused
        shards,
        workers: 2,
        queue_capacity: 16_384,
        ..ServingSpec::default()
    }
    .with_batcher(8, Duration::from_micros(100))
    .with_listener("127.0.0.1:0".parse().unwrap())
}

fn start_pure(spec: &ServingSpec) -> Session {
    Session::start(spec, |_shard| {
        Ok(Box::new(PureRunner) as Box<dyn BatchRunner>)
    })
    .unwrap()
}

/// Tiny deterministic generator for the property-style framing tests.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

// --------------------------------------------------- (a) framing round-trip

/// Every frame type round-trips through both the buffer API
/// (encode/decode) and the stream API (write_frame/read_frame), over a
/// seeded sweep of shapes including empty and large float vectors.
#[test]
fn frames_round_trip_bitwise() {
    let mut rng = Rng(0xF4A3E);
    let mut frames = Vec::new();
    for round in 0..200u64 {
        let n = (rng.next() % 65) as usize;
        let floats = |rng: &mut Rng| -> Vec<f32> {
            (0..n)
                .map(|_| (rng.next() % 100_000) as f32 * 0.0625 - 3125.0)
                .collect()
        };
        frames.push(match round % 3 {
            0 => Frame::Request(WireRequest {
                seq: rng.next(),
                label: rng.next() as u32,
                features: floats(&mut rng),
            }),
            1 => Frame::Response(WireResponse {
                seq: rng.next(),
                id: rng.next(),
                shard: rng.next() as u32,
                output: floats(&mut rng),
            }),
            _ => Frame::Error(WireError {
                seq: rng.next(),
                code: ErrorCode::from_u8((round % 4) as u8 + 1).unwrap(),
            }),
        });
    }
    for frame in &frames {
        let bytes = frame.encode();
        let (decoded, used) = Frame::decode(&bytes).unwrap();
        assert_eq!(&decoded, frame);
        assert_eq!(used, bytes.len());
    }
    // Stream API: all frames concatenated through one reader.
    let mut stream = Vec::new();
    for frame in &frames {
        write_frame(&mut stream, frame).unwrap();
    }
    let mut reader = &stream[..];
    for frame in &frames {
        let got = read_frame(&mut reader).unwrap().unwrap();
        assert_eq!(&got, frame);
    }
    assert!(read_frame(&mut reader).unwrap().is_none(), "clean EOF");
}

/// Non-finite floats survive by bit pattern (PartialEq would lie about
/// NaN, so compare `to_bits`).
#[test]
fn non_finite_floats_round_trip_by_bits() {
    let payload = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.0];
    let frame = Frame::Request(WireRequest {
        seq: 9,
        label: 3,
        features: payload.clone(),
    });
    let (decoded, _) = Frame::decode(&frame.encode()).unwrap();
    let Frame::Request(got) = decoded else {
        panic!("wrong frame type");
    };
    let want: Vec<u32> = payload.iter().map(|x| x.to_bits()).collect();
    let have: Vec<u32> = got.features.iter().map(|x| x.to_bits()).collect();
    assert_eq!(want, have);
}

// ------------------------------------------------- (b) garbage rejection

/// Truncation at *every* byte boundary of a valid frame is a typed
/// `Truncated`, never a panic or a bogus parse.
#[test]
fn truncation_at_every_boundary_is_typed() {
    let frame = Frame::Response(WireResponse {
        seq: 42,
        id: 7,
        shard: 1,
        output: vec![1.0, -2.5, 0.125],
    });
    let bytes = frame.encode();
    for cut in 0..bytes.len() {
        let err = Frame::decode(&bytes[..cut]).unwrap_err();
        assert!(
            matches!(err, FrameError::Truncated),
            "cut at {cut}: {err}"
        );
        // The stream reader agrees: EOF inside a frame is Truncated,
        // except the zero-byte case which is a clean end-of-stream.
        let mut reader = &bytes[..cut];
        match read_frame(&mut reader) {
            Ok(None) => assert_eq!(cut, 0, "only empty input is clean EOF"),
            Ok(Some(_)) => panic!("cut at {cut}: parsed a partial frame"),
            Err(e) => {
                assert!(matches!(e, FrameError::Truncated), "cut {cut}: {e}")
            }
        }
    }
}

/// Corrupted headers land in their specific error variants; a length
/// claim beyond the cap is rejected before any allocation.
#[test]
fn corrupted_headers_are_typed() {
    let good = Frame::Error(WireError {
        seq: 1,
        code: ErrorCode::Shed,
    })
    .encode();

    let mut bad_magic = good.clone();
    bad_magic[0] ^= 0xFF;
    assert!(matches!(
        Frame::decode(&bad_magic).unwrap_err(),
        FrameError::BadMagic(_)
    ));

    let mut bad_version = good.clone();
    bad_version[2] = WIRE_VERSION + 1;
    assert!(matches!(
        Frame::decode(&bad_version).unwrap_err(),
        FrameError::BadVersion(_)
    ));

    let mut bad_type = good.clone();
    bad_type[3] = 9;
    assert!(matches!(
        Frame::decode(&bad_type).unwrap_err(),
        FrameError::BadType(9)
    ));

    let mut oversized = good.clone();
    oversized[4..8].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
    assert!(matches!(
        Frame::decode(&oversized).unwrap_err(),
        FrameError::Oversized(_)
    ));

    // Unknown error code byte in an otherwise valid Error frame.
    let mut bad_code = good.clone();
    let last = bad_code.len() - 1;
    bad_code[last] = 200;
    assert!(matches!(
        Frame::decode(&bad_code).unwrap_err(),
        FrameError::BadPayload(_)
    ));

    // Trailing bytes after the payload fields.
    let mut trailing = good.clone();
    trailing.extend_from_slice(&[0u8; 3]);
    let grown = (trailing.len() - HEADER_LEN) as u32;
    trailing[4..8].copy_from_slice(&grown.to_le_bytes());
    assert!(matches!(
        Frame::decode(&trailing).unwrap_err(),
        FrameError::BadPayload(_)
    ));
}

/// Seeded byte soup: the decoder must return *something typed* for any
/// input (this test passing at all is the no-panic property).
#[test]
fn random_garbage_never_panics() {
    let mut rng = Rng(0xBAD_BEEF);
    for _ in 0..500 {
        let len = (rng.next() % 96) as usize;
        let mut bytes: Vec<u8> =
            (0..len).map(|_| rng.next() as u8).collect();
        let _ = Frame::decode(&bytes);
        let mut reader = &bytes[..];
        let _ = read_frame(&mut reader);
        // Same soup behind a valid magic/version prefix, exercising the
        // deeper paths.
        if bytes.len() >= 3 {
            bytes[..2].copy_from_slice(&WIRE_MAGIC.to_le_bytes());
            bytes[2] = WIRE_VERSION;
            let _ = Frame::decode(&bytes);
            let mut reader = &bytes[..];
            let _ = read_frame(&mut reader);
        }
    }
}

// -------------------------------------- (c) socket ≡ in-process, bitwise

/// Submit `n` events in-process and collect outputs keyed by event
/// index (via the session-id → index map built at submit time).
fn serve_in_process(shards: usize, n: u64) -> HashMap<u64, Vec<f32>> {
    let spec = listener_spec(shards); // listener unused on this path
    let session = start_pure(&spec);
    let mut index_of = HashMap::new();
    for i in 0..n {
        let request = session.prepare_event(features_for(i), (i % 2) as u32);
        index_of.insert(request.id, i);
        session.submit(request).unwrap();
    }
    let mut outputs = HashMap::new();
    for _ in 0..n {
        let completion = session.recv().expect("fabric alive");
        let index = index_of[&completion.id];
        assert!(outputs
            .insert(index, completion.output.to_vec())
            .is_none());
    }
    let report = session.shutdown().unwrap();
    assert_eq!(report.merged.completed, n);
    assert_eq!(report.merged.dropped, 0);
    outputs
}

/// Submit the same `n` events over TCP and collect outputs keyed by the
/// client-chosen `seq` (which *is* the event index).
fn serve_over_tcp(shards: usize, n: u64) -> HashMap<u64, Vec<f32>> {
    let session = start_pure(&listener_spec(shards));
    let server = session.serve_listener().unwrap();

    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    for i in 0..n {
        let frame = Frame::Request(WireRequest {
            seq: i,
            label: (i % 2) as u32,
            features: features_for(i),
        });
        write_frame(&mut stream, &frame).unwrap();
    }
    stream.shutdown(std::net::Shutdown::Write).unwrap();

    let mut outputs = HashMap::new();
    loop {
        match read_frame(&mut stream).expect("live connection") {
            Some(Frame::Response(resp)) => {
                assert!((resp.shard as usize) < shards);
                assert!(
                    outputs.insert(resp.seq, resp.output).is_none(),
                    "seq {} answered twice",
                    resp.seq
                );
            }
            Some(other) => panic!("unexpected frame {other:?}"),
            None => break, // server drained our replies, then EOF
        }
    }

    let report = server.shutdown().unwrap();
    assert_eq!(report.requests, n);
    assert_eq!(report.replies, n);
    assert_eq!(report.serving.merged.completed, n);
    assert_eq!(report.serving.merged.dropped, 0);
    assert_eq!(report.stranded, 0, "no orphaned reply routes");
    assert_eq!(
        report.serving.merged.generated,
        report.serving.merged.completed + report.serving.merged.dropped,
        "the accounting identity holds across the socket"
    );
    outputs
}

/// (c) The TCP path is semantics-free: bitwise-identical outputs to the
/// in-process submit path, for 1 and 4 shards.
#[test]
fn tcp_serving_is_bitwise_identical_to_in_process() {
    const N: u64 = 500;
    for shards in [1usize, 4] {
        let in_process = serve_in_process(shards, N);
        let over_tcp = serve_over_tcp(shards, N);
        assert_eq!(in_process.len(), N as usize);
        assert_eq!(
            in_process, over_tcp,
            "shards={shards}: socket outputs must match in-process"
        );
    }
}

// ------------------------------------------- (d) typed backpressure

/// Runner that parks on a gate so the queue can be filled
/// deterministically (same rig as tests/session_api.rs).
struct BlockingRunner {
    gate: Receiver<()>,
}

impl BatchRunner for BlockingRunner {
    fn max_batch(&self) -> usize {
        1
    }
    fn run(&mut self, _xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let _ = self.gate.recv();
        Ok(vec![vec![0.5]; n])
    }
}

/// (d) A full shard queue answers typed `SHED` frames over the wire,
/// and the client-side books balance exactly: every request is either
/// answered with a response or a shed — none vanish.
#[test]
fn queue_full_sheds_over_tcp() {
    const SENT: u64 = 50;
    let spec = ServingSpec {
        engine: BackendKind::Float,
        workers: 1,
        queue_capacity: 1,
        ..ServingSpec::default()
    }
    .with_batcher(1, Duration::ZERO)
    .with_listener("127.0.0.1:0".parse().unwrap());
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let slot = Arc::new(Mutex::new(Some(gate_rx)));
    let session = Session::start(&spec, move |_shard| {
        let gate = lock_or_recover(&slot)
            .take()
            .expect("exactly one worker builds a runner");
        Ok(Box::new(BlockingRunner { gate }) as Box<dyn BatchRunner>)
    })
    .unwrap();
    let server = session.serve_listener().unwrap();

    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    for i in 0..SENT {
        let frame = Frame::Request(WireRequest {
            seq: i,
            label: 0,
            features: features_for(i),
        });
        write_frame(&mut stream, &frame).unwrap();
    }
    // Wait until every request has touched the queue (each submit
    // counts `generated` whether admitted or shed) *before* releasing
    // the wedged worker — otherwise a fast engine could drain the
    // 1-deep queue between frames and nothing would shed.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while server.snapshot().merged.generated < SENT {
        assert!(
            std::time::Instant::now() < deadline,
            "requests never reached the queue"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    drop(gate_tx);
    stream.shutdown(std::net::Shutdown::Write).unwrap();

    let (mut responses, mut sheds) = (0u64, 0u64);
    loop {
        match read_frame(&mut stream).expect("live connection") {
            Some(Frame::Response(_)) => responses += 1,
            Some(Frame::Error(err)) => {
                assert_eq!(err.code, ErrorCode::Shed, "only shed expected");
                assert!(err.seq < SENT, "shed echoes the request's seq");
                sheds += 1;
            }
            Some(other) => panic!("unexpected frame {other:?}"),
            None => break,
        }
    }
    assert!(sheds >= 1, "a 1-deep queue behind a wedged worker must shed");
    assert!(responses >= 1, "admitted requests must still be served");
    assert_eq!(responses + sheds, SENT, "client ledger must balance");

    let report = server.shutdown().unwrap();
    assert_eq!(report.requests, SENT);
    // Server-side identity: every attempt counted generated, every
    // shed is a counted drop, and the two ledgers agree.
    assert_eq!(report.serving.merged.generated, SENT);
    assert_eq!(report.serving.merged.completed, responses);
    assert_eq!(report.serving.merged.dropped, sheds);
    assert_eq!(report.wire_errors, sheds);
}

/// (d) Beyond `max_connections` accepted-but-unfinished connections the
/// accept loop answers `BUSY` — connection-level admission control,
/// before anything touches the session.
#[test]
fn connection_flood_is_answered_busy() {
    let spec = listener_spec(1).with_max_connections(1);
    let session = start_pure(&spec);
    let server = session.serve_listener().unwrap();

    // First connection occupies the only slot (held open, idle).
    let holder = TcpStream::connect(server.local_addr()).unwrap();
    // Let the accept loop admit it before the second arrives.
    std::thread::sleep(Duration::from_millis(200));

    let mut second = TcpStream::connect(server.local_addr()).unwrap();
    second
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    match read_frame(&mut second).expect("live connection") {
        Some(Frame::Error(err)) => {
            assert_eq!(err.code, ErrorCode::Busy);
            assert_eq!(err.seq, 0, "connection-level: no request seq");
        }
        other => panic!("expected BUSY, got {other:?}"),
    }
    drop(second);
    drop(holder);

    let report = server.shutdown().unwrap();
    assert_eq!(report.refused, 1);
    assert_eq!(report.accepted, 1);
}

/// (d) Garbage bytes on an accepted connection answer `MALFORMED` and
/// drop the connection — the serving fabric is untouched.
#[test]
fn garbage_bytes_answer_malformed() {
    let session = start_pure(&listener_spec(1));
    let server = session.serve_listener().unwrap();

    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    std::io::Write::write_all(&mut stream, b"GET / HTTP/1.1\r\n\r\n").unwrap();
    match read_frame(&mut stream).expect("live connection") {
        Some(Frame::Error(err)) => {
            assert_eq!(err.code, ErrorCode::Malformed)
        }
        other => panic!("expected MALFORMED, got {other:?}"),
    }
    // The server hangs up after the answer.
    assert!(matches!(read_frame(&mut stream), Ok(None) | Err(_)));

    let report = server.shutdown().unwrap();
    assert_eq!(report.malformed, 1);
    assert_eq!(report.serving.merged.generated, 0, "fabric untouched");
}

// ------------------------------------------------ (e) drain-then-close

/// (e) Shutdown with requests still wedged in the engine: the edge
/// waits (accepts closed, session draining) and every in-flight reply
/// reaches the client before its socket closes — the drain-then-close
/// protocol, observed from outside the process.
#[test]
fn shutdown_drains_in_flight_replies() {
    const IN_FLIGHT: u64 = 4;
    let spec = ServingSpec {
        engine: BackendKind::Float,
        workers: 1,
        queue_capacity: 64,
        ..ServingSpec::default()
    }
    .with_batcher(1, Duration::ZERO)
    .with_listener("127.0.0.1:0".parse().unwrap());
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let slot = Arc::new(Mutex::new(Some(gate_rx)));
    let session = Session::start(&spec, move |_shard| {
        let gate = lock_or_recover(&slot)
            .take()
            .expect("exactly one worker builds a runner");
        Ok(Box::new(BlockingRunner { gate }) as Box<dyn BatchRunner>)
    })
    .unwrap();
    let server = session.serve_listener().unwrap();
    let addr = server.local_addr();

    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    for i in 0..IN_FLIGHT {
        let frame = Frame::Request(WireRequest {
            seq: i,
            label: 0,
            features: features_for(i),
        });
        write_frame(&mut stream, &frame).unwrap();
    }
    // Wait until the edge has admitted all of them into the session.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while server.snapshot().merged.generated < IN_FLIGHT {
        assert!(
            std::time::Instant::now() < deadline,
            "requests never admitted"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    // Shut down with the engine still wedged; release it shortly after,
    // from another thread — shutdown must block until the replies flow.
    let release = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(200));
        drop(gate_tx);
    });
    let report = server.shutdown().unwrap();
    release.join().unwrap();

    // Every in-flight reply was written before the socket closed.
    let mut got = 0u64;
    loop {
        match read_frame(&mut stream).expect("live connection") {
            Some(Frame::Response(_)) => got += 1,
            Some(other) => panic!("unexpected frame {other:?}"),
            None => break,
        }
    }
    assert_eq!(got, IN_FLIGHT, "drain-then-close must deliver replies");
    assert_eq!(report.replies, IN_FLIGHT);
    assert_eq!(report.serving.merged.completed, IN_FLIGHT);
    assert_eq!(report.stranded, 0);
}

// --------------------------------------------------- (f) metrics grammar

/// (f) The metrics endpoint answers one snapshot in the documented
/// grammar: `key value` lines, floats parseable, `end` terminator.
#[test]
fn metrics_endpoint_speaks_the_grammar() {
    const N: u64 = 100;
    let spec = listener_spec(1)
        .with_metrics_listener("127.0.0.1:0".parse().unwrap());
    let session = start_pure(&spec);
    let server = session.serve_listener().unwrap();
    let metrics_addr = server.metrics_addr().expect("metrics bound");

    // Serve a little traffic so the counters are non-trivial.
    let mut stream = TcpStream::connect(server.local_addr()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    for i in 0..N {
        let frame = Frame::Request(WireRequest {
            seq: i,
            label: 0,
            features: features_for(i),
        });
        write_frame(&mut stream, &frame).unwrap();
    }
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    while read_frame(&mut stream).expect("live connection").is_some() {}

    let mut metrics = TcpStream::connect(metrics_addr).unwrap();
    metrics
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut body = String::new();
    metrics.read_to_string(&mut body).unwrap();

    let lines: Vec<&str> = body.lines().collect();
    assert_eq!(lines.last(), Some(&"end"), "grammar: end terminator");
    let mut seen = HashMap::new();
    for line in &lines[..lines.len() - 1] {
        let mut parts = line.split_whitespace();
        let key = parts.next().expect("key on every line");
        if key == "backend" {
            continue; // homogeneous session: not expected, but legal
        }
        let value = parts.next().expect("value on every line");
        assert!(parts.next().is_none(), "grammar: key value only: {line}");
        assert!(
            value.parse::<f64>().is_ok(),
            "grammar: numeric value: {line}"
        );
        seen.insert(key.to_string(), value.to_string());
    }
    for key in [
        "generated",
        "completed",
        "dropped",
        "shed_completions",
        "connections_accepted",
        "connections_refused",
        "p50_us",
        "p99_us",
        "throughput_hz",
        "pool_hits",
        "pool_misses",
        "pool_occupancy",
    ] {
        assert!(seen.contains_key(key), "grammar: missing {key}\n{body}");
    }
    assert_eq!(seen["generated"], N.to_string());
    assert_eq!(seen["completed"], N.to_string());
    assert_eq!(seen["connections_accepted"], "1");

    server.shutdown().unwrap();
}

// ------------------------------------------------------- spec plumbing

/// A session whose spec named no listener refuses `serve_listener` with
/// the uniform error style, and the typed error codes line up with the
/// in-process rejections they mirror.
#[test]
fn serve_listener_requires_a_spec_listener() {
    let spec = ServingSpec {
        engine: BackendKind::Float,
        ..ServingSpec::default()
    };
    let session = start_pure(&spec);
    let err = session.serve_listener().unwrap_err().to_string();
    assert!(err.contains("no listener"), "{err}");

    // The wire codes are the in-process codes: one mapping, both sides.
    assert_eq!(ErrorCode::Shed as u8, 1);
    assert_eq!(ErrorCode::Closed as u8, 2);
    let spec = ServingSpec {
        engine: BackendKind::Float,
        ..ServingSpec::default()
    };
    let session = start_pure(&spec);
    let request = session.prepare_event(features_for(0), 0);
    session.submit(request).unwrap();
    let _ = session.recv();
    let report = session.shutdown().unwrap();
    assert_eq!(report.merged.completed, 1);
}
