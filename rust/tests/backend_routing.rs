//! Mixed-backend equivalence suite: a heterogeneous `ShardedServer`
//! (fixed-point trigger tier + float offline tier behind model-key tier
//! routing) must produce per-request outputs **bitwise identical** to
//! routing the same seeded stream through each backend's standalone
//! `Server` — heterogeneity, like sharding and batching, is a deployment
//! lever with zero semantic footprint.
//!
//! Method: a deterministic top-GRU-shaped generator encodes the event
//! index into the features, recording runners key every output by that
//! embedded id, and the tier mix's pure `(seed, id)` stamp tells the
//! test which backend the mixed session owed each request to.  The
//! standalone runs serve the *whole* stream through one backend, so for
//! every id the mixed output can be compared against the matching
//! standalone output.  Queues are sized so nothing drops (a drop would
//! shrink the comparison), and every run asserts `dropped == 0` first.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use rnn_hls::coordinator::{
    BatchRunner, BatcherConfig, EngineRunner, Request, Router, Server,
    ServerConfig, ShardPolicy, ShardedConfig, ShardedServer, SourceConfig,
    TierMix,
};
use rnn_hls::data::generators::{Event, Generator};
use rnn_hls::fixed::FixedSpec;
use rnn_hls::model::{zoo, Cell, Weights};
use rnn_hls::nn::{BackendCtx, BackendSpec};
use rnn_hls::util::sync::{lock_or_recover, Mutex};

const N_EVENTS: usize = 1_200;
const TIER_SEED: u64 = 0xC1A5;
/// top benchmark dimensions: seq 20 × 6 features.
const STRIDE: usize = 20 * 6;

/// Emits top-GRU-shaped events whose first feature is the event index
/// (exact in f32 at these stream sizes); the source assigns
/// `Request::id` in the same order, so runners recover the id from the
/// features alone.  The remaining features vary with the id so outputs
/// genuinely differ per request and per backend.
struct IdGen {
    next: u64,
}

impl Generator for IdGen {
    fn name(&self) -> &'static str {
        "id-top"
    }
    fn seq_len(&self) -> usize {
        20
    }
    fn n_feat(&self) -> usize {
        6
    }
    fn n_classes(&self) -> usize {
        1
    }
    fn generate(&mut self) -> Event {
        let id = self.next;
        self.next += 1;
        let mut features = vec![0.0f32; STRIDE];
        features[0] = id as f32;
        for (k, f) in features.iter_mut().enumerate().skip(1) {
            *f = ((id * 31 + k as u64 * 17) % 41) as f32 / 41.0 - 0.5;
        }
        Event {
            features,
            label: (id % 2) as u32,
        }
    }
}

/// Wraps a real engine runner, recording (embedded id → output) for
/// every sample served.
struct RecordingRunner {
    inner: Box<dyn BatchRunner>,
    outputs: Arc<Mutex<HashMap<u64, Vec<f32>>>>,
}

impl BatchRunner for RecordingRunner {
    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }
    fn run(&mut self, xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let out = self.inner.run(xs, n)?;
        let mut map = lock_or_recover(&self.outputs);
        for (i, probs) in out.iter().enumerate() {
            let id = xs[i * STRIDE] as u64;
            anyhow::ensure!(
                map.insert(id, probs.clone()).is_none(),
                "request {id} served twice"
            );
        }
        Ok(out)
    }
}

/// Build the named backend's engine runner over shared synthetic
/// weights: the same seed on every call, so each run constructs the
/// identical engine.
fn engine_runner(backend: &str) -> anyhow::Result<Box<dyn BatchRunner>> {
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let weights = Weights::synthetic(&arch, 0x0B5E55);
    let engine = BackendSpec::parse(backend)?.build(&BackendCtx {
        weights: &weights,
        fixed_spec: FixedSpec::new(16, 6),
        parallelism: 1,
    })?;
    Ok(Box::new(EngineRunner::new(engine, 8)))
}

fn config(workers: usize) -> ServerConfig {
    ServerConfig {
        workers,
        queue_capacity: 16_384, // > N_EVENTS: nothing can drop
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(100),
        },
        source: SourceConfig {
            rate_hz: 2_000_000.0, // saturating: pacing never the bottleneck
            poisson: false,
            n_events: N_EVENTS,
        },
    }
}

/// Serve the stream through the heterogeneous two-backend session.
fn run_mixed(
    mix: &TierMix,
) -> (HashMap<u64, Vec<f32>>, rnn_hls::coordinator::ShardedReport) {
    let outputs = Arc::new(Mutex::new(HashMap::new()));
    let sink = outputs.clone();
    let backends = ["fixed", "float"];
    let report = ShardedServer::run(
        ShardedConfig {
            shards: 2,
            policy: ShardPolicy::ModelKey,
            tier_mix: mix.clone(),
            shard_backends: backends.iter().map(|b| b.to_string()).collect(),
            shard_batchers: Vec::new(),
            server: config(2),
        },
        Box::new(IdGen { next: 0 }),
        move |shard| {
            Ok(Box::new(RecordingRunner {
                inner: engine_runner(backends[shard])?,
                outputs: sink.clone(),
            }) as Box<dyn BatchRunner>)
        },
    )
    .unwrap();
    let map = Arc::try_unwrap(outputs).unwrap().into_inner().unwrap();
    (map, report)
}

/// Serve the whole stream through one backend's standalone `Server`.
fn run_standalone(backend: &'static str) -> HashMap<u64, Vec<f32>> {
    let outputs = Arc::new(Mutex::new(HashMap::new()));
    let sink = outputs.clone();
    let report =
        Server::run(config(2), Box::new(IdGen { next: 0 }), move || {
            Ok(Box::new(RecordingRunner {
                inner: engine_runner(backend)?,
                outputs: sink.clone(),
            }) as Box<dyn BatchRunner>)
        })
        .unwrap();
    assert_eq!(report.dropped, 0, "standalone {backend} dropped events");
    Arc::try_unwrap(outputs).unwrap().into_inner().unwrap()
}

/// The acceptance contract: every request served by the mixed session is
/// bitwise identical to the same request served by its tier's backend
/// standalone, and the per-backend roll-up partitions the totals.
#[test]
fn mixed_backend_outputs_match_standalone_backends() {
    let mix = TierMix::new(&[0.5, 0.5], TIER_SEED).unwrap();
    let (mixed, report) = run_mixed(&mix);
    assert_eq!(report.merged.dropped, 0);
    assert_eq!(report.merged.completed, N_EVENTS as u64);
    assert_eq!(mixed.len(), N_EVENTS);

    let fixed_map = run_standalone("fixed");
    let float_map = run_standalone("float");
    assert_eq!(fixed_map.len(), N_EVENTS);
    assert_eq!(float_map.len(), N_EVENTS);

    // The backends must actually disagree somewhere, or the comparison
    // below is vacuous (quantization makes them differ on this stream).
    assert!(
        (0..N_EVENTS as u64).any(|id| fixed_map[&id] != float_map[&id]),
        "fixed and float produced identical outputs — vacuous test"
    );

    let mut per_tier = [0u64; 2];
    for id in 0..N_EVENTS as u64 {
        let tier = mix.stamp(id) as usize;
        per_tier[tier] += 1;
        let want = if tier == 0 {
            &fixed_map[&id]
        } else {
            &float_map[&id]
        };
        assert_eq!(&mixed[&id], want, "request {id} (tier {tier})");
    }
    assert!(
        per_tier[0] > 100 && per_tier[1] > 100,
        "both tiers must carry real traffic: {per_tier:?}"
    );

    // Per-backend roll-up: exact partition of the merged totals, keyed
    // by the configured labels.
    assert_eq!(report.per_backend.len(), 2);
    assert_eq!(report.per_backend[0].backend, "fixed");
    assert_eq!(report.per_backend[1].backend, "float");
    for (tier, b) in report.per_backend.iter().enumerate() {
        assert_eq!(b.report.completed, per_tier[tier], "{}", b.backend);
        assert_eq!(b.report.dropped, 0, "{}", b.backend);
    }
    let completed: u64 =
        report.per_backend.iter().map(|b| b.report.completed).sum();
    assert_eq!(completed, report.merged.completed);
    assert!(report.render().contains("backend fixed"));
}

/// Router + tier stamping partition the stream deterministically by
/// seed: same seed, same shard for every id; the configured fractions
/// hold; a different seed yields a different partition.
#[test]
fn tier_stamping_partitions_deterministically_by_seed() {
    let mix_a = TierMix::new(&[0.9, 0.1], 42).unwrap();
    let mix_b = TierMix::new(&[0.9, 0.1], 42).unwrap();
    let mut router = Router::new(ShardPolicy::ModelKey, 2);
    let mut shares = [0u64; 2];
    let n = 10_000u64;
    for id in 0..n {
        let key = mix_a.stamp(id);
        assert_eq!(key, mix_b.stamp(id), "same seed must stamp identically");
        assert!(key < 2);
        let request = Request {
            id,
            features: Vec::new(),
            label: 0,
            route_key: key,
            enqueued_at: std::time::Instant::now(),
        };
        let shard = router.route(&request);
        assert_eq!(
            shard, key as usize,
            "model-key routing must follow the tier stamp"
        );
        shares[shard] += 1;
    }
    let share0 = shares[0] as f64 / n as f64;
    assert!((share0 - 0.9).abs() < 0.02, "tier-0 share {share0}");

    let other = TierMix::new(&[0.9, 0.1], 43).unwrap();
    assert!(
        (0..n).any(|id| other.stamp(id) != mix_a.stamp(id)),
        "a different seed must repartition the stream"
    );
}
