//! Property-style invariants for the HLS design-space explorer
//! (`hls::explore`): grid validity by construction, Pareto-front
//! soundness (no survivor dominated, every pruned row names a surviving
//! dominator), device-fit of survivors, budget queries as true minima
//! over the unpruned grid, byte-stable artifacts, consistency with the
//! paper's own configuration grids, and the measured-accuracy join.

use std::path::PathBuf;

use rnn_hls::fixed::FixedSpec;
use rnn_hls::hls::explore::{
    self, AccuracyJoin, ExploreConfig, ExploreResult, Filters,
    TRIGGER_BUDGET_NS,
};
use rnn_hls::hls::{
    latency, paper, resource, DesignError, Device, HlsConfig, HlsDesign,
    ReuseFactor, Strategy,
};
use rnn_hls::model::{zoo, Cell};

fn fixtures() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn top_gru_config() -> ExploreConfig {
    ExploreConfig::new(
        vec![zoo::arch("top", Cell::Gru).unwrap()],
        Device::KU115,
    )
}

fn top_gru_result(filters: Filters) -> ExploreResult {
    explore::explore(&top_gru_config(), &[], filters).unwrap()
}

/// Every grid point passes [`HlsConfig::validate`]: the divisor-aware
/// reuse ladder can never produce a configuration the design layer
/// rejects.
#[test]
fn grid_is_valid_by_construction() {
    for arch in zoo::all_archs() {
        let cfg = ExploreConfig::new(vec![arch.clone()], Device::U250);
        let grid = explore::build_grid(&cfg);
        assert!(!grid.is_empty(), "{}: empty grid", arch.key());
        for (a, hls_cfg) in grid {
            hls_cfg.validate(&a).unwrap();
        }
    }
}

/// Regression for the silently-wrong-fractional-DSP bug: a non-divisor
/// reuse factor is a typed construction error, not a skewed estimate.
#[test]
fn non_divisor_reuse_rejected_at_construction() {
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    // 360 kernel mults: 7 is not a divisor.
    let cfg = HlsConfig::paper_default(
        FixedSpec::new(16, 6),
        ReuseFactor::new(7, 7),
    );
    assert!(matches!(
        HlsDesign::new(arch, cfg),
        Err(DesignError::ReuseNotDivisor {
            which: "kernel",
            reuse: 7,
            ..
        })
    ));
}

/// Front soundness: no survivor is dominated by any admitted row, every
/// pruned row names a *surviving* dominator that actually dominates it,
/// and the partition accounts for every admitted row.
#[test]
fn pareto_front_is_sound() {
    let r = top_gru_result(Filters::default());
    assert!(!r.front.is_empty());
    for &i in &r.front {
        for &j in &r.admitted {
            assert!(
                i == j || !r.candidates[j].dominates(&r.candidates[i]),
                "front row {} dominated by {}",
                r.candidates[i].name(),
                r.candidates[j].name()
            );
        }
    }
    for d in &r.dropped {
        assert!(
            r.front.contains(&d.dominated_by),
            "dominator of {} is not on the front",
            r.candidates[d.index].name()
        );
        assert!(
            r.candidates[d.dominated_by].dominates(&r.candidates[d.index]),
            "{} does not dominate {}",
            r.candidates[d.dominated_by].name(),
            r.candidates[d.index].name()
        );
    }
    assert_eq!(r.admitted.len(), r.front.len() + r.dropped.len());
}

/// Device fit is an admission gate: every survivor fits the target
/// part.
#[test]
fn front_rows_fit_the_device() {
    let r = top_gru_result(Filters::default());
    for c in r.front_rows() {
        assert!(c.fits_device, "{} on the front but does not fit", c.name());
    }
}

/// Budget queries answer over the full admitted grid, not just the
/// front: cross-check against an independent brute-force minimum.
#[test]
fn budget_queries_match_brute_force() {
    let r = top_gru_result(Filters::default());
    for budget_ns in [500.0, 1_000.0, 2_500.0, 10_000.0, 1e9] {
        let brute = r
            .admitted
            .iter()
            .map(|&i| &r.candidates[i])
            .filter(|c| c.latency_ns() <= budget_ns)
            .min_by_key(|c| ExploreResult::resource_cost(c));
        let got = r.cheapest_within(budget_ns);
        match (got, brute) {
            (None, None) => {}
            (Some(g), Some(b)) => {
                assert_eq!(
                    ExploreResult::resource_cost(g),
                    ExploreResult::resource_cost(b),
                    "budget {budget_ns}: {} vs brute-force {}",
                    g.name(),
                    b.name()
                );
            }
            (g, b) => panic!(
                "budget {budget_ns}: query {:?} vs brute force {:?}",
                g.map(|c| c.name()),
                b.map(|c| c.name())
            ),
        }
    }
    // The dual query: fastest design under a DSP cap, same cross-check.
    for max_dsp in [30, 300, 3_000, 10_000] {
        let brute = r
            .admitted
            .iter()
            .map(|&i| &r.candidates[i])
            .filter(|c| c.resources.dsp <= max_dsp)
            .map(|c| c.latency_ns())
            .fold(f64::INFINITY, f64::min);
        match r.fastest_within_dsp(max_dsp) {
            Some(c) => assert_eq!(c.latency_ns(), brute, "cap {max_dsp}"),
            None => assert_eq!(brute, f64::INFINITY, "cap {max_dsp}"),
        }
    }
}

/// The CI artifact is byte-stable: two full, independent runs over the
/// same grid serialize identically.
#[test]
fn bench_json_is_byte_stable_across_runs() {
    let dir = std::env::temp_dir()
        .join(format!("rnnhls-explore-stable-{}", std::process::id()));
    let run = |name: &str| {
        let r = top_gru_result(Filters {
            budget_ns: Some(5_000.0),
            min_auc: None,
        });
        let path = dir.join(name);
        rnn_hls::report::explore::write_bench_json(&path, &r).unwrap();
        std::fs::read_to_string(&path).unwrap()
    };
    let a = run("a.json");
    let b = run("b.json");
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(a, b, "same grid must serialize byte-identically");
    assert!(a.contains("\"bench\":\"explore\""));
    assert!(a.contains("\"budget_ns\":5000"));
}

/// Consistency with the paper's own grids: walking the published top
/// GRU reuse ladder (Table 2) at fixed precision/clock trades latency
/// for DSPs monotonically, and consecutive rungs are mutually
/// non-dominated — each is a genuine Pareto alternative.
#[test]
fn paper_reuse_grid_rungs_are_mutual_trade_offs() {
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let rungs: Vec<explore::Candidate> = paper::reuse_grid("top", Cell::Gru)
        .into_iter()
        .map(|reuse| {
            let cfg =
                HlsConfig::paper_default(FixedSpec::new(8, 6), reuse);
            explore::Candidate {
                arch_key: arch.key(),
                config: cfg,
                timing: latency::schedule(&arch, &cfg).unwrap(),
                resources: resource::estimate(&arch, &cfg),
                fits_device: true,
                auc: None,
            }
        })
        .collect();
    assert!(rungs.len() >= 4);
    for pair in rungs.windows(2) {
        let (lo, hi) = (&pair[0], &pair[1]);
        assert!(
            hi.timing.latency_cycles > lo.timing.latency_cycles,
            "latency must grow with reuse: {} vs {}",
            lo.name(),
            hi.name()
        );
        assert!(
            hi.resources.dsp < lo.resources.dsp,
            "DSPs must shrink with reuse: {} vs {}",
            lo.name(),
            hi.name()
        );
        assert!(!lo.dominates(hi), "{} dominates {}", lo.name(), hi.name());
        assert!(!hi.dominates(lo), "{} dominates {}", hi.name(), lo.name());
    }
}

/// The measured-accuracy join: annotated rows carry their per-precision
/// AUC, `--min-auc` admits only rows that measured above the bar, and a
/// bar nothing meets empties the front.
#[test]
fn accuracy_join_feeds_the_min_auc_filter() {
    let cfg = top_gru_config();
    let mut candidates = explore::evaluate(&cfg).unwrap();
    let specs = explore::distinct_specs(&candidates, "top_gru");
    assert_eq!(specs.len(), explore::DEFAULT_WIDTHS.len());
    let join = AccuracyJoin {
        key: "top_gru".into(),
        auc_float: 0.99,
        samples: 400,
        auc_by_spec: specs
            .iter()
            .map(|&s| {
                // Synthetic Fig. 2 shape: only wide types clear 0.98.
                (s, if s.width >= 16 { 0.985 } else { 0.90 })
            })
            .collect(),
    };
    explore::join_accuracy(&mut candidates, &join);
    assert!(candidates.iter().all(|c| c.auc.is_some()));

    let admitted_bar = Filters {
        budget_ns: None,
        min_auc: Some(0.98),
    };
    let r = explore::pareto(cfg.device, candidates.clone(), admitted_bar);
    assert!(!r.front.is_empty());
    for c in r.front_rows() {
        assert!(c.auc.unwrap() >= 0.98);
        assert!(c.config.spec.width >= 16, "{}", c.name());
    }

    let impossible_bar = Filters {
        budget_ns: None,
        min_auc: Some(0.999),
    };
    let r = explore::pareto(cfg.device, candidates, impossible_bar);
    assert!(r.admitted.is_empty() && r.front.is_empty());
}

/// The serving bridge: every front row serializes as a uniquely named
/// backend candidate whose tier follows its modeled latency.
#[test]
fn serving_bridge_rows_are_named_and_tiered() {
    let r = top_gru_result(Filters::default());
    let rows = r.backend_candidates();
    assert_eq!(rows.len(), r.front.len());
    let mut names: Vec<&str> = rows.iter().map(|b| b.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    assert_eq!(names.len(), rows.len(), "backend candidate names collide");
    for b in &rows {
        assert!(b.name.starts_with("top_gru_w"), "{}", b.name);
        assert_eq!(b.model_key, "top_gru");
        assert_eq!(b.backend, "fixed");
        assert_eq!(
            b.tier == rnn_hls::coordinator::TierClass::Trigger,
            b.latency_ns <= TRIGGER_BUDGET_NS,
            "{}",
            b.name
        );
    }
}

/// `FloatBaseline` refactor equivalence: the packaged `accuracy::run`,
/// an explicit baseline + sweep, and a spec-by-spec `eval_spec` loop
/// produce bit-identical reports — the explorer's one-baseline reuse
/// changes nothing.
#[test]
fn float_baseline_sweep_equals_run() {
    use rnn_hls::report::accuracy::{self, FloatBaseline};

    let weights = rnn_hls::model::Weights::load_path(
        fixtures().join("top_gru.json"),
        None,
    )
    .unwrap();
    let ds = rnn_hls::data::Dataset::load(
        fixtures().join("top_test_slice.bin"),
    )
    .unwrap()
    .truncated(40);
    let specs = [FixedSpec::new(8, 4), FixedSpec::new(16, 6)];

    let packaged = accuracy::run(&weights, &ds, &specs, 2).unwrap();
    let baseline = FloatBaseline::new(&weights, &ds, 2).unwrap();
    let swept = baseline.sweep(&specs, 2).unwrap();

    assert_eq!(packaged.key, swept.key);
    assert_eq!(packaged.samples, swept.samples);
    assert_eq!(
        packaged.auc_float.to_bits(),
        swept.auc_float.to_bits(),
        "float baseline diverged"
    );
    assert_eq!(packaged.points.len(), swept.points.len());
    for (p, s) in packaged.points.iter().zip(&swept.points) {
        assert_eq!(p.spec, s.spec);
        assert_eq!(p.auc_fixed.to_bits(), s.auc_fixed.to_bits());
        let lone = baseline.eval_spec(p.spec, 1).unwrap();
        assert_eq!(p.auc_fixed.to_bits(), lone.to_bits());
    }
    assert_eq!(baseline.auc_float().to_bits(), packaged.auc_float.to_bits());
    assert_eq!(baseline.samples(), 40);
    assert_eq!(baseline.key(), "top_gru");
}

/// The acceptance-criteria shape: a 1 µs budget on the KU115 still
/// leaves top GRU designs standing (the 400 MHz latency-strategy
/// corner), every one fitting the device inside the budget.
#[test]
fn one_microsecond_budget_is_satisfiable_on_ku115() {
    let r = top_gru_result(Filters {
        budget_ns: Some(1_000.0),
        min_auc: None,
    });
    assert!(!r.front.is_empty(), "nothing survives a 1 µs budget");
    for c in r.front_rows() {
        assert!(c.fits_device);
        assert!(c.latency_ns() <= 1_000.0, "{}", c.name());
        assert!(
            (c.config.clock_mhz - 400.0).abs() < 1e-9,
            "only the 400 MHz corner meets 1 µs, got {}",
            c.name()
        );
        assert_eq!(c.config.strategy, Strategy::Latency);
    }
    assert!(r.cheapest_within(1_000.0).is_some());
}
