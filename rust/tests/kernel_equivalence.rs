//! Kernel & memory acceptance suite for the SIMD + zero-allocation
//! redesign:
//!
//! (a) the dispatched kernels (`nn::kernels`) are **bitwise identical**
//!     to their scalar references on odd shapes, for both datapaths —
//!     trivially true without `--features simd`, the real assertion
//!     when the AVX2 path is live;
//! (b) every engine entry point — `forward`, `forward_batch`,
//!     `forward_packed`, `forward_packed_into` — produces bitwise
//!     identical outputs, for LSTM and GRU, batch 1/3/8, workers
//!     1/2/8, on both engines (the packed serving path may change
//!     memory layout and scheduling, never arithmetic);
//! (c) the buffer-recycling layer reaches a zero-allocation steady
//!     state: the session feature pool and the engine scratch pools
//!     stop missing once warm (misses plateau while hits climb), and
//!     the pooled serving path end-to-end (EngineRunner + packed
//!     output + shared-Arc completions) still matches direct engine
//!     calls bitwise.

use std::collections::HashMap;
use std::time::Duration;

use rnn_hls::coordinator::{BatchRunner, BatcherConfig, EngineRunner};
use rnn_hls::fixed::{FixedSpec, QuantConfig};
use rnn_hls::model::{zoo, Cell, Weights};
use rnn_hls::nn::{kernels, Engine, FixedEngine, FloatEngine, PackedOut};
use rnn_hls::{ServingSpec, Session};

// ---------------------------------------------------- (a) raw kernels

fn f32_vec(n: usize, salt: usize) -> Vec<f32> {
    (0..n)
        .map(|i| ((i * 7 + salt * 11) % 23) as f32 * 0.13 - 1.1)
        .collect()
}

fn i64_vec(n: usize, salt: usize) -> Vec<i64> {
    (0..n)
        .map(|i| ((i as i64 * 977 + salt as i64 * 131) - 9000) % (1 << 25))
        .collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

#[test]
fn dispatched_dot_matches_scalar_bitwise_on_odd_lengths() {
    for n in [0usize, 1, 2, 5, 7, 8, 9, 15, 16, 17, 31, 33, 64, 127] {
        let (x, w) = (f32_vec(n, 1), f32_vec(n, 2));
        assert_eq!(
            kernels::dot_f32(&x, &w).to_bits(),
            kernels::dot_f32_scalar(&x, &w).to_bits(),
            "f32 n={n} (simd_active={})",
            kernels::simd_active()
        );
        let (xi, wi) = (i64_vec(n, 3), i64_vec(n, 4));
        assert_eq!(
            kernels::dot_i64(&xi, &wi),
            kernels::dot_i64_scalar(&xi, &wi),
            "i64 n={n}"
        );
    }
}

#[test]
fn dispatched_matmul_matches_scalar_bitwise_on_odd_shapes() {
    for (rows, cols, batch) in [
        (1usize, 1usize, 1usize),
        (2, 3, 1),
        (3, 7, 2),
        (5, 9, 3),
        (7, 13, 5),
        (8, 8, 8),
        (11, 27, 4),
    ] {
        let wt = f32_vec(rows * cols, 5);
        let xs = f32_vec(batch * cols, 6);
        // Non-zero initial accumulators: matmul_acc *accumulates*.
        let mut a = vec![0.625f32; batch * rows];
        let mut b = a.clone();
        kernels::matmul_acc_f32(&wt, rows, cols, &xs, batch, &mut a);
        kernels::matmul_acc_f32_scalar(&wt, rows, cols, &xs, batch, &mut b);
        assert_eq!(bits(&a), bits(&b), "f32 {rows}x{cols} b{batch}");

        let wt = i64_vec(rows * cols, 7);
        let xs = i64_vec(batch * cols, 8);
        let mut a = vec![17i64; batch * rows];
        let mut b = a.clone();
        kernels::matmul_acc_i64(&wt, rows, cols, &xs, batch, &mut a);
        kernels::matmul_acc_i64_scalar(&wt, rows, cols, &xs, batch, &mut b);
        assert_eq!(a, b, "i64 {rows}x{cols} b{batch}");
    }
}

// ------------------------------------------------ (b) engine entry points

/// Deterministic sample `s` for an engine with the given input stride.
fn sample(stride: usize, s: usize) -> Vec<f32> {
    (0..stride)
        .map(|i| ((i * 7 + s * 13) % 19) as f32 * 0.05 - 0.4)
        .collect()
}

/// Assert `forward` ≡ `forward_batch` ≡ `forward_packed` ≡
/// `forward_packed_into` bitwise, across batch sizes and worker counts.
fn assert_entry_points_agree(make: &dyn Fn() -> Box<dyn Engine>, tag: &str) {
    let engine = make();
    let stride = engine.arch().seq_len * engine.arch().input_size;
    for batch in [1usize, 3, 8] {
        let samples: Vec<Vec<f32>> =
            (0..batch).map(|s| sample(stride, s)).collect();
        let refs: Vec<&[f32]> =
            samples.iter().map(|v| v.as_slice()).collect();
        let packed: Vec<f32> =
            samples.iter().flat_map(|v| v.iter().copied()).collect();
        let per_sample: Vec<Vec<f32>> =
            refs.iter().map(|x| engine.forward(x)).collect();
        let batched = engine.forward_batch(&refs);
        let packed_rows = engine.forward_packed(&packed, batch);
        let mut out = PackedOut::new();
        engine.forward_packed_into(&packed, batch, &mut out);
        for (i, want) in per_sample.iter().enumerate() {
            assert_eq!(
                bits(&batched[i]),
                bits(want),
                "{tag} b{batch} sample {i}: forward_batch"
            );
            assert_eq!(
                bits(&packed_rows[i]),
                bits(want),
                "{tag} b{batch} sample {i}: forward_packed"
            );
            assert_eq!(
                bits(out.row(i)),
                bits(want),
                "{tag} b{batch} sample {i}: packed_into"
            );
        }
        assert_eq!(out.rows(), batch, "{tag}: row count");
        assert_eq!(
            out.width(),
            engine.arch().output_size,
            "{tag}: row width"
        );
    }
}

#[test]
fn float_engine_entry_points_bitwise_identical() {
    for cell in [Cell::Lstm, Cell::Gru] {
        for workers in [1usize, 2, 8] {
            let arch = zoo::arch("top", cell).unwrap();
            let weights = Weights::synthetic(&arch, 0x5EED);
            assert_entry_points_agree(
                &move || {
                    Box::new(
                        FloatEngine::new(&weights)
                            .unwrap()
                            .with_parallelism(workers),
                    ) as Box<dyn Engine>
                },
                &format!("float/{cell:?} w{workers}"),
            );
        }
    }
}

#[test]
fn fixed_engine_entry_points_bitwise_identical() {
    let q16 = QuantConfig::ptq(FixedSpec::default16_6());
    for cell in [Cell::Lstm, Cell::Gru] {
        for workers in [1usize, 2, 8] {
            let arch = zoo::arch("top", cell).unwrap();
            let weights = Weights::synthetic(&arch, 0x5EED);
            assert_entry_points_agree(
                &move || {
                    Box::new(
                        FixedEngine::new(&weights, q16)
                            .unwrap()
                            .with_parallelism(workers),
                    ) as Box<dyn Engine>
                },
                &format!("fixed/{cell:?} w{workers}"),
            );
        }
    }
}

// --------------------------------------------- (c) zero-alloc steady state

/// Engine scratch pools go warm through the public packed entry point:
/// one miss to build the scratch, hits forever after.
#[test]
fn engine_scratch_pools_plateau_through_packed_path() {
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let weights = Weights::synthetic(&arch, 9);
    let stride = arch.seq_len * arch.input_size;
    let packed: Vec<f32> = (0..3)
        .flat_map(|s| sample(stride, s))
        .collect();

    let float = FloatEngine::new(&weights).unwrap();
    let fixed =
        FixedEngine::new(&weights, QuantConfig::ptq(FixedSpec::default16_6()))
            .unwrap();
    let mut out = PackedOut::new();
    for _ in 0..10 {
        float.forward_packed_into(&packed, 3, &mut out);
        fixed.forward_packed_into(&packed, 3, &mut out);
    }
    for (tag, stats) in
        [("float", float.scratch_stats()), ("fixed", fixed.scratch_stats())]
    {
        assert_eq!(stats.misses, 1, "{tag}: one cold scratch build");
        assert_eq!(stats.hits, 9, "{tag}: every later call reuses it");
    }
}

/// The session feature pool reaches zero-miss steady state under the
/// submit → recv → submit ping-pong: the worker recycles each request's
/// buffer *before* sending its completion, so a single-threaded client
/// always finds its previous buffer parked.
#[test]
fn session_feature_pool_plateaus_in_steady_state() {
    struct Width1;
    impl BatchRunner for Width1 {
        fn max_batch(&self) -> usize {
            1
        }
        fn run(
            &mut self,
            _xs: &[f32],
            n: usize,
        ) -> anyhow::Result<Vec<Vec<f32>>> {
            Ok(vec![vec![1.0f32]; n])
        }
    }

    let spec = ServingSpec {
        shards: 1,
        workers: 1,
        queue_capacity: 64,
        batcher: BatcherConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
        },
        ..ServingSpec::default()
    };
    let session = Session::start(&spec, |_shard| {
        Ok(Box::new(Width1) as Box<dyn BatchRunner>)
    })
    .unwrap();

    let roundtrip = |session: &Session| {
        let mut features = session.recycled_features();
        features.resize(16, 0.5f32);
        let request = session.prepare_event(features, 0);
        session.submit(request).unwrap();
        session.recv().expect("fabric alive");
    };

    for _ in 0..50 {
        roundtrip(&session);
    }
    let warm = session.snapshot().pool;
    for _ in 0..100 {
        roundtrip(&session);
    }
    let steady = session.snapshot().pool;
    assert_eq!(
        steady.misses, warm.misses,
        "a warm session must stop allocating feature buffers \
         (misses {} -> {})",
        warm.misses, steady.misses
    );
    assert!(
        steady.hits >= warm.hits + 100,
        "every steady-state draw is a pool hit ({} -> {})",
        warm.hits,
        steady.hits
    );
    session.shutdown().unwrap();
}

/// End-to-end bitwise check of the pooled serving path: a live session
/// over a real engine (EngineRunner → forward_packed_into → shared-Arc
/// completion windows) must reproduce direct `Engine::forward` calls
/// bit for bit, under real batching and two workers.
#[test]
fn pooled_serving_path_matches_direct_forward_bitwise() {
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let weights = Weights::synthetic(&arch, 0xA11);
    let stride = arch.seq_len * arch.input_size;
    let reference = FloatEngine::new(&weights).unwrap();

    let spec = ServingSpec {
        shards: 1,
        workers: 2,
        queue_capacity: 1024,
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(100),
        },
        ..ServingSpec::default()
    };
    let factory_weights = weights.clone();
    let session = Session::start(&spec, move |_shard| {
        let engine = FloatEngine::new(&factory_weights)?;
        Ok(Box::new(EngineRunner::new(Box::new(engine), 8))
            as Box<dyn BatchRunner>)
    })
    .unwrap();

    const N: usize = 40;
    let mut index_of = HashMap::new();
    for i in 0..N {
        let mut features = session.recycled_features();
        features.clear();
        features.extend_from_slice(&sample(stride, i));
        let request = session.prepare_event(features, 0);
        index_of.insert(request.id, i);
        session.submit(request).unwrap();
    }
    let mut seen = 0usize;
    for _ in 0..N {
        let completion = session.recv().expect("fabric alive");
        let i = index_of[&completion.id];
        let want = reference.forward(&sample(stride, i));
        assert_eq!(
            bits(&completion.output),
            bits(&want),
            "sample {i} over the pooled path"
        );
        seen += 1;
    }
    assert_eq!(seen, N);
    let report = session.shutdown().unwrap();
    assert_eq!(report.merged.completed, N as u64);
    assert_eq!(report.merged.dropped, 0);
}
