//! Session-API acceptance suite (the request-driven serving redesign):
//!
//! (a) a stream submitted via `Session::submit` is **bitwise identical**
//!     to the same stream replayed through `Server::run` /
//!     `ShardedServer::run`, for 1 and 4 shards — the live path and the
//!     replay path are one fabric;
//! (b) two concurrent submitters into one session produce a
//!     deterministic per-id output set (many sources, one fabric);
//! (c) backpressure (`SubmitError::Full`) and submit-after-shutdown
//!     (`SubmitError::Closed`) are typed errors carrying the request
//!     back — never panics, never silent losses.
//!
//! Method (as in `shard_equivalence.rs`): a deterministic generator
//! encodes the event index into the features, a recording runner keys
//! every output by that embedded id, and `source::run_with`'s
//! sink-independence guarantee lets the test collect the exact replay
//! stream up front and push it through the live API.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use rnn_hls::coordinator::source;
use rnn_hls::coordinator::{
    BatchRunner, Request, Server, ServerConfig, ShardPolicy, SourceConfig,
    SystemClock, TierMix,
};
use rnn_hls::data::generators::{Event, Generator};
use rnn_hls::util::sync::mpsc::{self, Receiver};
use rnn_hls::util::sync::{lock_or_recover, Mutex};
use rnn_hls::{BackendKind, ServingSpec, Session, SubmitError};

const N_EVENTS: usize = 2_000;

/// Emits events whose first feature is the event index (exact in f32 at
/// these sizes); the source assigns `Request::id` in the same order.
struct IdGen {
    next: u64,
}

impl Generator for IdGen {
    fn name(&self) -> &'static str {
        "id"
    }
    fn seq_len(&self) -> usize {
        4
    }
    fn n_feat(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        1
    }
    fn generate(&mut self) -> Event {
        let id = self.next;
        self.next += 1;
        let mut features = vec![0.0f32; self.seq_len() * self.n_feat()];
        features[0] = id as f32;
        features[1] = (id % 17) as f32 * 0.25;
        Event {
            features,
            label: (id % 2) as u32,
        }
    }
}

/// Output as a pure function of the embedded id — what both the replay
/// and the live runs must reproduce bit for bit.
fn expected_output(id: u64, second_feature: f32) -> Vec<f32> {
    let base = if id % 2 == 1 { 0.9f32 } else { 0.1f32 };
    vec![base + second_feature * 1e-4]
}

/// Records (id → output) for every sample it serves.
struct RecordingRunner {
    outputs: Arc<Mutex<HashMap<u64, Vec<f32>>>>,
}

impl BatchRunner for RecordingRunner {
    fn max_batch(&self) -> usize {
        8
    }
    fn run(&mut self, xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let stride = xs.len() / n.max(1);
        let mut out = Vec::with_capacity(n);
        let mut map = lock_or_recover(&self.outputs);
        for i in 0..n {
            let row = &xs[i * stride..(i + 1) * stride];
            let id = row[0] as u64;
            let probs = expected_output(id, row[1]);
            anyhow::ensure!(
                map.insert(id, probs.clone()).is_none(),
                "request {id} served twice"
            );
            out.push(probs);
        }
        Ok(out)
    }
}

fn server_config() -> ServerConfig {
    ServerConfig {
        workers: 2,
        queue_capacity: 16_384, // > N_EVENTS: nothing can drop
        batcher: rnn_hls::coordinator::BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(100),
        },
        source: SourceConfig {
            rate_hz: 5_000_000.0, // saturating: pacing never the bottleneck
            poisson: false,
            n_events: N_EVENTS,
        },
    }
}

fn live_spec(shards: usize) -> ServingSpec {
    let cfg = server_config();
    ServingSpec {
        engine: BackendKind::Float, // factory overrides; field is unused
        shards,
        shard_policy: ShardPolicy::HashId,
        workers: cfg.workers,
        queue_capacity: cfg.queue_capacity,
        batcher: cfg.batcher,
        source: cfg.source,
        ..ServingSpec::default()
    }
}

/// The replay baseline: the classic `Server::run` single coordinator.
fn run_replay_single() -> HashMap<u64, Vec<f32>> {
    let outputs = Arc::new(Mutex::new(HashMap::new()));
    let sink = outputs.clone();
    let report = Server::run(
        server_config(),
        Box::new(IdGen { next: 0 }),
        move || {
            Ok(Box::new(RecordingRunner {
                outputs: sink.clone(),
            }) as Box<dyn BatchRunner>)
        },
    )
    .unwrap();
    assert_eq!(report.dropped, 0);
    assert_eq!(report.completed, N_EVENTS as u64);
    Arc::try_unwrap(outputs).unwrap().into_inner().unwrap()
}

/// Collect the exact request stream the replay wrappers would drive:
/// `source::run_with` is a pure function of (generator, cfg, seed), so
/// the same seed reproduces the identical ids, features, and tier
/// stamps regardless of the sink.
fn collect_stream() -> Vec<Request> {
    let mut stream = Vec::with_capacity(N_EVENTS);
    source::run_with(
        Box::new(IdGen { next: 0 }),
        server_config().source,
        0xEE77, // the wrappers' source seed
        &TierMix::single(),
        &SystemClock,
        |request| stream.push(request),
    );
    stream
}

/// Serve the collected stream through the live `Session::submit` path,
/// returning both the runner-recorded map and the completion-channel
/// map.
fn run_live(
    shards: usize,
) -> (HashMap<u64, Vec<f32>>, HashMap<u64, Vec<f32>>) {
    let outputs = Arc::new(Mutex::new(HashMap::new()));
    let sink = outputs.clone();
    let session = Session::start(&live_spec(shards), move |_shard| {
        Ok(Box::new(RecordingRunner {
            outputs: sink.clone(),
        }) as Box<dyn BatchRunner>)
    })
    .unwrap();
    for request in collect_stream() {
        session.submit(request).unwrap();
    }
    let mut completions = HashMap::new();
    for _ in 0..N_EVENTS {
        let completion = session.recv().expect("fabric alive");
        assert!(completion.shard < shards);
        assert!(completion.completed_at >= completion.enqueued_at);
        assert!(
            completions
                .insert(completion.id, completion.output.to_vec())
                .is_none(),
            "completion {} delivered twice",
            completion.id
        );
    }
    assert_eq!(session.completions_lost(), 0, "egress channel overflowed");
    let report = session.shutdown().unwrap();
    assert_eq!(report.merged.generated, N_EVENTS as u64);
    assert_eq!(report.merged.dropped, 0);
    assert_eq!(report.merged.completed, N_EVENTS as u64);
    let served = Arc::try_unwrap(outputs).unwrap().into_inner().unwrap();
    (served, completions)
}

/// (a) Live submit ≡ replay, for 1 and 4 shards: same per-id outputs on
/// the runner side AND on the completion channel.
#[test]
fn submitted_stream_is_bitwise_identical_to_replay() {
    let replay = run_replay_single();
    assert_eq!(replay.len(), N_EVENTS);
    for shards in [1usize, 4] {
        let (served, completions) = run_live(shards);
        assert_eq!(served, replay, "shards={shards}: runner outputs");
        assert_eq!(
            completions, replay,
            "shards={shards}: completion outputs"
        );
    }
}

/// (b) Two concurrent submitters into one fabric: the union of their id
/// ranges is served exactly once each, with outputs deterministic per
/// id — repeated runs produce the identical map.
#[test]
fn concurrent_submitters_produce_deterministic_output_set() {
    let run_once = || -> HashMap<u64, Vec<f32>> {
        let outputs = Arc::new(Mutex::new(HashMap::new()));
        let sink = outputs.clone();
        let session = Session::start(&live_spec(2), move |_shard| {
            Ok(Box::new(RecordingRunner {
                outputs: sink.clone(),
            }) as Box<dyn BatchRunner>)
        })
        .unwrap();
        std::thread::scope(|scope| {
            for submitter in 0..2u64 {
                let handle = session.handle();
                scope.spawn(move || {
                    let base = submitter * 1_000;
                    for i in 0..1_000u64 {
                        let id = base + i;
                        let mut features = vec![0.0f32; 8];
                        features[0] = id as f32;
                        features[1] = (id % 17) as f32 * 0.25;
                        handle
                            .submit(Request {
                                id,
                                features,
                                label: (id % 2) as u32,
                                route_key: 0,
                                enqueued_at: Instant::now(),
                            })
                            .unwrap();
                    }
                });
            }
        });
        let mut completions = HashMap::new();
        for _ in 0..2_000 {
            let completion = session.recv().expect("fabric alive");
            completions.insert(completion.id, completion.output.to_vec());
        }
        let report = session.shutdown().unwrap();
        assert_eq!(report.merged.generated, 2_000);
        assert_eq!(report.merged.completed, 2_000);
        assert_eq!(report.merged.dropped, 0);
        let served =
            Arc::try_unwrap(outputs).unwrap().into_inner().unwrap();
        assert_eq!(served, completions);
        served
    };
    let first = run_once();
    assert_eq!(first.len(), 2_000);
    for (id, output) in &first {
        assert_eq!(
            output,
            &expected_output(*id, (*id % 17) as f32 * 0.25),
            "id {id}"
        );
    }
    let second = run_once();
    assert_eq!(first, second, "two runs must serve the identical set");
}

/// Runner that parks on a gate so the test can wedge the (single)
/// worker and fill the queue deterministically.
struct BlockingRunner {
    gate: Receiver<()>,
}

impl BatchRunner for BlockingRunner {
    fn max_batch(&self) -> usize {
        1
    }
    fn run(&mut self, _xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        // Parks until the test drops the sender; afterwards recv errors
        // immediately and the backlog drains.
        let _ = self.gate.recv();
        Ok(vec![vec![0.1]; n])
    }
}

fn tiny_request(id: u64) -> Request {
    Request {
        id,
        features: vec![0.0; 8],
        label: 0,
        route_key: 0,
        enqueued_at: Instant::now(),
    }
}

/// (c) Queue-full backpressure is a typed error carrying the request
/// back, counted as a drop — and the session keeps serving afterwards.
#[test]
fn queue_full_backpressure_is_a_typed_error() {
    let spec = ServingSpec {
        engine: BackendKind::Float,
        workers: 1,
        queue_capacity: 1,
        ..ServingSpec::default()
    }
    .with_batcher(1, Duration::ZERO);
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let slot = Arc::new(Mutex::new(Some(gate_rx)));
    let session = Session::start(&spec, move |_shard| {
        let gate = lock_or_recover(&slot)
            .take()
            .expect("exactly one worker builds a runner");
        Ok(Box::new(BlockingRunner { gate }) as Box<dyn BatchRunner>)
    })
    .unwrap();

    // The worker parks on the first request it pops; with capacity 1,
    // the queue must reject within a handful of submissions.
    let mut full: Option<SubmitError> = None;
    let mut admitted = 0u64;
    for id in 0..100u64 {
        match session.submit(tiny_request(id)) {
            Ok(()) => admitted += 1,
            Err(err) => {
                full = Some(err);
                break;
            }
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    let err = full.expect("a 1-deep queue behind a wedged worker must fill");
    match &err {
        SubmitError::Full { shard, request } => {
            assert_eq!(*shard, 0);
            assert_eq!(request.id, admitted, "request handed back intact");
        }
        other => panic!("expected Full, got {other}"),
    }
    assert!(err.to_string().contains("full"), "{err}");
    let rejected_id = err.into_request().id;
    assert_eq!(rejected_id, admitted);

    // Release the worker; everything admitted drains and the books
    // balance: generated = admitted + the counted drop.
    drop(gate_tx);
    let report = session.shutdown().unwrap();
    assert_eq!(report.merged.generated, admitted + 1);
    assert_eq!(report.merged.dropped, 1);
    assert_eq!(report.merged.completed, admitted);
}

/// (c) Submit after shutdown is a typed `Closed` error — on a handle
/// that outlived its session.
#[test]
fn submit_after_shutdown_is_a_typed_error() {
    let spec = ServingSpec {
        engine: BackendKind::Float,
        workers: 1,
        ..ServingSpec::default()
    };
    let outputs = Arc::new(Mutex::new(HashMap::new()));
    let sink = outputs.clone();
    let session = Session::start(&spec, move |_shard| {
        Ok(Box::new(RecordingRunner {
            outputs: sink.clone(),
        }) as Box<dyn BatchRunner>)
    })
    .unwrap();
    let handle = session.handle();
    session.submit(tiny_request(0)).unwrap();
    let report = session.shutdown().unwrap();
    assert_eq!(report.merged.completed, 1);

    let err = handle.submit(tiny_request(1)).unwrap_err();
    assert!(
        matches!(&err, SubmitError::Closed { request } if request.id == 1),
        "{err}"
    );
    assert!(err.to_string().contains("closed"), "{err}");
    // The rejected request was not counted anywhere.
    let err = handle.submit_event(vec![0.0; 8], 0).unwrap_err();
    assert!(matches!(err, SubmitError::Closed { .. }), "{err}");
}

/// Cheap constant-output runner for the shutdown-race tests: the books
/// are what is under test, not the outputs.
struct ConstRunner;

impl BatchRunner for ConstRunner {
    fn max_batch(&self) -> usize {
        4
    }
    fn run(&mut self, _xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        Ok(vec![vec![0.5]; n])
    }
}

/// Submits racing `shutdown` never unbalance the books.  Every `Ok`
/// admission is eventually completed, every `Full` rejection is a
/// counted drop, and every `Closed` rejection — including the narrow
/// race where `submit` passes the closed-flag check but lands on an
/// already-closed queue (the un-count path) — is counted nowhere.  The
/// final report must satisfy `generated == completed + dropped`
/// *exactly*, whatever the interleaving.  The same race is explored
/// schedule-exhaustively in `tests/model_check.rs`; this test keeps the
/// invariant pinned under real threads and real timing.
#[test]
fn shutdown_racing_submits_keeps_the_books_balanced() {
    let spec = ServingSpec {
        engine: BackendKind::Float,
        workers: 1,
        queue_capacity: 4,
        ..ServingSpec::default()
    }
    .with_batcher(4, Duration::from_micros(50));
    let session = Session::start(&spec, |_shard| {
        Ok(Box::new(ConstRunner) as Box<dyn BatchRunner>)
    })
    .unwrap();
    let mut submitters = Vec::new();
    for t in 0..4u64 {
        let handle = session.handle();
        submitters.push(std::thread::spawn(move || {
            let (mut ok, mut full) = (0u64, 0u64);
            let mut id = t * 1_000_000;
            loop {
                match handle.submit(tiny_request(id)) {
                    Ok(()) => ok += 1,
                    Err(SubmitError::Full { .. }) => full += 1,
                    Err(SubmitError::Closed { .. }) => break,
                }
                id += 1;
            }
            (ok, full)
        }));
    }
    std::thread::sleep(Duration::from_millis(5));
    let report = session.shutdown().unwrap();
    let (mut ok, mut full) = (0u64, 0u64);
    for submitter in submitters {
        let (o, f) = submitter.join().expect("submitter must not panic");
        ok += o;
        full += f;
    }
    assert!(ok > 0, "some submissions must land before the shutdown");
    assert_eq!(
        report.merged.generated,
        ok + full,
        "every admission attempt that touched the queue counted once"
    );
    assert_eq!(report.merged.dropped, full, "every Full is one drop");
    assert_eq!(report.merged.completed, ok, "every admission drains");
    assert_eq!(
        report.merged.generated,
        report.merged.completed + report.merged.dropped,
        "the accounting identity"
    );
}

/// `Session::Drop` (the non-orderly path: early `?` return, panic
/// unwind) racing a live submitter must never panic or deadlock: the
/// drop stops admission and closes the queues, the detached workers
/// drain and exit, and the handle that outlived the session is turned
/// away with `Closed` — with the rejected requests counted nowhere
/// (the un-count path runs under the race, not just after it).
#[test]
fn dropping_the_session_under_concurrent_submits_is_safe() {
    let spec = ServingSpec {
        engine: BackendKind::Float,
        workers: 1,
        queue_capacity: 8,
        ..ServingSpec::default()
    }
    .with_batcher(4, Duration::from_micros(50));
    let session = Session::start(&spec, |_shard| {
        Ok(Box::new(ConstRunner) as Box<dyn BatchRunner>)
    })
    .unwrap();
    let handle = session.handle();
    let submitter = std::thread::spawn(move || {
        let (mut ok, mut id) = (0u64, 0u64);
        loop {
            match handle.submit(tiny_request(id)) {
                Ok(()) => ok += 1,
                Err(SubmitError::Full { .. }) => std::thread::yield_now(),
                Err(SubmitError::Closed { .. }) => return ok,
            }
            id += 1;
        }
    });
    std::thread::sleep(Duration::from_millis(2));
    drop(session);
    let ok = submitter.join().expect("submitter must not panic");
    assert!(ok > 0, "some submissions must land before the drop");
}
