//! Tier-aware batching under a deterministic virtual clock.
//!
//! Every deadline decision in this suite is driven by
//! [`VirtualClock`] — there is not a single `std::thread::sleep` in this
//! file, and none is needed: an idle deadline wait auto-advances virtual
//! time to the deadline, so size-or-deadline flush semantics, the
//! trigger tier's strict batch-1 guarantee, and per-tier latency
//! percentiles are all *exact* assertions, not timing-tolerant ones.
//!
//! Covers the three tentpole claims:
//!
//! 1. trigger-tier requests are **never co-batched** (batch-1 is a
//!    guarantee of the `max_wait = 0` policy, not a best-effort);
//! 2. offline-tier flushes obey **size OR deadline, exactly**, under
//!    virtual time;
//! 3. per-tier p50/p99 in the metrics roll-up match **hand-computed**
//!    values from the virtual timeline.

use std::sync::Arc;
use std::time::{Duration, Instant};

use rnn_hls::coordinator::batcher::next_batch;
use rnn_hls::coordinator::{
    worker_loop, BatchRunner, BatcherConfig, BoundedQueue, Clock, Request,
    ServerConfig, ServerMetrics, ServerReport, ShardPolicy, ShardedConfig,
    ShardedServer, SourceConfig, TierClass, TierMix, TierPolicy,
    VirtualClock,
};
use rnn_hls::data::generators::{Event, Generator};

fn req(id: u64, enqueued_at: Instant) -> Request {
    Request {
        id,
        features: vec![0.0; 4],
        label: 0,
        route_key: 0,
        enqueued_at,
    }
}

/// Pre-fill a queue with `n` requests, all enqueued "now".
fn backlog(n: u64, clock: &VirtualClock) -> Arc<BoundedQueue<Request>> {
    let q = Arc::new(BoundedQueue::new(4096));
    for id in 0..n {
        q.push(req(id, clock.now())).unwrap();
    }
    q
}

// ------------------------------------------------------- (1) trigger tier

/// The trigger-tier policy (`max_batch = 1`, `max_wait = 0`) never
/// co-batches — even against a deep backlog, every flush is a singleton,
/// in FIFO order, and serving consumes zero (virtual) time waiting.
#[test]
fn trigger_tier_requests_are_never_co_batched() {
    let clock = VirtualClock::new();
    let q = backlog(64, &clock);
    let cfg = TierClass::Trigger.default_batcher();
    assert_eq!(cfg.max_batch, 1);
    assert!(cfg.max_wait.is_zero());
    let t0 = clock.now();
    for want in 0..64u64 {
        let b = next_batch(&q, &cfg, &clock).unwrap();
        assert_eq!(b.len(), 1, "request {want} was co-batched");
        assert_eq!(b.requests[0].id, want, "FIFO order violated");
        assert_eq!(b.formed_at, t0, "trigger flush must be immediate");
    }
    assert!(q.is_empty());
    assert_eq!(clock.now(), t0, "trigger serving must never wait");
}

/// `max_wait = 0` alone (even with a wide `max_batch`) is already the
/// strict batch-1 guarantee: zero-wait means *never* trade one event's
/// latency, not "drain whatever happens to be queued".
#[test]
fn zero_wait_is_batch_one_even_with_wide_max_batch() {
    let clock = VirtualClock::new();
    let q = backlog(10, &clock);
    let cfg = BatcherConfig {
        max_batch: 10,
        max_wait: Duration::ZERO,
    };
    for _ in 0..10 {
        assert_eq!(next_batch(&q, &cfg, &clock).unwrap().len(), 1);
    }
    assert!(q.is_empty());
}

// ------------------------------------------------------- (2) offline tier

/// Size flush: a full batch forms instantly off the backlog, never
/// consulting the deadline — zero virtual time passes.
#[test]
fn offline_tier_size_flush_is_instant_and_exact() {
    let clock = VirtualClock::new();
    let q = backlog(100, &clock);
    let cfg = BatcherConfig {
        max_batch: 64,
        max_wait: Duration::from_micros(2_000),
    };
    let t0 = clock.now();
    let b = next_batch(&q, &cfg, &clock).unwrap();
    assert_eq!(b.len(), 64, "size flush must take exactly max_batch");
    assert_eq!(b.formed_at, t0, "size flush must not wait");
    assert_eq!(clock.now(), t0);
    assert_eq!(q.len(), 36, "remainder stays queued");
}

/// Deadline flush: a partial batch is held exactly `max_wait` — no less
/// (it could still fill) and no more (the deadline is a promise) — then
/// flushed with whatever arrived.
#[test]
fn offline_tier_deadline_flush_is_exact_under_virtual_time() {
    let clock = VirtualClock::new();
    let cfg = BatcherConfig {
        max_batch: 64,
        max_wait: Duration::from_micros(2_000),
    };
    let q = backlog(5, &clock);
    let t0 = clock.now();
    let b = next_batch(&q, &cfg, &clock).unwrap();
    assert_eq!(b.len(), 5, "deadline flush takes what arrived");
    assert_eq!(
        b.formed_at,
        t0 + Duration::from_micros(2_000),
        "partial batch must flush exactly at the deadline"
    );
    assert_eq!(clock.now(), t0 + Duration::from_micros(2_000));

    // A closed queue flushes the remainder immediately (shutdown drain):
    // no deadline wait on a stream that can never grow.
    let q2 = backlog(3, &clock);
    q2.close();
    let t1 = clock.now();
    let b2 = next_batch(&q2, &cfg, &clock).unwrap();
    assert_eq!(b2.len(), 3);
    assert_eq!(b2.formed_at, t1, "closed-queue drain must not wait");
    assert!(next_batch(&q2, &cfg, &clock).is_none());
}

// --------------------------------------------- (3) hand-computed roll-up

/// Mirror of `LatencyHistogram`'s bucketing: upper bound 1.5^k µs, built
/// by the same iterated multiplication so the floats match bit for bit.
fn bucket_bound(us: f64) -> f64 {
    let mut bound = 1.0f64;
    for _ in 0..40 {
        if us < bound {
            return bound;
        }
        bound *= 1.5;
    }
    bound // overflow bucket reports top bound × 1.5 == 1.5^40
}

/// Hand-computed quantile: the histogram bound of the ceil(q·n)-th
/// smallest latency (bucketing is monotone, so this is exactly what the
/// cumulative bucket walk returns).
fn expected_quantile(latencies_us: &[f64], q: f64) -> f64 {
    let mut sorted = latencies_us.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let target = ((q * sorted.len() as f64).ceil() as usize).max(1);
    bucket_bound(sorted[target - 1])
}

/// Records every batch size it serves; outputs keep accuracy at 1.0
/// (prob 0.1 → predicted 0 == label 0).
struct CountingRunner {
    cap: usize,
    batch_sizes: Vec<usize>,
}

impl BatchRunner for CountingRunner {
    fn max_batch(&self) -> usize {
        self.cap
    }
    fn run(&mut self, _xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        self.batch_sizes.push(n);
        Ok(vec![vec![0.1]; n])
    }
}

/// Drive two tiers' worker loops on one virtual timeline with known
/// arrival instants, then assert the per-tier reports — and the merged
/// roll-up — reproduce hand-computed p50/p99 exactly.
#[test]
fn per_tier_percentiles_match_hand_computed_values() {
    let clock = VirtualClock::new();
    let t0 = clock.now();

    // Trigger tier: 8 requests, 100 µs apart.
    let trig_q = Arc::new(BoundedQueue::new(64));
    for id in 0..8u64 {
        trig_q.push(req(id, clock.now())).unwrap();
        clock.advance(Duration::from_micros(100));
    }
    // Offline tier: 12 requests, 25 µs apart, arriving after.
    let off_q = Arc::new(BoundedQueue::new(64));
    for id in 0..12u64 {
        off_q.push(req(100 + id, clock.now())).unwrap();
        clock.advance(Duration::from_micros(25));
    }
    trig_q.close();
    off_q.close();
    let done = clock.now();
    assert_eq!(done - t0, Duration::from_micros(8 * 100 + 12 * 25));

    // Hand-computed per-request latencies (µs) at the completion
    // instant `done`: trigger request i enqueued at t0 + 100·i,
    // offline request j at t0 + 800 + 25·j.
    let trig_lat: Vec<f64> =
        (0..8).map(|i| (1100 - 100 * i) as f64).collect();
    let off_lat: Vec<f64> = (0..12).map(|j| (300 - 25 * j) as f64).collect();

    // Serve both tiers: closed queues drain without advancing the
    // clock, so every completion lands exactly at `done`.
    let trig_m = ServerMetrics::new();
    let mut trig_runner = CountingRunner {
        cap: 64,
        batch_sizes: Vec::new(),
    };
    worker_loop(
        &mut trig_runner,
        &trig_q,
        &trig_m,
        &TierClass::Trigger.default_batcher(),
        &clock,
    )
    .unwrap();
    let off_m = ServerMetrics::new();
    let mut off_runner = CountingRunner {
        cap: 64,
        batch_sizes: Vec::new(),
    };
    worker_loop(
        &mut off_runner,
        &off_q,
        &off_m,
        &TierClass::Offline.default_batcher(),
        &clock,
    )
    .unwrap();
    assert_eq!(clock.now(), done, "drain must consume no virtual time");

    // Batch structure: trigger strictly singletons, offline one deep
    // drain batch.
    assert_eq!(trig_runner.batch_sizes, vec![1; 8]);
    assert_eq!(off_runner.batch_sizes, vec![12]);

    // Per-tier reports: percentiles equal the hand-computed bucket
    // bounds bit for bit, accuracy and counts exact.
    let trig = ServerReport::from_metrics(&trig_m, 1.0);
    assert_eq!(trig.completed, 8);
    assert_eq!(trig.mean_batch, 1.0);
    assert_eq!(trig.accuracy, 1.0);
    assert_eq!(trig.p50_latency_us, expected_quantile(&trig_lat, 0.5));
    assert_eq!(trig.p99_latency_us, expected_quantile(&trig_lat, 0.99));

    let off = ServerReport::from_metrics(&off_m, 1.0);
    assert_eq!(off.completed, 12);
    assert_eq!(off.mean_batch, 12.0);
    assert_eq!(off.p50_latency_us, expected_quantile(&off_lat, 0.5));
    assert_eq!(off.p99_latency_us, expected_quantile(&off_lat, 0.99));

    // The tiers genuinely differ — a blended percentile would describe
    // neither (the reason the roll-up splits per backend).
    assert!(trig.p50_latency_us > off.p50_latency_us);

    // Merged roll-up (the cross-shard primitive): quantiles over the
    // union, hand-computed the same way.
    let merged = ServerMetrics::new();
    merged.merge(&trig_m);
    merged.merge(&off_m);
    let all: Vec<f64> = trig_lat
        .iter()
        .chain(off_lat.iter())
        .copied()
        .collect();
    let merged_report = ServerReport::from_metrics(&merged, 1.0);
    assert_eq!(merged_report.completed, 20);
    assert_eq!(merged_report.p50_latency_us, expected_quantile(&all, 0.5));
    assert_eq!(merged_report.p99_latency_us, expected_quantile(&all, 0.99));
}

// ----------------------------------------------- end-to-end tier policy

/// Deterministic generator for full-session tests (no artifacts).
struct FlatGen;

impl Generator for FlatGen {
    fn name(&self) -> &'static str {
        "flat"
    }
    fn seq_len(&self) -> usize {
        4
    }
    fn n_feat(&self) -> usize {
        1
    }
    fn n_classes(&self) -> usize {
        1
    }
    fn generate(&mut self) -> Event {
        Event {
            features: vec![0.0; 4],
            label: 0,
        }
    }
}

/// Trigger-shard runner: *proves* no co-batching by failing the whole
/// session if it ever sees a batch of more than one.
struct MaxOneRunner;

impl BatchRunner for MaxOneRunner {
    fn max_batch(&self) -> usize {
        8 // wider than the policy: the shard's batcher must clamp, not us
    }
    fn run(&mut self, _xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        anyhow::ensure!(n == 1, "trigger tier co-batched {n} requests");
        Ok(vec![vec![0.1]; n])
    }
}

struct WideRunner;

impl BatchRunner for WideRunner {
    fn max_batch(&self) -> usize {
        64
    }
    fn run(&mut self, _xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        Ok(vec![vec![0.1]; n])
    }
}

/// Full heterogeneous session under per-shard batch policies: the
/// trigger shard provably serves batch-1 (its runner rejects anything
/// else), the roll-up carries each tier's policy, and nothing is lost.
#[test]
fn sharded_session_honors_per_shard_batch_policy() {
    let backends = vec!["fixed".to_string(), "float".to_string()];
    let cfg = ShardedConfig {
        shards: 2,
        policy: ShardPolicy::ModelKey,
        tier_mix: TierMix::new(&[0.75, 0.25], 0xC1A5).unwrap(),
        shard_backends: backends.clone(),
        shard_batchers: TierPolicy::for_backends(&backends).batchers(),
        server: ServerConfig {
            workers: 1,
            queue_capacity: 16_384, // > n_events: nothing can drop
            batcher: BatcherConfig::default(),
            source: SourceConfig {
                rate_hz: 1_000_000.0,
                poisson: false,
                n_events: 2_000,
            },
        },
    };
    let report = ShardedServer::run(cfg, Box::new(FlatGen), |shard| {
        if shard == 0 {
            Ok(Box::new(MaxOneRunner) as Box<dyn BatchRunner>)
        } else {
            Ok(Box::new(WideRunner) as Box<dyn BatchRunner>)
        }
    })
    .unwrap();

    assert_eq!(report.merged.generated, 2_000);
    assert_eq!(report.merged.dropped, 0);
    assert_eq!(report.merged.completed, 2_000);

    let trigger = &report.per_backend[0];
    assert_eq!(trigger.backend, "fixed");
    assert_eq!(trigger.batcher.max_batch, 1);
    assert!(trigger.batcher.max_wait.is_zero());
    assert!(trigger.report.completed > 0);
    assert_eq!(
        trigger.report.mean_batch, 1.0,
        "trigger tier must serve strict batch-1"
    );

    let offline = &report.per_backend[1];
    assert_eq!(offline.backend, "float");
    assert_eq!(offline.batcher.max_batch, 64);
    assert_eq!(
        offline.batcher.max_wait,
        Duration::from_micros(2_000)
    );
    assert!(offline.report.completed > 0);

    // Per-shard stats carry the tier policies too.
    assert_eq!(report.per_shard[0].batcher.max_batch, 1);
    assert_eq!(report.per_shard[1].batcher.max_batch, 64);
}

// ------------------------------------------------ max_batch = 0 regression

/// Regression: `max_batch = 0` (a batch that can never flush) must be
/// rejected at every construction path with a clear error.
#[test]
fn zero_max_batch_is_rejected_everywhere() {
    let err = BatcherConfig::new(0, Duration::from_micros(100)).unwrap_err();
    assert!(
        format!("{err:#}").contains("max_batch must be >= 1"),
        "{err:#}"
    );

    let err = TierPolicy::parse("trigger:0:0").unwrap_err();
    assert!(
        format!("{err:#}").contains("max_batch must be >= 1"),
        "{err:#}"
    );

    // A hand-built config (bypassing BatcherConfig::new) is still caught
    // at session start, before any worker spawns.
    let cfg = ShardedConfig {
        server: ServerConfig {
            batcher: BatcherConfig {
                max_batch: 0,
                max_wait: Duration::ZERO,
            },
            ..Default::default()
        },
        ..Default::default()
    };
    let result = ShardedServer::run(cfg, Box::new(FlatGen), |_| {
        Ok(Box::new(WideRunner) as Box<dyn BatchRunner>)
    });
    let err = format!("{:#}", result.unwrap_err());
    assert!(err.contains("max_batch must be >= 1"), "{err}");
}
