//! Property-based tests (seeded-random cases via `util::prop` — the
//! in-tree proptest substitute) over the substrate invariants.

use rnn_hls::fixed::{
    dequantize, quantize, requantize, FixedSpec, OverflowMode, QuantConfig,
    RoundMode,
};
use rnn_hls::hls::latency;
use rnn_hls::hls::{resource, HlsConfig, ReuseFactor, RnnMode};
use rnn_hls::model::zoo;
use rnn_hls::prop_assert;
use rnn_hls::util::prop::check;
use rnn_hls::util::rng::Rng;

fn random_spec(rng: &mut Rng) -> FixedSpec {
    let width = 2 + rng.below(24) as u32; // 2..=25
    let integer = 1 + rng.below(width as usize - 1) as u32;
    FixedSpec::new(width, integer)
}

// ------------------------------------------------------------- fixed point

#[test]
fn prop_quantize_roundtrip_error_below_lsb() {
    check("quantize-roundtrip", 500, |rng| {
        let spec = random_spec(rng);
        let cfg = QuantConfig::ptq(spec);
        // Values inside the representable range.
        let x = rng.range(spec.min_value(), spec.max_value());
        let back = dequantize(quantize(x, cfg), spec);
        let err = (back - x).abs();
        prop_assert!(
            err < spec.lsb() + 1e-12,
            "{}: x={x} back={back} err={err}",
            spec.label()
        );
        Ok(())
    });
}

#[test]
fn prop_saturation_bounds_any_input() {
    check("saturation-bounds", 500, |rng| {
        let spec = random_spec(rng);
        let cfg = QuantConfig::ptq(spec);
        let x = rng.normal(0.0, 1e6); // wildly out of range
        let raw = quantize(x, cfg);
        prop_assert!(
            raw >= spec.raw_min() && raw <= spec.raw_max(),
            "{}: raw {raw} outside [{}, {}]",
            spec.label(),
            spec.raw_min(),
            spec.raw_max()
        );
        Ok(())
    });
}

#[test]
fn prop_quantization_monotone() {
    check("quantize-monotone", 300, |rng| {
        let spec = random_spec(rng);
        let cfg = QuantConfig::ptq(spec);
        let a = rng.range(spec.min_value(), spec.max_value());
        let b = rng.range(spec.min_value(), spec.max_value());
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        prop_assert!(
            quantize(lo, cfg) <= quantize(hi, cfg),
            "{}: monotonicity violated at {lo} vs {hi}",
            spec.label()
        );
        Ok(())
    });
}

#[test]
fn prop_rnd_no_worse_than_trn() {
    check("rnd-beats-trn", 300, |rng| {
        let spec = random_spec(rng);
        let x = rng.range(spec.min_value(), spec.max_value());
        let trn = dequantize(
            quantize(
                x,
                QuantConfig {
                    spec,
                    round: RoundMode::Trn,
                    overflow: OverflowMode::Sat,
                },
            ),
            spec,
        );
        let rnd = dequantize(
            quantize(
                x,
                QuantConfig {
                    spec,
                    round: RoundMode::Rnd,
                    overflow: OverflowMode::Sat,
                },
            ),
            spec,
        );
        prop_assert!(
            (rnd - x).abs() <= (trn - x).abs() + 1e-12,
            "{}: x={x} rnd err {} > trn err {}",
            spec.label(),
            (rnd - x).abs(),
            (trn - x).abs()
        );
        Ok(())
    });
}

#[test]
fn prop_requantize_identity_when_same_spec() {
    check("requantize-identity", 300, |rng| {
        let spec = random_spec(rng);
        let cfg = QuantConfig::ptq(spec);
        let x = rng.range(spec.min_value(), spec.max_value());
        let raw = quantize(x, cfg);
        prop_assert!(
            requantize(raw, spec.frac(), cfg) == raw,
            "identity requantize changed raw"
        );
        Ok(())
    });
}

// --------------------------------------------------------------- scheduler

fn random_reuse(rng: &mut Rng) -> ReuseFactor {
    ReuseFactor::new(1 + rng.below(256), 1 + rng.below(256))
}

#[test]
fn prop_ii_never_exceeds_latency() {
    check("ii<=latency", 300, |rng| {
        let archs = zoo::all_archs();
        let arch = &archs[rng.below(archs.len())];
        let mode = if rng.uniform() < 0.5 {
            RnnMode::Static
        } else {
            RnnMode::NonStatic
        };
        let mut cfg = HlsConfig::paper_default(random_spec(rng), random_reuse(rng));
        cfg.mode = mode;
        let t = latency::schedule(arch, &cfg).map_err(|e| e.to_string())?;
        prop_assert!(
            t.ii_cycles <= t.latency_cycles,
            "{} {:?}: II {} > latency {}",
            arch.key(),
            mode,
            t.ii_cycles,
            t.latency_cycles
        );
        Ok(())
    });
}

#[test]
fn prop_nonstatic_ii_never_above_static() {
    check("nonstatic-ii<=static-ii", 300, |rng| {
        let archs = zoo::all_archs();
        let arch = &archs[rng.below(archs.len())];
        let mut cfg = HlsConfig::paper_default(random_spec(rng), random_reuse(rng));
        cfg.mode = RnnMode::Static;
        let stat = latency::schedule(arch, &cfg).map_err(|e| e.to_string())?;
        cfg.mode = RnnMode::NonStatic;
        let non = latency::schedule(arch, &cfg).map_err(|e| e.to_string())?;
        prop_assert!(
            non.ii_cycles <= stat.ii_cycles,
            "{}: non-static II {} > static II {}",
            arch.key(),
            non.ii_cycles,
            stat.ii_cycles
        );
        Ok(())
    });
}

#[test]
fn prop_latency_monotone_in_reuse() {
    check("latency-monotone-reuse", 300, |rng| {
        let archs = zoo::all_archs();
        let arch = &archs[rng.below(archs.len())];
        let spec = random_spec(rng);
        let r1 = 1 + rng.below(128);
        let r2 = r1 + 1 + rng.below(128);
        let cfg1 = HlsConfig::paper_default(spec, ReuseFactor::new(r1, r1));
        let cfg2 = HlsConfig::paper_default(spec, ReuseFactor::new(r2, r2));
        let t1 = latency::schedule(arch, &cfg1).map_err(|e| e.to_string())?;
        let t2 = latency::schedule(arch, &cfg2).map_err(|e| e.to_string())?;
        prop_assert!(
            t2.latency_cycles >= t1.latency_cycles,
            "{}: latency not monotone in reuse ({r1} -> {r2})",
            arch.key()
        );
        Ok(())
    });
}

#[test]
fn prop_resources_antimonotone_in_reuse_monotone_in_width() {
    check("resource-monotonicity", 200, |rng| {
        let archs = zoo::all_archs();
        let arch = &archs[rng.below(archs.len())];
        let w1 = 4 + rng.below(20) as u32;
        let w2 = w1 + 1 + rng.below(4) as u32;
        let integer = 1 + rng.below((w1 - 1) as usize) as u32;
        let r1 = 1 + rng.below(64);
        let r2 = r1 * 2;
        let mk = |w: u32, r: usize| {
            HlsConfig::paper_default(
                FixedSpec::new(w, integer.min(w - 1).max(1)),
                ReuseFactor::new(r, r),
            )
        };
        let wide = resource::estimate(arch, &mk(w2, r1));
        let narrow = resource::estimate(arch, &mk(w1, r1));
        prop_assert!(
            wide.lut >= narrow.lut && wide.ff >= narrow.ff,
            "{}: fabric not monotone in width {w1}->{w2}",
            arch.key()
        );
        let low_r = resource::estimate(arch, &mk(w1, r1));
        let high_r = resource::estimate(arch, &mk(w1, r2));
        prop_assert!(
            high_r.dsp <= low_r.dsp && high_r.lut <= low_r.lut,
            "{}: resources not anti-monotone in reuse {r1}->{r2}",
            arch.key()
        );
        Ok(())
    });
}

#[test]
fn prop_gru_cheaper_than_lstm_everywhere() {
    check("gru<=lstm", 200, |rng| {
        use rnn_hls::model::Cell;
        let names = ["top", "flavor", "quickdraw"];
        let name = names[rng.below(3)];
        let gru = zoo::arch(name, Cell::Gru).map_err(|e| e.to_string())?;
        let lstm = zoo::arch(name, Cell::Lstm).map_err(|e| e.to_string())?;
        let cfg = HlsConfig::paper_default(random_spec(rng), random_reuse(rng));
        let eg = resource::estimate(&gru, &cfg);
        let el = resource::estimate(&lstm, &cfg);
        prop_assert!(
            eg.dsp <= el.dsp && eg.lut <= el.lut && eg.ff <= el.ff,
            "{name}: GRU not cheaper (dsp {} vs {})",
            eg.dsp,
            el.dsp
        );
        Ok(())
    });
}

// ------------------------------------------------------------ coordinator

/// Deadline semantics of `next_batch` under a virtual clock, for random
/// arrival sequences: every flush is triggered by size OR by the batch
/// having waited `max_wait` — never neither, never held past the
/// deadline — `max_wait = 0` always yields batch size 1, order is FIFO,
/// and no request is lost.  Fully deterministic: virtual time only moves
/// via the batcher's own deadline auto-advance.
#[test]
fn prop_next_batch_deadline_semantics_under_virtual_clock() {
    use rnn_hls::coordinator::batcher::next_batch;
    use rnn_hls::coordinator::{
        BatcherConfig, BoundedQueue, Clock, Request, VirtualClock,
    };
    use std::sync::Arc;
    use std::time::Duration;

    check("batcher-deadline-virtual", 250, |rng| {
        let clock = VirtualClock::new();
        let queue = Arc::new(BoundedQueue::new(4096));
        let max_batch = 1 + rng.below(12);
        let wait_us = [0u64, 1, 40, 250, 1_000][rng.below(5)];
        let max_wait = Duration::from_micros(wait_us);
        let cfg = BatcherConfig::new(max_batch, max_wait)
            .map_err(|e| e.to_string())?;
        let n = 1 + rng.below(48) as u64;
        // Random arrival sequence: ids in order, gaps of 0..300 µs.
        for id in 0..n {
            if rng.uniform() < 0.5 {
                clock.advance(Duration::from_micros(rng.below(300) as u64));
            }
            queue
                .push(Request {
                    id,
                    features: vec![0.0; 2],
                    label: 0,
                    route_key: 0,
                    enqueued_at: clock.now(),
                })
                .map_err(|_| "queue overflow".to_string())?;
        }
        let mut popped = 0u64;
        while !queue.is_empty() {
            let t_pop = clock.now();
            let batch = next_batch(&queue, &cfg, &clock)
                .ok_or("non-empty open queue must yield a batch")?;
            let held = batch.formed_at - t_pop;
            prop_assert!(
                batch.len() >= 1 && batch.len() <= max_batch,
                "batch size {} outside 1..={max_batch}",
                batch.len()
            );
            if wait_us == 0 {
                prop_assert!(
                    batch.len() == 1,
                    "max_wait = 0 must be strict batch-1, got {}",
                    batch.len()
                );
            }
            let by_size = batch.len() == max_batch;
            let by_deadline = held >= max_wait;
            prop_assert!(
                by_size || by_deadline,
                "flush of {} after {held:?} satisfies neither size \
                 ({max_batch}) nor deadline ({max_wait:?})",
                batch.len()
            );
            prop_assert!(
                held <= max_wait,
                "batch held {held:?}, past the {max_wait:?} deadline"
            );
            for r in &batch.requests {
                prop_assert!(
                    r.id == popped,
                    "FIFO violated: got {} want {popped}",
                    r.id
                );
                popped += 1;
            }
        }
        prop_assert!(popped == n, "served {popped} of {n} requests");
        Ok(())
    });
}

// ------------------------------------------------------------ nn engines

#[test]
fn prop_fixed_engine_tracks_float_at_high_precision() {
    use rnn_hls::model::Weights;
    use rnn_hls::nn::{Engine, FixedEngine, FloatEngine};

    check("fixed-tracks-float", 20, |rng| {
        // Random small GRU model via the JSON path.
        let h = 2 + rng.below(6);
        let i = 1 + rng.below(4);
        let seq = 2 + rng.below(6);
        let gh = 3 * h;
        let mut rand_vec = |n: usize, scale: f64| -> String {
            let items: Vec<String> = (0..n)
                .map(|_| format!("{:.4}", rng.normal(0.0, scale)))
                .collect();
            format!("[{}]", items.join(","))
        };
        let w = rand_vec(i * gh, 0.4);
        let u = rand_vec(h * gh, 0.4);
        let b = rand_vec(2 * gh, 0.1);
        let dw = rand_vec(h * 4, 0.4);
        let db = rand_vec(4, 0.1);
        let ow = rand_vec(4, 0.4);
        let count = 3 * (i * h + h * h) + 6 * h + (h * 4 + 4) + (4 + 1);
        let doc = format!(
            r#"{{"arch": {{"name": "top", "cell": "gru", "seq_len": {seq},
                "input_size": {i}, "hidden_size": {h}, "dense_sizes": [4],
                "output_size": 1, "output_activation": "sigmoid"}},
              "param_count": {count},
              "layers": [
                {{"name": "rnn",
                  "w": {{"shape": [{i}, {gh}], "data": {w}}},
                  "u": {{"shape": [{h}, {gh}], "data": {u}}},
                  "b": {{"shape": [2, {gh}], "data": {b}}}}},
                {{"name": "dense0",
                  "w": {{"shape": [{h}, 4], "data": {dw}}},
                  "b": {{"shape": [4], "data": {db}}}}},
                {{"name": "out",
                  "w": {{"shape": [4, 1], "data": {ow}}},
                  "b": {{"shape": [1], "data": [0.02]}}}}
              ]}}"#
        );
        let weights = Weights::from_json(&doc).map_err(|e| e.to_string())?;
        let fl = FloatEngine::new(&weights).map_err(|e| e.to_string())?;
        let fx = FixedEngine::new(
            &weights,
            QuantConfig::ptq(FixedSpec::new(26, 8)),
        )
        .map_err(|e| e.to_string())?;
        let x: Vec<f32> = (0..seq * i)
            .map(|_| rng.normal(0.0, 1.0) as f32)
            .collect();
        let yf = fl.forward(&x);
        let yq = fx.forward(&x);
        prop_assert!(
            (yf[0] - yq[0]).abs() < 0.02,
            "h={h} i={i} seq={seq}: float {} vs fixed {}",
            yf[0],
            yq[0]
        );
        Ok(())
    });
}

#[test]
fn prop_forward_batch_bitwise_equals_forward_on_random_models() {
    use rnn_hls::model::{zoo, Weights};
    use rnn_hls::nn::{Engine, FixedEngine, FloatEngine};

    check("batch-equals-forward", 12, |rng| {
        // top + flavor cover lstm/gru × sigmoid/softmax; quickdraw is
        // excluded only to keep debug-mode test time in check.
        let archs: Vec<_> = zoo::all_archs()
            .into_iter()
            .filter(|a| a.name != "quickdraw")
            .collect();
        let arch = &archs[rng.below(archs.len())];
        let weights = Weights::synthetic(arch, rng.next_u64());
        let batch = 1 + rng.below(7);
        let stride = arch.seq_len * arch.input_size;
        let samples: Vec<Vec<f32>> = (0..batch)
            .map(|_| {
                (0..stride)
                    .map(|_| rng.normal(0.0, 1.0) as f32)
                    .collect()
            })
            .collect();
        let refs: Vec<&[f32]> = samples.iter().map(|v| v.as_slice()).collect();
        let workers = 1 + rng.below(8);

        let fl = FloatEngine::new(&weights)
            .map_err(|e| e.to_string())?
            .with_parallelism(workers);
        let want_f: Vec<Vec<f32>> = refs.iter().map(|x| fl.forward(x)).collect();
        prop_assert!(
            fl.forward_batch(&refs) == want_f,
            "{} float batch != forward (b={batch}, w={workers})",
            arch.key()
        );

        let fx = FixedEngine::new(
            &weights,
            QuantConfig::ptq(FixedSpec::new(16, 6)),
        )
        .map_err(|e| e.to_string())?
        .with_parallelism(workers);
        let want_q: Vec<Vec<f32>> = refs.iter().map(|x| fx.forward(x)).collect();
        prop_assert!(
            fx.forward_batch(&refs) == want_q,
            "{} fixed batch != forward (b={batch}, w={workers})",
            arch.key()
        );
        Ok(())
    });
}
