//! Bench: Fig. 2 — the PTQ quantization scan.
//!
//! Times the bit-accurate fixed-point engine (the workhorse of the scan)
//! per model, then regenerates a reduced Fig. 2 grid and checks its
//! shape.  `rnn-hls report fig2` runs the full-resolution version.

use rnn_hls::config::Fig2Config;
use rnn_hls::data::Dataset;
use rnn_hls::fixed::{FixedSpec, QuantConfig};
use rnn_hls::model::Weights;
use rnn_hls::nn::{Engine, FixedEngine, FloatEngine};
use rnn_hls::report::fig2;
use rnn_hls::runtime::manifest;
use rnn_hls::util::timing::{bench_for, report_row};
use std::time::Duration;

fn main() {
    let artifacts = manifest::default_artifacts_dir();
    if !artifacts.join("manifest.json").exists() {
        println!("no artifacts — run `make artifacts` first");
        return;
    }

    println!("=== engine forward-pass cost (per sample) ===");
    for key in ["top_gru", "flavor_gru", "quickdraw_lstm"] {
        let weights =
            Weights::load(artifacts.join(format!("weights/{key}.json"))).unwrap();
        let benchmark = key.split('_').next().unwrap();
        let ds = Dataset::load(
            artifacts.join(format!("data/{benchmark}_test.bin")),
        )
        .unwrap();
        let x = ds.sample(0);

        let float_engine = FloatEngine::new(&weights).unwrap();
        let stats = bench_for(Duration::from_millis(300), || {
            std::hint::black_box(float_engine.forward(x));
        });
        report_row(&format!("float/{key}"), &stats);

        let fixed_engine = FixedEngine::new(
            &weights,
            QuantConfig::ptq(FixedSpec::default16_6()),
        )
        .unwrap();
        let stats = bench_for(Duration::from_millis(300), || {
            std::hint::black_box(fixed_engine.forward(x));
        });
        report_row(&format!("fixed<16,6>/{key}"), &stats);
    }

    println!("\n=== reduced Fig. 2 grid (shape check) ===");
    let cfg = Fig2Config {
        keys: vec!["top_gru".into(), "top_lstm".into()],
        samples: 400,
        integer_bits: vec![6, 10],
        fractional_bits: vec![2, 6, 10, 14],
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let points = fig2::run(&artifacts, &cfg, None).unwrap();
    println!("scan wall time: {:.2} s", t0.elapsed().as_secs_f64());
    for key in &cfg.keys {
        match fig2::shape_check(&points, key) {
            Ok(()) => println!("shape OK: {key}"),
            Err(e) => println!("shape WARN: {e}"),
        }
    }
}
