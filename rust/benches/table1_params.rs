//! Bench: Table 1 — model-zoo construction + weight loading.
//!
//! Regenerates the Table 1 parameter counts (asserted against the paper)
//! and times the cold path a coordinator pays at startup: parsing and
//! validating a full weights JSON.

use rnn_hls::model::{zoo, Cell, Weights};
use rnn_hls::util::timing::{bench, report_row};

fn main() {
    println!("=== Table 1: hyperparameters + parameter counts ===");
    let paper = [
        ("top", 1409usize, 2160usize, 1680usize),
        ("flavor", 6593, 60960, 46080),
        ("quickdraw", 66565, 67584, 51072),
    ];
    for (name, non_rnn, lstm, gru) in paper {
        let al = zoo::arch(name, Cell::Lstm).unwrap();
        let ag = zoo::arch(name, Cell::Gru).unwrap();
        assert_eq!(al.non_rnn_param_count(), non_rnn, "{name} non-rnn");
        assert_eq!(al.rnn_param_count(), lstm, "{name} lstm");
        assert_eq!(ag.rnn_param_count(), gru, "{name} gru");
        println!(
            "{name:<10} non-RNN {non_rnn:>6}  LSTM {lstm:>6}  GRU {gru:>6}  (matches paper)"
        );
    }

    let stats = bench(2, 50, || {
        let archs = zoo::all_archs();
        assert_eq!(archs.len(), 6);
        let total: usize = archs.iter().map(|a| a.param_count()).sum();
        std::hint::black_box(total);
    });
    report_row("zoo/param_count_all6", &stats);

    let artifacts = rnn_hls::runtime::manifest::default_artifacts_dir();
    for key in ["top_gru", "quickdraw_lstm"] {
        let path = artifacts.join(format!("weights/{key}.json"));
        if !path.exists() {
            println!("(skip weight-load bench: {} missing)", path.display());
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let stats = bench(1, 10, || {
            let w = Weights::from_json(&text).unwrap();
            std::hint::black_box(w.param_count());
        });
        report_row(&format!("weights/parse+validate {key}"), &stats);
    }
}
