//! Bench: Fig. 6 + Table 5 — static vs non-static RNN mode.
//!
//! Regenerates the mode comparison (resources blow up ×seq_len, II
//! collapses to 1) and asserts the paper's >300× throughput claim.

use rnn_hls::fixed::FixedSpec;
use rnn_hls::hls::{latency, HlsConfig, ReuseFactor, RnnMode, Strategy};
use rnn_hls::model::{zoo, Cell};
use rnn_hls::report::{resources, tables};

fn main() {
    println!("=== Table 5 ===");
    tables::table5(None).unwrap();

    println!("=== Fig. 6 ===");
    resources::fig6(None).unwrap();

    // §5.3: "increased throughput for non-static mode by a factor of more
    // than 300" for the top-tagging models.
    for cell in [Cell::Gru, Cell::Lstm] {
        let arch = zoo::arch("top", cell).unwrap();
        let mut cfg = HlsConfig::paper_default(
            FixedSpec::new(10, 6),
            ReuseFactor::fully_parallel(),
        );
        cfg.strategy = Strategy::Latency;
        let stat = latency::schedule(&arch, &cfg).unwrap();
        cfg.mode = RnnMode::NonStatic;
        let non = latency::schedule(&arch, &cfg).unwrap();
        let gain = non.throughput_hz / stat.throughput_hz;
        println!(
            "{}: static II {} -> non-static II {} ({:.0}x throughput)",
            arch.key(),
            stat.ii_cycles,
            non.ii_cycles,
            gain
        );
        assert!(gain > 300.0, "paper claims >300x, got {gain:.0}x");
    }
}
