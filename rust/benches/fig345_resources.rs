//! Bench: Figs. 3–5 — DSP/FF/LUT vs total width.
//!
//! Regenerates the three resource figures for every benchmark and times
//! the estimator itself (it sits inside design-space search loops, so
//! its cost matters).

use rnn_hls::config::SweepConfig;
use rnn_hls::fixed::FixedSpec;
use rnn_hls::hls::{resource, HlsConfig, ReuseFactor};
use rnn_hls::model::{zoo, Cell};
use rnn_hls::report::resources;
use rnn_hls::util::timing::{bench, report_row};

fn main() {
    println!("=== estimator micro-cost ===");
    let arch = zoo::arch("quickdraw", Cell::Lstm).unwrap();
    let cfg = HlsConfig::paper_default(
        FixedSpec::new(16, 10),
        ReuseFactor::new(96, 64),
    );
    let stats = bench(100, 10_000, || {
        std::hint::black_box(resource::estimate(&arch, &cfg));
    });
    report_row("resource/estimate quickdraw_lstm", &stats);

    println!("\n=== Figs. 3-5 regeneration ===");
    let t0 = std::time::Instant::now();
    let mut total_points = 0;
    for benchmark in ["top", "flavor", "quickdraw"] {
        let points =
            resources::figs345(&SweepConfig::paper(benchmark), None).unwrap();
        total_points += points.len();
    }
    println!(
        "regenerated {} figure points in {:.2} s",
        total_points,
        t0.elapsed().as_secs_f64()
    );
}
