//! Bench: ablations over the design choices DESIGN.md calls out.
//!
//! 1. **Softmax LUT size/precision** (§5.1: "we find it necessary to
//!    increase the precision and size of the LUT used for the softmax …
//!    of the flavor-tagging and QuickDraw models"): quantized AUC with
//!    the default 1024-entry/<18,8> table vs the enlarged 4096/<24,10>.
//! 2. **Rounding/overflow mode** (Vivado defaults AP_TRN/AP_WRAP vs our
//!    PTQ AP_TRN/AP_SAT): wrap-induced AUC cliffs at small integer
//!    widths justify the saturation default.
//! 3. **Cached static mode** (§3's unimplemented future-work note,
//!    implemented in `hls::latency::schedule_cached_static`): throughput
//!    between plain static and non-static at zero resource cost.

use rnn_hls::data::Dataset;
use rnn_hls::fixed::{FixedSpec, QuantConfig, TableConfig};
use rnn_hls::hls::{latency, paper, HlsConfig};
use rnn_hls::model::{zoo, Cell, Weights};
use rnn_hls::nn::FixedEngine;
use rnn_hls::report::fig2::eval_auc;
use rnn_hls::runtime::manifest;
use rnn_hls::util::threads::default_workers;

fn main() {
    let artifacts = manifest::default_artifacts_dir();
    let workers = default_workers();

    if artifacts.join("manifest.json").exists() {
        println!("=== ablation 1: softmax LUT (flavor_gru, <16,6>) ===");
        let weights =
            Weights::load(artifacts.join("weights/flavor_gru.json")).unwrap();
        let ds = Dataset::load(artifacts.join("data/flavor_test.bin"))
            .unwrap()
            .truncated(500);
        let cfg = QuantConfig::ptq(FixedSpec::new(16, 6));
        for (label, table) in [
            ("default 1024/<18,8>", TableConfig::softmax_default()),
            ("enlarged 4096/<24,10>", TableConfig::softmax_high()),
        ] {
            let engine =
                FixedEngine::with_softmax_table(&weights, cfg, table).unwrap();
            let auc = eval_auc(&engine, &ds, workers);
            println!("  softmax table {label:<22} AUC {auc:.4}");
        }

        println!("\n=== ablation 2: overflow mode (top_gru, small int bits) ===");
        let weights =
            Weights::load(artifacts.join("weights/top_gru.json")).unwrap();
        let ds = Dataset::load(artifacts.join("data/top_test.bin"))
            .unwrap()
            .truncated(500);
        for int_bits in [2u32, 4, 6] {
            let spec = FixedSpec::new(int_bits + 10, int_bits);
            let sat = FixedEngine::new(&weights, QuantConfig::ptq(spec)).unwrap();
            let wrap =
                FixedEngine::new(&weights, QuantConfig::vivado_default(spec))
                    .unwrap();
            println!(
                "  int {int_bits}: AP_SAT AUC {:.4} | AP_WRAP AUC {:.4}",
                eval_auc(&sat, &ds, workers),
                eval_auc(&wrap, &ds, workers)
            );
        }
    } else {
        println!("(skip engine ablations: no artifacts)");
    }

    println!("\n=== ablation 3: cached static mode (§3 future work) ===");
    for (name, cell) in [("top", Cell::Gru), ("quickdraw", Cell::Lstm)] {
        let arch = zoo::arch(name, cell).unwrap();
        let reuse = paper::reuse_grid(name, cell)[0];
        let cfg = HlsConfig::paper_default(FixedSpec::new(16, 6), reuse);
        let plain = latency::schedule(&arch, &cfg).unwrap();
        let (cached, in_flight) =
            latency::schedule_cached_static(&arch, &cfg).unwrap();
        println!(
            "  {:<16} R={:<10} static {:>9.0} ev/s -> cached {:>9.0} ev/s \
             ({}x, {} in flight, latency unchanged {:.1} µs)",
            arch.key(),
            reuse.label(),
            plain.throughput_hz,
            cached.throughput_hz,
            in_flight,
            in_flight,
            cached.latency_us,
        );
        assert!(cached.throughput_hz >= plain.throughput_hz);
    }
}
