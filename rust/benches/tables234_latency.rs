//! Bench: Tables 2–4 — latency bands per reuse factor, model vs paper.
//!
//! Regenerates all three latency tables, reports the worst relative
//! error against the paper's minimum-latency columns, and times the
//! scheduler.

use rnn_hls::fixed::FixedSpec;
use rnn_hls::hls::{latency, HlsConfig, ReuseFactor};
use rnn_hls::model::{zoo, Cell};
use rnn_hls::report::tables;
use rnn_hls::util::timing::{bench, report_row};

fn main() {
    println!("=== scheduler micro-cost ===");
    let arch = zoo::arch("flavor", Cell::Gru).unwrap();
    let cfg = HlsConfig::paper_default(
        FixedSpec::new(16, 6),
        ReuseFactor::new(90, 60),
    );
    let stats = bench(100, 10_000, || {
        std::hint::black_box(latency::schedule(&arch, &cfg).unwrap());
    });
    report_row("latency/schedule flavor_gru", &stats);

    println!("\n=== Tables 2-4 (model vs paper) ===");
    let mut worst: f64 = 0.0;
    let mut worst_at = String::new();
    for benchmark in ["top", "flavor", "quickdraw"] {
        let rows = tables::latency_tables(benchmark, None).unwrap();
        for row in rows {
            if row.min_rel_err() > worst {
                worst = row.min_rel_err();
                worst_at =
                    format!("{benchmark} {} R={}", row.key, row.reuse.label());
            }
        }
    }
    println!(
        "worst min-latency deviation vs paper: {:.1}% ({worst_at})",
        worst * 100.0
    );
    assert!(worst < 0.20, "latency model drifted from the paper");
}
