//! Bench: §5.2 throughput — batch scaling of the serving engines.
//!
//! Seven parts:
//!
//! 1. **Engine batch × worker scaling** (no artifacts needed): the
//!    parallel `forward_batch` runtime vs the sequential per-sample
//!    baseline, swept over batch size × worker count for a small
//!    (top-tagging GRU) and a heavy (QuickDraw LSTM) model.  This is the
//!    measurable form of the paper's batched-GPU-serving comparison: the
//!    batcher+engine pair must turn batch size into throughput.  The
//!    acceptance bar — ≥2× over sequential at batch ≥ 64 with ≥ 4
//!    workers — is asserted on the heavy model.
//! 2. **Shards × workers serving sweep** (no artifacts needed): full
//!    `ShardedServer` sessions over shard counts and routing policies,
//!    reported as samples/s and p50/p99 latency per config.
//! 3. **Mixed-backend serving sweep** (no artifacts needed): the
//!    heterogeneous fixed+float session behind model-key tier routing
//!    vs each backend serving alone, reported *per backend* so the
//!    trigger and offline tiers track their own latency percentiles.
//! 4. **Tier-aware batching sweep** (no artifacts needed): the same
//!    heterogeneous session with per-shard batch policies — trigger
//!    tier pinned at batch-1/zero-wait, offline tier batching deep —
//!    emitting the per-backend batcher columns
//!    (`max_batch`, `max_wait_us`) in `BENCH_serving.json`.
//! 5. **Session-API overhead** (no artifacts needed): the replay
//!    wrapper vs the live request-driven path (public `Session::submit`
//!    + completion channel) on the same stream — the schema-v4
//!    `session_replay_*` / `session_submit_*` row pair.
//! 6. **Network saturation curves** (no artifacts needed): the socket
//!    loadgen drives the `ingest::wire` listener open-loop at 20k/100k/
//!    400k ev/s offered — the schema-v5 `loadgen_r*` rows carrying
//!    `offered_hz`, `shed`, and per-tier p50/p99 under overload.
//! 7. **PJRT vs analytical FPGA band** (requires `make artifacts`): the
//!    original QuickDraw-LSTM comparison against the scheduler's II.
//!
//! Flags (after `--`): `--smoke` runs the reduced-iteration CI variant
//! (shorter budgets, fewer events, no hard perf assertion — machines
//! vary); `--json PATH` writes the serving sweep as machine-readable
//! `BENCH_serving.json` (the CI bench-smoke artifact).

use std::path::PathBuf;
use std::time::Duration;

use rnn_hls::coordinator::ShardPolicy;
use rnn_hls::data::generators;
use rnn_hls::fixed::{FixedSpec, QuantConfig};
use rnn_hls::model::{zoo, Cell, Weights};
use rnn_hls::nn::{Engine, FixedEngine, FloatEngine};
use rnn_hls::report::throughput;
use rnn_hls::runtime::manifest;
use rnn_hls::util::timing::bench_for;

struct BenchOpts {
    smoke: bool,
    json: Option<PathBuf>,
}

fn parse_opts() -> BenchOpts {
    let mut opts = BenchOpts {
        smoke: false,
        json: None,
    };
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--smoke" => opts.smoke = true,
            "--json" => {
                let path = args.next().expect("--json needs a path");
                opts.json = Some(PathBuf::from(path));
            }
            // `cargo bench` forwards its own flags (e.g. --bench); ignore.
            _ => {}
        }
    }
    opts
}

fn scaling_for_engine(
    label: &str,
    engine: &mut FloatEngine,
    samples: &[Vec<f32>],
    budget: Duration,
) -> f64 {
    let mut best_speedup_b64_w4 = 0.0f64;
    println!("  {label}: events/s (speedup vs sequential per-sample loop)");
    println!("  {:>7} {:>12} {:>24} {:>24} {:>24} {:>24}",
        "batch", "sequential", "w=1", "w=2", "w=4", "w=8");
    for &batch in &[1usize, 16, 64, 256] {
        let batch = batch.min(samples.len());
        let xs: Vec<&[f32]> =
            samples[..batch].iter().map(|v| v.as_slice()).collect();
        let seq_stats = bench_for(budget, || {
            for x in &xs {
                std::hint::black_box(engine.forward(x));
            }
        });
        let seq_tput = seq_stats.throughput(batch);
        let mut cells = Vec::new();
        for &workers in &[1usize, 2, 4, 8] {
            engine.set_parallelism(workers);
            let stats = bench_for(budget, || {
                std::hint::black_box(engine.forward_batch(&xs));
            });
            let tput = stats.throughput(batch);
            let speedup = tput / seq_tput;
            if batch >= 64 && workers == 4 {
                best_speedup_b64_w4 = best_speedup_b64_w4.max(speedup);
            }
            cells.push(format!("{tput:>12.0} ({speedup:>4.2}x)"));
        }
        println!(
            "  {batch:>7} {seq_tput:>12.0} {:>24} {:>24} {:>24} {:>24}",
            cells[0], cells[1], cells[2], cells[3]
        );
    }
    engine.set_parallelism(1);
    best_speedup_b64_w4
}

fn engine_scaling(smoke: bool) {
    println!("=== engine batch × worker scaling (synthetic weights) ===");
    // Smoke mode trades statistical tightness for CI turnaround.
    let (budget_small, budget_heavy) = if smoke {
        (Duration::from_millis(40), Duration::from_millis(60))
    } else {
        (Duration::from_millis(150), Duration::from_millis(250))
    };

    // Small model: spawn overhead is visible, scaling is informational.
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let weights = Weights::synthetic(&arch, 0xBA7C4);
    let mut generator = generators::for_benchmark("top", 99).unwrap();
    let samples: Vec<Vec<f32>> =
        (0..256).map(|_| generator.generate().features).collect();
    let mut engine = FloatEngine::new(&weights).unwrap();
    scaling_for_engine("float/top_gru", &mut engine, &samples, budget_small);

    // Correctness spot-check: batched output identical to sequential.
    engine.set_parallelism(4);
    let xs: Vec<&[f32]> = samples[..64].iter().map(|v| v.as_slice()).collect();
    let want: Vec<Vec<f32>> = xs.iter().map(|x| engine.forward(x)).collect();
    assert_eq!(engine.forward_batch(&xs), want, "batched != sequential");
    engine.set_parallelism(1);

    // Heavy model: this is where the acceptance bar applies.
    let arch = zoo::arch("quickdraw", Cell::Lstm).unwrap();
    let weights = Weights::synthetic(&arch, 0xD0D0);
    let mut generator = generators::for_benchmark("quickdraw", 7).unwrap();
    let samples: Vec<Vec<f32>> =
        (0..256).map(|_| generator.generate().features).collect();
    let mut engine = FloatEngine::new(&weights).unwrap();
    let speedup = scaling_for_engine(
        "float/quickdraw_lstm",
        &mut engine,
        &samples,
        budget_heavy,
    );
    println!(
        "  quickdraw_lstm speedup at batch>=64, 4 workers: {speedup:.2}x \
         (bar: >= 2x)"
    );
    // Only enforce the bar where 4 workers can actually run in parallel
    // and we measured with full budgets; smoke runs (shared CI machines,
    // short budgets) report the number without aborting the job.
    let cores = rnn_hls::util::threads::default_workers();
    if cores >= 4 && !smoke {
        assert!(
            speedup >= 2.0,
            "parallel forward_batch only {speedup:.2}x over sequential at \
             batch>=64 with 4 workers ({cores} cores)"
        );
    } else if speedup < 2.0 {
        println!(
            "  (bar not enforced: smoke={smoke}, {cores} cores; \
             measured {speedup:.2}x)"
        );
    }

    // Fixed engine: the bit-accurate datapath scales the same way.
    let arch = zoo::arch("top", Cell::Gru).unwrap();
    let weights = Weights::synthetic(&arch, 0xF1C5);
    let mut generator = generators::for_benchmark("top", 3).unwrap();
    let samples: Vec<Vec<f32>> =
        (0..64).map(|_| generator.generate().features).collect();
    let xs: Vec<&[f32]> = samples.iter().map(|v| v.as_slice()).collect();
    let mut fixed =
        FixedEngine::new(&weights, QuantConfig::ptq(FixedSpec::new(16, 6)))
            .unwrap();
    let seq = bench_for(budget_small, || {
        for x in &xs {
            std::hint::black_box(fixed.forward(x));
        }
    });
    println!("  fixed<16,6>/top_gru batch 64:");
    println!("    sequential: {:>10.0} ev/s", seq.throughput(64));
    for workers in [1usize, 4] {
        fixed.set_parallelism(workers);
        let stats = bench_for(budget_small, || {
            std::hint::black_box(fixed.forward_batch(&xs));
        });
        println!(
            "    batched w={workers}: {:>9.0} ev/s ({:.2}x)",
            stats.throughput(64),
            stats.throughput(64) / seq.throughput(64)
        );
    }
}

/// Full serving sessions over shards × policy: the horizontal-scaling
/// counterpart to the per-engine sweep above, and the source of the
/// `BENCH_serving.json` rows CI tracks.
fn shard_scaling(smoke: bool) -> Vec<throughput::ServingBenchRow> {
    println!("\n=== shards × workers serving sweep (float/top_gru) ===");
    let n_events = if smoke { 4_000 } else { 20_000 };
    let shard_counts = [1usize, 2, 4];
    let policies = [ShardPolicy::HashId, ShardPolicy::RoundRobin];
    let rows = throughput::shard_sweep(&shard_counts, &policies, 2, n_events)
        .expect("shard sweep");
    println!(
        "  {:>22} {:>12} {:>10} {:>10} {:>10} {:>9}",
        "config", "samples/s", "p50 µs", "p99 µs", "completed", "dropped"
    );
    for r in &rows {
        println!(
            "  {:>22} {:>12.0} {:>10.1} {:>10.1} {:>10} {:>9}",
            r.config, r.samples_per_sec, r.p50_us, r.p99_us, r.completed,
            r.dropped
        );
        // Correctness, not speed: every event must be accounted for.
        assert_eq!(
            r.completed + r.dropped,
            n_events as u64,
            "{}: lost events",
            r.config
        );
    }
    rows
}

/// Heterogeneous serving: fixed+float in one session, per-backend rows.
fn backend_scaling(smoke: bool) -> Vec<throughput::ServingBenchRow> {
    println!(
        "\n=== mixed-backend serving sweep (fixed + float, model-key \
         tier routing) ==="
    );
    let n_events = if smoke { 3_000 } else { 12_000 };
    let rows = throughput::mixed_backend_sweep(2, n_events)
        .expect("mixed-backend sweep");
    println!(
        "  {:>22} {:>8} {:>12} {:>10} {:>10} {:>10} {:>9}",
        "config", "backend", "samples/s", "p50 µs", "p99 µs", "completed",
        "dropped"
    );
    for r in &rows {
        println!(
            "  {:>22} {:>8} {:>12.0} {:>10.1} {:>10.1} {:>10} {:>9}",
            r.config, r.backend, r.samples_per_sec, r.p50_us, r.p99_us,
            r.completed, r.dropped
        );
    }
    // Correctness, not speed: singles see the whole stream, the mixed
    // tiers partition it exactly.
    for r in rows.iter().filter(|r| r.config.starts_with("single_")) {
        assert_eq!(
            r.completed + r.dropped,
            n_events as u64,
            "{}: lost events",
            r.config
        );
    }
    let mixed: u64 = rows
        .iter()
        .filter(|r| r.config.starts_with("mixed"))
        .map(|r| r.completed + r.dropped)
        .sum();
    assert_eq!(mixed, n_events as u64, "mixed tiers must partition");
    rows
}

/// Session-API overhead: the replay wrapper vs the live submit path
/// (public `Session` API with the completion channel on), same stream.
fn session_scaling(smoke: bool) -> Vec<throughput::ServingBenchRow> {
    println!(
        "\n=== session API overhead (replay wrapper vs live submit) ==="
    );
    let n_events = if smoke { 3_000 } else { 12_000 };
    let rows = throughput::session_submit_sweep(2, n_events)
        .expect("session submit sweep");
    println!(
        "  {:>22} {:>12} {:>10} {:>10} {:>10} {:>9}",
        "config", "samples/s", "p50 µs", "p99 µs", "completed", "dropped"
    );
    for r in &rows {
        println!(
            "  {:>22} {:>12.0} {:>10.1} {:>10.1} {:>10} {:>9}",
            r.config, r.samples_per_sec, r.p50_us, r.p99_us, r.completed,
            r.dropped
        );
        // Correctness, not speed: both paths must account for every
        // event and actually serve the stream.
        assert_eq!(
            r.completed + r.dropped,
            n_events as u64,
            "{}: lost events",
            r.config
        );
        assert!(r.completed > 0, "{}: nothing served", r.config);
    }
    rows
}

/// Tier-aware batching: trigger tier at strict batch-1, offline tier
/// batching deep, per-backend rows carrying their batcher columns.
fn tier_batch_scaling(smoke: bool) -> Vec<throughput::ServingBenchRow> {
    println!(
        "\n=== tier-aware batching sweep (trigger batch-1 vs offline \
         deep) ==="
    );
    let n_events = if smoke { 3_000 } else { 12_000 };
    let rows = throughput::tier_batch_sweep(2, n_events)
        .expect("tier batch sweep");
    println!(
        "  {:>22} {:>8} {:>6} {:>8} {:>12} {:>10} {:>10} {:>10} {:>9}",
        "config", "backend", "batch", "wait µs", "samples/s", "p50 µs",
        "p99 µs", "completed", "dropped"
    );
    for r in &rows {
        println!(
            "  {:>22} {:>8} {:>6} {:>8} {:>12.0} {:>10.1} {:>10.1} {:>10} \
             {:>9}",
            r.config, r.backend, r.max_batch, r.max_wait_us,
            r.samples_per_sec, r.p50_us, r.p99_us, r.completed, r.dropped
        );
    }
    // Correctness, not speed: the tiers must partition the stream, and
    // the policy columns must carry the pinned tier configs.
    let routed: u64 = rows.iter().map(|r| r.completed + r.dropped).sum();
    assert_eq!(routed, n_events as u64, "tier sweep lost events");
    let fixed = rows.iter().find(|r| r.backend == "fixed").unwrap();
    assert_eq!(fixed.max_batch, 1, "trigger tier must be batch-1");
    assert_eq!(fixed.max_wait_us, 0);
    let float = rows.iter().find(|r| r.backend == "float").unwrap();
    assert!(float.max_batch > 1, "offline tier must batch deep");
    rows
}

/// Network saturation curves: real sockets, open-loop offered load,
/// three load points spanning under- to over-saturation — the source of
/// the schema-v5 `loadgen_r*` rows.
fn loadgen_scaling(smoke: bool) -> Vec<throughput::ServingBenchRow> {
    println!(
        "\n=== network saturation curves (socket loadgen, fixed + float) ==="
    );
    let events_per_point = if smoke { 2_000 } else { 20_000 };
    let rows = throughput::loadgen_sweep(2, events_per_point)
        .expect("loadgen sweep");
    println!(
        "  {:>24} {:>8} {:>11} {:>12} {:>10} {:>10} {:>10} {:>9} {:>8}",
        "config", "backend", "offered/s", "samples/s", "p50 µs", "p99 µs",
        "completed", "dropped", "shed"
    );
    for r in &rows {
        println!(
            "  {:>24} {:>8} {:>11.0} {:>12.0} {:>10.1} {:>10.1} {:>10} \
             {:>9} {:>8}",
            r.config, r.backend, r.offered_hz, r.samples_per_sec, r.p50_us,
            r.p99_us, r.completed, r.dropped, r.shed
        );
    }
    // Correctness, not speed: the sweep must produce the full load
    // ladder (loadgen_sweep already asserted the client-side identity
    // per point), and each point must serve something.
    let merged: Vec<_> = rows
        .iter()
        .filter(|r| r.config.ends_with("_merged_w2"))
        .collect();
    assert_eq!(merged.len(), 3, "expected 3 saturation-curve load points");
    for r in &merged {
        assert!(r.completed > 0, "{}: nothing served over TCP", r.config);
    }
    rows
}

fn main() {
    let opts = parse_opts();
    engine_scaling(opts.smoke);
    let mut rows = shard_scaling(opts.smoke);
    rows.extend(backend_scaling(opts.smoke));
    rows.extend(tier_batch_scaling(opts.smoke));
    rows.extend(session_scaling(opts.smoke));
    rows.extend(loadgen_scaling(opts.smoke));
    if let Some(path) = &opts.json {
        let written =
            throughput::write_bench_json(path, &rows).expect("bench json");
        println!("wrote {}", written.display());
    }

    println!("\n=== PJRT vs analytical FPGA band ===");
    let artifacts = manifest::default_artifacts_dir();
    if !artifacts.join("manifest.json").exists() {
        println!("no artifacts — skipping the PJRT comparison");
        return;
    }
    let report = throughput::run(&artifacts, 2_000, None).unwrap();
    match throughput::shape_check(&report) {
        Ok(()) => println!("shape check OK"),
        Err(e) => {
            println!("shape check FAILED: {e}");
            std::process::exit(1);
        }
    }
    // Paper's headline: batch-1 FPGA ≈ 10× batch-1 GPU.  Our analog:
    // the FPGA band must dominate the engine's batch-1 rate.
    let fpga_min = report.get("fpga_model_min").unwrap();
    let b1 = report.get("engine_batch1").unwrap();
    println!(
        "batch-1 advantage (fpga_min / engine_b1): {:.1}x (paper ~6.5-15x)",
        fpga_min / b1
    );
}
