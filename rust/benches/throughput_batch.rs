//! Bench: §5.2 throughput — FPGA estimate vs batched engine (GPU analog).
//!
//! Reproduces the paper's QuickDraw-LSTM comparison: the analytical FPGA
//! throughput band from the scheduler's II, against the measured PJRT
//! batch-1/10/100 throughput (the dense-pipeline engine standing in for
//! the V100).  The *shape* requirements — monotone batch scaling, large
//! batch-100 amortization, FPGA band in the paper's 4300–9700 ev/s
//! regime — are asserted.

use rnn_hls::report::throughput;
use rnn_hls::runtime::manifest;

fn main() {
    let artifacts = manifest::default_artifacts_dir();
    if !artifacts.join("manifest.json").exists() {
        println!("no artifacts — run `make artifacts` first");
        return;
    }
    let report = throughput::run(&artifacts, 2_000, None).unwrap();
    match throughput::shape_check(&report) {
        Ok(()) => println!("shape check OK"),
        Err(e) => {
            println!("shape check FAILED: {e}");
            std::process::exit(1);
        }
    }
    // Paper's headline: batch-1 FPGA ≈ 10× batch-1 GPU.  Our analog:
    // the FPGA band must dominate the engine's batch-1 rate.
    let fpga_min = report.get("fpga_model_min").unwrap();
    let b1 = report.get("engine_batch1").unwrap();
    println!(
        "batch-1 advantage (fpga_min / engine_b1): {:.1}x (paper ~6.5-15x)",
        fpga_min / b1
    );
}
