//! Bench: hot-path microbenchmarks for the §Perf optimization loop.
//!
//! Everything the serving path touches per request, measured in
//! isolation: fixed/float matvec-bound forwards, LUT activations, queue
//! handoff, batch formation, JSON parse (startup), PJRT dispatch.

use std::sync::Arc;
use std::time::Duration;

use rnn_hls::coordinator::{
    batcher, BatcherConfig, BoundedQueue, Request, SystemClock,
};
use rnn_hls::data::generators;
use rnn_hls::fixed::{ActTables, FixedSpec, QuantConfig};
use rnn_hls::model::{zoo, Cell, Weights};
use rnn_hls::nn::{Engine, FixedEngine, FloatEngine};
use rnn_hls::runtime::manifest;
use rnn_hls::util::timing::{bench, bench_for, report_row};

fn main() {
    let q16 = QuantConfig::ptq(FixedSpec::default16_6());

    // Activation LUT lookup.
    let tables = ActTables::new(q16);
    let raws: Vec<i64> = (-512..512).map(|i| i * 17).collect();
    let stats = bench(10, 2000, || {
        let mut acc = 0i64;
        for &r in &raws {
            acc = acc.wrapping_add(tables.sigmoid_raw(r, q16.spec));
        }
        std::hint::black_box(acc);
    });
    report_row("fixed/sigmoid_lut x1024", &stats);

    // Generator cost (source thread budget).
    let mut gen = generators::for_benchmark("top", 1).unwrap();
    let stats = bench(100, 5000, || {
        std::hint::black_box(gen.generate());
    });
    report_row("generator/top_event", &stats);

    // Queue push+pop round trip.
    let queue: Arc<BoundedQueue<Request>> = Arc::new(BoundedQueue::new(1024));
    let req = Request {
        id: 0,
        features: vec![0.0f32; 120],
        label: 0,
        route_key: 0,
        enqueued_at: std::time::Instant::now(),
    };
    let stats = bench(100, 100_000, || {
        queue.push(req.clone()).unwrap();
        std::hint::black_box(queue.pop_timeout(Duration::from_millis(1)));
    });
    report_row("queue/push+pop", &stats);

    // Batch formation from a pre-filled queue.
    let stats = bench(10, 2000, || {
        for i in 0..10 {
            queue
                .push(Request {
                    id: i,
                    features: vec![0.0f32; 120],
                    label: 0,
                    route_key: 0,
                    enqueued_at: std::time::Instant::now(),
                })
                .unwrap();
        }
        // Non-zero wait: zero is the strict batch-1 trigger regime now;
        // the pre-filled queue still fills the batch via the drain fast
        // path without ever consulting the deadline.
        let cfg = BatcherConfig {
            max_batch: 10,
            max_wait: Duration::from_micros(100),
        };
        let batch =
            batcher::next_batch(&queue, &cfg, &SystemClock).unwrap();
        std::hint::black_box(batch.packed_features());
    });
    report_row("batcher/form_batch10+pack", &stats);

    // Batched engine datapath: sequential vs lockstep vs parallel
    // (synthetic weights — exercises the serving hot path end to end).
    {
        let arch = zoo::arch("top", Cell::Gru).unwrap();
        let weights = Weights::synthetic(&arch, 0x707);
        let mut generator = generators::for_benchmark("top", 5).unwrap();
        let samples: Vec<Vec<f32>> =
            (0..64).map(|_| generator.generate().features).collect();
        let xs: Vec<&[f32]> =
            samples.iter().map(|v| v.as_slice()).collect();

        let mut float_engine = FloatEngine::new(&weights).unwrap();
        let stats = bench_for(Duration::from_millis(200), || {
            for x in &xs {
                std::hint::black_box(float_engine.forward(x));
            }
        });
        report_row("float/top_gru b64 sequential", &stats);
        for workers in [1usize, 4] {
            float_engine.set_parallelism(workers);
            let stats = bench_for(Duration::from_millis(200), || {
                std::hint::black_box(float_engine.forward_batch(&xs));
            });
            report_row(&format!("float/top_gru b64 batch w={workers}"), &stats);
        }

        let mut fixed_engine =
            FixedEngine::new(&weights, q16).unwrap();
        let stats = bench_for(Duration::from_millis(200), || {
            for x in &xs {
                std::hint::black_box(fixed_engine.forward(x));
            }
        });
        report_row("fixed<16,6>/top_gru b64 sequential", &stats);
        fixed_engine.set_parallelism(4);
        let stats = bench_for(Duration::from_millis(200), || {
            std::hint::black_box(fixed_engine.forward_batch(&xs));
        });
        report_row("fixed<16,6>/top_gru b64 batch w=4", &stats);
    }

    // PJRT dispatch (needs artifacts).
    let artifacts = manifest::default_artifacts_dir();
    if artifacts.join("manifest.json").exists() {
        let runtime = rnn_hls::runtime::Runtime::new(&artifacts).unwrap();
        for (key, batch) in
            [("top_gru", 1usize), ("top_gru", 10), ("quickdraw_lstm", 1)]
        {
            let model = runtime.model(key, batch).unwrap();
            let xs = vec![0.1f32; batch * model.seq_len * model.input_size];
            let stats = bench_for(Duration::from_millis(500), || {
                std::hint::black_box(model.run_batch(&xs, batch).unwrap());
            });
            report_row(&format!("pjrt/{key}_b{batch}"), &stats);
        }
    } else {
        println!("(skip pjrt benches: no artifacts)");
    }
}
