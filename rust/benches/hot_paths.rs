//! Bench: hot-path microbenchmarks for the §Perf optimization loop.
//!
//! Everything the serving path touches per request, measured in
//! isolation: fixed/float matvec-bound forwards, the raw matmul kernels
//! (dispatched vs scalar — the SIMD win, tracked in
//! `BENCH_kernels.json`), LUT activations, queue handoff, batch
//! formation, allocations per submit→complete round trip on a warm
//! session, and PJRT dispatch.
//!
//! Flags (after `cargo bench --bench hot_paths --`):
//!
//! * `--smoke`      — short iteration counts (CI's schema check, not a
//!                    measurement run)
//! * `--json PATH`  — also emit the kernel rows + alloc count as
//!                    machine-readable JSON (`BENCH_kernels.json`)

use std::alloc::{GlobalAlloc, Layout, System};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use rnn_hls::coordinator::{
    batcher, BatchRunner, BatcherConfig, BoundedQueue, Request,
    SystemClock,
};
use rnn_hls::data::generators;
use rnn_hls::fixed::{ActTables, FixedSpec, QuantConfig};
use rnn_hls::model::{zoo, Cell, Weights};
use rnn_hls::nn::{kernels, Engine, FixedEngine, FloatEngine};
use rnn_hls::runtime::manifest;
use rnn_hls::util::json;
use rnn_hls::util::timing::{bench, bench_for, report_row, Stats};
use rnn_hls::{ServingSpec, Session};

// ------------------------------------------------- counting allocator
//
// Wraps the system allocator with an allocation counter so the bench
// can report *allocations per request* on the warm serving path — the
// number the buffer-recycling layer exists to drive down.  Bench-only:
// library and test code never install a global allocator.

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

// ------------------------------------------------------ alloc round trip

/// Minimal width-1 runner for the allocation-count session: `run_into`
/// writes straight into the packed output so the runner itself is
/// steady-state alloc-free (the default `run` would build per-request
/// `Vec`s and drown the measurement).
struct SinkRunner;

impl BatchRunner for SinkRunner {
    fn max_batch(&self) -> usize {
        1
    }

    fn run(&mut self, _xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        Ok(vec![vec![0.5f32]; n])
    }

    fn run_into(
        &mut self,
        _xs: &[f32],
        n: usize,
        out: &mut rnn_hls::nn::PackedOut,
    ) -> anyhow::Result<()> {
        out.reset(1);
        for _ in 0..n {
            out.push_row(&[0.5f32]);
        }
        Ok(())
    }
}

/// One submit→complete round trip on the recycled-buffer path: draw a
/// feature buffer from the session pool, fill, submit, receive.
fn roundtrip(session: &Session) {
    let mut features = session.recycled_features();
    features.resize(120, 0.1f32);
    let request = session.prepare_event(features, 0);
    session.submit(request).expect("queue never full here");
    std::hint::black_box(session.recv().expect("fabric alive"));
}

/// Allocations per submit→complete round trip on a *warm* session —
/// feature buffers ping-pong through the pool, the runner writes into
/// the worker's packed buffer, so what remains is the per-batch floor
/// (batch Vec, output Arc, channel handoff), not per-request copies.
fn allocs_per_roundtrip(iters: usize) -> f64 {
    let spec = ServingSpec {
        shards: 1,
        workers: 1,
        queue_capacity: 64,
        batcher: BatcherConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
        },
        ..ServingSpec::default()
    };
    let session = Session::start(&spec, |_shard| {
        Ok(Box::new(SinkRunner) as Box<dyn BatchRunner>)
    })
    .unwrap();
    for _ in 0..200 {
        roundtrip(&session);
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..iters {
        roundtrip(&session);
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    session.shutdown().unwrap();
    delta as f64 / iters as f64
}

// ----------------------------------------------------------- json emit

/// Emit the kernel rows + alloc count as the `BENCH_kernels.json` CI
/// artifact (same idiom as `report::throughput::write_bench_json`).
fn write_kernels_json(
    path: &Path,
    rows: &[(String, Stats)],
    allocs: f64,
) -> anyhow::Result<()> {
    let doc = json::obj(vec![
        ("bench", json::s("kernels")),
        ("schema_version", json::num(1.0)),
        (
            "simd_compiled",
            json::num(u64::from(kernels::simd_compiled()) as f64),
        ),
        (
            "simd_active",
            json::num(u64::from(kernels::simd_active()) as f64),
        ),
        ("allocs_per_roundtrip", json::num(allocs)),
        (
            "rows",
            json::arr(
                rows.iter()
                    .map(|(name, s)| {
                        json::obj(vec![
                            ("name", json::s(name)),
                            ("mean_ns", json::num(s.mean.as_nanos() as f64)),
                            ("p50_ns", json::num(s.p50.as_nanos() as f64)),
                            ("p99_ns", json::num(s.p99.as_nanos() as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut text = doc.to_json();
    text.push('\n');
    std::fs::write(path, text)?;
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path: Option<PathBuf> = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from);

    // Smoke mode shrinks every loop: CI checks the schema and that each
    // row executes, not the numbers.
    let scale = |n: usize| if smoke { (n / 40).max(5) } else { n };
    let budget = Duration::from_millis(if smoke { 20 } else { 200 });

    let q16 = QuantConfig::ptq(FixedSpec::default16_6());

    // Activation LUT lookup.
    let tables = ActTables::new(q16);
    let raws: Vec<i64> = (-512..512).map(|i| i * 17).collect();
    let stats = bench(10, scale(2000), || {
        let mut acc = 0i64;
        for &r in &raws {
            acc = acc.wrapping_add(tables.sigmoid_raw(r, q16.spec));
        }
        std::hint::black_box(acc);
    });
    report_row("fixed/sigmoid_lut x1024", &stats);

    // Raw matmul kernels, dispatched vs scalar — serving-shaped
    // (64 outputs from 72 inputs, batch 8).  With `--features simd` on
    // an AVX2 host the dispatched rows take the vector path; the pair
    // of rows is the tracked speedup.
    let mut kernel_rows: Vec<(String, Stats)> = Vec::new();
    {
        let (rows_out, cols_in, batch) = (64usize, 72usize, 8usize);
        let wt: Vec<f32> = (0..rows_out * cols_in)
            .map(|i| ((i * 7 % 23) as f32 - 11.0) * 0.13)
            .collect();
        let xs: Vec<f32> = (0..batch * cols_in)
            .map(|i| (i as f32 * 0.37 - 1.5) * 0.61)
            .collect();
        let mut ys = vec![0.0f32; batch * rows_out];
        let stats = bench(100, scale(20_000), || {
            ys.iter_mut().for_each(|y| *y = 0.0);
            kernels::matmul_acc_f32(
                &wt, rows_out, cols_in, &xs, batch, &mut ys,
            );
            std::hint::black_box(&ys);
        });
        report_row("float/matmul_acc 64x72 b8", &stats);
        kernel_rows.push(("float/matmul_acc".to_string(), stats));
        let stats = bench(100, scale(20_000), || {
            ys.iter_mut().for_each(|y| *y = 0.0);
            kernels::matmul_acc_f32_scalar(
                &wt, rows_out, cols_in, &xs, batch, &mut ys,
            );
            std::hint::black_box(&ys);
        });
        report_row("float/matmul_acc_scalar 64x72 b8", &stats);
        kernel_rows.push(("float/matmul_acc_scalar".to_string(), stats));

        let wt: Vec<i64> = (0..rows_out * cols_in)
            .map(|i| (i as i64 * 131 - 64) % (1 << 25))
            .collect();
        let xs: Vec<i64> = (0..batch * cols_in)
            .map(|i| (i as i64 * 57 - 999) % (1 << 25))
            .collect();
        let mut ys = vec![0i64; batch * rows_out];
        let stats = bench(100, scale(20_000), || {
            ys.iter_mut().for_each(|y| *y = 0);
            kernels::matmul_acc_i64(
                &wt, rows_out, cols_in, &xs, batch, &mut ys,
            );
            std::hint::black_box(&ys);
        });
        report_row("fixed/matmul_acc 64x72 b8", &stats);
        kernel_rows.push(("fixed/matmul_acc".to_string(), stats));
        let stats = bench(100, scale(20_000), || {
            ys.iter_mut().for_each(|y| *y = 0);
            kernels::matmul_acc_i64_scalar(
                &wt, rows_out, cols_in, &xs, batch, &mut ys,
            );
            std::hint::black_box(&ys);
        });
        report_row("fixed/matmul_acc_scalar 64x72 b8", &stats);
        kernel_rows.push(("fixed/matmul_acc_scalar".to_string(), stats));
    }

    // Generator cost (source thread budget).
    let mut gen = generators::for_benchmark("top", 1).unwrap();
    let stats = bench(100, scale(5000), || {
        std::hint::black_box(gen.generate());
    });
    report_row("generator/top_event", &stats);

    // Queue push+pop round trip.  The request is moved through the
    // queue and recovered from the pop — no clone in the timed loop
    // (cloning a 120-float request used to dominate this row).
    let queue: Arc<BoundedQueue<Request>> = Arc::new(BoundedQueue::new(1024));
    let mut slot = Some(Request {
        id: 0,
        features: vec![0.0f32; 120],
        label: 0,
        route_key: 0,
        enqueued_at: std::time::Instant::now(),
    });
    let stats = bench(100, scale(100_000), || {
        queue.push(slot.take().unwrap()).unwrap();
        slot = queue.pop_timeout(Duration::from_millis(1));
        std::hint::black_box(slot.is_some());
    });
    report_row("queue/push+pop", &stats);

    // Batch formation from a pre-filled queue.
    let stats = bench(10, scale(2000), || {
        for i in 0..10 {
            queue
                .push(Request {
                    id: i,
                    features: vec![0.0f32; 120],
                    label: 0,
                    route_key: 0,
                    enqueued_at: std::time::Instant::now(),
                })
                .unwrap();
        }
        // Non-zero wait: zero is the strict batch-1 trigger regime now;
        // the pre-filled queue still fills the batch via the drain fast
        // path without ever consulting the deadline.
        let cfg = BatcherConfig {
            max_batch: 10,
            max_wait: Duration::from_micros(100),
        };
        let batch =
            batcher::next_batch(&queue, &cfg, &SystemClock).unwrap();
        std::hint::black_box(batch.packed_features());
    });
    report_row("batcher/form_batch10+pack", &stats);

    // Allocations per submit→complete round trip on a warm session —
    // the buffer-recycling regression number (per-request buffers come
    // from pools; what's left is the per-batch floor).
    let allocs = allocs_per_roundtrip(scale(2000));
    println!(
        "session/allocs_per_roundtrip                 {allocs:.2} \
         (simd_compiled={} simd_active={})",
        kernels::simd_compiled(),
        kernels::simd_active()
    );

    if let Some(path) = &json_path {
        write_kernels_json(path, &kernel_rows, allocs).unwrap();
        println!("wrote {}", path.display());
    }

    // Batched engine datapath: sequential vs lockstep vs parallel
    // (synthetic weights — exercises the serving hot path end to end).
    {
        let arch = zoo::arch("top", Cell::Gru).unwrap();
        let weights = Weights::synthetic(&arch, 0x707);
        let mut generator = generators::for_benchmark("top", 5).unwrap();
        let samples: Vec<Vec<f32>> =
            (0..64).map(|_| generator.generate().features).collect();
        let xs: Vec<&[f32]> =
            samples.iter().map(|v| v.as_slice()).collect();

        let mut float_engine = FloatEngine::new(&weights).unwrap();
        let stats = bench_for(budget, || {
            for x in &xs {
                std::hint::black_box(float_engine.forward(x));
            }
        });
        report_row("float/top_gru b64 sequential", &stats);
        for workers in [1usize, 4] {
            float_engine.set_parallelism(workers);
            let stats = bench_for(budget, || {
                std::hint::black_box(float_engine.forward_batch(&xs));
            });
            report_row(&format!("float/top_gru b64 batch w={workers}"), &stats);
        }

        let mut fixed_engine =
            FixedEngine::new(&weights, q16).unwrap();
        let stats = bench_for(budget, || {
            for x in &xs {
                std::hint::black_box(fixed_engine.forward(x));
            }
        });
        report_row("fixed<16,6>/top_gru b64 sequential", &stats);
        fixed_engine.set_parallelism(4);
        let stats = bench_for(budget, || {
            std::hint::black_box(fixed_engine.forward_batch(&xs));
        });
        report_row("fixed<16,6>/top_gru b64 batch w=4", &stats);
    }

    // PJRT dispatch (needs artifacts).
    let artifacts = manifest::default_artifacts_dir();
    if !smoke && artifacts.join("manifest.json").exists() {
        let runtime = rnn_hls::runtime::Runtime::new(&artifacts).unwrap();
        for (key, batch) in
            [("top_gru", 1usize), ("top_gru", 10), ("quickdraw_lstm", 1)]
        {
            let model = runtime.model(key, batch).unwrap();
            let xs = vec![0.1f32; batch * model.seq_len * model.input_size];
            let stats = bench_for(Duration::from_millis(500), || {
                std::hint::black_box(model.run_batch(&xs, batch).unwrap());
            });
            report_row(&format!("pjrt/{key}_b{batch}"), &stats);
        }
    } else {
        println!("(skip pjrt benches: no artifacts or smoke mode)");
    }
}
