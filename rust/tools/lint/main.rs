//! Invariant lint for the serving fabric — pure std, line-based
//! "AST-lite" rules, wired into `ci.sh` (and `ci.sh --analysis`).
//!
//! The crate routes every sync primitive through the `util::sync` shim
//! so the model checker can instrument them; these rules keep that
//! gateway (and the accounting/unsafe discipline around it) from
//! eroding:
//!
//! * **R1 sync-gateway** — no `use std::sync::{Mutex, MutexGuard,
//!   Condvar}` or `std::sync::mpsc` (imports or qualified paths)
//!   outside `util/sync.rs`.  `Arc`, `PoisonError`, and
//!   `std::sync::atomic` remain legal everywhere.
//! * **R2 accounting-ordering** — no `Ordering::Relaxed` on a *write*
//!   (`fetch_add` / `fetch_sub` / `fetch_max` / `.store(`) touching an
//!   accounting counter (`generated`, `dropped`, `completed`, `lost`).
//!   The `generated == completed + dropped` identity is checked across
//!   threads; relaxed loads for display stay legal.
//! * **R3 lock-recovery** — no `.unwrap()` / `.expect(` on a statement
//!   containing `.lock()` outside the shim: lock acquisition goes
//!   through `lock_or_recover`, which survives poisoning.
//! * **R4 unsafe-allowlist** — `unsafe` only in allowlisted files
//!   (`util/threads.rs` for the scoped-thread transmute,
//!   `nn/kernels.rs` for the SIMD intrinsics), and there only with a
//!   `SAFETY:` comment in the preceding lines.
//! * **R5 shim-confinement** — the network front-end
//!   (`src/coordinator/net*`), the ingest layer (`src/ingest/`), and
//!   the buffer-pool primitive (`src/util/pool.rs`) must take atomics
//!   and threads through `crate::util::sync` too: no
//!   `std::sync::atomic` or `std::thread` paths there.  Elsewhere
//!   `std::sync::atomic` stays legal (R1's scope); these modules sit
//!   on the cross-thread hot path and are fully shim-instrumented, so
//!   the model checker sees every sync point they touch.  The pool is
//!   confined because it *is* a sync primitive: every worker thread
//!   and every ingest connection recycles buffers through it.
//!
//! `lint --self-test` runs a seeded-violation negative suite: every
//! rule must fire on a synthetic violation and stay quiet on the clean
//! counterpart.  CI runs the self-test before the real scan so a rule
//! that silently stopped matching fails the build instead of passing
//! it.
//!
//! Known AST-lite limits (accepted): `//` inside string literals ends a
//! line early; nested `use std::{sync::{..}}` groups are not expanded
//! (the codebase does not use them — and R1's qualified-path check
//! still catches the expanded form).

use std::env;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Counters participating in a cross-thread accounting identity.
const ACCOUNTING: [&str; 4] = ["generated", "dropped", "completed", "lost"];

/// Files allowed to contain `unsafe` (each use still needs `SAFETY:`):
/// the scoped-thread lifetime transmute and the AVX2 kernel lanes.
const UNSAFE_ALLOWLIST: [&str; 2] =
    ["src/util/threads.rs", "src/nn/kernels.rs"];

/// Tokens whose import from `std::sync` is confined to the shim.
const GATEWAY_TOKENS: [&str; 4] = ["Mutex", "MutexGuard", "Condvar", "mpsc"];

/// Paths fully confined to the `util::sync` shim (R5): even atomics and
/// threads, which R1 leaves legal elsewhere, must come through the shim
/// here so the model checker instruments every sync point.  The buffer
/// pool is on this list because it is itself a cross-thread primitive —
/// workers and ingest connections recycle buffers through it.
const SHIM_CONFINED_PREFIXES: [&str; 3] =
    ["src/coordinator/net", "src/ingest/", "src/util/pool.rs"];

/// Paths R5 forbids in the confined modules.
const SHIM_CONFINED_PATHS: [&str; 2] = ["std::sync::atomic", "std::thread"];

/// How far above an `unsafe` keyword the `SAFETY:` comment may sit
/// (the threads.rs transmute carries an 18-line justification).
const SAFETY_LOOKBACK: usize = 25;

struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    message: String,
}

fn main() -> ExitCode {
    let args: Vec<String> = env::args().skip(1).collect();
    if args.iter().any(|a| a == "--self-test") {
        return self_test();
    }
    let roots: Vec<PathBuf> = if args.is_empty() {
        vec![PathBuf::from("src"), PathBuf::from("tests")]
    } else {
        args.iter().map(PathBuf::from).collect()
    };
    let mut files: Vec<PathBuf> = Vec::new();
    for root in &roots {
        if !root.exists() {
            eprintln!("lint: scan root {} does not exist", root.display());
            return ExitCode::from(2);
        }
        collect_rs(root, &mut files);
    }
    files.sort();

    let mut violations: Vec<Violation> = Vec::new();
    for file in &files {
        let content = match fs::read_to_string(file) {
            Ok(content) => content,
            Err(err) => {
                eprintln!("lint: reading {}: {err}", file.display());
                return ExitCode::from(2);
            }
        };
        let rel = file.to_string_lossy().replace('\\', "/");
        violations.extend(check_file(&rel, &content));
    }

    if violations.is_empty() {
        println!(
            "lint: {} file(s) clean (R1 sync-gateway, R2 \
             accounting-ordering, R3 lock-recovery, R4 unsafe-allowlist, \
             R5 shim-confinement)",
            files.len()
        );
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.message);
        }
        eprintln!("lint: {} violation(s) in {} file(s)", violations.len(), files.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) {
    if root.is_file() {
        if root.extension().is_some_and(|e| e == "rs") {
            out.push(root.to_path_buf());
        }
        return;
    }
    let entries = match fs::read_dir(root) {
        Ok(entries) => entries,
        Err(_) => return,
    };
    for entry in entries.flatten() {
        let path = entry.path();
        let name = entry.file_name();
        // Vendored crates and build output are not ours to lint.
        if path.is_dir() && (name == "vendor" || name == "target") {
            continue;
        }
        collect_rs(&path, out);
    }
}

// ------------------------------------------------------------ the rules

fn check_file(rel: &str, content: &str) -> Vec<Violation> {
    let mut out = Vec::new();
    let shim = rel.ends_with("util/sync.rs");
    let allow_unsafe =
        UNSAFE_ALLOWLIST.iter().any(|allowed| rel.ends_with(allowed));
    let raw_lines: Vec<&str> = content.lines().collect();
    let lines: Vec<String> =
        raw_lines.iter().map(|l| strip_line_comment(l)).collect();

    if !shim {
        rule_sync_gateway(rel, &lines, &mut out);
        rule_lock_recovery(rel, &lines, &mut out);
    }
    rule_accounting_ordering(rel, &lines, &mut out);
    rule_unsafe_allowlist(rel, &lines, &raw_lines, allow_unsafe, &mut out);
    if SHIM_CONFINED_PREFIXES.iter().any(|p| rel.contains(p)) {
        rule_shim_confinement(rel, &lines, &mut out);
    }
    out
}

/// R5: the network/ingest modules and the pool primitive route *all*
/// sync — atomics and threads included — through `crate::util::sync`.
fn rule_shim_confinement(
    rel: &str,
    lines: &[String],
    out: &mut Vec<Violation>,
) {
    for (idx, line) in lines.iter().enumerate() {
        for path in SHIM_CONFINED_PATHS {
            if line.contains(path) {
                out.push(Violation {
                    file: rel.to_string(),
                    line: idx + 1,
                    rule: "R5",
                    message: format!(
                        "`{path}` in a shim-confined module — use \
                         `crate::util::sync::{}` so the model checker \
                         instruments it",
                        if path.ends_with("atomic") {
                            "atomic"
                        } else {
                            "thread"
                        }
                    ),
                });
            }
        }
    }
}

/// R1: sync primitives enter the crate only through `util::sync`.
fn rule_sync_gateway(rel: &str, lines: &[String], out: &mut Vec<Violation>) {
    let mut import_buf = String::new();
    let mut import_start = 0usize;
    let mut in_import = false;
    for (idx, line) in lines.iter().enumerate() {
        if in_import {
            import_buf.push(' ');
            import_buf.push_str(line.trim());
            if line.contains(';') {
                flag_gateway_import(rel, import_start, &import_buf, out);
                in_import = false;
                import_buf.clear();
            }
            continue;
        }
        let head = line.trim_start();
        if head.starts_with("use std::sync::")
            || head.starts_with("pub use std::sync::")
        {
            if line.contains(';') {
                flag_gateway_import(rel, idx + 1, line, out);
            } else {
                in_import = true;
                import_start = idx + 1;
                import_buf.clear();
                import_buf.push_str(line.trim());
            }
            continue;
        }
        // Qualified paths in code bypass imports entirely.
        for token in GATEWAY_TOKENS {
            let needle = format!("std::sync::{token}");
            if let Some(pos) = line.find(&needle) {
                let after = line[pos + needle.len()..].chars().next();
                if !matches!(after, Some(c) if is_ident_char(c)) {
                    out.push(Violation {
                        file: rel.to_string(),
                        line: idx + 1,
                        rule: "R1",
                        message: format!(
                            "qualified `{needle}` outside util/sync.rs — \
                             go through the `util::sync` shim"
                        ),
                    });
                }
            }
        }
    }
}

fn flag_gateway_import(
    rel: &str,
    line: usize,
    import: &str,
    out: &mut Vec<Violation>,
) {
    for token in GATEWAY_TOKENS {
        if contains_word(import, token) {
            out.push(Violation {
                file: rel.to_string(),
                line,
                rule: "R1",
                message: format!(
                    "`{token}` imported from std::sync outside \
                     util/sync.rs — import it from `crate::util::sync` \
                     (or `rnn_hls::util::sync` in integration tests)"
                ),
            });
        }
    }
}

/// R2: accounting counters take SeqCst on every write.
fn rule_accounting_ordering(
    rel: &str,
    lines: &[String],
    out: &mut Vec<Violation>,
) {
    for (idx, line) in lines.iter().enumerate() {
        if !line.contains("Ordering::Relaxed") {
            continue;
        }
        let is_write = line.contains("fetch_add")
            || line.contains("fetch_sub")
            || line.contains("fetch_max")
            || line.contains(".store(");
        if !is_write {
            continue;
        }
        if let Some(name) = ACCOUNTING
            .iter()
            .copied()
            .find(|name| contains_word(line, name))
        {
            out.push(Violation {
                file: rel.to_string(),
                line: idx + 1,
                rule: "R2",
                message: format!(
                    "Relaxed write to accounting counter `{name}` — the \
                     generated == completed + dropped identity needs \
                     SeqCst on every write"
                ),
            });
        }
    }
}

/// R3: lock results are recovered, never unwrapped, outside the shim.
fn rule_lock_recovery(rel: &str, lines: &[String], out: &mut Vec<Violation>) {
    let mut stmt = String::new();
    let mut stmt_start = 0usize;
    let flush = |stmt: &mut String, start: usize, out: &mut Vec<Violation>| {
        if stmt.contains(".lock()")
            && (stmt.contains(".unwrap()") || stmt.contains(".expect("))
        {
            out.push(Violation {
                file: rel.to_string(),
                line: start,
                rule: "R3",
                message: "`.unwrap()`/`.expect()` on a lock result — use \
                          `util::sync::lock_or_recover` (poisoning must \
                          not cascade)"
                    .to_string(),
            });
        }
        stmt.clear();
    };
    for (idx, line) in lines.iter().enumerate() {
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if stmt.is_empty() {
            stmt_start = idx + 1;
        }
        stmt.push(' ');
        stmt.push_str(trimmed);
        if trimmed.ends_with(';')
            || trimmed.ends_with('{')
            || trimmed.ends_with('}')
            || trimmed.ends_with(',')
        {
            flush(&mut stmt, stmt_start, out);
        }
    }
    flush(&mut stmt, stmt_start, out);
}

/// R4: `unsafe` is allowlisted per file and justified per use.
fn rule_unsafe_allowlist(
    rel: &str,
    lines: &[String],
    raw_lines: &[&str],
    allowed: bool,
    out: &mut Vec<Violation>,
) {
    for (idx, line) in lines.iter().enumerate() {
        if !contains_word(line, "unsafe") {
            continue;
        }
        if !allowed {
            out.push(Violation {
                file: rel.to_string(),
                line: idx + 1,
                rule: "R4",
                message: "`unsafe` outside the allowlist (see \
                          UNSAFE_ALLOWLIST in tools/lint) — justify and \
                          allowlist it, or find a safe formulation"
                    .to_string(),
            });
            continue;
        }
        let from = idx.saturating_sub(SAFETY_LOOKBACK);
        let justified =
            raw_lines[from..idx].iter().any(|l| l.contains("SAFETY:"));
        if !justified {
            out.push(Violation {
                file: rel.to_string(),
                line: idx + 1,
                rule: "R4",
                message: format!(
                    "`unsafe` without a `SAFETY:` comment in the \
                     preceding {SAFETY_LOOKBACK} lines"
                ),
            });
        }
    }
}

// ------------------------------------------------------------- helpers

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Whole-identifier containment: `lost` matches `sink.lost` but not
/// `completions_lost` or `lost_and_found`.
fn contains_word(haystack: &str, word: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = haystack[from..].find(word) {
        let start = from + pos;
        let end = start + word.len();
        let before_ok = start == 0
            || !is_ident_char(haystack[..start].chars().next_back().unwrap());
        let after_ok = end == haystack.len()
            || !is_ident_char(haystack[end..].chars().next().unwrap());
        if before_ok && after_ok {
            return true;
        }
        from = end;
    }
    false
}

/// Drop a `//` line comment (doc comments included).  Accepts the
/// AST-lite false cut on `//` inside string literals.
fn strip_line_comment(line: &str) -> String {
    match line.find("//") {
        Some(pos) => line[..pos].to_string(),
        None => line.to_string(),
    }
}

// ----------------------------------------------------------- self-test

/// Seeded-violation negative suite: every rule must fire on a synthetic
/// violation and stay quiet on its clean counterpart.  Run by CI before
/// the real scan.
fn self_test() -> ExitCode {
    struct Case {
        name: &'static str,
        file: &'static str,
        source: &'static str,
        expect: &'static [&'static str],
    }
    let cases = [
        Case {
            name: "R1 fires on a direct Mutex import",
            file: "src/coordinator/x.rs",
            source: "use std::sync::Mutex;\n",
            expect: &["R1"],
        },
        Case {
            name: "R1 fires inside a multi-line brace import",
            file: "src/coordinator/x.rs",
            source: "use std::sync::{\n    Arc,\n    Condvar,\n};\n",
            expect: &["R1"],
        },
        Case {
            name: "R1 fires on a qualified path",
            file: "src/coordinator/x.rs",
            source: "let m = std::sync::Mutex::new(0);\n",
            expect: &["R1"],
        },
        Case {
            name: "R1 fires on an mpsc import",
            file: "tests/x.rs",
            source: "use std::sync::mpsc::{self, Receiver};\n",
            expect: &["R1"],
        },
        Case {
            name: "R1 ignores Arc/PoisonError/atomic imports",
            file: "src/coordinator/x.rs",
            source: "use std::sync::{Arc, PoisonError};\n\
                     use std::sync::atomic::{AtomicU64, Ordering};\n",
            expect: &[],
        },
        Case {
            name: "R1 does not apply inside the shim",
            file: "src/util/sync.rs",
            source: "pub use std::sync::{Condvar, Mutex, MutexGuard};\n",
            expect: &[],
        },
        Case {
            name: "R2 fires on a Relaxed accounting fetch_add",
            file: "src/coordinator/x.rs",
            source: "m.generated.fetch_add(1, Ordering::Relaxed);\n",
            expect: &["R2"],
        },
        Case {
            name: "R2 fires on a Relaxed accounting store",
            file: "src/coordinator/x.rs",
            source: "self.dropped.store(0, Ordering::Relaxed);\n",
            expect: &["R2"],
        },
        Case {
            name: "R2 ignores Relaxed accounting loads",
            file: "src/coordinator/x.rs",
            source: "let g = m.generated.load(Ordering::Relaxed);\n",
            expect: &[],
        },
        Case {
            name: "R2 ignores non-accounting Relaxed writes",
            file: "src/coordinator/x.rs",
            source: "self.batches.fetch_add(1, Ordering::Relaxed);\n\
                     self.completions_lost_total.store(0, Ordering::Relaxed);\n",
            expect: &[],
        },
        Case {
            name: "R3 fires on lock().unwrap()",
            file: "src/coordinator/x.rs",
            source: "let g = q.lock().unwrap();\n",
            expect: &["R3"],
        },
        Case {
            name: "R3 fires across a multi-line chain",
            file: "src/coordinator/x.rs",
            source: "let g = q\n    .lock()\n    .expect(\"poisoned\");\n",
            expect: &["R3"],
        },
        Case {
            name: "R3 ignores lock_or_recover and unrelated unwraps",
            file: "src/coordinator/x.rs",
            source: "let g = lock_or_recover(&q);\nlet v = rx.recv().unwrap();\n",
            expect: &[],
        },
        Case {
            name: "R4 fires outside the allowlist",
            file: "src/coordinator/x.rs",
            source: "let p = unsafe { std::mem::transmute(q) };\n",
            expect: &["R4"],
        },
        Case {
            name: "R4 fires in an allowlisted file without SAFETY",
            file: "src/util/threads.rs",
            source: "let p = unsafe { std::mem::transmute(q) };\n",
            expect: &["R4"],
        },
        Case {
            name: "R4 passes allowlisted unsafe with a SAFETY comment",
            file: "src/util/threads.rs",
            source: "// SAFETY: lifetimes only; the call frame outlives\n\
                     // every job (collection loop blocks on all reports).\n\
                     let p = unsafe { std::mem::transmute(q) };\n",
            expect: &[],
        },
        Case {
            name: "R5 fires on std::thread in the net front-end",
            file: "src/coordinator/net.rs",
            source: "let h = std::thread::spawn(|| serve());\n",
            expect: &["R5"],
        },
        Case {
            name: "R5 fires on a std::sync::atomic import in ingest",
            file: "src/ingest/loadgen.rs",
            source: "use std::sync::atomic::AtomicU64;\n",
            expect: &["R5"],
        },
        Case {
            name: "R5 leaves Arc and shim imports alone in ingest",
            file: "src/ingest/wire.rs",
            source: "use std::sync::Arc;\n\
                     use crate::util::sync::thread;\n\
                     use crate::util::sync::atomic::AtomicU64;\n",
            expect: &[],
        },
        Case {
            name: "R5 does not apply outside the confined modules",
            file: "src/coordinator/server.rs",
            source: "use std::sync::atomic::AtomicU64;\n",
            expect: &[],
        },
        Case {
            name: "R4 fires on kernel unsafe without SAFETY",
            file: "src/nn/kernels.rs",
            source: "let acc = unsafe { _mm256_setzero_ps() };\n",
            expect: &["R4"],
        },
        Case {
            name: "R4 passes kernel unsafe with a SAFETY comment",
            file: "src/nn/kernels.rs",
            source: "// SAFETY: AVX2 confirmed by the dispatcher; loads\n\
                     // stay inside the slice by construction.\n\
                     let acc = unsafe { _mm256_setzero_ps() };\n",
            expect: &[],
        },
        Case {
            name: "R1 fires on a direct Mutex import in the pool primitive",
            file: "src/util/pool.rs",
            source: "use std::sync::Mutex;\n",
            expect: &["R1"],
        },
        Case {
            name: "R5 fires on a std::sync::atomic import in the pool",
            file: "src/util/pool.rs",
            source: "use std::sync::atomic::{AtomicU64, Ordering};\n",
            expect: &["R5"],
        },
        Case {
            name: "pool primitive on shim imports is clean",
            file: "src/util/pool.rs",
            source: "use crate::util::sync::atomic::{AtomicU64, Ordering};\n\
                     use crate::util::sync::{lock_or_recover, Mutex};\n",
            expect: &[],
        },
    ];

    let mut failures = 0usize;
    for case in &cases {
        let got: Vec<&'static str> = check_file(case.file, case.source)
            .iter()
            .map(|v| v.rule)
            .collect();
        if got != case.expect {
            failures += 1;
            eprintln!(
                "lint self-test FAIL: {} — expected {:?}, got {:?}",
                case.name, case.expect, got
            );
        }
    }
    if failures == 0 {
        println!("lint self-test: {} case(s) pass", cases.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("lint self-test: {failures} case(s) FAILED");
        ExitCode::FAILURE
    }
}
