//! # rnn-hls — ultra-low-latency RNN inference, reproduced in software
//!
//! Reproduction of *"Ultra-low latency recurrent neural network inference
//! on FPGAs for physics applications with hls4ml"* (Khoda et al., 2022) as
//! a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the request-path system: a trigger-style
//!   serving coordinator ([`coordinator`]), a PJRT runtime that executes
//!   the AOT-compiled JAX/Pallas models ([`runtime`]), a bit-accurate
//!   `ap_fixed` engine that plays the role of the synthesized FPGA
//!   datapath ([`fixed`], [`nn`]), and the analytical HLS
//!   latency/resource model standing in for Vivado HLS ([`hls`]).
//! * **L2 (python/compile)** — the benchmark models in JAX, trained at
//!   build time and AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels)** — fused Pallas LSTM/GRU kernels.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! step that invokes it.
//!
//! See `DESIGN.md` for the experiment index (every table and figure of
//! the paper mapped to a module and bench target) and `EXPERIMENTS.md`
//! for paper-vs-measured results.

pub mod api;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fixed;
pub mod hls;
pub mod ingest;
pub mod model;
pub mod nn;
pub mod report;
pub mod runtime;
pub mod util;

// The primary serving API, re-exported at the crate root: describe a
// session with a typed [`ServingSpec`], start it with
// [`Session::start`], submit requests from any number of threads, read
// completions and live snapshots, then shut down for the final report.
// `coordinator::{Server, ShardedServer}` are replay wrappers over this,
// and [`api`] is the canonical import path (these root re-exports feed
// through it, plus the stable [`api::ErrorCode`] numeric space shared
// with the wire protocol).
pub use api::{
    BackendKind, Completion, ServingPlan, ServingSpec, Session,
    SessionHandle, SubmitError,
};
