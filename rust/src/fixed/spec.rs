//! Fixed-point type descriptors: the software `ap_fixed<W,I>`.

/// Rounding mode applied when discarding fractional bits.
///
/// Mirrors Vivado HLS quantization modes (UG902): `AP_TRN` is the default.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoundMode {
    /// `AP_TRN`: truncate toward negative infinity (drop bits). Default.
    Trn,
    /// `AP_RND`: round to nearest, ties toward +∞.
    Rnd,
}

/// Overflow mode applied when a value exceeds the representable range.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OverflowMode {
    /// `AP_WRAP`: two's-complement wraparound (Vivado default).
    Wrap,
    /// `AP_SAT`: saturate to the representable extremes.
    Sat,
}

/// `ap_fixed<W,I>`: signed fixed point, `width` total bits of which
/// `integer` are integer bits (sign included), so `width - integer`
/// fractional bits.
///
/// The paper's Fig. 2 scans `integer ∈ {6, 8, 10, 12}` and fractional
/// `∈ [2, 14]`; Figs. 3–6 scan the *total* width at fixed integer bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FixedSpec {
    /// Total bits W, `1..=48`.
    pub width: u32,
    /// Integer bits I (including sign), `1..=width`.
    pub integer: u32,
}

impl FixedSpec {
    /// Construct, panicking on invalid combinations (programming errors).
    pub fn new(width: u32, integer: u32) -> Self {
        assert!(
            (1..=48).contains(&width),
            "fixed width {width} out of range 1..=48"
        );
        assert!(
            (1..=width).contains(&integer),
            "integer bits {integer} out of range 1..={width}"
        );
        Self { width, integer }
    }

    /// hls4ml's default layer type: `ap_fixed<16,6>`.
    pub fn default16_6() -> Self {
        Self::new(16, 6)
    }

    /// Number of fractional bits `F = W - I`.
    #[inline]
    pub fn frac(&self) -> u32 {
        self.width - self.integer
    }

    /// Smallest representable increment, `2^-F`.
    #[inline]
    pub fn lsb(&self) -> f64 {
        (2.0f64).powi(-(self.frac() as i32))
    }

    /// Largest representable raw value, `2^(W-1) - 1`.
    #[inline]
    pub fn raw_max(&self) -> i64 {
        (1i64 << (self.width - 1)) - 1
    }

    /// Smallest representable raw value, `-2^(W-1)`.
    #[inline]
    pub fn raw_min(&self) -> i64 {
        -(1i64 << (self.width - 1))
    }

    /// Largest representable real value.
    #[inline]
    pub fn max_value(&self) -> f64 {
        self.raw_max() as f64 * self.lsb()
    }

    /// Smallest (most negative) representable real value.
    #[inline]
    pub fn min_value(&self) -> f64 {
        self.raw_min() as f64 * self.lsb()
    }

    /// Display as the paper writes it, e.g. `<16,6>`.
    pub fn label(&self) -> String {
        format!("<{},{}>", self.width, self.integer)
    }
}

/// Full quantization configuration for an engine run: the data type plus
/// rounding/overflow behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct QuantConfig {
    pub spec: FixedSpec,
    pub round: RoundMode,
    pub overflow: OverflowMode,
}

impl QuantConfig {
    /// The configuration used for the Fig. 2 reproduction: truncation (the
    /// Vivado default) with saturation.  Saturation rather than wrap is
    /// deliberate: with the paper's small integer widths an `AP_WRAP`
    /// accumulator overflow flips sign and produces AUC cliffs, while the
    /// paper's curves degrade smoothly — practical hls4ml deployments set
    /// `AP_SAT` on the output types for exactly this reason.
    pub fn ptq(spec: FixedSpec) -> Self {
        Self {
            spec,
            round: RoundMode::Trn,
            overflow: OverflowMode::Sat,
        }
    }

    /// Vivado's literal defaults (`AP_TRN`, `AP_WRAP`).
    pub fn vivado_default(spec: FixedSpec) -> Self {
        Self {
            spec,
            round: RoundMode::Trn,
            overflow: OverflowMode::Wrap,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frac_and_lsb() {
        let s = FixedSpec::new(16, 6);
        assert_eq!(s.frac(), 10);
        assert!((s.lsb() - 1.0 / 1024.0).abs() < 1e-15);
    }

    #[test]
    fn range_16_6() {
        let s = FixedSpec::new(16, 6);
        assert_eq!(s.raw_max(), 32767);
        assert_eq!(s.raw_min(), -32768);
        assert!((s.max_value() - 31.9990234375).abs() < 1e-9);
        assert!((s.min_value() + 32.0).abs() < 1e-9);
    }

    #[test]
    fn one_bit_types() {
        let s = FixedSpec::new(1, 1);
        assert_eq!(s.frac(), 0);
        assert_eq!(s.raw_max(), 0);
        assert_eq!(s.raw_min(), -1);
    }

    #[test]
    #[should_panic]
    fn integer_cannot_exceed_width() {
        FixedSpec::new(8, 9);
    }

    #[test]
    #[should_panic]
    fn width_zero_rejected() {
        FixedSpec::new(0, 0);
    }

    #[test]
    fn label_matches_paper_notation() {
        assert_eq!(FixedSpec::new(16, 6).label(), "<16,6>");
    }
}
