//! Software substrate for Vivado HLS `ap_fixed<W,I>` arithmetic.
//!
//! The paper quantizes every input, weight, bias, partial sum and output to
//! a fixed-point type `ap_fixed<W,I>` (W total bits, I integer bits
//! including sign; see §5.1).  We have no Vivado, so this module is the
//! substitution: a bit-accurate software model of that arithmetic, used by
//! the [`crate::nn`] engine to reproduce the post-training-quantization
//! scan of Fig. 2.
//!
//! What is modelled:
//!
//! * two's-complement storage in `W` bits with `F = W - I` fractional bits
//!   ([`FixedSpec`]);
//! * quantization (f32 → raw) with HLS rounding modes `AP_TRN` (truncate
//!   toward −∞, the Vivado default) and `AP_RND` (round to nearest, ties
//!   toward +∞), and overflow modes `AP_WRAP` (Vivado default) and
//!   `AP_SAT` ([`RoundMode`], [`OverflowMode`]);
//! * exact integer products with `2F` fractional bits and wide (i64)
//!   accumulators, then requantization — matching hls4ml's wider
//!   `accum_t` default;
//! * hls4ml's LUT-based activations ([`tables`]): sigmoid/tanh/exp/inv
//!   lookup tables with configurable size and table precision, including
//!   the paper's note that the softmax LUT needs higher precision for the
//!   flavor-tagging and QuickDraw models.

pub mod spec;
pub mod tables;
pub mod value;

pub use spec::{FixedSpec, OverflowMode, QuantConfig, RoundMode};
pub use tables::{ActTables, SoftmaxTables, TableConfig};
pub use value::{dequantize, quantize, quantize_vec, requantize};
