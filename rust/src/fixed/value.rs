//! Raw fixed-point value conversion and requantization.
//!
//! A *raw* value is an `i64` holding a two's-complement `W`-bit pattern in
//! units of `2^-F`.  All arithmetic in the engine keeps products exact
//! (`2F` fractional bits in i64) and only requantizes at the points where
//! the HLS design would: after the accumulator, and after activations.

use super::spec::{FixedSpec, OverflowMode, QuantConfig, RoundMode};

/// Apply the overflow mode to an arbitrary raw value, returning a raw value
/// representable in `spec.width` bits.
#[inline]
pub fn overflow(raw: i64, spec: FixedSpec, mode: OverflowMode) -> i64 {
    let (lo, hi) = (spec.raw_min(), spec.raw_max());
    match mode {
        OverflowMode::Sat => raw.clamp(lo, hi),
        OverflowMode::Wrap => {
            // Keep the low W bits, sign-extended: two's-complement wrap.
            let w = spec.width;
            let mask = if w >= 64 { !0u64 } else { (1u64 << w) - 1 };
            let bits = (raw as u64) & mask;
            let sign_bit = 1u64 << (w - 1);
            if bits & sign_bit != 0 {
                (bits | !mask) as i64
            } else {
                bits as i64
            }
        }
    }
}

/// Shift a raw value right by `shift` fractional bits with the given
/// rounding mode (the fixed-point "drop bits" primitive).
#[inline]
pub fn shift_round(raw: i64, shift: u32, round: RoundMode) -> i64 {
    if shift == 0 {
        return raw;
    }
    debug_assert!(shift < 63, "shift {shift} too large");
    match round {
        // Arithmetic right shift == floor division by 2^shift (AP_TRN).
        RoundMode::Trn => raw >> shift,
        // AP_RND: add half an LSB then truncate => nearest, ties toward +∞.
        RoundMode::Rnd => (raw + (1i64 << (shift - 1))) >> shift,
    }
}

/// Quantize a real value into a raw fixed-point value under `cfg`.
#[inline]
pub fn quantize(x: f64, cfg: QuantConfig) -> i64 {
    let scaled = x * (1i64 << cfg.spec.frac()) as f64;
    let raw = match cfg.round {
        RoundMode::Trn => scaled.floor(),
        RoundMode::Rnd => (scaled + 0.5).floor(),
    };
    // f64 -> i64 cast saturates in rust for out-of-range values, but guard
    // against NaN explicitly (quantizes to 0 like HLS x-propagation won't,
    // but the engine never produces NaN from finite inputs).
    let raw = if raw.is_nan() { 0 } else { raw as i64 };
    overflow(raw, cfg.spec, cfg.overflow)
}

/// Recover the real value of a raw fixed-point number.
#[inline]
pub fn dequantize(raw: i64, spec: FixedSpec) -> f64 {
    raw as f64 * spec.lsb()
}

/// Quantize a slice (used for weights/inputs at engine-load time).
pub fn quantize_vec(xs: &[f32], cfg: QuantConfig) -> Vec<i64> {
    xs.iter().map(|&x| quantize(x as f64, cfg)).collect()
}

/// Requantize a raw value that currently carries `from_frac` fractional
/// bits into `cfg` (dropping or adding fractional bits, then applying
/// overflow handling).  This is the "cast" at the output of an
/// accumulator.
#[inline]
pub fn requantize(raw: i64, from_frac: u32, cfg: QuantConfig) -> i64 {
    let to_frac = cfg.spec.frac();
    let shifted = if from_frac > to_frac {
        shift_round(raw, from_frac - to_frac, cfg.round)
    } else {
        raw << (to_frac - from_frac)
    };
    overflow(shifted, cfg.spec, cfg.overflow)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(w: u32, i: u32) -> QuantConfig {
        QuantConfig::ptq(FixedSpec::new(w, i))
    }

    #[test]
    fn quantize_exact_values() {
        let c = cfg(16, 6); // F = 10
        assert_eq!(quantize(0.0, c), 0);
        assert_eq!(quantize(1.0, c), 1024);
        assert_eq!(quantize(-1.0, c), -1024);
        assert_eq!(quantize(0.125, c), 128);
    }

    #[test]
    fn truncation_rounds_toward_neg_inf() {
        let c = cfg(8, 6); // F = 2, lsb 0.25
        assert_eq!(quantize(0.3, c), 1); // 0.25
        assert_eq!(quantize(-0.3, c), -2); // -0.5, floor
        assert_eq!(dequantize(quantize(-0.3, c), c.spec), -0.5);
    }

    #[test]
    fn rnd_rounds_to_nearest() {
        let mut c = cfg(8, 6);
        c.round = RoundMode::Rnd;
        assert_eq!(quantize(0.3, c), 1); // 0.25 nearest
        assert_eq!(quantize(-0.3, c), -1); // -0.25 nearest
        assert_eq!(quantize(0.375, c), 2); // tie -> +inf -> 0.5
    }

    #[test]
    fn saturation_clamps() {
        let c = cfg(8, 4); // range [-8, 7.9375]
        assert_eq!(dequantize(quantize(100.0, c), c.spec), 7.9375);
        assert_eq!(dequantize(quantize(-100.0, c), c.spec), -8.0);
    }

    #[test]
    fn wrap_wraps_two_complement() {
        let c = QuantConfig::vivado_default(FixedSpec::new(8, 4)); // F=4
        // 8.0 -> raw 128 -> wraps to -128 -> -8.0
        assert_eq!(dequantize(quantize(8.0, c), c.spec), -8.0);
        // 16.0 -> raw 256 -> wraps to 0
        assert_eq!(quantize(16.0, c), 0);
    }

    #[test]
    fn nan_quantizes_to_zero() {
        assert_eq!(quantize(f64::NAN, cfg(16, 6)), 0);
    }

    #[test]
    fn requantize_down_truncates() {
        let c = cfg(16, 6); // to F=10
        // raw with F=20: value 1.5 = 1.5 * 2^20
        let raw20 = (1.5 * (1 << 20) as f64) as i64;
        assert_eq!(requantize(raw20, 20, c), 1536); // 1.5 * 1024
    }

    #[test]
    fn requantize_up_shifts_left() {
        let c = cfg(16, 6);
        assert_eq!(requantize(3, 2, c), 3 << 8); // F=2 -> F=10
    }

    #[test]
    fn roundtrip_within_lsb() {
        let c = cfg(16, 6);
        for &x in &[0.0, 0.1, -0.1, 3.14159, -31.9, 14.2857] {
            let err = (dequantize(quantize(x, c), c.spec) - x).abs();
            assert!(err < c.spec.lsb() + 1e-12, "x={x} err={err}");
        }
    }

    #[test]
    fn product_semantics_are_exact() {
        // (a * b) with raw i64: fracs add; requantize once at the end.
        let c = cfg(16, 6);
        let a = quantize(1.5, c);
        let b = quantize(-2.25, c);
        let prod = a * b; // F = 20
        let back = requantize(prod, 20, c);
        assert_eq!(dequantize(back, c.spec), -3.375);
    }
}
