//! hls4ml-style lookup-table activations.
//!
//! hls4ml does not compute `sigmoid`/`tanh`/`exp` in logic; it indexes
//! precomputed tables (default 1024 entries, `ap_fixed<18,8>` entries) over
//! a fixed input range.  The quantization of *the table itself* is a real
//! contributor to the Fig. 2 AUC degradation, so we reproduce the scheme:
//! left-edge sampled tables, range ±8 for sigmoid, ±4 for tanh, and the
//! two-table (exp + reciprocal) construction for softmax.
//!
//! The paper (§5.1) notes the softmax LUT needs a size/precision bump for
//! the flavor-tagging and QuickDraw models; [`TableConfig::softmax_high`]
//! is that bump.

use super::spec::{FixedSpec, QuantConfig};
use super::value::{dequantize, overflow, quantize};

/// Size / precision / range of one activation table.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TableConfig {
    /// Number of entries (hls4ml default 1024).
    pub size: usize,
    /// Fixed-point type of the table entries (hls4ml `table_t`, default
    /// `ap_fixed<18,8>`).
    pub spec: FixedSpec,
    /// Input half-range: the table covers `[-range, +range)`.
    pub range: f64,
}

impl TableConfig {
    pub fn sigmoid_default() -> Self {
        Self {
            size: 1024,
            spec: FixedSpec::new(18, 8),
            range: 8.0,
        }
    }

    pub fn tanh_default() -> Self {
        Self {
            size: 1024,
            spec: FixedSpec::new(18, 8),
            range: 4.0,
        }
    }

    pub fn softmax_default() -> Self {
        Self {
            size: 1024,
            spec: FixedSpec::new(18, 8),
            range: 8.0,
        }
    }

    /// The enlarged softmax table the paper uses for the flavor-tagging
    /// and QuickDraw models (bigger + more fractional bits).
    pub fn softmax_high() -> Self {
        Self {
            size: 4096,
            spec: FixedSpec::new(24, 10),
            range: 8.0,
        }
    }
}

/// Build a bin-center-sampled table of `f` over `[-range, range)`,
/// quantized to the table spec.  Center sampling (vs hls4ml's historical
/// left-edge) halves the systematic bias per lookup, which matters for
/// the LSTM where lookup errors compound across the recurrence.
fn build_table(cfg: TableConfig, f: impl Fn(f64) -> f64) -> Vec<i64> {
    let q = QuantConfig::ptq(cfg.spec);
    let dx = 2.0 * cfg.range / cfg.size as f64;
    (0..cfg.size)
        .map(|i| quantize(f(-cfg.range + dx * (i as f64 + 0.5)), q))
        .collect()
}

/// Index into a table for a real-valued input (clamping at the edges,
/// exactly as the generated HLS does).
#[inline]
fn table_index(x: f64, cfg: &TableConfig) -> usize {
    let pos = (x + cfg.range) * cfg.size as f64 / (2.0 * cfg.range);
    (pos.floor().max(0.0) as usize).min(cfg.size - 1)
}

/// Integer-only index for a raw fixed-point input (§Perf: the f64
/// dequantize+floor on the activation hot path costs ~3× the shift).
/// Valid because table ranges and sizes are powers of two; falls back to
/// the f64 path otherwise.  `idx = (raw + range·2^F) >> (F + log2(2·range) − log2(size))`.
#[inline]
fn table_index_raw(raw: i64, in_frac: u32, cfg: &TableConfig) -> usize {
    debug_assert!(cfg.range.fract() == 0.0);
    let range_i = cfg.range as i64;
    if range_i <= 0 || !(range_i as u64).is_power_of_two() || !cfg.size.is_power_of_two() {
        return table_index(super::value::dequantize(raw, FixedSpec::new(48, 48 - in_frac)), cfg);
    }
    let log_2range = (2 * range_i).trailing_zeros();
    let log_size = cfg.size.trailing_zeros();
    let shifted = raw + (range_i << in_frac);
    if shifted <= 0 {
        return 0;
    }
    let total_shift = in_frac as i32 + log_2range as i32 - log_size as i32;
    let idx = if total_shift >= 0 {
        (shifted >> total_shift) as usize
    } else {
        (shifted << (-total_shift)) as usize
    };
    idx.min(cfg.size - 1)
}

/// Sigmoid + tanh tables for one layer output type.
#[derive(Debug, Clone)]
pub struct ActTables {
    out: QuantConfig,
    sig_cfg: TableConfig,
    tanh_cfg: TableConfig,
    sigmoid: Vec<i64>,
    tanh: Vec<i64>,
}

impl ActTables {
    /// Build tables whose looked-up values are cast to `out`.
    pub fn new(out: QuantConfig) -> Self {
        let sig_cfg = TableConfig::sigmoid_default();
        let tanh_cfg = TableConfig::tanh_default();
        Self {
            out,
            sig_cfg,
            tanh_cfg,
            sigmoid: build_table(sig_cfg, |x| 1.0 / (1.0 + (-x).exp())),
            tanh: build_table(tanh_cfg, f64::tanh),
        }
    }

    /// LUT sigmoid: raw in (spec `in_spec`) → raw out (engine type).
    #[inline]
    pub fn sigmoid_raw(&self, raw: i64, in_spec: FixedSpec) -> i64 {
        let entry =
            self.sigmoid[table_index_raw(raw, in_spec.frac(), &self.sig_cfg)];
        cast(entry, self.sig_cfg.spec, self.out)
    }

    /// LUT tanh: raw in → raw out.
    #[inline]
    pub fn tanh_raw(&self, raw: i64, in_spec: FixedSpec) -> i64 {
        let entry =
            self.tanh[table_index_raw(raw, in_spec.frac(), &self.tanh_cfg)];
        cast(entry, self.tanh_cfg.spec, self.out)
    }

    pub fn output_config(&self) -> QuantConfig {
        self.out
    }
}

/// Softmax via exp- and reciprocal-tables (hls4ml's "stable" variant:
/// subtract the row max before exponentiating).
#[derive(Debug, Clone)]
pub struct SoftmaxTables {
    out: QuantConfig,
    exp_cfg: TableConfig,
    inv_cfg: TableConfig,
    exp: Vec<i64>,
    /// Reciprocal table over `(0, inv_range]`.
    inv: Vec<i64>,
    inv_range: f64,
}

impl SoftmaxTables {
    pub fn new(out: QuantConfig, cfg: TableConfig) -> Self {
        let inv_range = 64.0;
        let inv_cfg = cfg;
        Self {
            out,
            exp_cfg: cfg,
            inv_cfg,
            exp: build_table(cfg, f64::exp),
            inv: (0..cfg.size)
                .map(|i| {
                    // left-edge over (0, inv_range]; entry 0 guards /0.
                    let x = inv_range * (i as f64) / cfg.size as f64;
                    let v = if x <= 0.0 { cfg.spec.max_value() } else { 1.0 / x };
                    quantize(v, QuantConfig::ptq(cfg.spec))
                })
                .collect(),
            inv_range,
        }
    }

    /// Softmax over one row of raw logits.
    pub fn softmax_raw(&self, logits: &[i64], in_spec: FixedSpec) -> Vec<i64> {
        let xs: Vec<f64> = logits.iter().map(|&r| dequantize(r, in_spec)).collect();
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        // exp(x - max) through the table (inputs in [-2*range, 0], clamped).
        let exps: Vec<i64> = xs
            .iter()
            .map(|&x| self.exp[table_index(x - max, &self.exp_cfg)])
            .collect();
        let sum_raw: i64 = exps.iter().sum();
        let sum = dequantize(sum_raw, self.exp_cfg.spec);
        let inv_idx = ((sum / self.inv_range * self.inv_cfg.size as f64).floor()
            as usize)
            .min(self.inv_cfg.size - 1);
        let inv = self.inv[inv_idx];
        // product carries 2x table frac bits; cast down to the output type.
        let prod_frac = 2 * self.exp_cfg.spec.frac();
        exps.iter()
            .map(|&e| super::value::requantize(e * inv, prod_frac, self.out))
            .collect()
    }
}

/// Cast a raw value between specs (requantize + overflow handling).
#[inline]
fn cast(raw: i64, from: FixedSpec, to: QuantConfig) -> i64 {
    let v = super::value::requantize(raw, from.frac(), to);
    overflow(v, to.spec, to.overflow)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn out16() -> QuantConfig {
        QuantConfig::ptq(FixedSpec::new(16, 6))
    }

    #[test]
    fn sigmoid_table_accuracy() {
        let t = ActTables::new(out16());
        let in_spec = FixedSpec::new(16, 6);
        for &x in &[-6.0, -2.0, -0.5, 0.0, 0.5, 2.0, 6.0] {
            let raw = quantize(x, QuantConfig::ptq(in_spec));
            let got = dequantize(t.sigmoid_raw(raw, in_spec), in_spec);
            let want = 1.0 / (1.0 + (-x as f64).exp());
            // table step is 16/1024 ≈ 0.016 in x; sigmoid' ≤ 1/4.
            assert!((got - want).abs() < 0.006, "x={x} got={got} want={want}");
        }
    }

    #[test]
    fn sigmoid_saturates_at_range_edges() {
        let t = ActTables::new(out16());
        let s = FixedSpec::new(16, 6);
        let lo = t.sigmoid_raw(quantize(-20.0, QuantConfig::ptq(s)), s);
        let hi = t.sigmoid_raw(quantize(20.0, QuantConfig::ptq(s)), s);
        assert!(dequantize(lo, s) < 0.001);
        assert!(dequantize(hi, s) > 0.999);
    }

    #[test]
    fn tanh_table_accuracy_and_sign() {
        let t = ActTables::new(out16());
        let s = FixedSpec::new(16, 6);
        for &x in &[-3.0, -1.0, -0.25, 0.25, 1.0, 3.0] {
            let raw = quantize(x, QuantConfig::ptq(s));
            let got = dequantize(t.tanh_raw(raw, s), s);
            assert!((got - (x as f64).tanh()).abs() < 0.01, "x={x} got={got}");
            assert_eq!(got > 0.0, x > 0.0);
        }
    }

    #[test]
    fn low_precision_table_is_coarse() {
        // With a 4-bit output type the LUT output collapses to few levels —
        // the mechanism behind Fig. 2's low-width AUC loss.
        let out = QuantConfig::ptq(FixedSpec::new(4, 2));
        let t = ActTables::new(out);
        let s = FixedSpec::new(16, 6);
        let distinct: std::collections::HashSet<i64> = (-40..40)
            .map(|i| t.sigmoid_raw(quantize(i as f64 * 0.2, QuantConfig::ptq(s)), s))
            .collect();
        assert!(distinct.len() <= 4, "got {} levels", distinct.len());
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let sm = SoftmaxTables::new(out16(), TableConfig::softmax_default());
        let s = FixedSpec::new(16, 6);
        let q = QuantConfig::ptq(s);
        let logits: Vec<i64> = [2.0, 0.5, -1.0]
            .iter()
            .map(|&x| quantize(x, q))
            .collect();
        let probs = sm.softmax_raw(&logits, s);
        let vals: Vec<f64> = probs.iter().map(|&p| dequantize(p, s)).collect();
        let sum: f64 = vals.iter().sum();
        assert!((sum - 1.0).abs() < 0.05, "sum={sum}");
        assert!(vals[0] > vals[1] && vals[1] > vals[2]);
    }

    #[test]
    fn softmax_high_precision_is_closer() {
        let s = FixedSpec::new(16, 6);
        let q = QuantConfig::ptq(s);
        let logits: Vec<i64> = [1.3, 0.9, 0.2, -0.4, -2.0]
            .iter()
            .map(|&x| quantize(x, q))
            .collect();
        let want: Vec<f64> = {
            let xs = [1.3f64, 0.9, 0.2, -0.4, -2.0];
            let m = 1.3;
            let es: Vec<f64> = xs.iter().map(|x| (x - m).exp()).collect();
            let sum: f64 = es.iter().sum();
            es.iter().map(|e| e / sum).collect()
        };
        let err = |cfg: TableConfig| -> f64 {
            let sm = SoftmaxTables::new(q, cfg);
            sm.softmax_raw(&logits, s)
                .iter()
                .zip(&want)
                .map(|(&p, &w)| (dequantize(p, s) - w).abs())
                .fold(0.0, f64::max)
        };
        let e_def = err(TableConfig::softmax_default());
        let e_high = err(TableConfig::softmax_high());
        assert!(e_high <= e_def + 1e-12, "high {e_high} vs default {e_def}");
    }

    #[test]
    fn integer_index_matches_f64_index() {
        // §Perf opt 1 correctness: the shift-based index must agree with
        // the f64 reference for every table config and input spec.
        for cfg in [
            TableConfig::sigmoid_default(),
            TableConfig::tanh_default(),
            TableConfig::softmax_high(),
        ] {
            for in_spec in [
                FixedSpec::new(16, 6),
                FixedSpec::new(8, 6),
                FixedSpec::new(24, 10),
                FixedSpec::new(12, 2),
            ] {
                for raw in (-40_000i64..40_000).step_by(997) {
                    let raw = raw.clamp(in_spec.raw_min(), in_spec.raw_max());
                    let x = dequantize(raw, in_spec);
                    assert_eq!(
                        table_index_raw(raw, in_spec.frac(), &cfg),
                        table_index(x, &cfg),
                        "cfg range {} size {} spec {} raw {raw}",
                        cfg.range,
                        cfg.size,
                        in_spec.label()
                    );
                }
            }
        }
    }

    /// Satellite property test: the shift-based fast path must agree with
    /// the f64 reference for **every** raw value across the table range —
    /// including the `shifted <= 0` early-out, the negative-total-shift
    /// (left-shift) branch, and the non-power-of-two fallback path.
    #[test]
    fn integer_index_matches_f64_index_exhaustively() {
        let cfgs = [
            // positive shift (the common case)
            TableConfig::sigmoid_default(),
            TableConfig::tanh_default(),
            // 4096 entries: with a 2-fractional-bit input spec the total
            // shift goes negative (left-shift branch)
            TableConfig::softmax_high(),
            TableConfig {
                size: 4096,
                spec: FixedSpec::new(18, 8),
                range: 8.0,
            },
            // non-power-of-two size: must take the f64 fallback
            TableConfig {
                size: 1000,
                spec: FixedSpec::new(18, 8),
                range: 8.0,
            },
        ];
        let in_specs = [
            FixedSpec::new(16, 6),  // F = 10
            FixedSpec::new(8, 6),   // F = 2 → negative shift vs size 4096
            FixedSpec::new(12, 4),  // F = 8
            FixedSpec::new(10, 9),  // F = 1, wide integer range
        ];
        for cfg in &cfgs {
            for in_spec in in_specs {
                for raw in in_spec.raw_min()..=in_spec.raw_max() {
                    let x = dequantize(raw, in_spec);
                    assert_eq!(
                        table_index_raw(raw, in_spec.frac(), cfg),
                        table_index(x, cfg),
                        "cfg size {} range {} spec {}, raw {raw}",
                        cfg.size,
                        cfg.range,
                        in_spec.label()
                    );
                }
            }
        }
    }

    #[test]
    fn table_index_raw_branch_coverage() {
        // `shifted <= 0`: raw at/below -range*2^F indexes bin 0.
        let cfg = TableConfig::sigmoid_default(); // range 8, input F = 10
        let edge = -(8i64 << 10);
        assert_eq!(table_index_raw(edge, 10, &cfg), 0);
        assert_eq!(table_index_raw(edge - 1, 10, &cfg), 0);
        assert_eq!(table_index_raw(i64::from(i16::MIN), 10, &cfg), 0);
        // negative total shift: F=2, 2·range=16, size=4096 → shift -6.
        let big = TableConfig {
            size: 4096,
            spec: FixedSpec::new(18, 8),
            range: 8.0,
        };
        let spec2 = FixedSpec::new(8, 6); // F = 2
        let raw = 5i64; // x = 1.25 → pos = (1.25+8)*4096/16 = 2368
        assert_eq!(table_index_raw(raw, spec2.frac(), &big), 2368);
        assert_eq!(table_index(dequantize(raw, spec2), &big), 2368);
    }

    #[test]
    fn table_index_clamps() {
        let cfg = TableConfig::sigmoid_default();
        assert_eq!(table_index(-100.0, &cfg), 0);
        assert_eq!(table_index(100.0, &cfg), cfg.size - 1);
        assert_eq!(table_index(-8.0, &cfg), 0);
    }
}
