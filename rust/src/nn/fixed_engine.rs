//! Bit-accurate `ap_fixed` inference engine — the FPGA-datapath stand-in.
//!
//! Reproduces what the generated HLS computes (§5.1): every input, weight,
//! bias, layer output and activation is an `ap_fixed<W,I>`; products are
//! exact (2F fractional bits) and accumulated in a wide integer (hls4ml's
//! `accum_t`), then cast back to the layer type; sigmoid/tanh/softmax go
//! through lookup tables.  Running this engine over the frozen test sets
//! at different `(W, I)` regenerates the PTQ scan of Fig. 2.
//!
//! All integer inner products go through [`super::kernels`]
//! (`matmul_acc_i64`): integer addition is associative, so the scalar and
//! SIMD lanes are exact by construction, and [`MAX_WIDTH`] additionally
//! keeps every raw value inside the 32-bit range the vectorized multiply
//! requires.  The serving entry point `forward_packed_into` recycles all
//! recurrence/head temporaries through a scratch pool; with a
//! sigmoid-output head the steady state allocates nothing (the LUT
//! softmax's small per-row temporaries are the one documented exception).

use crate::fixed::{
    dequantize, quantize, requantize, ActTables, QuantConfig,
    SoftmaxTables, TableConfig,
};
use crate::model::{Arch, Cell, OutputActivation, Weights};
use crate::util::pool::{BufferPool, PoolStats};
use crate::util::threads::WorkerPool;

use super::{kernels, BatchRows, Engine, PackedOut};

/// Maximum supported total width: products carry `2W` bits and the widest
/// accumulation fan-in here is 512 (quickdraw dense head, 2^9), so
/// `2 * 26 + 9 = 61 < 63` keeps i64 accumulation exact.  The same bound
/// keeps raw values below 2^26, well inside the i32 range the SIMD
/// integer multiply (`kernels::matmul_acc_i64`) loads from.
pub const MAX_WIDTH: u32 = 26;

/// Transposed integer matrix: raw weights at the engine's F, `[out][in]`.
struct MatTI {
    rows_out: usize,
    cols_in: usize,
    data: Vec<i64>,
}

impl MatTI {
    fn from_keras(shape: &[usize], data: &[f32], cfg: QuantConfig) -> Self {
        let (i, o) = (shape[0], shape[1]);
        let mut t = vec![0i64; i * o];
        for r in 0..i {
            for c in 0..o {
                t[c * i + r] = quantize(data[r * o + c] as f64, cfg);
            }
        }
        Self {
            rows_out: o,
            cols_in: i,
            data: t,
        }
    }

    /// `y[o] += Σ_i x[i] * w[o,i]` — accumulator carries 2F fractional
    /// bits.  A batch-1 [`MatTI::matmul_acc`] through the kernel layer.
    #[inline]
    fn matvec_acc(&self, x: &[i64], y: &mut [i64]) {
        debug_assert_eq!(x.len(), self.cols_in);
        debug_assert_eq!(y.len(), self.rows_out);
        kernels::matmul_acc_i64(&self.data, self.rows_out, self.cols_in, x, 1, y);
    }

    /// Batched `matvec_acc` over packed `[batch][cols_in]` inputs into
    /// packed `[batch][rows_out]` accumulators; the weight row streams
    /// across the whole batch.  Integer arithmetic is exact, so this is
    /// trivially identical to the per-sample path — and to the SIMD lanes.
    fn matmul_acc(&self, xs: &[i64], batch: usize, ys: &mut [i64]) {
        debug_assert_eq!(xs.len(), batch * self.cols_in);
        debug_assert_eq!(ys.len(), batch * self.rows_out);
        kernels::matmul_acc_i64(
            &self.data,
            self.rows_out,
            self.cols_in,
            xs,
            batch,
            ys,
        );
    }
}

struct DenseLayerI {
    w: MatTI,
    /// Bias pre-shifted to 2F (accumulator units).
    b2f: Vec<i64>,
}

impl DenseLayerI {
    fn new(
        wshape: &[usize],
        wdata: &[f32],
        bdata: &[f32],
        cfg: QuantConfig,
    ) -> Self {
        let f = cfg.spec.frac();
        Self {
            w: MatTI::from_keras(wshape, wdata, cfg),
            b2f: bdata
                .iter()
                .map(|&v| quantize(v as f64, cfg) << f)
                .collect(),
        }
    }
}

/// Per-worker recurrence/head temporaries, recycled through the engine's
/// scratch pool so steady-state batches allocate nothing.
#[derive(Default)]
struct FixedScratch {
    /// Quantized inputs, packed `[b][seq * input_size]`.
    x_raw: Vec<i64>,
    /// Gathered timestep inputs, packed `[b][input_size]`.
    xt: Vec<i64>,
    /// Hidden state `[b][h]` (raw); doubles as the dense-head ping buffer.
    h: Vec<i64>,
    /// LSTM cell state `[b][h]`.
    c: Vec<i64>,
    /// Gate accumulators: LSTM `[b][4h]`, GRU input-half `[b][3h]`.
    z: Vec<i64>,
    /// GRU recurrent-half gate accumulators `[b][3h]`.
    hm: Vec<i64>,
    /// Dense-head pong buffer (accumulator units).
    acts: Vec<i64>,
    /// One output row of cast-back logits.
    logits: Vec<i64>,
}

#[inline]
fn zeroed(buf: &mut Vec<i64>, n: usize) {
    buf.clear();
    buf.resize(n, 0);
}

/// The quantized engine.
pub struct FixedEngine {
    arch: Arch,
    cfg: QuantConfig,
    rnn_w: MatTI,
    rnn_u: MatTI,
    /// LSTM: full 4H bias; GRU: input-bias row, both pre-shifted to 2F.
    rnn_b2f: Vec<i64>,
    /// GRU only: recurrent-bias row at 2F.
    rnn_b_rec2f: Option<Vec<i64>>,
    dense: Vec<DenseLayerI>,
    out: DenseLayerI,
    act: ActTables,
    softmax: Option<SoftmaxTables>,
    /// Batch-level parallelism for `forward_batch` (default 1 = inline).
    pool: WorkerPool,
    /// Recycled recurrence/head temporaries (one per in-flight chunk).
    scratch: BufferPool<FixedScratch>,
}

impl FixedEngine {
    /// Build with the paper's table policy: default LUTs, with the
    /// enlarged softmax table for the flavor/quickdraw models (§5.1).
    pub fn new(weights: &Weights, cfg: QuantConfig) -> anyhow::Result<Self> {
        let table = if weights.arch.name == "top" {
            TableConfig::softmax_default()
        } else {
            TableConfig::softmax_high()
        };
        Self::with_softmax_table(weights, cfg, table)
    }

    /// Build with an explicit softmax table configuration (used by the
    /// ablation bench comparing default vs enlarged softmax LUTs).
    pub fn with_softmax_table(
        weights: &Weights,
        cfg: QuantConfig,
        softmax_table: TableConfig,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            cfg.spec.width <= MAX_WIDTH,
            "width {} exceeds engine maximum {MAX_WIDTH} (i64 accumulator)",
            cfg.spec.width
        );
        let a = weights.arch.clone();
        let f = cfg.spec.frac();
        let w = weights.tensor("rnn", "w")?;
        let u = weights.tensor("rnn", "u")?;
        let b = weights.tensor("rnn", "b")?;
        let quant_shift =
            |xs: &[f32]| -> Vec<i64> { xs.iter().map(|&v| quantize(v as f64, cfg) << f).collect() };
        let (rnn_b2f, rnn_b_rec2f) = match a.cell {
            Cell::Lstm => (quant_shift(&b.data), None),
            Cell::Gru => {
                let gh = 3 * a.hidden_size;
                (
                    quant_shift(&b.data[..gh]),
                    Some(quant_shift(&b.data[gh..])),
                )
            }
        };
        let mut dense = Vec::new();
        for idx in 0..a.dense_sizes.len() {
            let lw = weights.tensor(&format!("dense{idx}"), "w")?;
            let lb = weights.tensor(&format!("dense{idx}"), "b")?;
            dense.push(DenseLayerI::new(&lw.shape, &lw.data, &lb.data, cfg));
        }
        let ow = weights.tensor("out", "w")?;
        let ob = weights.tensor("out", "b")?;
        let softmax = match a.output_activation {
            OutputActivation::Softmax => {
                Some(SoftmaxTables::new(cfg, softmax_table))
            }
            OutputActivation::Sigmoid => None,
        };
        Ok(Self {
            arch: a,
            cfg,
            rnn_w: MatTI::from_keras(&w.shape, &w.data, cfg),
            rnn_u: MatTI::from_keras(&u.shape, &u.data, cfg),
            rnn_b2f,
            rnn_b_rec2f,
            dense,
            out: DenseLayerI::new(&ow.shape, &ow.data, &ob.data, cfg),
            act: ActTables::new(cfg),
            softmax,
            pool: WorkerPool::new(1),
            scratch: BufferPool::new(32),
        })
    }

    pub fn config(&self) -> QuantConfig {
        self.cfg
    }

    /// Set the number of worker threads `forward_batch` may use.
    pub fn set_parallelism(&mut self, workers: usize) {
        self.pool = WorkerPool::new(workers);
    }

    /// Builder form of [`Self::set_parallelism`].
    pub fn with_parallelism(mut self, workers: usize) -> Self {
        self.set_parallelism(workers);
        self
    }

    pub fn parallelism(&self) -> usize {
        self.pool.workers()
    }

    /// Scratch-pool counters — the zero-allocation regression tests
    /// assert misses plateau once the pool is warm.
    pub fn scratch_stats(&self) -> PoolStats {
        self.scratch.stats()
    }

    /// Cast an accumulator value (2F fractional bits) to the engine type.
    #[inline]
    fn cast_acc(&self, acc: i64) -> i64 {
        requantize(acc, 2 * self.cfg.spec.frac(), self.cfg)
    }

    /// Hadamard product of two engine-type raws, cast back to engine type.
    #[inline]
    fn had(&self, a: i64, b: i64) -> i64 {
        requantize(a * b, 2 * self.cfg.spec.frac(), self.cfg)
    }

    fn lstm_forward(&self, x_raw: &[i64]) -> Vec<i64> {
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let spec = self.cfg.spec;
        let mut h = vec![0i64; h_sz];
        let mut c = vec![0i64; h_sz];
        let mut z = vec![0i64; 4 * h_sz];
        for t in 0..self.arch.seq_len {
            let x_t = &x_raw[t * i_sz..(t + 1) * i_sz];
            z.copy_from_slice(&self.rnn_b2f);
            self.rnn_w.matvec_acc(x_t, &mut z);
            self.rnn_u.matvec_acc(&h, &mut z);
            for j in 0..h_sz {
                let zi = self.cast_acc(z[j]);
                let zf = self.cast_acc(z[h_sz + j]);
                let zc = self.cast_acc(z[2 * h_sz + j]);
                let zo = self.cast_acc(z[3 * h_sz + j]);
                let i_g = self.act.sigmoid_raw(zi, spec);
                let f_g = self.act.sigmoid_raw(zf, spec);
                let g = self.act.tanh_raw(zc, spec);
                let o_g = self.act.sigmoid_raw(zo, spec);
                c[j] = self.had(f_g, c[j]) + self.had(i_g, g);
                // c re-enters the representable range via the cast in had();
                // clamp the sum as the output cast of the cell-state adder.
                c[j] = crate::fixed::value::overflow(c[j], spec, self.cfg.overflow);
                let tc = self.act.tanh_raw(c[j], spec);
                h[j] = self.had(o_g, tc);
            }
        }
        h
    }

    fn gru_forward(&self, x_raw: &[i64]) -> Vec<i64> {
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let spec = self.cfg.spec;
        let b_rec = self.rnn_b_rec2f.as_ref().expect("gru recurrent bias");
        let one = 1i64 << spec.frac(); // 1.0 in engine units
        let mut h = vec![0i64; h_sz];
        let mut xm = vec![0i64; 3 * h_sz];
        let mut hm = vec![0i64; 3 * h_sz];
        for t in 0..self.arch.seq_len {
            let x_t = &x_raw[t * i_sz..(t + 1) * i_sz];
            xm.copy_from_slice(&self.rnn_b2f);
            self.rnn_w.matvec_acc(x_t, &mut xm);
            hm.copy_from_slice(b_rec);
            self.rnn_u.matvec_acc(&h, &mut hm);
            for j in 0..h_sz {
                let z_pre = self.cast_acc(xm[j] + hm[j]);
                let r_pre = self.cast_acc(xm[h_sz + j] + hm[h_sz + j]);
                let z_g = self.act.sigmoid_raw(z_pre, spec);
                let r_g = self.act.sigmoid_raw(r_pre, spec);
                // reset_after Hadamard on the recurrent half (paper §3).
                let rec = self.had(r_g, self.cast_acc(hm[2 * h_sz + j]));
                let g_pre = crate::fixed::value::overflow(
                    self.cast_acc(xm[2 * h_sz + j]) + rec,
                    spec,
                    self.cfg.overflow,
                );
                let g = self.act.tanh_raw(g_pre, spec);
                let keep = self.had(z_g, h[j]);
                let new = self.had(one - z_g, g);
                h[j] = crate::fixed::value::overflow(
                    keep + new,
                    spec,
                    self.cfg.overflow,
                );
            }
        }
        h
    }

    /// Final-layer LUT activation for one raw-logit row, appended to
    /// `out`.  Sigmoid is allocation-free; the LUT softmax builds small
    /// per-row temporaries inside [`SoftmaxTables::softmax_raw`].
    fn output_probs_into(&self, logits: &[i64], out: &mut Vec<f32>) {
        let spec = self.cfg.spec;
        match self.arch.output_activation {
            OutputActivation::Sigmoid => out.extend(
                logits
                    .iter()
                    .map(|&z| dequantize(self.act.sigmoid_raw(z, spec), spec) as f32),
            ),
            OutputActivation::Softmax => {
                let sm = self.softmax.as_ref().expect("softmax tables");
                out.extend(
                    sm.softmax_raw(logits, spec)
                        .iter()
                        .map(|&p| dequantize(p, spec) as f32),
                );
            }
        }
    }

    /// Final-layer LUT activation for one raw-logit row.
    fn output_probs(&self, logits: &[i64]) -> Vec<f32> {
        let mut out = Vec::with_capacity(logits.len());
        self.output_probs_into(logits, &mut out);
        out
    }

    // ---- lockstep batched path (bit-exact integer datapath) ------------

    /// Gather timestep `t` of every sample from the packed quantized
    /// buffer into `xt`.
    fn gather_step(
        x_raw: &[i64],
        stride: usize,
        t: usize,
        i_sz: usize,
        xt: &mut [i64],
    ) {
        for bi in 0..xt.len() / i_sz {
            xt[bi * i_sz..(bi + 1) * i_sz].copy_from_slice(
                &x_raw[bi * stride + t * i_sz..bi * stride + (t + 1) * i_sz],
            );
        }
    }

    /// Tile a 2F-bias row across the batch, recycling `out`'s capacity.
    fn tile_bias_into(bias: &[i64], batch: usize, out: &mut Vec<i64>) {
        out.clear();
        out.reserve(batch * bias.len());
        for _ in 0..batch {
            out.extend_from_slice(bias);
        }
    }

    /// Lockstep LSTM over the packed quantized inputs in `s.x_raw`;
    /// leaves the packed `[b][h]` state in `s.h`.
    fn lstm_forward_batch(&self, b: usize, s: &mut FixedScratch) {
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let stride = self.arch.seq_len * i_sz;
        let spec = self.cfg.spec;
        zeroed(&mut s.h, b * h_sz);
        zeroed(&mut s.c, b * h_sz);
        zeroed(&mut s.z, b * 4 * h_sz);
        zeroed(&mut s.xt, b * i_sz);
        for t in 0..self.arch.seq_len {
            Self::gather_step(&s.x_raw, stride, t, i_sz, &mut s.xt);
            for bi in 0..b {
                s.z[bi * 4 * h_sz..(bi + 1) * 4 * h_sz]
                    .copy_from_slice(&self.rnn_b2f);
            }
            self.rnn_w.matmul_acc(&s.xt, b, &mut s.z);
            self.rnn_u.matmul_acc(&s.h, b, &mut s.z);
            for bi in 0..b {
                let zb = &s.z[bi * 4 * h_sz..(bi + 1) * 4 * h_sz];
                for j in 0..h_sz {
                    let zi = self.cast_acc(zb[j]);
                    let zf = self.cast_acc(zb[h_sz + j]);
                    let zc = self.cast_acc(zb[2 * h_sz + j]);
                    let zo = self.cast_acc(zb[3 * h_sz + j]);
                    let i_g = self.act.sigmoid_raw(zi, spec);
                    let f_g = self.act.sigmoid_raw(zf, spec);
                    let g = self.act.tanh_raw(zc, spec);
                    let o_g = self.act.sigmoid_raw(zo, spec);
                    let cj = &mut s.c[bi * h_sz + j];
                    *cj = self.had(f_g, *cj) + self.had(i_g, g);
                    *cj = crate::fixed::value::overflow(
                        *cj,
                        spec,
                        self.cfg.overflow,
                    );
                    let tc = self.act.tanh_raw(*cj, spec);
                    s.h[bi * h_sz + j] = self.had(o_g, tc);
                }
            }
        }
    }

    /// Lockstep GRU over the packed quantized inputs in `s.x_raw`;
    /// leaves the packed `[b][h]` state in `s.h` (`s.z` holds the
    /// input-half gates, `s.hm` the recurrent half).
    fn gru_forward_batch(&self, b: usize, s: &mut FixedScratch) {
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let stride = self.arch.seq_len * i_sz;
        let spec = self.cfg.spec;
        let b_rec = self.rnn_b_rec2f.as_ref().expect("gru recurrent bias");
        let one = 1i64 << spec.frac();
        zeroed(&mut s.h, b * h_sz);
        zeroed(&mut s.z, b * 3 * h_sz);
        zeroed(&mut s.hm, b * 3 * h_sz);
        zeroed(&mut s.xt, b * i_sz);
        for t in 0..self.arch.seq_len {
            Self::gather_step(&s.x_raw, stride, t, i_sz, &mut s.xt);
            for bi in 0..b {
                s.z[bi * 3 * h_sz..(bi + 1) * 3 * h_sz]
                    .copy_from_slice(&self.rnn_b2f);
                s.hm[bi * 3 * h_sz..(bi + 1) * 3 * h_sz].copy_from_slice(b_rec);
            }
            self.rnn_w.matmul_acc(&s.xt, b, &mut s.z);
            self.rnn_u.matmul_acc(&s.h, b, &mut s.hm);
            for bi in 0..b {
                let xb = &s.z[bi * 3 * h_sz..(bi + 1) * 3 * h_sz];
                let hb = &s.hm[bi * 3 * h_sz..(bi + 1) * 3 * h_sz];
                for j in 0..h_sz {
                    let z_pre = self.cast_acc(xb[j] + hb[j]);
                    let r_pre = self.cast_acc(xb[h_sz + j] + hb[h_sz + j]);
                    let z_g = self.act.sigmoid_raw(z_pre, spec);
                    let r_g = self.act.sigmoid_raw(r_pre, spec);
                    let rec = self.had(r_g, self.cast_acc(hb[2 * h_sz + j]));
                    let g_pre = crate::fixed::value::overflow(
                        self.cast_acc(xb[2 * h_sz + j]) + rec,
                        spec,
                        self.cfg.overflow,
                    );
                    let g = self.act.tanh_raw(g_pre, spec);
                    let hj = &mut s.h[bi * h_sz + j];
                    let keep = self.had(z_g, *hj);
                    let new = self.had(one - z_g, g);
                    *hj = crate::fixed::value::overflow(
                        keep + new,
                        spec,
                        self.cfg.overflow,
                    );
                }
            }
        }
    }

    /// One worker's share of a batch: quantize the chunk's inputs once
    /// into pooled scratch, run the lockstep recurrence, then the batched
    /// dense head — output rows appended flat to `out`.
    fn forward_rows_into(
        &self,
        rows: BatchRows,
        s: &mut FixedScratch,
        out: &mut Vec<f32>,
    ) {
        let b = rows.len();
        if b == 0 {
            return;
        }
        let stride = self.arch.seq_len * self.arch.input_size;
        // Input quantization once per chunk into the packed scratch buffer.
        s.x_raw.clear();
        s.x_raw.reserve(b * stride);
        for bi in 0..b {
            s.x_raw
                .extend(rows.row(bi).iter().map(|&v| quantize(v as f64, self.cfg)));
        }
        match self.arch.cell {
            Cell::Lstm => self.lstm_forward_batch(b, s),
            Cell::Gru => self.gru_forward_batch(b, s),
        }
        for layer in &self.dense {
            Self::tile_bias_into(&layer.b2f, b, &mut s.acts);
            layer.w.matmul_acc(&s.h, b, &mut s.acts);
            s.h.clear();
            s.h.extend(
                s.acts
                    .iter()
                    .map(|&acc| self.cast_acc(acc).max(0)), // ReLU is exact
            );
        }
        Self::tile_bias_into(&self.out.b2f, b, &mut s.acts);
        self.out.w.matmul_acc(&s.h, b, &mut s.acts);
        let out_sz = self.out.b2f.len();
        for row in s.acts.chunks_exact(out_sz) {
            s.logits.clear();
            s.logits.extend(row.iter().map(|&acc| self.cast_acc(acc)));
            self.output_probs_into(&s.logits, out);
        }
    }

    /// One worker's share of a batch in the legacy per-sample layout.
    fn forward_chunk(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        let mut s = self.scratch.get_with(FixedScratch::default);
        let mut flat = Vec::with_capacity(xs.len() * self.arch.output_size);
        self.forward_rows_into(BatchRows::Slices(xs), &mut s, &mut flat);
        self.scratch.put(s);
        flat.chunks_exact(self.arch.output_size.max(1))
            .map(|r| r.to_vec())
            .collect()
    }
}

impl Engine for FixedEngine {
    fn forward(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.arch.seq_len * self.arch.input_size);
        let x_raw: Vec<i64> =
            x.iter().map(|&v| quantize(v as f64, self.cfg)).collect();
        let mut h = match self.arch.cell {
            Cell::Lstm => self.lstm_forward(&x_raw),
            Cell::Gru => self.gru_forward(&x_raw),
        };
        for layer in &self.dense {
            let mut y = layer.b2f.clone();
            layer.w.matvec_acc(&h, &mut y);
            h = y
                .iter()
                .map(|&acc| self.cast_acc(acc).max(0)) // ReLU is exact
                .collect();
        }
        let mut y = self.out.b2f.clone();
        self.out.w.matvec_acc(&h, &mut y);
        let logits: Vec<i64> = y.iter().map(|&acc| self.cast_acc(acc)).collect();
        self.output_probs(&logits)
    }

    fn arch(&self) -> &Arch {
        &self.arch
    }

    /// Parallel batched forward: the integer datapath is exact, so any
    /// chunking/worker count reproduces per-sample `forward` bit-for-bit.
    fn forward_batch(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        if xs.is_empty() {
            return Vec::new();
        }
        self.pool
            .map_chunks(xs.len(), |range| self.forward_chunk(&xs[range]))
    }

    /// The zero-allocation serving path: quantized inputs and recurrence
    /// temporaries come from the scratch pool and rows land in the
    /// caller's recycled `out`.  Single-worker engines (the serving
    /// default) allocate nothing once the pool is warm — except the LUT
    /// softmax's per-row temporaries on softmax-output models.
    fn forward_packed_into(&self, xs: &[f32], n: usize, out: &mut PackedOut) {
        let stride = self.arch.seq_len * self.arch.input_size;
        assert_eq!(
            xs.len(),
            n * stride,
            "packed buffer length {} != {} samples x stride {}",
            xs.len(),
            n,
            stride
        );
        out.reset(self.arch.output_size);
        if n == 0 {
            return;
        }
        if self.pool.workers() <= 1 {
            let mut s = self.scratch.get_with(FixedScratch::default);
            let mut flat = std::mem::take(&mut out.data);
            self.forward_rows_into(
                BatchRows::Packed { xs, stride, start: 0, len: n },
                &mut s,
                &mut flat,
            );
            out.data = flat;
            self.scratch.put(s);
        } else {
            out.data = self.pool.map_chunks(n, |range| {
                let mut s = self.scratch.get_with(FixedScratch::default);
                let mut flat =
                    Vec::with_capacity(range.len() * self.arch.output_size);
                self.forward_rows_into(
                    BatchRows::Packed {
                        xs,
                        stride,
                        start: range.start,
                        len: range.len(),
                    },
                    &mut s,
                    &mut flat,
                );
                self.scratch.put(s);
                flat
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed::FixedSpec;
    use crate::nn::float_engine::FloatEngine;

    /// Small deterministic weights for a scaled-down "top"-like model.
    fn tiny_weights(cell: &str) -> Weights {
        let h = 4usize;
        let i = 3usize;
        let g = if cell == "lstm" { 4 } else { 3 };
        let mut w = Vec::new();
        for r in 0..i {
            for c in 0..g * h {
                w.push((((r * 7 + c * 3) % 13) as f32 - 6.0) / 13.0);
            }
        }
        let mut u = Vec::new();
        for r in 0..h {
            for c in 0..g * h {
                u.push((((r * 5 + c * 11) % 17) as f32 - 8.0) / 17.0);
            }
        }
        let b: Vec<f32> = if cell == "lstm" {
            (0..4 * h)
                .map(|j| if (h..2 * h).contains(&j) { 1.0 } else { 0.0 })
                .collect()
        } else {
            vec![0.05; 2 * 3 * h]
        };
        let b_shape = if cell == "lstm" {
            vec![4 * h]
        } else {
            vec![2, 3 * h]
        };
        let dw: Vec<f32> = (0..h * 5).map(|k| ((k % 9) as f32 - 4.0) / 9.0).collect();
        let ow: Vec<f32> = (0..5).map(|k| ((k % 3) as f32 - 1.0) / 2.0).collect();
        let count = if cell == "lstm" {
            4 * (i * h + h * h + h) + (h * 5 + 5) + (5 + 1)
        } else {
            3 * (i * h + h * h) + 6 * h + (h * 5 + 5) + (5 + 1)
        };
        let farr = |xs: &[f32]| -> String {
            let items: Vec<String> = xs.iter().map(|v| format!("{v}")).collect();
            format!("[{}]", items.join(","))
        };
        let uarr = |xs: &[usize]| -> String {
            let items: Vec<String> = xs.iter().map(|v| format!("{v}")).collect();
            format!("[{}]", items.join(","))
        };
        let doc = format!(
            r#"{{
            "arch": {{
                "name": "top", "cell": "{cell}", "seq_len": 5,
                "input_size": {i}, "hidden_size": {h}, "dense_sizes": [5],
                "output_size": 1, "output_activation": "sigmoid"
            }},
            "param_count": {count},
            "layers": [
                {{"name": "rnn",
                 "w": {{"shape": [{i}, {gh}], "data": {w}}},
                 "u": {{"shape": [{h}, {gh}], "data": {u}}},
                 "b": {{"shape": {b_shape}, "data": {b}}}}},
                {{"name": "dense0",
                 "w": {{"shape": [{h}, 5], "data": {dw}}},
                 "b": {{"shape": [5], "data": [0.1, -0.1, 0.0, 0.2, 0.0]}}}},
                {{"name": "out",
                 "w": {{"shape": [5, 1], "data": {ow}}},
                 "b": {{"shape": [1], "data": [0.05]}}}}
            ]
        }}"#,
            gh = g * h,
            w = farr(&w),
            u = farr(&u),
            b_shape = uarr(&b_shape),
            b = farr(&b),
            dw = farr(&dw),
            ow = farr(&ow),
        );
        Weights::from_json(&doc).unwrap()
    }

    fn sample_input(len: usize) -> Vec<f32> {
        (0..len).map(|k| ((k * 37 % 21) as f32 - 10.0) / 10.0).collect()
    }

    #[test]
    fn high_precision_matches_float_lstm() {
        let w = tiny_weights("lstm");
        let fl = FloatEngine::new(&w).unwrap();
        let fx = FixedEngine::new(&w, QuantConfig::ptq(FixedSpec::new(26, 8))).unwrap();
        let x = sample_input(15);
        let yf = fl.forward(&x);
        let yq = fx.forward(&x);
        assert!(
            (yf[0] - yq[0]).abs() < 0.01,
            "float {} vs fixed {}",
            yf[0],
            yq[0]
        );
    }

    #[test]
    fn high_precision_matches_float_gru() {
        let w = tiny_weights("gru");
        let fl = FloatEngine::new(&w).unwrap();
        let fx = FixedEngine::new(&w, QuantConfig::ptq(FixedSpec::new(26, 8))).unwrap();
        let x = sample_input(15);
        let yf = fl.forward(&x);
        let yq = fx.forward(&x);
        assert!(
            (yf[0] - yq[0]).abs() < 0.01,
            "float {} vs fixed {}",
            yf[0],
            yq[0]
        );
    }

    #[test]
    fn precision_ladder_converges_monotonically_on_average() {
        // Error vs float should shrink as fractional bits grow (Fig. 2's
        // mechanism).  Averaged over inputs to tolerate per-point noise.
        let w = tiny_weights("lstm");
        let fl = FloatEngine::new(&w).unwrap();
        let mut errs = Vec::new();
        for frac in [2u32, 6, 10, 14] {
            let cfg = QuantConfig::ptq(FixedSpec::new(6 + frac, 6));
            let fx = FixedEngine::new(&w, cfg).unwrap();
            let mut e = 0.0f32;
            for s in 0..8 {
                let x: Vec<f32> = (0..15)
                    .map(|k| (((k + s * 3) * 37 % 21) as f32 - 10.0) / 10.0)
                    .collect();
                e += (fl.forward(&x)[0] - fx.forward(&x)[0]).abs();
            }
            errs.push(e / 8.0);
        }
        assert!(errs[3] < errs[0], "errors {errs:?}");
        assert!(errs[3] < 0.02, "errors {errs:?}");
    }

    #[test]
    fn forward_batch_is_bitwise_identical() {
        for cell in ["lstm", "gru"] {
            let w = tiny_weights(cell);
            let mut fx =
                FixedEngine::new(&w, QuantConfig::ptq(FixedSpec::new(16, 6)))
                    .unwrap();
            let samples: Vec<Vec<f32>> = (0..5)
                .map(|s| {
                    (0..15)
                        .map(|k| {
                            (((k + s * 7) * 37 % 21) as f32 - 10.0) / 10.0
                        })
                        .collect()
                })
                .collect();
            let refs: Vec<&[f32]> =
                samples.iter().map(|v| v.as_slice()).collect();
            let want: Vec<Vec<f32>> =
                refs.iter().map(|x| fx.forward(x)).collect();
            for workers in [1usize, 2, 8] {
                fx.set_parallelism(workers);
                assert_eq!(fx.forward_batch(&refs), want, "{cell} w{workers}");
            }
        }
    }

    #[test]
    fn rejects_overwide_type() {
        let w = tiny_weights("lstm");
        assert!(
            FixedEngine::new(&w, QuantConfig::ptq(FixedSpec::new(32, 8))).is_err()
        );
    }

    #[test]
    fn output_is_valid_probability() {
        let w = tiny_weights("gru");
        for width in [8u32, 12, 16, 20] {
            let fx =
                FixedEngine::new(&w, QuantConfig::ptq(FixedSpec::new(width, 6)))
                    .unwrap();
            let y = fx.forward(&sample_input(15));
            assert!(y[0] >= -0.01 && y[0] <= 1.01, "w={width} y={}", y[0]);
        }
    }

    #[test]
    fn scratch_pool_goes_warm() {
        let w = tiny_weights("lstm");
        let fx =
            FixedEngine::new(&w, QuantConfig::ptq(FixedSpec::new(16, 6))).unwrap();
        let xs: Vec<f32> = (0..3).flat_map(|_| sample_input(15)).collect();
        let mut out = PackedOut::new();
        for _ in 0..10 {
            fx.forward_packed_into(&xs, 3, &mut out);
            assert_eq!(out.rows(), 3);
        }
        let stats = fx.scratch_stats();
        assert_eq!(stats.misses, 1, "one scratch build, then recycled");
        assert_eq!(stats.hits, 9);
    }
}
