//! Backend registry: serving engines as data, not hardcoded match arms.
//!
//! A *backend* is a named constructor from `(Arch + weights, context)` to
//! a boxed [`Engine`].  The serving layers (CLI `serve --backends`, the
//! heterogeneous `ShardedServer` factories, the bench sweeps) resolve
//! names against the registry table through [`BackendSpec`], so adding
//! an engine kind is one new registry row — no routing, CLI, or report
//! code changes.
//!
//! Registered backends:
//!
//! * `float` — the f32 reference engine ([`FloatEngine`]): the
//!   offline/full-precision tier.
//! * `fixed` — the bit-accurate `ap_fixed` engine ([`FixedEngine`]): the
//!   trigger tier, quantized per the context's [`FixedSpec`].
//! * `pjrt` — reserved slot for the PJRT runtime.  This build vendors
//!   only the PJRT interface stub (`vendor/xla`, no plugin), so
//!   construction fails with a clear error; the row keeps the name
//!   stable for when the real bindings are reinstated (ROADMAP).

use crate::fixed::{FixedSpec, QuantConfig};
use crate::model::Weights;

use super::{Engine, FixedEngine, FloatEngine};

/// Everything a backend constructor may draw on.  One context serves all
/// backends so the factory call sites stay backend-agnostic; fields a
/// given backend does not need (e.g. `fixed_spec` for `float`) are
/// simply ignored by it.
pub struct BackendCtx<'a> {
    /// Trained or synthetic weights (carry the [`crate::model::Arch`]).
    pub weights: &'a Weights,
    /// Quantization type for the `fixed` backend.
    pub fixed_spec: FixedSpec,
    /// Per-batch worker threads inside the engine (1 = inline).
    pub parallelism: usize,
}

type BuildFn = fn(&BackendCtx) -> anyhow::Result<Box<dyn Engine>>;

/// One registry row: a name, a help line, and a constructor.
#[derive(Debug)]
struct BackendEntry {
    name: &'static str,
    help: &'static str,
    build: BuildFn,
}

fn build_float(ctx: &BackendCtx) -> anyhow::Result<Box<dyn Engine>> {
    Ok(Box::new(
        FloatEngine::new(ctx.weights)?.with_parallelism(ctx.parallelism),
    ))
}

fn build_fixed(ctx: &BackendCtx) -> anyhow::Result<Box<dyn Engine>> {
    Ok(Box::new(
        FixedEngine::new(ctx.weights, QuantConfig::ptq(ctx.fixed_spec))?
            .with_parallelism(ctx.parallelism),
    ))
}

fn build_pjrt(_ctx: &BackendCtx) -> anyhow::Result<Box<dyn Engine>> {
    anyhow::bail!(
        "backend \"pjrt\" is registered but unavailable: this build vendors \
         only the PJRT interface stub (vendor/xla, no plugin), so the slot \
         cannot construct an engine — pick \"fixed\" or \"float\", or \
         reinstate the real bindings (see ROADMAP: PJRT backend)"
    )
}

/// The backend table.  Order is the order `names()` reports and help
/// text lists.
const REGISTRY: &[BackendEntry] = &[
    BackendEntry {
        name: "fixed",
        help: "bit-accurate ap_fixed datapath (trigger tier)",
        build: build_fixed,
    },
    BackendEntry {
        name: "float",
        help: "f32 reference engine (offline tier)",
        build: build_float,
    },
    BackendEntry {
        name: "pjrt",
        help: "PJRT runtime slot (interface stub in this build)",
        build: build_pjrt,
    },
];

/// A resolved backend: a handle into the registry table.  Cheap to copy
/// and thread-safe, so serving factories can capture one per shard.
#[derive(Debug, Clone, Copy)]
pub struct BackendSpec {
    entry: &'static BackendEntry,
}

impl BackendSpec {
    /// Resolve a backend name; the error lists the registered names.
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        REGISTRY
            .iter()
            .find(|entry| entry.name == name)
            .map(|entry| Self { entry })
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown backend {name:?} (registered: {:?})",
                    Self::names()
                )
            })
    }

    /// Resolve a comma-separated backend list (`"fixed,float"`), one
    /// entry per shard.
    pub fn parse_list(csv: &str) -> anyhow::Result<Vec<Self>> {
        let specs: Vec<Self> = csv
            .split(',')
            .map(|part| Self::parse(part.trim()))
            .collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(!specs.is_empty(), "backend list is empty");
        Ok(specs)
    }

    pub fn name(&self) -> &'static str {
        self.entry.name
    }

    pub fn help(&self) -> &'static str {
        self.entry.help
    }

    /// Construct this backend's engine over the context.
    pub fn build(&self, ctx: &BackendCtx) -> anyhow::Result<Box<dyn Engine>> {
        (self.entry.build)(ctx).map_err(|e| {
            anyhow::anyhow!("backend {:?}: {e}", self.entry.name)
        })
    }

    /// All registered backend names, registry order.
    pub fn names() -> Vec<&'static str> {
        REGISTRY.iter().map(|entry| entry.name).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::model::Cell;

    fn ctx_weights() -> Weights {
        let arch = zoo::arch("top", Cell::Gru).unwrap();
        Weights::synthetic(&arch, 0xB0B)
    }

    #[test]
    fn registry_resolves_known_names() {
        assert_eq!(BackendSpec::names(), vec!["fixed", "float", "pjrt"]);
        for name in BackendSpec::names() {
            let spec = BackendSpec::parse(name).unwrap();
            assert_eq!(spec.name(), name);
            assert!(!spec.help().is_empty());
        }
        let err = BackendSpec::parse("tpu").unwrap_err().to_string();
        assert!(err.contains("registered"), "{err}");
        assert!(err.contains("fixed"), "{err}");
    }

    #[test]
    fn parse_list_splits_and_validates() {
        let specs = BackendSpec::parse_list("fixed, float").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name(), "fixed");
        assert_eq!(specs[1].name(), "float");
        assert!(BackendSpec::parse_list("fixed,nope").is_err());
        assert!(BackendSpec::parse_list("").is_err());
    }

    #[test]
    fn fixed_and_float_build_engines_over_the_arch() {
        let weights = ctx_weights();
        let ctx = BackendCtx {
            weights: &weights,
            fixed_spec: FixedSpec::new(16, 6),
            parallelism: 1,
        };
        for name in ["fixed", "float"] {
            let engine = BackendSpec::parse(name).unwrap().build(&ctx).unwrap();
            assert_eq!(engine.arch().key(), "top_gru", "{name}");
            let x = vec![0.1f32; engine.arch().seq_len * engine.arch().input_size];
            assert_eq!(engine.forward(&x).len(), 1, "{name}");
        }
    }

    #[test]
    fn pjrt_slot_rejects_with_clear_error() {
        let weights = ctx_weights();
        let ctx = BackendCtx {
            weights: &weights,
            fixed_spec: FixedSpec::new(16, 6),
            parallelism: 1,
        };
        let err = BackendSpec::parse("pjrt")
            .unwrap()
            .build(&ctx)
            .unwrap_err()
            .to_string();
        assert!(err.contains("stub"), "{err}");
        assert!(err.contains("pjrt"), "{err}");
    }
}
