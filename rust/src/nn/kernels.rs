//! Vectorized inner-product kernels — the one place the engines compute
//! dot products, scalar or SIMD.
//!
//! This is the software analogue of the paper's ReuseFactor=1 full
//! unroll: saturate the multiplier lanes every cycle.  Two datapaths:
//!
//! * **f32** (`FloatEngine`) — the reduction order is *pinned*: partial
//!   sums are kept in [`F32_LANES`] lanes filled lane-strided
//!   (`acc[l] += x[c*L + l] * w[c*L + l]` for whole chunks in increasing
//!   `c`, then tail element `j` into lane `j`), combined by the fixed
//!   tree `((a0+a1)+(a2+a3)) + ((a4+a5)+(a6+a7))`.  The scalar fallback
//!   implements exactly this order, and the AVX2 path performs the
//!   identical per-lane multiply-then-add (no FMA — fused contraction
//!   would change the rounding), so **float results are bitwise
//!   identical** with `--features simd` on or off, on every target.
//! * **i64** (`FixedEngine`) — integer addition is associative, so any
//!   reduction order is exact; the scalar path is a plain sequential
//!   sum.  The AVX2 path uses `_mm256_mul_epi32` (signed 32×32→64 from
//!   each 64-bit lane's low half), exact because the fixed engine's
//!   `MAX_WIDTH = 26` bounds every raw value well inside `i32`.
//!
//! Dispatch happens once per matrix multiply (`matmul_acc_*`), not per
//! dot product: with `--features simd` on x86_64 an AVX2-capable host
//! takes the vector path (runtime `is_x86_feature_detected!`), anything
//! else falls back to the canonical scalar loops.  `tests/
//! kernel_equivalence.rs` pins SIMD ≡ scalar bitwise for raw kernels
//! and whole engines across odd shapes; `benches/hot_paths.rs` tracks
//! the throughput win in `BENCH_kernels.json`.

/// f32 accumulator lanes (one AVX2 `__m256` register).
pub const F32_LANES: usize = 8;
/// i64 accumulator lanes (one AVX2 `__m256i` register).
pub const I64_LANES: usize = 4;

/// Whether the vector kernels were compiled in (`--features simd` on a
/// target we have lanes for).
#[inline]
pub fn simd_compiled() -> bool {
    cfg!(all(feature = "simd", target_arch = "x86_64"))
}

/// Whether the vector kernels are actually taken on this host (compiled
/// in *and* the CPU reports AVX2).
#[inline]
pub fn simd_active() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// The pinned f32 lane-combination tree.  Shared verbatim by the scalar
/// and AVX2 paths — this is what makes them bitwise interchangeable.
#[inline]
fn reduce_f32(acc: &[f32; F32_LANES]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3]))
        + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Canonical f32 dot product: lane-strided partial sums, fixed tree
/// reduction.  This *is* the contract; the AVX2 path mirrors it.
#[inline]
pub fn dot_f32_scalar(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    let mut acc = [0.0f32; F32_LANES];
    for (xc, wc) in x.chunks_exact(F32_LANES).zip(w.chunks_exact(F32_LANES)) {
        for ((a, xi), wi) in acc.iter_mut().zip(xc).zip(wc) {
            *a += xi * wi;
        }
    }
    let tail = x.len() - x.len() % F32_LANES;
    for ((a, xi), wi) in acc.iter_mut().zip(&x[tail..]).zip(&w[tail..]) {
        *a += xi * wi;
    }
    reduce_f32(&acc)
}

/// i64 dot product — integer addition is associative, so the plain
/// sequential sum is the canonical (and exact) order.
#[inline]
pub fn dot_i64_scalar(x: &[i64], w: &[i64]) -> i64 {
    debug_assert_eq!(x.len(), w.len());
    let mut acc = 0i64;
    for (xi, wi) in x.iter().zip(w) {
        acc += xi * wi;
    }
    acc
}

/// f32 dot product, dispatched (AVX2 where compiled + detected).
#[inline]
pub fn dot_f32(x: &[f32], w: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if std::arch::is_x86_feature_detected!("avx2") {
        // SAFETY: AVX2 support was just confirmed at runtime.
        return unsafe { x86::dot_f32_avx2(x, w) };
    }
    dot_f32_scalar(x, w)
}

/// i64 dot product, dispatched (AVX2 where compiled + detected).
#[inline]
pub fn dot_i64(x: &[i64], w: &[i64]) -> i64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if std::arch::is_x86_feature_detected!("avx2") {
        debug_assert!(fits_i32(x) && fits_i32(w), "mul_epi32 precondition");
        // SAFETY: AVX2 support was just confirmed at runtime.
        return unsafe { x86::dot_i64_avx2(x, w) };
    }
    dot_i64_scalar(x, w)
}

/// `ys[b * rows_out + o] += Σ_i xs[b * cols_in + i] * wt[o * cols_in + i]`
/// — the scalar reference, identical accumulation order to
/// [`dot_f32_scalar`] per (sample, output) pair.
pub fn matmul_acc_f32_scalar(
    wt: &[f32],
    rows_out: usize,
    cols_in: usize,
    xs: &[f32],
    batch: usize,
    ys: &mut [f32],
) {
    debug_assert_eq!(wt.len(), rows_out * cols_in);
    debug_assert_eq!(xs.len(), batch * cols_in);
    debug_assert_eq!(ys.len(), batch * rows_out);
    for (o, row) in wt.chunks_exact(cols_in).enumerate() {
        for (b, x) in xs.chunks_exact(cols_in).enumerate() {
            ys[b * rows_out + o] += dot_f32_scalar(x, row);
        }
    }
}

/// Batched f32 matmul-accumulate, dispatched once per call.
pub fn matmul_acc_f32(
    wt: &[f32],
    rows_out: usize,
    cols_in: usize,
    xs: &[f32],
    batch: usize,
    ys: &mut [f32],
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if std::arch::is_x86_feature_detected!("avx2") {
        debug_assert_eq!(wt.len(), rows_out * cols_in);
        debug_assert_eq!(xs.len(), batch * cols_in);
        debug_assert_eq!(ys.len(), batch * rows_out);
        // SAFETY: AVX2 support was just confirmed at runtime.
        unsafe { x86::matmul_acc_f32_avx2(wt, rows_out, cols_in, xs, batch, ys) };
        return;
    }
    matmul_acc_f32_scalar(wt, rows_out, cols_in, xs, batch, ys);
}

/// i64 variant of [`matmul_acc_f32_scalar`]; exact under any order.
pub fn matmul_acc_i64_scalar(
    wt: &[i64],
    rows_out: usize,
    cols_in: usize,
    xs: &[i64],
    batch: usize,
    ys: &mut [i64],
) {
    debug_assert_eq!(wt.len(), rows_out * cols_in);
    debug_assert_eq!(xs.len(), batch * cols_in);
    debug_assert_eq!(ys.len(), batch * rows_out);
    for (o, row) in wt.chunks_exact(cols_in).enumerate() {
        for (b, x) in xs.chunks_exact(cols_in).enumerate() {
            ys[b * rows_out + o] += dot_i64_scalar(x, row);
        }
    }
}

/// Batched i64 matmul-accumulate, dispatched once per call.
///
/// SIMD precondition (debug-asserted): every value fits `i32`.  The
/// fixed engine's `MAX_WIDTH = 26` keeps raw values under 2^26, far
/// inside the bound, so the `_mm256_mul_epi32` low-half multiply is
/// exact.
pub fn matmul_acc_i64(
    wt: &[i64],
    rows_out: usize,
    cols_in: usize,
    xs: &[i64],
    batch: usize,
    ys: &mut [i64],
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if std::arch::is_x86_feature_detected!("avx2") {
        debug_assert_eq!(wt.len(), rows_out * cols_in);
        debug_assert_eq!(xs.len(), batch * cols_in);
        debug_assert_eq!(ys.len(), batch * rows_out);
        debug_assert!(fits_i32(xs) && fits_i32(wt), "mul_epi32 precondition");
        // SAFETY: AVX2 support was just confirmed at runtime.
        unsafe { x86::matmul_acc_i64_avx2(wt, rows_out, cols_in, xs, batch, ys) };
        return;
    }
    matmul_acc_i64_scalar(wt, rows_out, cols_in, xs, batch, ys);
}

/// Debug-only guard for the `_mm256_mul_epi32` low-half precondition.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn fits_i32(vals: &[i64]) -> bool {
    vals.iter().all(|&v| i32::try_from(v).is_ok())
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    //! AVX2 lane implementations.  Every function is `unsafe` only for
    //! the `#[target_feature]` contract: the *sole* precondition is
    //! that the host supports AVX2, which the dispatchers in the parent
    //! module verify with `is_x86_feature_detected!` before every call.
    //! All memory access below stays in bounds by construction
    //! (`chunks_exact` + checked tails), so no other obligation exists.

    use core::arch::x86_64::{
        __m256i, _mm256_add_epi64, _mm256_add_ps, _mm256_loadu_ps,
        _mm256_loadu_si256, _mm256_mul_epi32, _mm256_mul_ps,
        _mm256_setzero_ps, _mm256_setzero_si256, _mm256_storeu_ps,
        _mm256_storeu_si256,
    };

    use super::{reduce_f32, F32_LANES, I64_LANES};

    // SAFETY: `unsafe fn` only for the target-feature contract — the
    // dispatcher confirms AVX2 before every call (module doc above).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_f32_avx2(x: &[f32], w: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), w.len());
        let chunks = x.len() / F32_LANES;
        // SAFETY: (whole function) AVX2 is guaranteed by the caller per
        // the module contract; every pointer below is derived from a
        // slice and offset strictly inside its length (`c * 8 + 8 <=
        // chunks * 8 <= len`), and unaligned loads/stores are used
        // throughout, so alignment is irrelevant.
        let mut acc = unsafe { _mm256_setzero_ps() };
        for c in 0..chunks {
            let base = c * F32_LANES;
            // SAFETY: base + 8 <= x.len() and w.len(); loadu has no
            // alignment requirement.
            let xv = unsafe { _mm256_loadu_ps(x.as_ptr().add(base)) };
            let wv = unsafe { _mm256_loadu_ps(w.as_ptr().add(base)) };
            // Multiply then add, NOT fmadd: the scalar fallback rounds
            // after the multiply, and bitwise identity is the contract.
            // SAFETY: pure register arithmetic under confirmed AVX2.
            acc = unsafe { _mm256_add_ps(acc, _mm256_mul_ps(xv, wv)) };
        }
        let mut lanes = [0.0f32; F32_LANES];
        // SAFETY: `lanes` is exactly 8 f32s; storeu is unaligned-safe.
        unsafe { _mm256_storeu_ps(lanes.as_mut_ptr(), acc) };
        let tail = x.len() - x.len() % F32_LANES;
        for ((a, xi), wi) in lanes.iter_mut().zip(&x[tail..]).zip(&w[tail..]) {
            *a += xi * wi;
        }
        reduce_f32(&lanes)
    }

    // SAFETY: `unsafe fn` only for the target-feature contract — the
    // dispatcher confirms AVX2 before every call (module doc above).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_i64_avx2(x: &[i64], w: &[i64]) -> i64 {
        debug_assert_eq!(x.len(), w.len());
        let chunks = x.len() / I64_LANES;
        // SAFETY: (whole function) AVX2 per the module contract; all
        // loads are unaligned (`loadu`) from offsets bounded by
        // `chunks * 4 <= len`, and the store target is a local array of
        // exactly 4 i64s.
        let mut acc = unsafe { _mm256_setzero_si256() };
        for c in 0..chunks {
            let base = c * I64_LANES;
            // SAFETY: base + 4 <= x.len() and w.len().
            let xv = unsafe {
                _mm256_loadu_si256(x.as_ptr().add(base) as *const __m256i)
            };
            let wv = unsafe {
                _mm256_loadu_si256(w.as_ptr().add(base) as *const __m256i)
            };
            // mul_epi32 multiplies each 64-bit lane's low 32 bits,
            // sign-extended — exact while |values| < 2^31 (debug-
            // asserted in the dispatcher; MAX_WIDTH = 26 upstream).
            // SAFETY: pure register arithmetic under confirmed AVX2.
            acc = unsafe { _mm256_add_epi64(acc, _mm256_mul_epi32(xv, wv)) };
        }
        let mut lanes = [0i64; I64_LANES];
        // SAFETY: `lanes` is exactly one __m256i wide.
        unsafe {
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc)
        };
        let mut total = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
        let tail = x.len() - x.len() % I64_LANES;
        for (xi, wi) in x[tail..].iter().zip(&w[tail..]) {
            total += xi * wi;
        }
        total
    }

    // SAFETY: `unsafe fn` only for the target-feature contract — the
    // dispatcher confirms AVX2 before every call (module doc above).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_acc_f32_avx2(
        wt: &[f32],
        rows_out: usize,
        cols_in: usize,
        xs: &[f32],
        batch: usize,
        ys: &mut [f32],
    ) {
        for (o, row) in wt.chunks_exact(cols_in).enumerate() {
            for (b, x) in xs.chunks_exact(cols_in).enumerate() {
                // SAFETY: same target-feature contract as this caller;
                // AVX2 was confirmed before entering the avx2 matmul.
                ys[b * rows_out + o] += unsafe { dot_f32_avx2(x, row) };
            }
        }
    }

    // SAFETY: `unsafe fn` only for the target-feature contract — the
    // dispatcher confirms AVX2 before every call (module doc above).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn matmul_acc_i64_avx2(
        wt: &[i64],
        rows_out: usize,
        cols_in: usize,
        xs: &[i64],
        batch: usize,
        ys: &mut [i64],
    ) {
        for (o, row) in wt.chunks_exact(cols_in).enumerate() {
            for (b, x) in xs.chunks_exact(cols_in).enumerate() {
                // SAFETY: same target-feature contract as this caller;
                // AVX2 was confirmed before entering the avx2 matmul.
                ys[b * rows_out + o] += unsafe { dot_i64_avx2(x, row) };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f32_inputs(n: usize) -> (Vec<f32>, Vec<f32>) {
        let x: Vec<f32> =
            (0..n).map(|i| (i as f32 * 0.37 - 1.5) * 0.61).collect();
        let w: Vec<f32> =
            (0..n).map(|i| ((i * 7 % 23) as f32 - 11.0) * 0.13).collect();
        (x, w)
    }

    /// The scalar kernel is *defined* by the lane-strided order; this
    /// pins it against an independent re-implementation so refactors
    /// can't silently change the contract.
    #[test]
    fn scalar_f32_order_is_lane_strided() {
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100] {
            let (x, w) = f32_inputs(n);
            let mut acc = [0.0f32; F32_LANES];
            for (j, (xi, wi)) in x.iter().zip(&w).enumerate() {
                acc[j % F32_LANES] += xi * wi;
            }
            let want = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
                + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
            assert_eq!(dot_f32_scalar(&x, &w).to_bits(), want.to_bits(), "{n}");
        }
    }

    /// Whatever path `dot_*` dispatches to must agree bitwise with the
    /// scalar reference (trivially true without `--features simd`; the
    /// real assertion when the AVX2 path is live).
    #[test]
    fn dispatched_dot_matches_scalar_bitwise() {
        for n in [0usize, 1, 5, 8, 13, 16, 31, 96, 257] {
            let (x, w) = f32_inputs(n);
            assert_eq!(
                dot_f32(&x, &w).to_bits(),
                dot_f32_scalar(&x, &w).to_bits(),
                "f32 n={n} (simd_active={})",
                simd_active()
            );
            let xi: Vec<i64> =
                (0..n).map(|i| (i as i64 * 977 - 800) % (1 << 25)).collect();
            let wi: Vec<i64> =
                (0..n).map(|i| (i as i64 * 313 - 999) % (1 << 25)).collect();
            assert_eq!(
                dot_i64(&xi, &wi),
                dot_i64_scalar(&xi, &wi),
                "i64 n={n}"
            );
        }
    }

    /// Matmul over odd shapes: every (rows, cols, batch) cell of the
    /// dispatched kernel equals the scalar kernel bitwise.
    #[test]
    fn dispatched_matmul_matches_scalar_bitwise() {
        for (rows, cols, batch) in
            [(1usize, 1usize, 1usize), (3, 7, 2), (5, 9, 3), (4, 24, 8)]
        {
            let (wt, _) = f32_inputs(rows * cols);
            let (xs, _) = f32_inputs(batch * cols);
            let mut a = vec![0.25f32; batch * rows];
            let mut b = a.clone();
            matmul_acc_f32(&wt, rows, cols, &xs, batch, &mut a);
            matmul_acc_f32_scalar(&wt, rows, cols, &xs, batch, &mut b);
            let abits: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
            let bbits: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
            assert_eq!(abits, bbits, "f32 {rows}x{cols} b{batch}");

            let wt: Vec<i64> =
                (0..rows * cols).map(|i| i as i64 * 131 - 64).collect();
            let xs: Vec<i64> =
                (0..batch * cols).map(|i| i as i64 * 57 - 999).collect();
            let mut a = vec![7i64; batch * rows];
            let mut b = a.clone();
            matmul_acc_i64(&wt, rows, cols, &xs, batch, &mut a);
            matmul_acc_i64_scalar(&wt, rows, cols, &xs, batch, &mut b);
            assert_eq!(a, b, "i64 {rows}x{cols} b{batch}");
        }
    }
}
