//! Inference engines over the benchmark models.
//!
//! * [`FloatEngine`] — f32 reference implementation, numerically identical
//!   to the python `ref.py` oracle (cross-validated against the golden
//!   outputs in `artifacts/golden/`).
//! * [`FixedEngine`] — the bit-accurate `ap_fixed` datapath: quantized
//!   weights, integer matvecs with wide accumulators, LUT activations.
//!   This is the software stand-in for the synthesized FPGA design and
//!   produces the quantized AUCs of Fig. 2.
//!
//! Both implement [`Engine`], so the evaluation/serving layers are
//! engine-agnostic.

pub mod fixed_engine;
pub mod float_engine;

pub use fixed_engine::FixedEngine;
pub use float_engine::FloatEngine;

use crate::model::Arch;

/// A model that maps one input sequence to output probabilities.
pub trait Engine: Send + Sync {
    /// Forward one sample.  `x` is row-major `[seq_len][input_size]`,
    /// returns `output_size` probabilities (sigmoid/softmax applied).
    fn forward(&self, x: &[f32]) -> Vec<f32>;

    fn arch(&self) -> &Arch;

    /// Forward a batch (default: sequential; engines may parallelize).
    ///
    /// Contract: the output must be **bitwise identical** to calling
    /// [`Engine::forward`] per sample, for any worker count — batching
    /// and chunking may only change memory layout and scheduling, never
    /// per-sample arithmetic order.  `tests/batch_equivalence.rs` holds
    /// both engines to this.
    fn forward_batch(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        xs.iter().map(|x| self.forward(x)).collect()
    }

    /// Forward `n` samples packed row-major in one flat buffer
    /// (`[n * seq_len * input_size]`) — the coordinator's batch layout
    /// (see `coordinator::Batch::packed_features`).
    fn forward_packed(&self, xs: &[f32], n: usize) -> Vec<Vec<f32>> {
        let stride = self.arch().seq_len * self.arch().input_size;
        debug_assert_eq!(xs.len(), n * stride);
        let refs: Vec<&[f32]> = xs.chunks_exact(stride).take(n).collect();
        self.forward_batch(&refs)
    }
}
