//! Inference engines over the benchmark models.
//!
//! * [`FloatEngine`] — f32 reference implementation, numerically identical
//!   to the python `ref.py` oracle (cross-validated against the golden
//!   outputs in `artifacts/golden/`).
//! * [`FixedEngine`] — the bit-accurate `ap_fixed` datapath: quantized
//!   weights, integer matvecs with wide accumulators, LUT activations.
//!   This is the software stand-in for the synthesized FPGA design and
//!   produces the quantized AUCs of Fig. 2.
//!
//! Both implement [`Engine`], so the evaluation/serving layers are
//! engine-agnostic.

pub mod fixed_engine;
pub mod float_engine;

pub use fixed_engine::FixedEngine;
pub use float_engine::FloatEngine;

use crate::model::Arch;

/// A model that maps one input sequence to output probabilities.
pub trait Engine: Send + Sync {
    /// Forward one sample.  `x` is row-major `[seq_len][input_size]`,
    /// returns `output_size` probabilities (sigmoid/softmax applied).
    fn forward(&self, x: &[f32]) -> Vec<f32>;

    fn arch(&self) -> &Arch;

    /// Forward a batch (default: sequential; engines may parallelize).
    fn forward_batch(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        xs.iter().map(|x| self.forward(x)).collect()
    }
}
