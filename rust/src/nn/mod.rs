//! Inference engines over the benchmark models.
//!
//! * [`FloatEngine`] — f32 reference implementation, numerically identical
//!   to the python `ref.py` oracle (cross-validated against the golden
//!   outputs in `artifacts/golden/`).
//! * [`FixedEngine`] — the bit-accurate `ap_fixed` datapath: quantized
//!   weights, integer matvecs with wide accumulators, LUT activations.
//!   This is the software stand-in for the synthesized FPGA design and
//!   produces the quantized AUCs of Fig. 2.
//! * [`backend`] — the backend registry: engines resolvable by name
//!   ([`BackendSpec`]), which is how the heterogeneous serving fabric
//!   hands each coordinator shard a different engine kind (`fixed` for
//!   the trigger tier, `float` for the offline tier, a reserved `pjrt`
//!   slot).
//! * [`kernels`] — the vectorized inner-product layer both engines sit
//!   on: scalar loops always available, AVX2 lanes behind `--features
//!   simd`, bitwise-identical by a pinned reduction order.
//!
//! All engines implement [`Engine`], so the evaluation/serving layers are
//! engine-agnostic.  The serving hot path uses
//! [`Engine::forward_packed_into`] with a caller-recycled [`PackedOut`]
//! so the steady state materializes no per-request `Vec`s.

pub mod backend;
pub mod fixed_engine;
pub mod float_engine;
pub mod kernels;

pub use backend::{BackendCtx, BackendSpec};
pub use fixed_engine::FixedEngine;
pub use float_engine::FloatEngine;

use crate::model::Arch;

/// Reusable packed output buffer: `rows()` rows of `width()` f32s each,
/// stored flat.  The coordinator's worker loop owns one per worker and
/// [`PackedOut::reset`]s it per batch, so the engine output path
/// recycles one allocation for the life of the worker.
#[derive(Debug, Default, Clone)]
pub struct PackedOut {
    pub(crate) data: Vec<f32>,
    pub(crate) width: usize,
}

impl PackedOut {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear and set the row width; capacity is retained.
    pub fn reset(&mut self, width: usize) {
        self.data.clear();
        self.width = width;
    }

    pub fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.width);
        self.data.extend_from_slice(row);
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn rows(&self) -> usize {
        if self.width == 0 {
            0
        } else {
            self.data.len() / self.width
        }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.width..(i + 1) * self.width]
    }

    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.width.max(1))
    }

    /// The flat `[rows * width]` buffer.
    pub fn as_flat(&self) -> &[f32] {
        &self.data
    }

    /// Copy out as the legacy per-sample layout.
    pub fn to_vecs(&self) -> Vec<Vec<f32>> {
        self.iter_rows().map(|r| r.to_vec()).collect()
    }
}

/// A borrowed view of a batch's input rows — either the slice-of-slices
/// layout (`forward_batch`) or a window of the coordinator's packed
/// buffer (`forward_packed_into`).  Both engines run their lockstep
/// recurrence off this one view, so the two entry points share a single
/// code path and bitwise identity between them holds by construction.
#[derive(Clone, Copy)]
pub(crate) enum BatchRows<'a> {
    Slices(&'a [&'a [f32]]),
    Packed {
        xs: &'a [f32],
        stride: usize,
        start: usize,
        len: usize,
    },
}

impl BatchRows<'_> {
    pub(crate) fn len(&self) -> usize {
        match self {
            BatchRows::Slices(rows) => rows.len(),
            BatchRows::Packed { len, .. } => *len,
        }
    }

    pub(crate) fn row(&self, i: usize) -> &[f32] {
        match self {
            BatchRows::Slices(rows) => rows[i],
            BatchRows::Packed { xs, stride, start, len } => {
                debug_assert!(i < *len);
                let at = (start + i) * stride;
                &xs[at..at + stride]
            }
        }
    }
}

/// A model that maps one input sequence to output probabilities.
pub trait Engine: Send + Sync {
    /// Forward one sample.  `x` is row-major `[seq_len][input_size]`,
    /// returns `output_size` probabilities (sigmoid/softmax applied).
    fn forward(&self, x: &[f32]) -> Vec<f32>;

    fn arch(&self) -> &Arch;

    /// Forward a batch (default: sequential; engines may parallelize).
    ///
    /// Contract: the output must be **bitwise identical** to calling
    /// [`Engine::forward`] per sample, for any worker count — batching
    /// and chunking may only change memory layout and scheduling, never
    /// per-sample arithmetic order.  `tests/batch_equivalence.rs` holds
    /// both engines to this.
    fn forward_batch(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        xs.iter().map(|x| self.forward(x)).collect()
    }

    /// Forward `n` samples packed row-major in one flat buffer
    /// (`[n * seq_len * input_size]`) — the coordinator's batch layout
    /// (see `coordinator::Batch::packed_features`).
    ///
    /// The length contract `xs.len() == n * stride` holds
    /// **unconditionally** (a hard `assert`, not a `debug_assert`): a
    /// mismatched buffer would otherwise be silently truncated or
    /// misaligned in release builds, serving some requests a neighbor's
    /// features.  Callers that cannot guarantee the invariant must check
    /// first (the coordinator's `EngineRunner` does, returning an error
    /// instead of panicking).
    fn forward_packed(&self, xs: &[f32], n: usize) -> Vec<Vec<f32>> {
        let mut out = PackedOut::new();
        self.forward_packed_into(xs, n, &mut out);
        out.to_vecs()
    }

    /// [`Engine::forward_packed`], writing into a caller-recycled
    /// [`PackedOut`] instead of materializing `Vec<Vec<f32>>` — the
    /// allocation-free serving entry point (`worker_loop_with_sink`
    /// reuses one `PackedOut` per worker).  Same bitwise contract and
    /// the same hard length `assert` as `forward_packed`.
    ///
    /// The default delegates through [`Engine::forward_batch`]; the
    /// in-tree engines override it with scratch-pooled implementations
    /// that write rows straight into `out`.
    fn forward_packed_into(&self, xs: &[f32], n: usize, out: &mut PackedOut) {
        let stride = self.arch().seq_len * self.arch().input_size;
        assert_eq!(
            xs.len(),
            n * stride,
            "packed buffer length {} != {} samples x stride {}",
            xs.len(),
            n,
            stride
        );
        let refs: Vec<&[f32]> = xs.chunks_exact(stride).collect();
        let rows = self.forward_batch(&refs);
        out.reset(self.arch().output_size);
        for row in &rows {
            assert_eq!(row.len(), out.width(), "engine output width");
            out.push_row(row);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Cell, OutputActivation};

    /// Minimal engine whose output is the first feature of each sample —
    /// enough to observe which rows `forward_packed` actually serves.
    struct FirstFeature {
        arch: Arch,
    }

    fn mock() -> FirstFeature {
        FirstFeature {
            arch: Arch {
                name: "mock".into(),
                cell: Cell::Gru,
                seq_len: 2,
                input_size: 3,
                hidden_size: 1,
                dense_sizes: vec![],
                output_size: 1,
                output_activation: OutputActivation::Sigmoid,
            },
        }
    }

    impl Engine for FirstFeature {
        fn forward(&self, x: &[f32]) -> Vec<f32> {
            vec![x[0]]
        }
        fn arch(&self) -> &Arch {
            &self.arch
        }
    }

    #[test]
    fn forward_packed_splits_rows_in_order() {
        let engine = mock();
        // stride = 2 * 3 = 6; two samples.
        let xs: Vec<f32> = (0..12).map(|v| v as f32).collect();
        assert_eq!(engine.forward_packed(&xs, 2), vec![vec![0.0], vec![6.0]]);
        // n = 0 with an empty buffer is legal.
        assert!(engine.forward_packed(&[], 0).is_empty());
    }

    #[test]
    #[should_panic(expected = "packed buffer length")]
    fn forward_packed_rejects_short_buffer() {
        mock().forward_packed(&[0.0; 11], 2);
    }

    /// The regression this contract exists for: a buffer holding MORE
    /// samples than `n` used to be silently truncated to `n` rows by
    /// `chunks_exact(..).take(n)` once the debug assertion compiled out.
    #[test]
    #[should_panic(expected = "packed buffer length")]
    fn forward_packed_rejects_oversized_buffer() {
        mock().forward_packed(&[0.0; 18], 2);
    }
}
