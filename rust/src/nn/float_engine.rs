//! f32 reference engine — the rust twin of `python/compile/kernels/ref.py`.
//!
//! `forward_batch` runs the paper's batched-GPU-serving analog (§5.2): the
//! batch is split into contiguous chunks across a persistent
//! [`WorkerPool`], and each chunk runs the recurrence in lockstep over its
//! samples so every weight row is streamed across the whole chunk
//! ([`MatT::matmul_acc`]) instead of being re-fetched per sample.
//! Per-sample arithmetic order is unchanged, so batched outputs are
//! bitwise-identical to `forward`.

use crate::model::{Arch, Cell, OutputActivation, Weights};
use crate::util::threads::WorkerPool;

use super::Engine;

/// Row-major matrix with Keras orientation `(in, out)`, stored transposed
/// `(out, in)` so each output's dot product is a contiguous scan.
#[derive(Debug, Clone)]
pub(crate) struct MatT {
    pub rows_out: usize,
    pub cols_in: usize,
    pub data: Vec<f32>, // [out][in]
}

impl MatT {
    pub fn from_keras(shape: &[usize], data: &[f32]) -> Self {
        let (i, o) = (shape[0], shape[1]);
        let mut t = vec![0.0f32; i * o];
        for r in 0..i {
            for c in 0..o {
                t[c * i + r] = data[r * o + c];
            }
        }
        Self {
            rows_out: o,
            cols_in: i,
            data: t,
        }
    }

    /// `y[o] += Σ_i x[i] * w[o, i]`
    #[inline]
    pub fn matvec_acc(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols_in);
        debug_assert_eq!(y.len(), self.rows_out);
        for (o, yo) in y.iter_mut().enumerate() {
            let row = &self.data[o * self.cols_in..(o + 1) * self.cols_in];
            let mut acc = 0.0f32;
            for (xi, wi) in x.iter().zip(row) {
                acc += xi * wi;
            }
            *yo += acc;
        }
    }

    /// Batched `matvec_acc` over packed row-major buffers:
    /// `ys[b][o] += Σ_i xs[b][i] * w[o, i]` for every sample `b`.
    ///
    /// The weight row is loaded once per output and streamed across the
    /// whole batch (cache blocking on the batch axis); the per-(sample,
    /// output) accumulation order is exactly `matvec_acc`'s, so results
    /// are bitwise-equal to the per-sample path.
    pub fn matmul_acc(&self, xs: &[f32], batch: usize, ys: &mut [f32]) {
        debug_assert_eq!(xs.len(), batch * self.cols_in);
        debug_assert_eq!(ys.len(), batch * self.rows_out);
        for (o, row) in self.data.chunks_exact(self.cols_in).enumerate() {
            for (b, x) in xs.chunks_exact(self.cols_in).enumerate() {
                let mut acc = 0.0f32;
                for (xi, wi) in x.iter().zip(row) {
                    acc += xi * wi;
                }
                ys[b * self.rows_out + o] += acc;
            }
        }
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

struct DenseLayer {
    w: MatT,
    b: Vec<f32>,
}

/// f32 inference engine.
pub struct FloatEngine {
    arch: Arch,
    rnn_w: MatT,
    rnn_u: MatT,
    rnn_b: Vec<f32>,
    /// GRU only: recurrent bias row (`b[1]`); `rnn_b` is then `b[0]`.
    rnn_b_rec: Option<Vec<f32>>,
    dense: Vec<DenseLayer>,
    out: DenseLayer,
    /// Batch-level parallelism for `forward_batch` (default 1 = inline).
    pool: WorkerPool,
}

impl FloatEngine {
    pub fn new(weights: &Weights) -> anyhow::Result<Self> {
        let a = weights.arch.clone();
        let w = weights.tensor("rnn", "w")?;
        let u = weights.tensor("rnn", "u")?;
        let b = weights.tensor("rnn", "b")?;
        let (rnn_b, rnn_b_rec) = match a.cell {
            Cell::Lstm => (b.data.clone(), None),
            Cell::Gru => {
                let gh = 3 * a.hidden_size;
                (b.data[..gh].to_vec(), Some(b.data[gh..].to_vec()))
            }
        };
        let mut dense = Vec::new();
        for idx in 0..a.dense_sizes.len() {
            let lw = weights.tensor(&format!("dense{idx}"), "w")?;
            let lb = weights.tensor(&format!("dense{idx}"), "b")?;
            dense.push(DenseLayer {
                w: MatT::from_keras(&lw.shape, &lw.data),
                b: lb.data.clone(),
            });
        }
        let ow = weights.tensor("out", "w")?;
        let ob = weights.tensor("out", "b")?;
        Ok(Self {
            arch: a,
            rnn_w: MatT::from_keras(&w.shape, &w.data),
            rnn_u: MatT::from_keras(&u.shape, &u.data),
            rnn_b,
            rnn_b_rec,
            dense,
            out: DenseLayer {
                w: MatT::from_keras(&ow.shape, &ow.data),
                b: ob.data.clone(),
            },
            pool: WorkerPool::new(1),
        })
    }

    /// Set the number of worker threads `forward_batch` may use.
    pub fn set_parallelism(&mut self, workers: usize) {
        self.pool = WorkerPool::new(workers);
    }

    /// Builder form of [`Self::set_parallelism`].
    pub fn with_parallelism(mut self, workers: usize) -> Self {
        self.set_parallelism(workers);
        self
    }

    pub fn parallelism(&self) -> usize {
        self.pool.workers()
    }

    fn lstm_forward(&self, x: &[f32]) -> Vec<f32> {
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let mut h = vec![0.0f32; h_sz];
        let mut c = vec![0.0f32; h_sz];
        let mut z = vec![0.0f32; 4 * h_sz];
        for t in 0..self.arch.seq_len {
            let x_t = &x[t * i_sz..(t + 1) * i_sz];
            z.copy_from_slice(&self.rnn_b);
            self.rnn_w.matvec_acc(x_t, &mut z);
            self.rnn_u.matvec_acc(&h, &mut z);
            for j in 0..h_sz {
                let i_g = sigmoid(z[j]);
                let f_g = sigmoid(z[h_sz + j]);
                let g = z[2 * h_sz + j].tanh();
                let o_g = sigmoid(z[3 * h_sz + j]);
                c[j] = f_g * c[j] + i_g * g;
                h[j] = o_g * c[j].tanh();
            }
        }
        h
    }

    fn gru_forward(&self, x: &[f32]) -> Vec<f32> {
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let b_rec = self.rnn_b_rec.as_ref().expect("gru has recurrent bias");
        let mut h = vec![0.0f32; h_sz];
        let mut xm = vec![0.0f32; 3 * h_sz];
        let mut hm = vec![0.0f32; 3 * h_sz];
        for t in 0..self.arch.seq_len {
            let x_t = &x[t * i_sz..(t + 1) * i_sz];
            xm.copy_from_slice(&self.rnn_b);
            self.rnn_w.matvec_acc(x_t, &mut xm);
            hm.copy_from_slice(b_rec);
            self.rnn_u.matvec_acc(&h, &mut hm);
            for j in 0..h_sz {
                let z_g = sigmoid(xm[j] + hm[j]);
                let r_g = sigmoid(xm[h_sz + j] + hm[h_sz + j]);
                // reset_after: r gates the post-matmul recurrent term.
                let g = (xm[2 * h_sz + j] + r_g * hm[2 * h_sz + j]).tanh();
                h[j] = z_g * h[j] + (1.0 - z_g) * g;
            }
        }
        h
    }

    /// Final-layer activation for one logit row.
    fn output_probs(&self, y: &[f32]) -> Vec<f32> {
        match self.arch.output_activation {
            OutputActivation::Sigmoid => y.iter().map(|&v| sigmoid(v)).collect(),
            OutputActivation::Softmax => {
                let max = y.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let exps: Vec<f32> = y.iter().map(|&v| (v - max).exp()).collect();
                let sum: f32 = exps.iter().sum();
                exps.iter().map(|&e| e / sum).collect()
            }
        }
    }

    // ---- lockstep batched path (bitwise-identical per sample) ----------

    /// Gather timestep `t` of every sample into a packed `[b][i_sz]` buffer.
    fn gather_step(xs: &[&[f32]], t: usize, i_sz: usize, xt: &mut [f32]) {
        for (bi, x) in xs.iter().enumerate() {
            xt[bi * i_sz..(bi + 1) * i_sz]
                .copy_from_slice(&x[t * i_sz..(t + 1) * i_sz]);
        }
    }

    /// Tile a bias row across the batch into a packed `[b][len]` buffer.
    fn tile_bias(bias: &[f32], batch: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(batch * bias.len());
        for _ in 0..batch {
            out.extend_from_slice(bias);
        }
        out
    }

    /// Lockstep LSTM over a chunk of samples; returns packed `[b][h]`.
    fn lstm_forward_batch(&self, xs: &[&[f32]]) -> Vec<f32> {
        let b = xs.len();
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let mut h = vec![0.0f32; b * h_sz];
        let mut c = vec![0.0f32; b * h_sz];
        let mut z = vec![0.0f32; b * 4 * h_sz];
        let mut xt = vec![0.0f32; b * i_sz];
        for t in 0..self.arch.seq_len {
            Self::gather_step(xs, t, i_sz, &mut xt);
            for bi in 0..b {
                z[bi * 4 * h_sz..(bi + 1) * 4 * h_sz]
                    .copy_from_slice(&self.rnn_b);
            }
            self.rnn_w.matmul_acc(&xt, b, &mut z);
            self.rnn_u.matmul_acc(&h, b, &mut z);
            for bi in 0..b {
                let zb = &z[bi * 4 * h_sz..(bi + 1) * 4 * h_sz];
                for j in 0..h_sz {
                    let i_g = sigmoid(zb[j]);
                    let f_g = sigmoid(zb[h_sz + j]);
                    let g = zb[2 * h_sz + j].tanh();
                    let o_g = sigmoid(zb[3 * h_sz + j]);
                    let cj = &mut c[bi * h_sz + j];
                    *cj = f_g * *cj + i_g * g;
                    h[bi * h_sz + j] = o_g * cj.tanh();
                }
            }
        }
        h
    }

    /// Lockstep GRU over a chunk of samples; returns packed `[b][h]`.
    fn gru_forward_batch(&self, xs: &[&[f32]]) -> Vec<f32> {
        let b = xs.len();
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let b_rec = self.rnn_b_rec.as_ref().expect("gru has recurrent bias");
        let mut h = vec![0.0f32; b * h_sz];
        let mut xm = vec![0.0f32; b * 3 * h_sz];
        let mut hm = vec![0.0f32; b * 3 * h_sz];
        let mut xt = vec![0.0f32; b * i_sz];
        for t in 0..self.arch.seq_len {
            Self::gather_step(xs, t, i_sz, &mut xt);
            for bi in 0..b {
                xm[bi * 3 * h_sz..(bi + 1) * 3 * h_sz]
                    .copy_from_slice(&self.rnn_b);
                hm[bi * 3 * h_sz..(bi + 1) * 3 * h_sz].copy_from_slice(b_rec);
            }
            self.rnn_w.matmul_acc(&xt, b, &mut xm);
            self.rnn_u.matmul_acc(&h, b, &mut hm);
            for bi in 0..b {
                let xb = &xm[bi * 3 * h_sz..(bi + 1) * 3 * h_sz];
                let hb = &hm[bi * 3 * h_sz..(bi + 1) * 3 * h_sz];
                for j in 0..h_sz {
                    let z_g = sigmoid(xb[j] + hb[j]);
                    let r_g = sigmoid(xb[h_sz + j] + hb[h_sz + j]);
                    let g = (xb[2 * h_sz + j] + r_g * hb[2 * h_sz + j]).tanh();
                    let hj = &mut h[bi * h_sz + j];
                    *hj = z_g * *hj + (1.0 - z_g) * g;
                }
            }
        }
        h
    }

    /// Dense head + output activation over a packed `[b][h]` state.
    fn head_forward_batch(&self, mut h: Vec<f32>, b: usize) -> Vec<Vec<f32>> {
        for layer in &self.dense {
            let mut y = Self::tile_bias(&layer.b, b);
            layer.w.matmul_acc(&h, b, &mut y);
            for v in &mut y {
                *v = v.max(0.0); // ReLU head (paper §4)
            }
            h = y;
        }
        let mut y = Self::tile_bias(&self.out.b, b);
        self.out.w.matmul_acc(&h, b, &mut y);
        let out_sz = self.out.b.len();
        y.chunks_exact(out_sz)
            .map(|row| self.output_probs(row))
            .collect()
    }

    /// One worker's share of a batch: lockstep recurrence + batched head.
    fn forward_chunk(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        let b = xs.len();
        let h = match self.arch.cell {
            Cell::Lstm => self.lstm_forward_batch(xs),
            Cell::Gru => self.gru_forward_batch(xs),
        };
        self.head_forward_batch(h, b)
    }
}

impl Engine for FloatEngine {
    fn forward(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.arch.seq_len * self.arch.input_size);
        let mut h = match self.arch.cell {
            Cell::Lstm => self.lstm_forward(x),
            Cell::Gru => self.gru_forward(x),
        };
        for layer in &self.dense {
            let mut y = layer.b.clone();
            layer.w.matvec_acc(&h, &mut y);
            for v in &mut y {
                *v = v.max(0.0); // ReLU head (paper §4)
            }
            h = y;
        }
        let mut y = self.out.b.clone();
        self.out.w.matvec_acc(&h, &mut y);
        self.output_probs(&y)
    }

    fn arch(&self) -> &Arch {
        &self.arch
    }

    /// Parallel batched forward: contiguous chunks across the worker
    /// pool, lockstep recurrence inside each chunk.  Bitwise-identical
    /// to per-sample [`Engine::forward`] for any worker count.
    fn forward_batch(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        if xs.is_empty() {
            return Vec::new();
        }
        self.pool
            .map_chunks(xs.len(), |range| self.forward_chunk(&xs[range]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_transpose_is_consistent() {
        // keras (2,3): [[1,2,3],[4,5,6]]; y = x @ w for x=[1,1] -> [5,7,9]
        let m = MatT::from_keras(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        let mut y = vec![0.0; 3];
        m.matvec_acc(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn matmul_acc_matches_matvec_per_sample() {
        let m = MatT::from_keras(&[3, 2], &[1., 2., 3., 4., 5., 6.]);
        let xs = [0.5f32, -1.0, 2.0, 1.5, 0.25, -0.75];
        let mut packed = vec![0.0f32; 2 * 2];
        m.matmul_acc(&xs, 2, &mut packed);
        for b in 0..2 {
            let mut y = vec![0.0f32; 2];
            m.matvec_acc(&xs[b * 3..(b + 1) * 3], &mut y);
            assert_eq!(&packed[b * 2..(b + 1) * 2], &y[..], "sample {b}");
        }
    }

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(10.0) > 0.9999);
        assert!(sigmoid(-10.0) < 0.0001);
    }
}
