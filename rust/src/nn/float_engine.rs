//! f32 reference engine — the rust twin of `python/compile/kernels/ref.py`.
//!
//! `forward_batch` runs the paper's batched-GPU-serving analog (§5.2): the
//! batch is split into contiguous chunks across a persistent
//! [`WorkerPool`], and each chunk runs the recurrence in lockstep over its
//! samples so every weight row is streamed across the whole chunk
//! ([`MatT::matmul_acc`]) instead of being re-fetched per sample.
//!
//! Every inner product — per-sample or batched — goes through
//! [`super::kernels`], whose reduction order is pinned (lane-strided
//! partial sums + fixed combine tree).  That makes `forward`,
//! `forward_batch`, and `forward_packed_into` bitwise identical to each
//! other for any worker count, and identical with the SIMD feature on
//! or off.
//!
//! The serving entry point `forward_packed_into` allocates nothing in
//! steady state: per-timestep temporaries (`xt`/`h`/`c`/gate buffers)
//! live in a [`BufferPool`]-recycled [`FloatScratch`], and output rows
//! are written straight into the caller's [`PackedOut`].

use crate::model::{Arch, Cell, OutputActivation, Weights};
use crate::util::pool::{BufferPool, PoolStats};
use crate::util::threads::WorkerPool;

use super::{kernels, BatchRows, Engine, PackedOut};

/// Row-major matrix with Keras orientation `(in, out)`, stored transposed
/// `(out, in)` so each output's dot product is a contiguous scan.
#[derive(Debug, Clone)]
pub(crate) struct MatT {
    pub rows_out: usize,
    pub cols_in: usize,
    pub data: Vec<f32>, // [out][in]
}

impl MatT {
    pub fn from_keras(shape: &[usize], data: &[f32]) -> Self {
        let (i, o) = (shape[0], shape[1]);
        let mut t = vec![0.0f32; i * o];
        for r in 0..i {
            for c in 0..o {
                t[c * i + r] = data[r * o + c];
            }
        }
        Self {
            rows_out: o,
            cols_in: i,
            data: t,
        }
    }

    /// `y[o] += Σ_i x[i] * w[o, i]` — one sample through the kernel
    /// layer (a batch-1 [`MatT::matmul_acc`], so the per-dot reduction
    /// order is identical to the batched path by construction).
    #[inline]
    pub fn matvec_acc(&self, x: &[f32], y: &mut [f32]) {
        debug_assert_eq!(x.len(), self.cols_in);
        debug_assert_eq!(y.len(), self.rows_out);
        kernels::matmul_acc_f32(&self.data, self.rows_out, self.cols_in, x, 1, y);
    }

    /// Batched `matvec_acc` over packed row-major buffers:
    /// `ys[b][o] += Σ_i xs[b][i] * w[o, i]` for every sample `b`.
    ///
    /// The weight row is loaded once per output and streamed across the
    /// whole batch (cache blocking on the batch axis); every (sample,
    /// output) pair reduces in `kernels`' pinned lane order, so results
    /// are bitwise-equal to the per-sample path — and to the SIMD path.
    pub fn matmul_acc(&self, xs: &[f32], batch: usize, ys: &mut [f32]) {
        debug_assert_eq!(xs.len(), batch * self.cols_in);
        debug_assert_eq!(ys.len(), batch * self.rows_out);
        kernels::matmul_acc_f32(
            &self.data,
            self.rows_out,
            self.cols_in,
            xs,
            batch,
            ys,
        );
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

struct DenseLayer {
    w: MatT,
    b: Vec<f32>,
}

/// Per-worker recurrence/head temporaries, recycled through the
/// engine's scratch pool so steady-state batches allocate nothing.
#[derive(Default)]
struct FloatScratch {
    /// Gathered timestep inputs, packed `[b][input_size]`.
    xt: Vec<f32>,
    /// Hidden state `[b][h]`; doubles as the dense-head ping buffer.
    h: Vec<f32>,
    /// LSTM cell state `[b][h]`.
    c: Vec<f32>,
    /// Gate pre-activations: LSTM `[b][4h]`, GRU input-half `[b][3h]`.
    z: Vec<f32>,
    /// GRU recurrent-half gate pre-activations `[b][3h]`.
    hm: Vec<f32>,
    /// Dense-head pong buffer.
    acts: Vec<f32>,
    /// Output-layer logits `[b][out]`.
    logits: Vec<f32>,
}

#[inline]
fn zeroed(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

/// f32 inference engine.
pub struct FloatEngine {
    arch: Arch,
    rnn_w: MatT,
    rnn_u: MatT,
    rnn_b: Vec<f32>,
    /// GRU only: recurrent bias row (`b[1]`); `rnn_b` is then `b[0]`.
    rnn_b_rec: Option<Vec<f32>>,
    dense: Vec<DenseLayer>,
    out: DenseLayer,
    /// Batch-level parallelism for `forward_batch` (default 1 = inline).
    pool: WorkerPool,
    /// Recycled recurrence/head temporaries (one per in-flight chunk).
    scratch: BufferPool<FloatScratch>,
}

impl FloatEngine {
    pub fn new(weights: &Weights) -> anyhow::Result<Self> {
        let a = weights.arch.clone();
        let w = weights.tensor("rnn", "w")?;
        let u = weights.tensor("rnn", "u")?;
        let b = weights.tensor("rnn", "b")?;
        let (rnn_b, rnn_b_rec) = match a.cell {
            Cell::Lstm => (b.data.clone(), None),
            Cell::Gru => {
                let gh = 3 * a.hidden_size;
                (b.data[..gh].to_vec(), Some(b.data[gh..].to_vec()))
            }
        };
        let mut dense = Vec::new();
        for idx in 0..a.dense_sizes.len() {
            let lw = weights.tensor(&format!("dense{idx}"), "w")?;
            let lb = weights.tensor(&format!("dense{idx}"), "b")?;
            dense.push(DenseLayer {
                w: MatT::from_keras(&lw.shape, &lw.data),
                b: lb.data.clone(),
            });
        }
        let ow = weights.tensor("out", "w")?;
        let ob = weights.tensor("out", "b")?;
        Ok(Self {
            arch: a,
            rnn_w: MatT::from_keras(&w.shape, &w.data),
            rnn_u: MatT::from_keras(&u.shape, &u.data),
            rnn_b,
            rnn_b_rec,
            dense,
            out: DenseLayer {
                w: MatT::from_keras(&ow.shape, &ow.data),
                b: ob.data.clone(),
            },
            pool: WorkerPool::new(1),
            scratch: BufferPool::new(32),
        })
    }

    /// Set the number of worker threads `forward_batch` may use.
    pub fn set_parallelism(&mut self, workers: usize) {
        self.pool = WorkerPool::new(workers);
    }

    /// Builder form of [`Self::set_parallelism`].
    pub fn with_parallelism(mut self, workers: usize) -> Self {
        self.set_parallelism(workers);
        self
    }

    pub fn parallelism(&self) -> usize {
        self.pool.workers()
    }

    /// Scratch-pool counters — the zero-allocation regression tests
    /// assert misses plateau once the pool is warm.
    pub fn scratch_stats(&self) -> PoolStats {
        self.scratch.stats()
    }

    fn lstm_forward(&self, x: &[f32]) -> Vec<f32> {
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let mut h = vec![0.0f32; h_sz];
        let mut c = vec![0.0f32; h_sz];
        let mut z = vec![0.0f32; 4 * h_sz];
        for t in 0..self.arch.seq_len {
            let x_t = &x[t * i_sz..(t + 1) * i_sz];
            z.copy_from_slice(&self.rnn_b);
            self.rnn_w.matvec_acc(x_t, &mut z);
            self.rnn_u.matvec_acc(&h, &mut z);
            for j in 0..h_sz {
                let i_g = sigmoid(z[j]);
                let f_g = sigmoid(z[h_sz + j]);
                let g = z[2 * h_sz + j].tanh();
                let o_g = sigmoid(z[3 * h_sz + j]);
                c[j] = f_g * c[j] + i_g * g;
                h[j] = o_g * c[j].tanh();
            }
        }
        h
    }

    fn gru_forward(&self, x: &[f32]) -> Vec<f32> {
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let b_rec = self.rnn_b_rec.as_ref().expect("gru has recurrent bias");
        let mut h = vec![0.0f32; h_sz];
        let mut xm = vec![0.0f32; 3 * h_sz];
        let mut hm = vec![0.0f32; 3 * h_sz];
        for t in 0..self.arch.seq_len {
            let x_t = &x[t * i_sz..(t + 1) * i_sz];
            xm.copy_from_slice(&self.rnn_b);
            self.rnn_w.matvec_acc(x_t, &mut xm);
            hm.copy_from_slice(b_rec);
            self.rnn_u.matvec_acc(&h, &mut hm);
            for j in 0..h_sz {
                let z_g = sigmoid(xm[j] + hm[j]);
                let r_g = sigmoid(xm[h_sz + j] + hm[h_sz + j]);
                // reset_after: r gates the post-matmul recurrent term.
                let g = (xm[2 * h_sz + j] + r_g * hm[2 * h_sz + j]).tanh();
                h[j] = z_g * h[j] + (1.0 - z_g) * g;
            }
        }
        h
    }

    /// Final-layer activation for one logit row, appended to `out`.
    fn output_probs_into(&self, y: &[f32], out: &mut Vec<f32>) {
        match self.arch.output_activation {
            OutputActivation::Sigmoid => {
                out.extend(y.iter().map(|&v| sigmoid(v)));
            }
            OutputActivation::Softmax => {
                let max = y.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut sum = 0.0f32;
                for &v in y {
                    sum += (v - max).exp();
                }
                out.extend(y.iter().map(|&v| (v - max).exp() / sum));
            }
        }
    }

    /// Final-layer activation for one logit row.
    fn output_probs(&self, y: &[f32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(y.len());
        self.output_probs_into(y, &mut out);
        out
    }

    // ---- lockstep batched path (bitwise-identical per sample) ----------

    /// Gather timestep `t` of every sample into a packed `[b][i_sz]` buffer.
    fn gather_step(rows: &BatchRows, t: usize, i_sz: usize, xt: &mut [f32]) {
        for bi in 0..rows.len() {
            let x = rows.row(bi);
            xt[bi * i_sz..(bi + 1) * i_sz]
                .copy_from_slice(&x[t * i_sz..(t + 1) * i_sz]);
        }
    }

    /// Tile a bias row across the batch, recycling `out`'s capacity.
    fn tile_bias_into(bias: &[f32], batch: usize, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(batch * bias.len());
        for _ in 0..batch {
            out.extend_from_slice(bias);
        }
    }

    /// Lockstep LSTM over a chunk; leaves the packed `[b][h]` state in
    /// `s.h`.
    fn lstm_forward_batch(&self, rows: &BatchRows, s: &mut FloatScratch) {
        let b = rows.len();
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        zeroed(&mut s.h, b * h_sz);
        zeroed(&mut s.c, b * h_sz);
        zeroed(&mut s.z, b * 4 * h_sz);
        zeroed(&mut s.xt, b * i_sz);
        for t in 0..self.arch.seq_len {
            Self::gather_step(rows, t, i_sz, &mut s.xt);
            for bi in 0..b {
                s.z[bi * 4 * h_sz..(bi + 1) * 4 * h_sz]
                    .copy_from_slice(&self.rnn_b);
            }
            self.rnn_w.matmul_acc(&s.xt, b, &mut s.z);
            self.rnn_u.matmul_acc(&s.h, b, &mut s.z);
            for bi in 0..b {
                let zb = &s.z[bi * 4 * h_sz..(bi + 1) * 4 * h_sz];
                for j in 0..h_sz {
                    let i_g = sigmoid(zb[j]);
                    let f_g = sigmoid(zb[h_sz + j]);
                    let g = zb[2 * h_sz + j].tanh();
                    let o_g = sigmoid(zb[3 * h_sz + j]);
                    let cj = &mut s.c[bi * h_sz + j];
                    *cj = f_g * *cj + i_g * g;
                    s.h[bi * h_sz + j] = o_g * cj.tanh();
                }
            }
        }
    }

    /// Lockstep GRU over a chunk; leaves the packed `[b][h]` state in
    /// `s.h` (`s.z` holds the input-half gates, `s.hm` the recurrent
    /// half).
    fn gru_forward_batch(&self, rows: &BatchRows, s: &mut FloatScratch) {
        let b = rows.len();
        let h_sz = self.arch.hidden_size;
        let i_sz = self.arch.input_size;
        let b_rec = self.rnn_b_rec.as_ref().expect("gru has recurrent bias");
        zeroed(&mut s.h, b * h_sz);
        zeroed(&mut s.z, b * 3 * h_sz);
        zeroed(&mut s.hm, b * 3 * h_sz);
        zeroed(&mut s.xt, b * i_sz);
        for t in 0..self.arch.seq_len {
            Self::gather_step(rows, t, i_sz, &mut s.xt);
            for bi in 0..b {
                s.z[bi * 3 * h_sz..(bi + 1) * 3 * h_sz]
                    .copy_from_slice(&self.rnn_b);
                s.hm[bi * 3 * h_sz..(bi + 1) * 3 * h_sz]
                    .copy_from_slice(b_rec);
            }
            self.rnn_w.matmul_acc(&s.xt, b, &mut s.z);
            self.rnn_u.matmul_acc(&s.h, b, &mut s.hm);
            for bi in 0..b {
                let xb = &s.z[bi * 3 * h_sz..(bi + 1) * 3 * h_sz];
                let hb = &s.hm[bi * 3 * h_sz..(bi + 1) * 3 * h_sz];
                for j in 0..h_sz {
                    let z_g = sigmoid(xb[j] + hb[j]);
                    let r_g = sigmoid(xb[h_sz + j] + hb[h_sz + j]);
                    let g =
                        (xb[2 * h_sz + j] + r_g * hb[2 * h_sz + j]).tanh();
                    let hj = &mut s.h[bi * h_sz + j];
                    *hj = z_g * *hj + (1.0 - z_g) * g;
                }
            }
        }
    }

    /// Dense head + output activation over the packed `[b][h]` state in
    /// `s.h`; appends `b * output_size` probabilities to `out`.
    fn head_forward_into(
        &self,
        b: usize,
        s: &mut FloatScratch,
        out: &mut Vec<f32>,
    ) {
        for layer in &self.dense {
            Self::tile_bias_into(&layer.b, b, &mut s.acts);
            layer.w.matmul_acc(&s.h, b, &mut s.acts);
            for v in &mut s.acts {
                *v = v.max(0.0); // ReLU head (paper §4)
            }
            std::mem::swap(&mut s.h, &mut s.acts);
        }
        Self::tile_bias_into(&self.out.b, b, &mut s.logits);
        self.out.w.matmul_acc(&s.h, b, &mut s.logits);
        let out_sz = self.out.b.len();
        for row in s.logits.chunks_exact(out_sz) {
            self.output_probs_into(row, out);
        }
    }

    /// One worker's share of a batch: lockstep recurrence + batched
    /// head, output rows appended flat to `out`.
    fn forward_rows_into(
        &self,
        rows: BatchRows,
        s: &mut FloatScratch,
        out: &mut Vec<f32>,
    ) {
        let b = rows.len();
        if b == 0 {
            return;
        }
        match self.arch.cell {
            Cell::Lstm => self.lstm_forward_batch(&rows, s),
            Cell::Gru => self.gru_forward_batch(&rows, s),
        }
        self.head_forward_into(b, s, out);
    }

    /// One worker's share of a batch in the legacy per-sample layout.
    fn forward_chunk(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        let mut s = self.scratch.get_with(FloatScratch::default);
        let mut flat = Vec::with_capacity(xs.len() * self.arch.output_size);
        self.forward_rows_into(BatchRows::Slices(xs), &mut s, &mut flat);
        self.scratch.put(s);
        flat.chunks_exact(self.arch.output_size.max(1))
            .map(|r| r.to_vec())
            .collect()
    }
}

impl Engine for FloatEngine {
    fn forward(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.arch.seq_len * self.arch.input_size);
        let mut h = match self.arch.cell {
            Cell::Lstm => self.lstm_forward(x),
            Cell::Gru => self.gru_forward(x),
        };
        for layer in &self.dense {
            let mut y = layer.b.clone();
            layer.w.matvec_acc(&h, &mut y);
            for v in &mut y {
                *v = v.max(0.0); // ReLU head (paper §4)
            }
            h = y;
        }
        let mut y = self.out.b.clone();
        self.out.w.matvec_acc(&h, &mut y);
        self.output_probs(&y)
    }

    fn arch(&self) -> &Arch {
        &self.arch
    }

    /// Parallel batched forward: contiguous chunks across the worker
    /// pool, lockstep recurrence inside each chunk.  Bitwise-identical
    /// to per-sample [`Engine::forward`] for any worker count.
    fn forward_batch(&self, xs: &[&[f32]]) -> Vec<Vec<f32>> {
        if xs.is_empty() {
            return Vec::new();
        }
        self.pool
            .map_chunks(xs.len(), |range| self.forward_chunk(&xs[range]))
    }

    /// The zero-allocation serving path: recurrence temporaries come
    /// from the scratch pool and rows land in the caller's recycled
    /// `out`.  Single-worker engines (the serving default — each
    /// coordinator worker owns its engine) allocate nothing once the
    /// pool is warm; multi-worker engines allocate one chunk buffer per
    /// worker inside `map_chunks`.
    fn forward_packed_into(&self, xs: &[f32], n: usize, out: &mut PackedOut) {
        let stride = self.arch.seq_len * self.arch.input_size;
        assert_eq!(
            xs.len(),
            n * stride,
            "packed buffer length {} != {} samples x stride {}",
            xs.len(),
            n,
            stride
        );
        out.reset(self.arch.output_size);
        if n == 0 {
            return;
        }
        if self.pool.workers() <= 1 {
            let mut s = self.scratch.get_with(FloatScratch::default);
            let mut flat = std::mem::take(&mut out.data);
            self.forward_rows_into(
                BatchRows::Packed { xs, stride, start: 0, len: n },
                &mut s,
                &mut flat,
            );
            out.data = flat;
            self.scratch.put(s);
        } else {
            out.data = self.pool.map_chunks(n, |range| {
                let mut s = self.scratch.get_with(FloatScratch::default);
                let mut flat =
                    Vec::with_capacity(range.len() * self.arch.output_size);
                self.forward_rows_into(
                    BatchRows::Packed {
                        xs,
                        stride,
                        start: range.start,
                        len: range.len(),
                    },
                    &mut s,
                    &mut flat,
                );
                self.scratch.put(s);
                flat
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_transpose_is_consistent() {
        // keras (2,3): [[1,2,3],[4,5,6]]; y = x @ w for x=[1,1] -> [5,7,9]
        let m = MatT::from_keras(&[2, 3], &[1., 2., 3., 4., 5., 6.]);
        let mut y = vec![0.0; 3];
        m.matvec_acc(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn matmul_acc_matches_matvec_per_sample() {
        let m = MatT::from_keras(&[3, 2], &[1., 2., 3., 4., 5., 6.]);
        let xs = [0.5f32, -1.0, 2.0, 1.5, 0.25, -0.75];
        let mut packed = vec![0.0f32; 2 * 2];
        m.matmul_acc(&xs, 2, &mut packed);
        for b in 0..2 {
            let mut y = vec![0.0f32; 2];
            m.matvec_acc(&xs[b * 3..(b + 1) * 3], &mut y);
            assert_eq!(&packed[b * 2..(b + 1) * 2], &y[..], "sample {b}");
        }
    }

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(10.0) > 0.9999);
        assert!(sigmoid(-10.0) < 0.0001);
    }

    #[test]
    fn scratch_pool_goes_warm() {
        use crate::model::{zoo, Cell};
        let arch = zoo::arch("top", Cell::Gru).unwrap();
        let weights = crate::model::Weights::synthetic(&arch, 7);
        let engine = FloatEngine::new(&weights).unwrap();
        let stride = arch.seq_len * arch.input_size;
        let xs = vec![0.25f32; 3 * stride];
        let mut out = PackedOut::new();
        for _ in 0..10 {
            engine.forward_packed_into(&xs, 3, &mut out);
            assert_eq!(out.rows(), 3);
        }
        let stats = engine.scratch_stats();
        assert_eq!(stats.misses, 1, "one scratch build, then recycled");
        assert_eq!(stats.hits, 9);
    }
}
