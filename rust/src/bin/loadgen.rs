//! `loadgen` — open-loop socket load generator for the network front-end.
//!
//! Drives real TCP connections speaking `ingest::wire` at a configured
//! arrival rate (Poisson or bursty), splits the client population across
//! connections, and prints the client-side ledger: generated, completed,
//! shed, closed, lost, RTT p50/p99.  The accounting identity
//! `generated == completed + shed + closed + lost` is asserted — a load
//! test that loses events silently is not a load test.
//!
//! ```text
//! loadgen --clients 10000 --profile poisson          # self-served
//! loadgen --addr 127.0.0.1:9000 --rate 400000 \
//!         --events 1000000 --profile bursty          # external server
//! ```
//!
//! Without `--addr` the binary starts an in-process serving session
//! (fixed+float tiers behind model-key routing, synthetic top_gru
//! weights) on a loopback listener and aims the load at itself, so the
//! full socket path is exercisable from a bare checkout.  With `--addr`
//! it is a pure client; `--feature-len` must then match the server's
//! model (`seq_len * input_size`).

use rnn_hls::api::{BackendKind, ServingSpec, Session};
use rnn_hls::coordinator::{
    BatchRunner, EngineRunner, NetServer, ShardPolicy, TierMix,
};
use rnn_hls::fixed::FixedSpec;
use rnn_hls::ingest::loadgen::{run_load, LoadConfig, LoadReport, Profile};
use rnn_hls::model::{zoo, Cell, Weights};
use rnn_hls::nn::BackendCtx;
use rnn_hls::util::cli::Command;

fn main() {
    if let Err(err) = run() {
        eprintln!("error: {err:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = Command::new("loadgen", "open-loop socket load generator")
        .opt(
            "addr",
            "target wire endpoint (host:port); absent = self-serve an \
             in-process session on loopback",
            None,
        )
        .opt("clients", "simulated client population", Some("10000"))
        .opt("connections", "TCP connections to spread load over", Some("8"))
        .opt("rate", "offered arrival rate (events/s)", Some("100000"))
        .opt("events", "total events to generate", Some("100000"))
        .opt("profile", "arrival process: poisson | bursty", Some("poisson"))
        .opt("seed", "PRNG seed (same seed = same schedule)", Some("12648430"))
        .opt(
            "feature-len",
            "floats per request; must match the server's seq_len * \
             input_size (ignored when self-serving)",
            Some("120"),
        )
        .opt(
            "workers",
            "self-serve only: engine workers per shard",
            Some("2"),
        );
    let args = cmd.parse(&argv)?;

    let profile: Profile = args.get_or("profile", "poisson").parse()?;
    let clients: usize = args.parse_num("clients", 10_000usize)?;
    let connections: usize = args.parse_num("connections", 8usize)?;
    let rate_hz: f64 = args.parse_num("rate", 100_000.0f64)?;
    let events: usize = args.parse_num("events", 100_000usize)?;
    let seed: u64 = args.parse_num("seed", 0xC0FFEEu64)?;

    // Self-serve when no target was named: stand up the same two-tier
    // session the bench sweep measures and aim the load at its listener.
    let (addr, feature_len, server) = match args.get("addr") {
        Some(addr) => (
            addr.parse()?,
            args.parse_num("feature-len", 120usize)?,
            None,
        ),
        None => {
            let workers: usize = args.parse_num("workers", 2usize)?;
            let (server, feature_len) = self_serve(workers)?;
            println!(
                "self-serving fixed+float session on {}",
                server.local_addr()
            );
            (server.local_addr(), feature_len, Some(server))
        }
    };

    let mut load = LoadConfig::new(addr);
    load.clients = clients;
    load.connections = connections;
    load.rate_hz = rate_hz;
    load.events = events;
    load.profile = profile;
    load.seed = seed;
    load.feature_len = feature_len;

    println!(
        "offering {events} events at {rate_hz:.0} ev/s ({} arrivals, \
         {clients} clients over {connections} connections) to {addr}",
        profile.name()
    );
    let report = run_load(&load)?;
    report.check_identity()?;
    print_report(&report);

    if let Some(server) = server {
        let net = server.shutdown()?;
        println!("\nserver-side roll-up:");
        println!("{}", net.serving.render());
        println!(
            "  net: accepted {} refused {} requests {} replies {} \
             wire_errors {} malformed {}",
            net.accepted, net.refused, net.requests, net.replies,
            net.wire_errors, net.malformed
        );
    }
    Ok(())
}

/// The self-serve session: two shards (fixed trigger tier 90 %, float
/// offline tier 10 %) behind model-key routing, synthetic top_gru
/// weights — the same shape as `report::throughput::loadgen_sweep`, so
/// a standalone `loadgen` run probes what CI tracks.
fn self_serve(workers: usize) -> anyhow::Result<(NetServer, usize)> {
    let arch = zoo::arch("top", Cell::Gru)?;
    let weights = Weights::synthetic(&arch, 0x5EED5);
    let feature_len = arch.seq_len * arch.input_size;
    let fixed_spec = FixedSpec::new(16, 6);

    let spec = ServingSpec::default()
        .with_backends(vec![BackendKind::Fixed, BackendKind::Float])
        .with_shards(2)
        .with_shard_policy(ShardPolicy::ModelKey)
        .with_tier_mix(TierMix::new(&[0.9, 0.1], 0x7135)?)
        .with_workers(workers)
        .with_queue_capacity(8192)
        .with_listener("127.0.0.1:0".parse()?);
    let plan = spec.build()?;
    let caps: Vec<usize> = (0..2).map(|shard| plan.runner_cap(shard)).collect();
    let kinds: Vec<BackendKind> =
        (0..2).map(|shard| plan.kind_for(shard)).collect();
    let session = Session::start_plan(plan, move |shard| {
        let engine = kinds[shard].spec().build(&BackendCtx {
            weights: &weights,
            fixed_spec,
            parallelism: 1,
        })?;
        Ok(Box::new(EngineRunner::new(engine, caps[shard]))
            as Box<dyn BatchRunner>)
    })?;
    Ok((session.serve_listener()?, feature_len))
}

fn print_report(report: &LoadReport) {
    println!(
        "\ngenerated {} = completed {} + shed {} + closed {} + lost {} \
         (busy retries refused: {})",
        report.generated, report.completed, report.shed, report.closed,
        report.lost, report.busy
    );
    println!(
        "achieved {:.0} ev/s over {:.2} s; RTT p50 {:.1} µs p99 {:.1} µs",
        report.completed_hz(),
        report.wall_seconds,
        report.latency.quantile_us(0.5),
        report.latency.quantile_us(0.99),
    );
}
