//! Open-loop load generation against a live ingest listener — the
//! "million users" harness.
//!
//! The generator is **open-loop**: arrivals follow a fixed schedule
//! derived from the offered rate, never from the server's responses —
//! exactly the trigger regime, where the detector does not slow down
//! because the downstream is saturated.  A closed-loop generator
//! (send → wait → send) measures its own backoff; an open-loop one
//! measures the server's shed rate and latency *under the offered
//! load*, which is the quantity the saturation curves in
//! `BENCH_serving.json` report.
//!
//! Shape: `connections` socket pairs, each with a writer thread (paces
//! the schedule, frames requests) and a reader thread (matches
//! `Response`/`Error` frames back by `seq`, records round-trip
//! latency).  `clients` logical clients are multiplexed over the
//! connections (the request label carries the client id), so
//! `--clients 10000` over 32 sockets models ten thousand users without
//! ten thousand file descriptors.
//!
//! Every generated event is accounted for exactly once:
//!
//! ```text
//! generated == completed + shed + closed + lost
//! ```
//!
//! `shed`/`closed` are the server's typed rejections
//! ([`ErrorCode::Shed`]/[`ErrorCode::Closed`]); `lost` counts events
//! written but never answered (connection died, or the server shed the
//! completion itself).  [`LoadReport::check_identity`] asserts it.

use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::str::FromStr;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::ErrorCode;
use crate::coordinator::LatencyHistogram;
use crate::ingest::wire::{read_frame, write_frame, Frame, WireRequest};
use crate::util::sync::thread;
use crate::util::sync::{lock_or_recover, Mutex};

/// Reader poll tick (re-checks the give-up deadline between frames).
const READ_TICK: Duration = Duration::from_millis(250);
/// Once a reply's first byte is visible, the whole frame must follow
/// within this budget (same peek-then-read discipline as the server's
/// conn workers — `read_frame` has no partial-read buffering, so a
/// timeout mid-frame would desync the stream).
const FRAME_READ_TIMEOUT: Duration = Duration::from_secs(5);
/// A reader with in-flight requests gives up this long after the last
/// frame arrived (a wedged server must not hang the harness).
const QUIET_DEADLINE: Duration = Duration::from_secs(10);
/// Events per burst in the bursty profile.
const BURST: usize = 32;

// -------------------------------------------------------------- profiles

/// Arrival process of the open-loop schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Profile {
    /// Exponential inter-arrivals at the offered rate — the paper's
    /// trigger arrivals are Poisson to first order.
    Poisson,
    /// Back-to-back bursts of [`BURST`] events separated by idle gaps,
    /// same mean rate — stresses the queue depth rather than the
    /// steady-state throughput.
    Bursty,
}

impl Profile {
    pub fn name(self) -> &'static str {
        match self {
            Self::Poisson => "poisson",
            Self::Bursty => "bursty",
        }
    }
}

impl FromStr for Profile {
    type Err = anyhow::Error;

    fn from_str(name: &str) -> anyhow::Result<Self> {
        match name {
            "poisson" => Ok(Self::Poisson),
            "bursty" => Ok(Self::Bursty),
            other => anyhow::bail!(
                "unknown arrival profile {other:?} (poisson, bursty)"
            ),
        }
    }
}

// --------------------------------------------------------------- config

/// One load run, fully specified — same config, same schedule.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    /// The ingest listener to drive.
    pub addr: SocketAddr,
    /// Logical clients multiplexed over the connections (the request
    /// label carries the client id).
    pub clients: usize,
    /// Socket connections (one writer + one reader thread each).
    pub connections: usize,
    /// Aggregate offered rate across all connections, events/s.
    pub rate_hz: f64,
    /// Total events to offer.
    pub events: usize,
    /// Arrival process.
    pub profile: Profile,
    /// Schedule + payload seed.
    pub seed: u64,
    /// Features per event (must match the served model's input arity
    /// when outputs matter; the fabric itself is shape-agnostic).
    pub feature_len: usize,
}

impl LoadConfig {
    pub fn new(addr: SocketAddr) -> Self {
        Self {
            addr,
            clients: 1,
            connections: 1,
            rate_hz: 10_000.0,
            events: 10_000,
            profile: Profile::Poisson,
            seed: 0xC0FFEE,
            feature_len: 8,
        }
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.clients >= 1, "need at least one client");
        anyhow::ensure!(
            self.connections >= 1,
            "need at least one connection"
        );
        anyhow::ensure!(self.events >= 1, "need at least one event");
        anyhow::ensure!(
            self.rate_hz > 0.0 && self.rate_hz.is_finite(),
            "offered rate must be positive"
        );
        Ok(())
    }
}

// --------------------------------------------------------------- report

/// Merged outcome of one load run.
#[derive(Debug)]
pub struct LoadReport {
    /// Request frames written onto the wire.
    pub generated: u64,
    /// `Response` frames received (served requests).
    pub completed: u64,
    /// `SHED` rejections (queue-full backpressure) — retryable.
    pub shed: u64,
    /// `CLOSED` rejections (session shutting down).
    pub closed: u64,
    /// `BUSY` connection refusals (answer no particular request, so
    /// they sit outside the per-event identity).
    pub busy: u64,
    /// Events written but never answered (connection died, or the
    /// server shed the completion itself).
    pub lost: u64,
    /// Client-observed round-trip latency of completed events.
    pub latency: LatencyHistogram,
    /// Wall time of the whole run, seconds.
    pub wall_seconds: f64,
}

impl LoadReport {
    /// The end-to-end accounting identity, across the process boundary:
    /// every generated event is completed, shed, closed, or lost —
    /// exactly once.
    pub fn check_identity(&self) -> anyhow::Result<()> {
        let answered =
            self.completed + self.shed + self.closed + self.lost;
        anyhow::ensure!(
            self.generated == answered,
            "load accounting broken: generated {} != completed {} + \
             shed {} + closed {} + lost {}",
            self.generated,
            self.completed,
            self.shed,
            self.closed,
            self.lost
        );
        Ok(())
    }

    /// Achieved completion rate, events/s.
    pub fn completed_hz(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.completed as f64 / self.wall_seconds
        } else {
            0.0
        }
    }
}

// ------------------------------------------------------------- generator

/// SplitMix64 — the repo's standard seedable generator shape; local so
/// the schedule needs nothing from the data layer.
struct SplitMix64(u64);

impl SplitMix64 {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in (0, 1].
    fn uniform(&mut self) -> f64 {
        ((self.next() >> 11) as f64 + 1.0) / (1u64 << 53) as f64
    }
}

/// Seconds until the next arrival under `profile` at `rate` ev/s.
fn inter_arrival(
    profile: Profile,
    rate: f64,
    index: usize,
    rng: &mut SplitMix64,
) -> f64 {
    match profile {
        Profile::Poisson => -rng.uniform().ln() / rate,
        // Bursty: BURST back-to-back events, then one gap that restores
        // the mean rate.
        Profile::Bursty => {
            if index % BURST == 0 {
                BURST as f64 / rate
            } else {
                0.0
            }
        }
    }
}

// ------------------------------------------------------------------ run

/// What one connection's reader thread tallies.
struct ReadTally {
    completed: u64,
    shed: u64,
    closed: u64,
    busy: u64,
    latency: LatencyHistogram,
}

/// Drive `config.events` at `config.rate_hz` against the listener and
/// merge the per-connection books.  The run is open-loop: the schedule
/// never waits for the server.  Callers wanting the identity enforced
/// chain [`LoadReport::check_identity`].
pub fn run_load(config: &LoadConfig) -> anyhow::Result<LoadReport> {
    config.validate()?;
    let started = Instant::now();
    let per_conn_rate = config.rate_hz / config.connections as f64;

    let mut joins = Vec::with_capacity(config.connections);
    for conn in 0..config.connections {
        // Spread the remainder so every event is offered exactly once.
        let share = config.events / config.connections
            + usize::from(conn < config.events % config.connections);
        if share == 0 {
            continue;
        }
        let config = config.clone();
        joins.push(thread::spawn(move || {
            drive_connection(&config, conn, share, per_conn_rate)
        }));
    }

    let mut report = LoadReport {
        generated: 0,
        completed: 0,
        shed: 0,
        closed: 0,
        busy: 0,
        lost: 0,
        latency: LatencyHistogram::new(),
        wall_seconds: 0.0,
    };
    let mut first_err = None;
    for join in joins {
        match join.join().expect("load connection panicked") {
            Ok(conn_report) => {
                report.generated += conn_report.generated;
                report.completed += conn_report.completed;
                report.shed += conn_report.shed;
                report.closed += conn_report.closed;
                report.busy += conn_report.busy;
                report.lost += conn_report.lost;
                report.latency.merge(&conn_report.latency);
            }
            Err(e) => {
                first_err.get_or_insert(e);
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    report.wall_seconds = started.elapsed().as_secs_f64();
    Ok(report)
}

/// One connection: writer paces the schedule on this thread, a reader
/// thread matches replies back by `seq`.
fn drive_connection(
    config: &LoadConfig,
    conn: usize,
    events: usize,
    rate: f64,
) -> anyhow::Result<LoadReport> {
    let stream = TcpStream::connect(config.addr).map_err(|e| {
        anyhow::anyhow!("connect {} (conn {conn}): {e}", config.addr)
    })?;
    let _ = stream.set_nodelay(true);
    let mut writer = stream.try_clone()?;

    // seq → send instant; the reader removes what it answers, leftovers
    // are `lost`.
    let in_flight: Arc<Mutex<HashMap<u64, Instant>>> =
        Arc::new(Mutex::new(HashMap::new()));

    let reader_map = in_flight.clone();
    let reader =
        thread::spawn(move || read_replies(stream, &reader_map));

    let mut rng = SplitMix64(
        config.seed ^ (conn as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    let start = Instant::now();
    let mut at = 0.0f64;
    let mut generated = 0u64;
    for i in 0..events {
        at += inter_arrival(config.profile, rate, i, &mut rng);
        let target = start + Duration::from_secs_f64(at);
        let now = Instant::now();
        if target > now {
            thread::sleep(target - now);
        }
        // Open-loop: when behind schedule, send immediately — never
        // stretch the offered rate to match the server.
        let seq = i as u64;
        let label = (rng.next() % config.clients as u64) as u32;
        let features: Vec<f32> = (0..config.feature_len)
            .map(|_| (rng.next() % 1000) as f32 / 1000.0)
            .collect();
        // Register before writing so a same-instant reply always finds
        // its send time.
        lock_or_recover(&in_flight).insert(seq, Instant::now());
        let frame = Frame::Request(WireRequest {
            seq,
            label,
            features,
        });
        if write_frame(&mut writer, &frame).is_err() {
            // Connection died mid-run (e.g. dropped after BUSY): the
            // unsent event was never offered.
            lock_or_recover(&in_flight).remove(&seq);
            break;
        }
        generated += 1;
    }
    // Half-close: the server sees a clean EOF, drains our in-flight
    // replies, then closes — the reader exits on its EOF.
    let _ = writer.shutdown(Shutdown::Write);
    drop(writer);

    let tally = reader.join().expect("load reader panicked");
    let lost = lock_or_recover(&in_flight).len() as u64;
    Ok(LoadReport {
        generated,
        completed: tally.completed,
        shed: tally.shed,
        closed: tally.closed,
        busy: tally.busy,
        lost,
        latency: tally.latency,
        wall_seconds: start.elapsed().as_secs_f64(),
    })
}

/// Reader half: match every `Response`/`Error` back to its send time;
/// exit on EOF, a dead connection, or a quiet-deadline expiry.
fn read_replies(
    stream: TcpStream,
    in_flight: &Mutex<HashMap<u64, Instant>>,
) -> ReadTally {
    let _ = stream.set_read_timeout(Some(READ_TICK));
    let mut stream = stream;
    let mut tally = ReadTally {
        completed: 0,
        shed: 0,
        closed: 0,
        busy: 0,
        latency: LatencyHistogram::new(),
    };
    let mut last_frame = Instant::now();
    loop {
        // Idle-poll with `peek`, mirroring the server's conn workers: a
        // READ_TICK timeout must never fire after `read_frame` consumed
        // part of a frame (the retry would start mid-frame, hit
        // BadMagic, and abandon the connection with its in-flight
        // events miscounted as lost).  Bytes are consumed only once at
        // least one is visible; the whole frame then gets a long
        // budget.
        let mut probe = [0u8; 1];
        match stream.peek(&mut probe) {
            Ok(0) => break, // clean EOF: all replies in
            Ok(_) => {}
            Err(ref e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                if last_frame.elapsed() > QUIET_DEADLINE {
                    break; // wedged server: leftovers count as lost
                }
                continue;
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::Interrupted =>
            {
                continue;
            }
            Err(_) => break, // dead connection
        }
        let _ = stream.set_read_timeout(Some(FRAME_READ_TIMEOUT));
        let frame = read_frame(&mut stream);
        let _ = stream.set_read_timeout(Some(READ_TICK));
        match frame {
            Ok(Some(Frame::Response(resp))) => {
                last_frame = Instant::now();
                if let Some(sent) =
                    lock_or_recover(in_flight).remove(&resp.seq)
                {
                    tally.completed += 1;
                    tally.latency.record(last_frame - sent);
                }
            }
            Ok(Some(Frame::Error(err))) => {
                last_frame = Instant::now();
                match err.code {
                    // Connection-level refusal: answers no event.
                    ErrorCode::Busy => tally.busy += 1,
                    code => {
                        if lock_or_recover(in_flight)
                            .remove(&err.seq)
                            .is_some()
                        {
                            match code {
                                ErrorCode::Shed => tally.shed += 1,
                                ErrorCode::Closed => tally.closed += 1,
                                // Malformed (or a future code) naming
                                // a known seq: no retry class —
                                // re-insert the entry so the event is
                                // counted in `lost` at run end.
                                _ => {
                                    lock_or_recover(in_flight)
                                        .insert(err.seq, last_frame);
                                }
                            }
                        }
                    }
                }
            }
            // The server never sends Requests; ignore defensively.
            Ok(Some(Frame::Request(_))) => {}
            Ok(None) => break, // clean EOF: all replies in
            // With the peek gate above, a timeout here means a frame
            // trickling slower than the budget — treat the connection
            // as dead, like any garbage or transport failure.
            Err(_) => break,
        }
    }
    tally
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_parse() {
        assert_eq!("poisson".parse::<Profile>().unwrap(), Profile::Poisson);
        assert_eq!("bursty".parse::<Profile>().unwrap(), Profile::Bursty);
        assert!("uniform".parse::<Profile>().is_err());
        assert_eq!(Profile::Poisson.name(), "poisson");
    }

    /// The schedule is deterministic in the seed and open-loop in shape:
    /// Poisson inter-arrivals average 1/rate, bursty gaps restore the
    /// mean rate exactly.
    #[test]
    fn schedules_hit_their_mean_rate() {
        let rate = 1000.0;
        let n = 20_000;
        let mut rng = SplitMix64(7);
        let total: f64 = (0..n)
            .map(|i| inter_arrival(Profile::Poisson, rate, i, &mut rng))
            .sum();
        let mean = total / n as f64;
        assert!(
            (mean - 1.0 / rate).abs() < 0.1 / rate,
            "poisson mean inter-arrival {mean} vs expected {}",
            1.0 / rate
        );

        let mut rng = SplitMix64(7);
        let total: f64 = (0..BURST * 100)
            .map(|i| inter_arrival(Profile::Bursty, rate, i, &mut rng))
            .sum();
        let expect = (BURST * 100) as f64 / rate;
        assert!(
            (total - expect).abs() < 1e-9,
            "bursty schedule length {total} vs {expect}"
        );
    }

    #[test]
    fn identity_check_catches_imbalance() {
        let mut report = LoadReport {
            generated: 10,
            completed: 6,
            shed: 2,
            closed: 1,
            busy: 0,
            lost: 1,
            latency: LatencyHistogram::new(),
            wall_seconds: 1.0,
        };
        report.check_identity().unwrap();
        report.lost = 0;
        let err = report.check_identity().unwrap_err().to_string();
        assert!(err.contains("accounting broken"), "{err}");
    }

    #[test]
    fn config_validation_is_uniform() {
        let addr: SocketAddr = "127.0.0.1:0".parse().unwrap();
        let mut config = LoadConfig::new(addr);
        config.connections = 0;
        let err = run_load(&config).unwrap_err().to_string();
        assert!(err.contains("at least one connection"), "{err}");
        let mut config = LoadConfig::new(addr);
        config.rate_hz = 0.0;
        let err = run_load(&config).unwrap_err().to_string();
        assert!(err.contains("rate must be positive"), "{err}");
    }
}
