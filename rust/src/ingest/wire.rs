//! The length-prefixed binary wire protocol of the network ingest
//! front-end: typed frames with a versioned header, little-endian
//! throughout, no external dependencies.
//!
//! ## Frame layout
//!
//! Every frame is an 8-byte header followed by `len` payload bytes:
//!
//! ```text
//! offset  size  field     value
//! 0       2     magic     0x4852  (u16 LE)
//! 2       1     version   1
//! 3       1     type      1 = Request | 2 = Response | 3 = Error
//! 4       4     len       payload bytes (u32 LE, <= 1 MiB)
//! ```
//!
//! Payloads (all integers LE, floats as IEEE-754 LE bit patterns —
//! decode(encode(x)) is bitwise-identical):
//!
//! ```text
//! Request   seq u64 · label u32 · count u32 · features f32 × count
//! Response  seq u64 · id u64 · shard u32 · count u32 · output f32 × count
//! Error     seq u64 · code u8          (codes: crate::api::ErrorCode)
//! ```
//!
//! `seq` is a client-chosen correlation id, echoed verbatim in the
//! answering `Response`/`Error`; `id` is the session-assigned request
//! id.  A malformed header (bad magic/version/type, oversized `len`) or
//! a short read is a typed [`FrameError`], never a panic — garbage from
//! the network must not take a serving thread down.

use std::io::{Read, Write};

use crate::api::ErrorCode;

/// Header magic: `"RH"` little-endian.
pub const WIRE_MAGIC: u16 = 0x4852;
/// Protocol revision carried in every header.
pub const WIRE_VERSION: u8 = 1;
/// Hard payload cap: a header claiming more is rejected before any
/// allocation (a garbage `len` must not OOM the server).
pub const MAX_PAYLOAD: u32 = 1 << 20;
/// Header size in bytes.
pub const HEADER_LEN: usize = 8;

const TYPE_REQUEST: u8 = 1;
const TYPE_RESPONSE: u8 = 2;
const TYPE_ERROR: u8 = 3;

// ---------------------------------------------------------------- frames

/// An inference request: `seq` correlates the answer, `label` rides
/// through to the completion (ground truth for accuracy accounting).
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub seq: u64,
    pub label: u32,
    pub features: Vec<f32>,
}

/// A served request's output, bitwise-identical to what an in-process
/// [`Session::recv`](crate::api::Session::recv) would deliver.
#[derive(Debug, Clone, PartialEq)]
pub struct WireResponse {
    /// Echo of the request's `seq`.
    pub seq: u64,
    /// Session-assigned request id.
    pub id: u64,
    /// Shard that served the request.
    pub shard: u32,
    pub output: Vec<f32>,
}

/// A typed rejection: `code` distinguishes shed (retryable
/// backpressure) from closed (session gone) from busy (connection
/// refused) — see [`ErrorCode`] for the frozen numeric mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireError {
    /// Echo of the request's `seq` (0 for connection-level errors that
    /// answer no particular request).
    pub seq: u64,
    pub code: ErrorCode,
}

/// One protocol frame, as sent on the socket.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Request(WireRequest),
    Response(WireResponse),
    Error(WireError),
}

// ---------------------------------------------------------------- errors

/// Why a byte stream failed to parse as a frame.  Every variant is a
/// recoverable, typed rejection — the decoder never panics on garbage.
#[derive(Debug)]
pub enum FrameError {
    /// First two bytes were not [`WIRE_MAGIC`].
    BadMagic(u16),
    /// Unsupported protocol revision.
    BadVersion(u8),
    /// Unknown frame type byte.
    BadType(u8),
    /// Header `len` exceeds [`MAX_PAYLOAD`].
    Oversized(u32),
    /// The stream ended inside a frame (mid-header or mid-payload).
    Truncated,
    /// Structurally valid header, inconsistent payload (e.g. `count`
    /// disagreeing with `len`, unknown error code).
    BadPayload(&'static str),
    /// Transport error underneath the framing.
    Io(std::io::Error),
}

impl FrameError {
    /// True when the underlying transport hit a read timeout (the
    /// server's poll tick, not a protocol violation).
    pub fn is_timeout(&self) -> bool {
        matches!(
            self,
            Self::Io(e) if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
            )
        )
    }
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::BadMagic(m) => {
                write!(f, "bad frame magic {m:#06x} (want {WIRE_MAGIC:#06x})")
            }
            Self::BadVersion(v) => {
                write!(f, "unsupported wire version {v} (want {WIRE_VERSION})")
            }
            Self::BadType(t) => write!(f, "unknown frame type {t}"),
            Self::Oversized(len) => write!(
                f,
                "frame payload {len} bytes exceeds cap {MAX_PAYLOAD}"
            ),
            Self::Truncated => f.write_str("truncated frame"),
            Self::BadPayload(why) => write!(f, "bad frame payload: {why}"),
            Self::Io(e) => write!(f, "frame transport: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Self::Truncated
        } else {
            Self::Io(e)
        }
    }
}

// --------------------------------------------------------------- encode

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

impl Frame {
    /// Frame type byte, as carried in the header.
    pub fn frame_type(&self) -> u8 {
        match self {
            Self::Request(_) => TYPE_REQUEST,
            Self::Response(_) => TYPE_RESPONSE,
            Self::Error(_) => TYPE_ERROR,
        }
    }

    /// Serialize header + payload into one buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        match self {
            Self::Request(r) => {
                payload.extend_from_slice(&r.seq.to_le_bytes());
                payload.extend_from_slice(&r.label.to_le_bytes());
                payload
                    .extend_from_slice(&(r.features.len() as u32).to_le_bytes());
                put_f32s(&mut payload, &r.features);
            }
            Self::Response(r) => {
                payload.extend_from_slice(&r.seq.to_le_bytes());
                payload.extend_from_slice(&r.id.to_le_bytes());
                payload.extend_from_slice(&r.shard.to_le_bytes());
                payload
                    .extend_from_slice(&(r.output.len() as u32).to_le_bytes());
                put_f32s(&mut payload, &r.output);
            }
            Self::Error(e) => {
                payload.extend_from_slice(&e.seq.to_le_bytes());
                payload.push(e.code as u8);
            }
        }
        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
        out.push(WIRE_VERSION);
        out.push(self.frame_type());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Parse one frame from the front of `bytes`; returns the frame and
    /// the number of bytes consumed.  A slice ending mid-frame is
    /// [`FrameError::Truncated`].
    pub fn decode(bytes: &[u8]) -> Result<(Self, usize), FrameError> {
        if bytes.len() < HEADER_LEN {
            return Err(FrameError::Truncated);
        }
        let (frame_type, len) = check_header(&bytes[..HEADER_LEN])?;
        let total = HEADER_LEN + len as usize;
        if bytes.len() < total {
            return Err(FrameError::Truncated);
        }
        let frame = decode_payload(frame_type, &bytes[HEADER_LEN..total])?;
        Ok((frame, total))
    }
}

/// Validate an 8-byte header; returns (type, payload len).
fn check_header(header: &[u8]) -> Result<(u8, u32), FrameError> {
    let magic = u16::from_le_bytes([header[0], header[1]]);
    if magic != WIRE_MAGIC {
        return Err(FrameError::BadMagic(magic));
    }
    if header[2] != WIRE_VERSION {
        return Err(FrameError::BadVersion(header[2]));
    }
    let frame_type = header[3];
    if !(TYPE_REQUEST..=TYPE_ERROR).contains(&frame_type) {
        return Err(FrameError::BadType(frame_type));
    }
    let len =
        u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
    if len > MAX_PAYLOAD {
        return Err(FrameError::Oversized(len));
    }
    Ok((frame_type, len))
}

// --------------------------------------------------------------- decode

/// A cursor over a payload slice: every read is bounds-checked into a
/// typed error (no slicing panics on adversarial input).
struct Cursor<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        let end = self
            .at
            .checked_add(n)
            .filter(|&end| end <= self.bytes.len())
            .ok_or(FrameError::BadPayload("payload shorter than its fields"))?;
        let out = &self.bytes[self.at..end];
        self.at = end;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32s(&mut self, count: u32) -> Result<Vec<f32>, FrameError> {
        let mut out = Vec::new();
        self.f32s_into(count, &mut out)?;
        Ok(out)
    }

    /// [`Cursor::f32s`] into a caller-recycled buffer: the buffer is
    /// cleared and refilled, so its capacity survives across frames and
    /// a steady-state connection decodes features without allocating.
    fn f32s_into(
        &mut self,
        count: u32,
        out: &mut Vec<f32>,
    ) -> Result<(), FrameError> {
        let n = count as usize;
        let bytes = self
            .bytes
            .len()
            .checked_sub(self.at)
            .unwrap_or(0);
        if n.checked_mul(4).map(|need| need > bytes).unwrap_or(true) {
            return Err(FrameError::BadPayload(
                "float count exceeds payload length",
            ));
        }
        out.clear();
        out.reserve(n);
        for _ in 0..n {
            let b = self.take(4)?;
            out.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        Ok(())
    }

    fn finish(self) -> Result<(), FrameError> {
        if self.at != self.bytes.len() {
            return Err(FrameError::BadPayload(
                "trailing bytes after payload fields",
            ));
        }
        Ok(())
    }
}

fn decode_payload(frame_type: u8, payload: &[u8]) -> Result<Frame, FrameError> {
    let mut cur = Cursor {
        bytes: payload,
        at: 0,
    };
    let frame = match frame_type {
        TYPE_REQUEST => {
            let seq = cur.u64()?;
            let label = cur.u32()?;
            let count = cur.u32()?;
            let features = cur.f32s(count)?;
            Frame::Request(WireRequest {
                seq,
                label,
                features,
            })
        }
        TYPE_RESPONSE => {
            let seq = cur.u64()?;
            let id = cur.u64()?;
            let shard = cur.u32()?;
            let count = cur.u32()?;
            let output = cur.f32s(count)?;
            Frame::Response(WireResponse {
                seq,
                id,
                shard,
                output,
            })
        }
        TYPE_ERROR => {
            let seq = cur.u64()?;
            let code = ErrorCode::from_u8(cur.u8()?)
                .ok_or(FrameError::BadPayload("unknown error code"))?;
            Frame::Error(WireError { seq, code })
        }
        other => return Err(FrameError::BadType(other)),
    };
    cur.finish()?;
    Ok(frame)
}

// -------------------------------------------------------------- streams

/// Read one frame off a stream.  `Ok(None)` is a *clean* EOF — the peer
/// closed at a frame boundary; EOF inside a frame is
/// [`FrameError::Truncated`].  A read timeout surfaces as an `Io` error
/// with [`FrameError::is_timeout`] true, so pollers can distinguish
/// their tick from a dead peer.
pub fn read_frame<R: Read>(reader: &mut R) -> Result<Option<Frame>, FrameError> {
    read_frame_pooled(reader, &mut Vec::new(), &mut Vec::new())
}

/// [`read_frame`] with caller-recycled buffers — the zero-allocation
/// ingest path.  `payload` is the raw-bytes scratch (cleared and
/// refilled each call, capacity retained); `features` seeds the decoded
/// [`WireRequest::features`] vector for `Request` frames: it is filled
/// in place and then moved (`std::mem::take`) into the returned frame,
/// leaving `features` empty.  Callers refill it for the next frame from
/// the session's feature pool
/// ([`Session::recycled_features`](crate::api::Session::recycled_features)),
/// closing the recycle loop: decode → submit → complete → pool → decode.
/// Non-`Request` frames leave `features` untouched.
///
/// Decoded frames are bitwise-identical to [`read_frame`]'s (the wire
/// suite asserts it); only the allocation behaviour differs.
pub fn read_frame_pooled<R: Read>(
    reader: &mut R,
    payload: &mut Vec<u8>,
    features: &mut Vec<f32>,
) -> Result<Option<Frame>, FrameError> {
    let mut header = [0u8; HEADER_LEN];
    // First byte separately: a clean close lands here as Ok(0).
    let mut first = [0u8; 1];
    loop {
        match reader.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(FrameError::Io(e)),
        }
    }
    header[0] = first[0];
    reader.read_exact(&mut header[1..])?;
    let (frame_type, len) = check_header(&header)?;
    payload.clear();
    payload.resize(len as usize, 0);
    reader.read_exact(payload)?;
    if frame_type == TYPE_REQUEST {
        // Decode the hot frame type in place so the features land in
        // the recycled buffer instead of a fresh allocation.
        let mut cur = Cursor {
            bytes: payload,
            at: 0,
        };
        let seq = cur.u64()?;
        let label = cur.u32()?;
        let count = cur.u32()?;
        cur.f32s_into(count, features)?;
        cur.finish()?;
        return Ok(Some(Frame::Request(WireRequest {
            seq,
            label,
            features: std::mem::take(features),
        })));
    }
    decode_payload(frame_type, payload).map(Some)
}

/// Write one frame to a stream (header + payload, flushed).
pub fn write_frame<W: Write>(
    writer: &mut W,
    frame: &Frame,
) -> std::io::Result<()> {
    writer.write_all(&frame.encode())?;
    writer.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_constants() {
        let frame = Frame::Error(WireError {
            seq: 7,
            code: ErrorCode::Shed,
        });
        let bytes = frame.encode();
        assert_eq!(&bytes[..2], &WIRE_MAGIC.to_le_bytes());
        assert_eq!(bytes[2], WIRE_VERSION);
        assert_eq!(bytes[3], 3);
        assert_eq!(bytes.len(), HEADER_LEN + 9);
    }

    #[test]
    fn decode_reports_consumed_length_and_ignores_trailing() {
        let a = Frame::Error(WireError {
            seq: 1,
            code: ErrorCode::Closed,
        });
        let b = Frame::Request(WireRequest {
            seq: 2,
            label: 5,
            features: vec![1.0, -2.5],
        });
        let mut bytes = a.encode();
        let first_len = bytes.len();
        bytes.extend_from_slice(&b.encode());
        let (frame, used) = Frame::decode(&bytes).unwrap();
        assert_eq!(frame, a);
        assert_eq!(used, first_len);
        let (frame, _) = Frame::decode(&bytes[used..]).unwrap();
        assert_eq!(frame, b);
    }

    /// The pooled reader must be a pure allocation optimisation: frames
    /// it decodes are bitwise-identical to [`read_frame`]'s, the
    /// `features` seed is consumed by `Request` frames (moved into the
    /// frame, left empty) and untouched by every other frame type, and
    /// buffer capacity survives across frames.
    #[test]
    fn pooled_read_matches_plain_read_and_recycles_buffers() {
        let frames = vec![
            Frame::Request(WireRequest {
                seq: 1,
                label: 3,
                features: vec![1.0, -2.5, f32::MIN_POSITIVE],
            }),
            Frame::Error(WireError {
                seq: 2,
                code: ErrorCode::Shed,
            }),
            Frame::Request(WireRequest {
                seq: 3,
                label: 0,
                features: vec![0.25; 7],
            }),
        ];
        let mut stream = Vec::new();
        for f in &frames {
            stream.extend_from_slice(&f.encode());
        }

        let mut plain = std::io::Cursor::new(stream.clone());
        let mut pooled = std::io::Cursor::new(stream);
        let mut payload = Vec::new();
        let mut features = Vec::with_capacity(16);
        for want in &frames {
            let a = read_frame(&mut plain).unwrap().unwrap();
            let b =
                read_frame_pooled(&mut pooled, &mut payload, &mut features)
                    .unwrap()
                    .unwrap();
            assert_eq!(a, b);
            assert_eq!(&b, want);
            if matches!(want, Frame::Request(_)) {
                assert!(
                    features.is_empty(),
                    "Request frames take the seed buffer"
                );
                // Simulate the serve loop redrawing from the pool.
                features = Vec::with_capacity(16);
            }
        }
        assert!(read_frame(&mut plain).unwrap().is_none());
        assert!(read_frame_pooled(&mut pooled, &mut payload, &mut features)
            .unwrap()
            .is_none());
        assert!(
            payload.capacity() > 0,
            "payload scratch capacity is retained across frames"
        );
    }

    #[test]
    fn payload_count_must_match_length() {
        // A request whose count field claims more floats than the
        // payload carries.
        let good = Frame::Request(WireRequest {
            seq: 1,
            label: 0,
            features: vec![1.0, 2.0],
        })
        .encode();
        let mut lying = good.clone();
        // count field sits at payload offset 12 (header 8 + seq 8 + label 4).
        lying[HEADER_LEN + 12] = 200;
        let err = Frame::decode(&lying).unwrap_err();
        assert!(matches!(err, FrameError::BadPayload(_)), "{err}");
    }
}
