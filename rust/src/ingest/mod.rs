//! Network ingest: the typed wire protocol ([`wire`]) the coordinator's
//! TCP front-end ([`crate::coordinator::net`]) speaks, and the open-loop
//! load generator ([`loadgen`]) that drives it at saturation.
//!
//! The layering is deliberate: this module knows *bytes and sockets on
//! the client side* — frame encode/decode and load generation — while
//! `coordinator::net` owns the serving side (accept loop, connection
//! workers, completion dispatch).  Both share the
//! [`crate::api::ErrorCode`] numeric space, so a wire-level `SHED` and
//! an in-process [`SubmitError::Full`](crate::api::SubmitError) are the
//! same observable event.

pub mod loadgen;
pub mod wire;
