//! In-tree substrates for facilities that are normally crates.
//!
//! This build environment resolves only the crates vendored for the XLA
//! reference example (`xla`, `anyhow` and their build closure), so the
//! usual ecosystem picks — serde/serde_json, clap, tokio, rayon,
//! criterion, proptest — are unavailable.  Per the substitution rule we
//! implement the slices we need in-tree:
//!
//! * [`json`]    — recursive-descent JSON parser + writer (weights,
//!   manifest, reports).
//! * [`rng`]     — splitmix64/xoshiro256** PRNG + distributions
//!   (generators, property tests; deterministic by seed).
//! * [`threads`] — scoped parallel-map + the persistent channel-fed
//!   [`threads::WorkerPool`] behind the engines' batched `forward_batch`
//!   (the rayon slice we use; pool threads outlive the batches they
//!   serve).
//! * [`timing`]  — measurement harness with warmup and percentile stats
//!   (the criterion slice we use; benches are `harness = false` mains).
//! * [`prop`]    — miniature property-testing loop (the proptest slice we
//!   use: seeded random cases + failure reporting, no shrinking).
//! * [`cli`]     — declarative flag parsing for the launcher.
//! * [`sync`]    — the crate's one gateway to `std::sync`: zero-cost
//!   re-exports in normal builds, the "loom-lite" model checker under
//!   `--features model-check` (the loom slice we use; deterministic
//!   interleaving exploration with seed/trace replay).
//! * [`pool`]    — the bounded [`pool::BufferPool`] free list behind the
//!   zero-allocation steady state (request feature buffers, engine
//!   scratch), built entirely on the [`sync`] gateway's shim surface
//!   and treated by `tools/lint` as gateway-confined alongside it.

pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod sync;
pub mod threads;
pub mod timing;
