//! Scoped parallel map over a worker pool (the rayon slice we need).

/// Apply `f` to `0..n` across `workers` OS threads, collecting results in
/// index order.  Work is distributed by atomic counter, so uneven item
/// costs balance automatically.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};

    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let val = f(i);
                **slots[i].lock().expect("slot poisoned") = Some(val);
            });
        }
    });
    out.into_iter()
        .map(|v| v.expect("worker filled every slot"))
        .collect()
}

/// Number of worker threads to default to (physical parallelism).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let got = parallel_map(100, 8, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(parallel_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(parallel_map(2, 64, |i| i + 1), vec![1, 2]);
    }

    #[test]
    fn balances_uneven_work() {
        // Items with wildly different costs still all complete correctly.
        let got = parallel_map(32, 4, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(got, (0..32).collect::<Vec<_>>());
    }
}
