//! Scoped parallel map over a worker pool (the rayon slice we need).
//!
//! Two execution shapes:
//!
//! * [`parallel_map`] — per-item fan-out with an atomic work counter;
//!   best when item costs are uneven (the Fig. 2 grid scan).
//! * [`WorkerPool::map_chunks`] — contiguous-chunk fan-out used by the
//!   batched inference path: each worker owns a contiguous slice of the
//!   batch, so per-sample state buffers stay worker-local and results
//!   concatenate in order.  Threads are scoped (spawned per call, no
//!   `unsafe` lifetime erasure); the spawn cost is amortized over a whole
//!   batch of forwards, which is the granularity the serving coordinator
//!   hands us anyway.

use std::ops::Range;

/// Apply `f` to `0..n` across `workers` OS threads, collecting results in
/// index order.  Work is distributed by atomic counter, so uneven item
/// costs balance automatically.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};

    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let slots: Vec<std::sync::Mutex<&mut Option<T>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let val = f(i);
                **slots[i].lock().expect("slot poisoned") = Some(val);
            });
        }
    });
    out.into_iter()
        .map(|v| v.expect("worker filled every slot"))
        .collect()
}

/// Number of worker threads to default to (physical parallelism).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// A sized pool of batch workers.  `workers == 1` (the default for the
/// inference engines) runs inline on the caller's thread — zero overhead
/// and bitwise-deterministic ordering either way, since chunking never
/// changes per-sample arithmetic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerPool {
    workers: usize,
}

impl WorkerPool {
    pub fn new(workers: usize) -> Self {
        Self {
            workers: workers.max(1),
        }
    }

    /// Pool sized to the machine.
    pub fn per_core() -> Self {
        Self::new(default_workers())
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Split `0..n` into at most `workers` contiguous chunks, run
    /// `chunk_fn` on each across scoped threads, and concatenate the
    /// per-chunk results in index order.
    pub fn map_chunks<T, F>(&self, n: usize, chunk_fn: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> Vec<T> + Sync,
    {
        let workers = self.workers.clamp(1, n.max(1));
        if workers <= 1 {
            return chunk_fn(0..n);
        }
        let base = n / workers;
        let rem = n % workers;
        let mut ranges = Vec::with_capacity(workers);
        let mut start = 0usize;
        for k in 0..workers {
            let len = base + usize::from(k < rem);
            if len == 0 {
                continue;
            }
            ranges.push(start..start + len);
            start += len;
        }
        let mut results: Vec<Option<Vec<T>>> =
            ranges.iter().map(|_| None).collect();
        std::thread::scope(|scope| {
            for (slot, range) in results.iter_mut().zip(&ranges) {
                let chunk_fn = &chunk_fn;
                let range = range.clone();
                scope.spawn(move || {
                    *slot = Some(chunk_fn(range));
                });
            }
        });
        results
            .into_iter()
            .flat_map(|chunk| chunk.expect("chunk completed"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let got = parallel_map(100, 8, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(parallel_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(parallel_map(2, 64, |i| i + 1), vec![1, 2]);
    }

    #[test]
    fn map_chunks_preserves_order_and_coverage() {
        for workers in [1usize, 2, 3, 8, 64] {
            for n in [0usize, 1, 2, 9, 100] {
                let pool = WorkerPool::new(workers);
                let got = pool.map_chunks(n, |r| r.map(|i| i * 3).collect());
                let want: Vec<usize> = (0..n).map(|i| i * 3).collect();
                assert_eq!(got, want, "workers={workers} n={n}");
            }
        }
    }

    #[test]
    fn map_chunks_gives_contiguous_ranges() {
        use std::sync::Mutex;
        let seen = Mutex::new(Vec::new());
        let pool = WorkerPool::new(4);
        pool.map_chunks(10, |r| {
            seen.lock().unwrap().push(r.clone());
            r.map(|_| ()).collect()
        });
        let mut ranges = seen.into_inner().unwrap();
        ranges.sort_by_key(|r| r.start);
        assert_eq!(ranges.len(), 4);
        assert_eq!(ranges[0].start, 0);
        assert_eq!(ranges.last().unwrap().end, 10);
        for pair in ranges.windows(2) {
            assert_eq!(pair[0].end, pair[1].start);
        }
    }

    #[test]
    fn pool_clamps_to_at_least_one_worker() {
        assert_eq!(WorkerPool::new(0).workers(), 1);
        assert!(WorkerPool::per_core().workers() >= 1);
    }

    #[test]
    fn balances_uneven_work() {
        // Items with wildly different costs still all complete correctly.
        let got = parallel_map(32, 4, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(got, (0..32).collect::<Vec<_>>());
    }
}
