//! Scoped parallel map + a persistent worker pool (the rayon slice we
//! need).
//!
//! Two execution shapes:
//!
//! * [`parallel_map`] — per-item fan-out with an atomic work counter over
//!   scoped threads; best when item costs are uneven and calls are rare
//!   (the Fig. 2 grid scan).
//! * [`WorkerPool::map_chunks`] — contiguous-chunk fan-out used by the
//!   batched inference path: each worker owns a contiguous slice of the
//!   batch, so per-sample state buffers stay worker-local and results
//!   concatenate in order.  The pool's threads are **long-lived and
//!   channel-fed**: they spawn once in [`WorkerPool::new`] and serve
//!   every subsequent `map_chunks` call, so the serving hot path pays a
//!   channel send per chunk instead of an OS thread spawn (~15 µs each)
//!   per batch — the difference is the whole margin for small batches on
//!   small models.  `workers == 1` (the engines' default) keeps the old
//!   inline behavior: no threads, zero overhead.
//!
//! Chunking never changes per-sample arithmetic, so results are bitwise
//! identical for any worker count — the batch-equivalence contract the
//! engines are held to.
//!
//! Sync primitives come from [`crate::util::sync`]: normal builds get
//! the std types verbatim; `--features model-check` lets the model
//! checker schedule the pool (`tests/model_check.rs` drives a panicking
//! job through `map_chunks` across interleavings).  Locks are acquired
//! with [`lock_or_recover`] — in a pool, poisoning is routine (a
//! panicking job is *expected*, and reported to the caller), so no path
//! here may cascade it.

use crate::util::sync::mpsc::{channel, Receiver, Sender};
use crate::util::sync::thread::{Builder, JoinHandle};
use crate::util::sync::{lock_or_recover, Mutex};
use std::ops::Range;
use std::sync::Arc;

/// Apply `f` to `0..n` across `workers` OS threads, collecting results in
/// index order.  Work is distributed by atomic counter, so uneven item
/// costs balance automatically.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    use std::sync::atomic::{AtomicUsize, Ordering};

    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<&mut Option<T>>> =
        out.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let val = f(i);
                **lock_or_recover(&slots[i]) = Some(val);
            });
        }
    });
    out.into_iter()
        .map(|v| v.expect("worker filled every slot"))
        .collect()
}

/// Number of worker threads to default to (physical parallelism).
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// A type-erased unit of pool work.  `'static` as far as the channel is
/// concerned; [`WorkerPool::map_chunks`] erases the caller's lifetimes
/// and re-establishes safety by blocking until every submitted job has
/// reported back (see the SAFETY note there).
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Channel plumbing shared between the pool handle and its threads.
struct PoolShared {
    /// Job injector.  `Option` so `Drop` can disconnect the channel
    /// (workers observe `recv` failing and exit).
    sender: Mutex<Option<Sender<Job>>>,
    /// Single shared job queue; workers take turns holding the lock
    /// while they block in `recv`.  Jobs are chunk-sized (one per worker
    /// per batch), so dequeue contention is irrelevant.
    receiver: Mutex<Receiver<Job>>,
}

fn pool_worker(shared: &PoolShared) {
    loop {
        let job = {
            // Recover, don't cascade: a sibling worker panicking inside
            // a job poisons this lock, but the panic is *reported* to
            // the `map_chunks` caller — the pool itself stays healthy.
            let receiver = lock_or_recover(&shared.receiver);
            receiver.recv()
        };
        match job {
            Ok(job) => job(),
            // All senders dropped: the pool handle is gone; exit.
            Err(_) => break,
        }
    }
}

/// Owns the threads; dropping the last pool handle disconnects the
/// channel and joins them.
struct PoolInner {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl Drop for PoolInner {
    fn drop(&mut self) {
        // Must disconnect even when the sender mutex is poisoned: if the
        // sender survived (an `if let Ok` here once skipped it), the
        // workers would never see `recv` fail and the joins below would
        // hang the dropping thread forever.
        *lock_or_recover(&self.shared.sender) = None;
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// A sized pool of batch workers.  `workers == 1` (the default for the
/// inference engines) runs inline on the caller's thread — zero overhead
/// — and `workers > 1` spawns that many long-lived channel-fed threads
/// up front.  Cloning shares the threads; the engines hold one pool for
/// their lifetime ([`crate::nn::FloatEngine::set_parallelism`] swaps it,
/// retiring the old threads).
///
/// Results are bitwise-deterministic for any worker count, since
/// chunking never changes per-sample arithmetic order.
#[derive(Clone)]
pub struct WorkerPool {
    workers: usize,
    /// `None` when `workers == 1` (inline execution, no threads).
    inner: Option<Arc<PoolInner>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("workers", &self.workers)
            .field("persistent", &self.inner.is_some())
            .finish()
    }
}

impl WorkerPool {
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        if workers == 1 {
            return Self {
                workers,
                inner: None,
            };
        }
        let (sender, receiver) = channel::<Job>();
        let shared = Arc::new(PoolShared {
            sender: Mutex::new(Some(sender)),
            receiver: Mutex::new(receiver),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = shared.clone();
                Builder::new()
                    .name(format!("rnn-hls-pool-{i}"))
                    .spawn(move || pool_worker(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self {
            workers,
            inner: Some(Arc::new(PoolInner { shared, handles })),
        }
    }

    /// Pool sized to the machine.
    pub fn per_core() -> Self {
        Self::new(default_workers())
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    fn submit(&self, job: Job) {
        let inner = self.inner.as_ref().expect("submit needs a live pool");
        let sender = lock_or_recover(&inner.shared.sender);
        sender
            .as_ref()
            .expect("pool channel already closed")
            .send(job)
            .expect("pool worker threads exited");
    }

    /// Split `0..n` into at most `workers` contiguous chunks, run
    /// `chunk_fn` on each across the pool's persistent threads, and
    /// concatenate the per-chunk results in index order.  Blocks until
    /// every chunk completes; a panic inside `chunk_fn` is re-raised on
    /// the calling thread (after the remaining chunks finish), leaving
    /// the pool serviceable.
    ///
    /// Do not call `map_chunks` re-entrantly from inside `chunk_fn` on
    /// the *same* pool: the nested call's chunks would wait behind the
    /// very jobs blocking on them.  (The engines never nest.)
    pub fn map_chunks<T, F>(&self, n: usize, chunk_fn: F) -> Vec<T>
    where
        T: Send,
        F: Fn(Range<usize>) -> Vec<T> + Sync,
    {
        use std::sync::atomic::{AtomicUsize, Ordering};

        let workers = self.workers.clamp(1, n.max(1));
        if workers <= 1 || self.inner.is_none() {
            return chunk_fn(0..n);
        }
        let base = n / workers;
        let rem = n % workers;
        let mut ranges = Vec::with_capacity(workers);
        let mut start = 0usize;
        for k in 0..workers {
            let len = base + usize::from(k < rem);
            if len == 0 {
                continue;
            }
            ranges.push(start..start + len);
            start += len;
        }

        // Every chunk reports through this per-call channel: its index
        // plus either the result or the panic payload.  `inflight` is
        // the job epoch for this call: decremented by each job *before*
        // it reports, so once the collection loop below has all the
        // reports, a zero epoch proves no submitted job can still be
        // executing (the debug assertion that backs the transmute).
        let (report, results) =
            channel::<(usize, std::thread::Result<Vec<T>>)>();
        let inflight = Arc::new(AtomicUsize::new(ranges.len()));
        for (k, range) in ranges.iter().enumerate() {
            let report = report.clone();
            let inflight = inflight.clone();
            let chunk_fn = &chunk_fn;
            let range = range.clone();
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let result = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| chunk_fn(range)),
                );
                // Epoch before report: the borrow of `chunk_fn` (the
                // closure environment) is dead from here on.
                inflight.fetch_sub(1, Ordering::SeqCst);
                // Receiver outlives every send: `map_chunks` cannot
                // return before collecting this message.
                let _ = report.send((k, result));
            });
            // SAFETY: the job borrows `chunk_fn` (and through it the
            // caller's data), which do not live `'static`, so erasing
            // the lifetime is sound only while this call frame is the
            // jobs' lifetime bound.  That holds because:
            //  * the collection loop below blocks until *every*
            //    submitted job has sent its report — panicking jobs
            //    included, via `catch_unwind` — and a job's last use of
            //    the borrow strictly precedes its report (it decrements
            //    `inflight` in between, which the debug assertion below
            //    re-checks);
            //  * nothing on this thread between here and the end of
            //    that loop can panic or early-return: `submit`/`recv`
            //    only panic if the pool threads themselves are gone, in
            //    which case no job holds the borrow either;
            //  * the pool is never dropped from inside `chunk_fn` (the
            //    caller holds `&self`).
            // The transmute erases only lifetimes: source and target
            // are the same fat-pointer type.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
            };
            self.submit(job);
        }
        drop(report);

        let mut chunks: Vec<Option<Vec<T>>> =
            ranges.iter().map(|_| None).collect();
        let mut panic_payload = None;
        for _ in 0..ranges.len() {
            let (k, result) =
                results.recv().expect("pool worker lost a chunk");
            match result {
                Ok(chunk) => chunks[k] = Some(chunk),
                Err(payload) => panic_payload = Some(payload),
            }
        }
        // The job epoch must be spent before the borrows go out of
        // scope — a nonzero count here means a job could still be
        // executing with a dangling environment.
        debug_assert_eq!(
            inflight.load(Ordering::SeqCst),
            0,
            "map_chunks returning with jobs still in flight"
        );
        if let Some(payload) = panic_payload {
            std::panic::resume_unwind(payload);
        }
        chunks
            .into_iter()
            .flat_map(|chunk| chunk.expect("chunk completed"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let got = parallel_map(100, 8, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(parallel_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(parallel_map(0, 8, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(parallel_map(2, 64, |i| i + 1), vec![1, 2]);
    }

    #[test]
    fn map_chunks_preserves_order_and_coverage() {
        for workers in [1usize, 2, 3, 8, 64] {
            for n in [0usize, 1, 2, 9, 100] {
                let pool = WorkerPool::new(workers);
                let got = pool.map_chunks(n, |r| r.map(|i| i * 3).collect());
                let want: Vec<usize> = (0..n).map(|i| i * 3).collect();
                assert_eq!(got, want, "workers={workers} n={n}");
            }
        }
    }

    #[test]
    fn map_chunks_gives_contiguous_ranges() {
        let seen = Mutex::new(Vec::new());
        let pool = WorkerPool::new(4);
        pool.map_chunks(10, |r| {
            lock_or_recover(&seen).push(r.clone());
            r.map(|_| ()).collect()
        });
        let mut ranges = seen.into_inner().unwrap();
        ranges.sort_by_key(|r| r.start);
        assert_eq!(ranges.len(), 4);
        assert_eq!(ranges[0].start, 0);
        assert_eq!(ranges.last().unwrap().end, 10);
        for pair in ranges.windows(2) {
            assert_eq!(pair[0].end, pair[1].start);
        }
    }

    #[test]
    fn pool_clamps_to_at_least_one_worker() {
        assert_eq!(WorkerPool::new(0).workers(), 1);
        assert!(WorkerPool::per_core().workers() >= 1);
    }

    /// The point of the persistent pool: the same OS threads serve every
    /// call.  Chunks never run on the caller's thread, and across many
    /// calls the set of serving threads stays within the pool's size
    /// (scoped spawning would mint fresh `ThreadId`s — which the runtime
    /// never reuses — on every call).
    #[test]
    fn pool_threads_persist_across_calls() {
        use std::collections::HashSet;

        let pool = WorkerPool::new(2);
        let caller = std::thread::current().id();
        let ids = Mutex::new(HashSet::new());
        for _ in 0..8 {
            pool.map_chunks(4, |r| {
                lock_or_recover(&ids).insert(std::thread::current().id());
                r.collect::<Vec<_>>()
            });
        }
        let ids = ids.into_inner().unwrap();
        assert!(!ids.contains(&caller), "chunks must run on pool threads");
        assert!(
            ids.len() <= 2,
            "8 calls used {} distinct threads — pool is not persistent",
            ids.len()
        );
    }

    /// A panicking chunk propagates to the caller without wedging or
    /// killing the pool.
    #[test]
    fn chunk_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                pool.map_chunks(4, |r| {
                    assert!(!r.contains(&0), "chunk boom");
                    r.collect::<Vec<_>>()
                })
            }),
        );
        assert!(result.is_err(), "panic must propagate");
        assert_eq!(
            pool.map_chunks(3, |r| r.map(|i| i + 1).collect::<Vec<_>>()),
            vec![1, 2, 3],
            "pool must stay serviceable after a panic"
        );
    }

    /// The transmute's regression test: when one chunk panics,
    /// `map_chunks` must still block until the *other* (slower) chunks
    /// finish before unwinding — returning early would free the borrowed
    /// closure environment while pool threads still run it.
    #[test]
    fn panicking_chunk_cannot_leak_past_return() {
        let pool = WorkerPool::new(3);
        let witness = Arc::new(());
        let held = witness.clone();
        let executed = Mutex::new(Vec::new());
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                // 6 items over 3 workers: ranges 0..2, 2..4, 4..6.
                pool.map_chunks(6, |r| {
                    let _anchor = &held;
                    if r.start == 0 {
                        panic!("first chunk dies");
                    }
                    // Slow chunks: if map_chunks unwound early, these
                    // would still be running at the asserts below.
                    std::thread::sleep(std::time::Duration::from_millis(5));
                    lock_or_recover(&executed).push(r.start);
                    r.collect::<Vec<_>>()
                })
            }),
        );
        assert!(result.is_err(), "panic must propagate");
        // Unwound only *after* every surviving chunk completed…
        let mut done = executed.into_inner().unwrap();
        done.sort_unstable();
        assert_eq!(done, vec![2, 4], "all surviving chunks ran to completion");
        // …and the closure environment is dead: only our handle remains.
        drop(held);
        assert_eq!(
            Arc::strong_count(&witness),
            1,
            "a job outlived map_chunks and still holds the environment"
        );
    }

    /// Dropping the pool while its sender mutex is poisoned must still
    /// disconnect the channel and join the workers (a hang here is the
    /// regression: `if let Ok` on the poisoned lock used to skip the
    /// disconnect, leaving `recv` blocked forever).
    #[test]
    fn pool_drop_completes_with_poisoned_sender_lock() {
        let pool = WorkerPool::new(2);
        {
            let inner = pool.inner.as_ref().expect("persistent pool");
            let shared = inner.shared.clone();
            let poisoner = std::thread::spawn(move || {
                let _guard = lock_or_recover(&shared.sender);
                panic!("die holding the sender lock");
            });
            assert!(poisoner.join().is_err());
        }
        // Must not hang on the worker joins, nor panic.
        drop(pool);
    }

    #[test]
    fn clones_share_the_pool() {
        let pool = WorkerPool::new(3);
        let other = pool.clone();
        assert_eq!(other.workers(), 3);
        assert_eq!(
            other.map_chunks(6, |r| r.map(|i| i * 2).collect()),
            vec![0, 2, 4, 6, 8, 10]
        );
    }

    #[test]
    fn balances_uneven_work() {
        // Items with wildly different costs still all complete correctly.
        let got = parallel_map(32, 4, |i| {
            if i % 7 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(got, (0..32).collect::<Vec<_>>());
    }
}
