//! Buffer recycling for the zero-allocation steady state.
//!
//! [`BufferPool`] is a capacity-bounded free list of reusable buffers
//! (feature `Vec<f32>`s on the request path, engine scratch on the
//! compute path).  `get_with` pops a recycled buffer or builds a fresh
//! one; `put` hands it back, dropping beyond the cap so an arrival
//! burst can't pin memory forever.  Hit/miss/occupancy counters feed
//! the serving metrics grammar (`pool_hits` / `pool_misses` /
//! `pool_occupancy`), which is also how the zero-allocation regression
//! test observes the steady state: after warm-up, misses plateau.
//!
//! Concurrency: one `util::sync` gateway `Mutex` around the free list
//! (uncontended in steady state — pops and pushes are O(1)), counters
//! on shim atomics with `Relaxed` ordering (they are diagnostics, not
//! part of the `generated == completed + dropped` accounting identity,
//! which is why they do not take `SeqCst`).  Like the queue, the pool
//! builds only on the gateway's shim surface, so the model checker can
//! instrument it under `--features model-check`.

use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::{lock_or_recover, Mutex};

/// A bounded free list of reusable buffers.
#[derive(Debug)]
pub struct BufferPool<T> {
    slots: Mutex<Vec<T>>,
    cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Point-in-time pool counters, merged into serving snapshots.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// `get_with` calls served from a recycled buffer.
    pub hits: u64,
    /// `get_with` calls that had to construct a fresh buffer.
    pub misses: u64,
    /// Buffers currently parked in the free list.
    pub occupancy: usize,
    /// Free-list bound: `put` beyond this drops the buffer.
    pub capacity: usize,
}

impl PoolStats {
    /// Fold another pool's counters into this roll-up.
    pub fn absorb(&mut self, other: &PoolStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.occupancy += other.occupancy;
        self.capacity += other.capacity;
    }
}

impl<T> BufferPool<T> {
    /// A pool retaining at most `cap` parked buffers (min 1).
    pub fn new(cap: usize) -> Self {
        Self {
            slots: Mutex::new(Vec::new()),
            cap: cap.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Pop a recycled buffer, or build one with `make`.  The caller is
    /// responsible for clearing recycled state (`put` on the feature
    /// path stores cleared `Vec`s, so capacity — not contents — is what
    /// recycles).
    pub fn get_with(&self, make: impl FnOnce() -> T) -> T {
        let recycled = lock_or_recover(&self.slots).pop();
        match recycled {
            Some(buf) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                buf
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                make()
            }
        }
    }

    /// Park a buffer for reuse; silently dropped once `cap` buffers are
    /// already parked.
    pub fn put(&self, buf: T) {
        let mut slots = lock_or_recover(&self.slots);
        if slots.len() < self.cap {
            slots.push(buf);
        }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            occupancy: lock_or_recover(&self.slots).len(),
            capacity: self.cap,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycles_and_counts() {
        let pool: BufferPool<Vec<f32>> = BufferPool::new(4);
        let mut a = pool.get_with(Vec::new); // miss
        a.extend_from_slice(&[1.0, 2.0, 3.0]);
        let ptr = a.as_ptr();
        a.clear();
        pool.put(a);
        let b = pool.get_with(Vec::new); // hit: same allocation back
        assert_eq!(b.as_ptr(), ptr);
        assert!(b.is_empty() && b.capacity() >= 3);
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.occupancy, s.capacity), (1, 1, 0, 4));
    }

    #[test]
    fn cap_bounds_the_free_list() {
        let pool: BufferPool<Vec<u8>> = BufferPool::new(2);
        for _ in 0..5 {
            pool.put(Vec::new());
        }
        assert_eq!(pool.stats().occupancy, 2);
        // Draining past the parked buffers turns into misses again.
        for _ in 0..3 {
            let _ = pool.get_with(Vec::new);
        }
        let s = pool.stats();
        assert_eq!((s.hits, s.misses, s.occupancy), (2, 1, 0));
    }

    #[test]
    fn steady_state_stops_missing() {
        let pool: BufferPool<Vec<f32>> = BufferPool::new(8);
        // Warm-up: one buffer in flight at a time.
        for round in 0..100 {
            let mut buf = pool.get_with(Vec::new);
            buf.resize(120, round as f32);
            buf.clear();
            pool.put(buf);
        }
        let s = pool.stats();
        assert_eq!(s.misses, 1, "steady state must not allocate");
        assert_eq!(s.hits, 99);
    }

    #[test]
    fn stats_absorb_rolls_up() {
        let mut total = PoolStats::default();
        total.absorb(&PoolStats { hits: 2, misses: 1, occupancy: 3, capacity: 8 });
        total.absorb(&PoolStats { hits: 5, misses: 0, occupancy: 1, capacity: 8 });
        assert_eq!(
            total,
            PoolStats { hits: 7, misses: 1, occupancy: 4, capacity: 16 }
        );
    }
}
