//! Miniature property-testing harness (the proptest slice we need).
//!
//! `check(name, cases, |rng| ...)` runs the closure over `cases` seeded
//! random inputs; on failure it re-raises with the failing case index and
//! seed so the case reproduces exactly.  No shrinking — failures print
//! the seed, and generators are cheap enough to debug directly.

use super::rng::Rng;

/// Run a property over `cases` seeded random cases.  The closure returns
/// `Err(msg)` (or panics) to signal a counterexample.
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Assert-style helper for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-reverse", 50, |rng| {
            let v: Vec<u64> = (0..rng.below(20)).map(|_| rng.next_u64()).collect();
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            prop_assert!(r == v, "double reverse changed {v:?}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\"")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 3, |_rng| Err("nope".to_string()));
    }
}
