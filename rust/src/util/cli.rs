//! Declarative flag parsing for the launcher (the clap slice we need).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and trailing
//! positionals.  Unknown flags are errors; `--help` text is generated from
//! the declared options.

use std::collections::BTreeMap;

/// One declared option.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub takes_value: bool,
}

/// Parsed arguments.
#[derive(Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn parse_num<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(text) => text
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {text:?}: {e}")),
        }
    }

    /// Value of `--name`, validated against an allowed set; the error
    /// lists the choices.  Missing values fall back to `default`.
    pub fn one_of<'a>(
        &'a self,
        name: &str,
        default: &'a str,
        allowed: &[&str],
    ) -> anyhow::Result<&'a str> {
        let value = self.get_or(name, default);
        anyhow::ensure!(
            allowed.contains(&value),
            "--{name} {value:?}: expected one of {allowed:?}"
        );
        Ok(value)
    }
}

/// A subcommand spec: name, summary, options.
pub struct Command {
    pub name: &'static str,
    pub summary: &'static str,
    pub opts: Vec<Opt>,
}

impl Command {
    pub fn new(name: &'static str, summary: &'static str) -> Self {
        Self {
            name,
            summary,
            opts: Vec::new(),
        }
    }

    pub fn opt(
        mut self,
        name: &'static str,
        help: &'static str,
        default: Option<&'static str>,
    ) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default,
            takes_value: true,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: None,
            takes_value: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nOptions:\n", self.name, self.summary);
        for opt in &self.opts {
            let default = opt
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let value = if opt.takes_value { " <value>" } else { "" };
            out.push_str(&format!(
                "  --{}{}\n        {}{}\n",
                opt.name, value, opt.help, default
            ));
        }
        out
    }

    /// Parse a raw arg list (without the binary/subcommand names).
    pub fn parse(&self, raw: &[String]) -> anyhow::Result<Args> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        for opt in &self.opts {
            if let Some(default) = opt.default {
                values.insert(opt.name.to_string(), default.to_string());
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let arg = &raw[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                if name == "help" {
                    anyhow::bail!("{}", self.usage());
                }
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown option --{name}\n\n{}",
                            self.usage()
                        )
                    })?;
                if !opt.takes_value {
                    anyhow::ensure!(
                        inline.is_none(),
                        "--{name} takes no value"
                    );
                    flags.push(name.to_string());
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .ok_or_else(|| {
                                    anyhow::anyhow!("--{name} needs a value")
                                })?
                                .clone()
                        }
                    };
                    values.insert(name.to_string(), value);
                }
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(Args {
            values,
            flags,
            positional,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("serve", "run the server")
            .opt("model", "model key", Some("top_gru"))
            .opt("rate", "events/sec", None)
            .flag("verbose", "log more")
    }

    fn strs(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let args = cmd().parse(&[]).unwrap();
        assert_eq!(args.get("model"), Some("top_gru"));
        assert_eq!(args.get("rate"), None);
        assert!(!args.has("verbose"));
    }

    #[test]
    fn values_and_flags() {
        let args = cmd()
            .parse(&strs(&["--model=flavor_lstm", "--rate", "5000", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(args.get("model"), Some("flavor_lstm"));
        assert_eq!(args.parse_num::<u64>("rate", 0).unwrap(), 5000);
        assert!(args.has("verbose"));
        assert_eq!(args.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_flag_is_error() {
        assert!(cmd().parse(&strs(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(cmd().parse(&strs(&["--rate"])).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let args = cmd().parse(&strs(&["--rate", "abc"])).unwrap();
        assert!(args.parse_num::<u64>("rate", 0).is_err());
    }

    #[test]
    fn one_of_validates_against_choices() {
        let args = cmd().parse(&strs(&["--model", "flavor_lstm"])).unwrap();
        assert_eq!(
            args.one_of("model", "top_gru", &["top_gru", "flavor_lstm"])
                .unwrap(),
            "flavor_lstm"
        );
        let err = args
            .one_of("model", "top_gru", &["top_gru"])
            .unwrap_err()
            .to_string();
        assert!(err.contains("expected one of"), "{err}");
        // Unset option falls back to (and validates) the default.
        let args = cmd().parse(&[]).unwrap();
        assert_eq!(args.one_of("rate", "low", &["low", "high"]).unwrap(), "low");
    }

    #[test]
    fn help_bails_with_usage() {
        let err = cmd().parse(&strs(&["--help"])).unwrap_err();
        assert!(err.to_string().contains("Options:"));
    }
}
