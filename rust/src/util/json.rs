//! Minimal JSON: recursive-descent parser and compact writer.
//!
//! Handles the full JSON grammar (RFC 8259) minus exotic corner cases we
//! never produce (no `\u` surrogate-pair validation beyond replacement).
//! Numbers parse as f64 — adequate for f32 weights and integer metadata.
//! Object order is preserved (`Vec<(String, Value)>`), matching the
//! python side's insertion order.

use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Typed accessors returning anyhow errors with a path hint.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> anyhow::Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            other => anyhow::bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> anyhow::Result<usize> {
        let n = self.as_f64()?;
        anyhow::ensure!(
            n >= 0.0 && n.fract() == 0.0 && n <= usize::MAX as f64,
            "expected unsigned integer, got {n}"
        );
        Ok(n as usize)
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => anyhow::bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_array(&self) -> anyhow::Result<&[Value]> {
        match self {
            Value::Array(items) => Ok(items),
            other => anyhow::bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_object(&self) -> anyhow::Result<&[(String, Value)]> {
        match self {
            Value::Object(pairs) => Ok(pairs),
            other => anyhow::bail!("expected object, got {other:?}"),
        }
    }

    /// Array of numbers → Vec<f32> (the weights fast path).
    pub fn as_f32_vec(&self) -> anyhow::Result<Vec<f32>> {
        let items = self.as_array()?;
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            out.push(item.as_f64()? as f32);
        }
        Ok(out)
    }

    pub fn as_usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        self.as_array()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Value::Object(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report emission.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(text: &str) -> Value {
    Value::Str(text.to_string())
}

/// Parse a JSON document.
pub fn parse(text: &str) -> anyhow::Result<Value> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(
        p.pos == bytes.len(),
        "trailing garbage at byte {} of {}",
        p.pos,
        bytes.len()
    );
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    #[inline]
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    #[inline]
    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.peek() == Some(b),
            "expected {:?} at byte {}, found {:?}",
            b as char,
            self.pos,
            self.peek().map(|c| c as char)
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> anyhow::Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> anyhow::Result<Value> {
        let end = self.pos + lit.len();
        anyhow::ensure!(
            self.bytes.get(self.pos..end) == Some(lit.as_bytes()),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos = end;
        Ok(v)
    }

    fn number(&mut self) -> anyhow::Result<Value> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            match b {
                b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9' => self.pos += 1,
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n: f64 = text
            .parse()
            .map_err(|e| anyhow::anyhow!("bad number {text:?} at {start}: {e}"))?;
        Ok(Value::Num(n))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| anyhow::anyhow!("dangling escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow::anyhow!("short \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                        }
                        other => {
                            anyhow::bail!("bad escape \\{}", other as char)
                        }
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes at once (fast path for the
                    // multi-megabyte weights files).
                    let start = self.pos;
                    while let Some(b) = self.peek() {
                        if b == b'"' || b == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(
                        &self.bytes[start..self.pos],
                    )?);
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                other => anyhow::bail!(
                    "expected ',' or ']' at byte {}, found {:?}",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Value> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(pairs));
                }
                other => anyhow::bail!(
                    "expected ',' or '}}' at byte {}, found {:?}",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.25e2").unwrap(), Value::Num(-325.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2]
                .get("b")
                .unwrap(),
            &Value::Bool(false)
        );
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn roundtrip_through_writer() {
        let src = r#"{"name":"rnn","shape":[2,3],"data":[0.5,-1.25,0,3,1e-3,12345678],"ok":true,"none":null}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("02a").is_err());
        assert!(parse("{\"a\":1} extra").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            parse(r#""é""#).unwrap(),
            Value::Str("é".into())
        );
    }

    #[test]
    fn typed_accessors_error_cleanly() {
        let v = parse(r#"{"n": 1.5}"#).unwrap();
        assert!(v.req("missing").is_err());
        assert!(v.get("n").unwrap().as_usize().is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    fn f32_vec_fast_path() {
        let v = parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }
}
