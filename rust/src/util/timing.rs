//! Measurement harness for the `harness = false` benches (the criterion
//! slice we need): warmup, repeated timed runs, percentile statistics,
//! and aligned table output.

use std::time::{Duration, Instant};

/// Summary statistics over a set of per-iteration durations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn from_durations(mut samples: Vec<Duration>) -> Self {
        assert!(!samples.is_empty());
        samples.sort();
        let iters = samples.len();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| samples[((iters as f64 * p) as usize).min(iters - 1)];
        Self {
            iters,
            mean: total / iters as u32,
            p50: pct(0.50),
            p99: pct(0.99),
            min: samples[0],
            max: samples[iters - 1],
        }
    }

    /// Throughput in items/sec given items per iteration.
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.mean.as_secs_f64()
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let samples: Vec<Duration> = (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed()
        })
        .collect();
    Stats::from_durations(samples)
}

/// Time `f` adaptively: run batches until ~`budget` of wall time is spent.
pub fn bench_for<F: FnMut()>(budget: Duration, mut f: F) -> Stats {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 5 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break;
        }
    }
    Stats::from_durations(samples)
}

pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.2} s", ns as f64 / 1e9)
    }
}

/// One result row for bench output; `cargo bench` prints these.
pub fn report_row(name: &str, stats: &Stats) {
    println!(
        "{name:<44} mean {:>10}  p50 {:>10}  p99 {:>10}  (n={})",
        fmt_duration(stats.mean),
        fmt_duration(stats.p50),
        fmt_duration(stats.p99),
        stats.iters
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = Stats::from_durations(vec![
            Duration::from_micros(10),
            Duration::from_micros(20),
            Duration::from_micros(30),
        ]);
        assert_eq!(s.min, Duration::from_micros(10));
        assert_eq!(s.max, Duration::from_micros(30));
        assert_eq!(s.mean, Duration::from_micros(20));
        assert!(s.p50 >= s.min && s.p99 <= s.max);
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0usize;
        let s = bench(2, 10, || count += 1);
        assert_eq!(count, 12);
        assert_eq!(s.iters, 10);
    }

    #[test]
    fn throughput_math() {
        let s = Stats::from_durations(vec![Duration::from_millis(10)]);
        let tput = s.throughput(100);
        assert!((tput - 10_000.0).abs() < 1.0);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500 ns");
        assert!(fmt_duration(Duration::from_micros(1500)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).contains(" s"));
    }
}
