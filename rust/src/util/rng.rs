//! Deterministic PRNG + distributions (the `rand` slice we need).
//!
//! xoshiro256** seeded via splitmix64.  Used by the rust-side data
//! generators (live event sources for serving) and the property tests.
//! Deterministic across platforms: same seed → same stream.

/// One splitmix64 step: advance `state` by the golden-ratio increment and
/// return the avalanche-mixed output.  Seeds [`Rng`]'s 256-bit state and
/// doubles as the coordinator's shard-routing hash (one step from
/// `state = id`) — a single implementation so the two can't drift.
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the 256-bit state.
        let mut x = seed;
        let s = [
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
            splitmix64(&mut x),
        ];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire rejection-free is overkill; modulo bias is < 2^-53 here.
        (self.uniform() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — generators are not on the hot path).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Exponential with the given scale (mean).
    pub fn exponential(&mut self, scale: f64) -> f64 {
        -scale * (1.0 - self.uniform()).ln()
    }

    /// Poisson via inversion (fine for the small means we use).
    pub fn poisson(&mut self, mean: f64) -> usize {
        let limit = (-mean).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.uniform();
            if p <= limit || k > 1000 {
                return k;
            }
            k += 1;
        }
    }

    /// Dirichlet over `n` categories with symmetric concentration `alpha`,
    /// via normalized Gamma(alpha) draws (Marsaglia–Tsang).
    pub fn dirichlet(&mut self, n: usize, alpha: f64) -> Vec<f64> {
        let mut draws: Vec<f64> =
            (0..n).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = draws.iter().sum();
        if sum <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        for d in &mut draws {
            *d /= sum;
        }
        draws
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // Johnk boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.uniform().max(1e-12);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal(0.0, 1.0);
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Weighted choice over probabilities summing to ~1.
    pub fn choice_weighted(&mut self, probs: &[f64]) -> usize {
        let r = self.uniform();
        let mut acc = 0.0;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if r < acc {
                return i;
            }
        }
        probs.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_with_sane_mean() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(2);
        let n = 40_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal(3.0, 2.0);
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(3);
        let n = 40_000;
        let mean: f64 =
            (0..n).map(|_| rng.exponential(0.25)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn poisson_mean() {
        let mut rng = Rng::new(4);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| rng.poisson(3.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Rng::new(5);
        for _ in 0..100 {
            let d = rng.dirichlet(3, 3.0);
            assert_eq!(d.len(), 3);
            assert!((d.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(d.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn below_covers_range() {
        let mut rng = Rng::new(6);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[rng.below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn weighted_choice_tracks_weights() {
        let mut rng = Rng::new(7);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.choice_weighted(&[0.6, 0.3, 0.1])] += 1;
        }
        assert!(counts[0] > counts[1] && counts[1] > counts[2]);
        assert!((counts[0] as f64 / 30_000.0 - 0.6).abs() < 0.02);
    }
}
