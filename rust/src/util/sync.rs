//! The crate's one gateway to `std::sync` — and, under the
//! `model-check` feature, a deterministic concurrency model checker
//! ("loom-lite") behind the same API.
//!
//! * **Normal builds** re-export the std primitives directly (plus the
//!   [`lock_or_recover`] poison-recovery helper), so the shim compiles
//!   to zero overhead: `sync::Mutex` *is* `std::sync::Mutex`.
//! * **`--features model-check`** swaps in instrumented wrappers
//!   (`Mutex`, `Condvar`, mpsc channels, atomics, `thread::spawn`)
//!   driven by a cooperative scheduler that serializes the test onto
//!   one runnable thread at a time and forces a *decision* at every
//!   sync point.  The decision stream is either exhaustively enumerated
//!   (DFS over the decision tree — [`check::explore_exhaustive`], right
//!   for 2–3 thread scenarios) or drawn from a seeded splitmix64 stream
//!   ([`check::explore_random`], for bigger fabrics like a full
//!   [`Session`](crate::coordinator::session::Session)).  Failures
//!   print a replay line (`MODEL_CHECK_TRACE=…` / `MODEL_CHECK_SEED=…`)
//!   that deterministically re-runs the failing interleaving.
//!
//! The serving fabric (`coordinator::queue`, `util::threads`,
//! `coordinator::session`) takes all of its sync primitives from this
//! module — enforced statically by the `tools/lint` binary — which is
//! what lets `tests/model_check.rs` drive the *production* queue, pool,
//! and session code through adversarial interleavings.
//!
//! ## Model fidelity and limits
//!
//! * Instrumented mutexes/channels fall back to their real blocking
//!   behavior on threads the scheduler does not know about (anything
//!   not spawned through [`thread::spawn`]/[`thread::Builder`] inside a
//!   running exploration), so ordinary `cargo test --features
//!   model-check` runs stay correct — they just are not explored.
//! * Condvar timeouts and `recv_timeout` deadlines are *scheduler
//!   choices*, not clock reads: a timed wait may be woken "by timeout"
//!   at any point, which doubles as the spurious-wakeup model.
//!   Consecutive timeout wake-ups per thread are capped so exhaustive
//!   exploration of retry loops terminates.
//! * Blocking `SyncSender::send` is intentionally not implemented (the
//!   fabric sheds with `try_send` instead of ever blocking a worker).

#[cfg(not(feature = "model-check"))]
pub use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

/// `std::sync::mpsc` in normal builds; instrumented channels under
/// `model-check`.
#[cfg(not(feature = "model-check"))]
pub mod mpsc {
    pub use std::sync::mpsc::*;
}

/// `std::sync::atomic` in normal builds; yield-instrumented atomics
/// under `model-check`.
#[cfg(not(feature = "model-check"))]
pub mod atomic {
    pub use std::sync::atomic::*;
}

/// The slice of `std::thread` the serving fabric uses, so spawn/sleep/
/// join become scheduler decision points under `model-check`.
#[cfg(not(feature = "model-check"))]
pub mod thread {
    pub use std::thread::{sleep, spawn, yield_now, Builder, JoinHandle};
}

#[cfg(feature = "model-check")]
pub use model::{
    atomic, mpsc, thread, Condvar, Mutex, MutexGuard, WaitTimeoutResult,
};

/// The exploration harness (only under `model-check`):
/// [`check::explore_exhaustive`] / [`check::explore_random`].
#[cfg(feature = "model-check")]
pub use model::check;

use std::sync::PoisonError;

/// Lock a mutex, recovering the guard if the mutex is poisoned.
///
/// The fabric's counters and queues stay *consistent* under a panicking
/// worker (every mutation is complete before its guard drops), so a
/// poisoned lock carries no torn state — propagating the poison would
/// only cascade one worker's panic into unrelated threads and wedge the
/// shutdown/Drop paths that must still drain and report.  This is the
/// only sanctioned way in this crate to acquire a shim mutex; see the
/// `tools/lint` rule forbidding `.unwrap()`/`.expect()` on lock
/// results.
pub fn lock_or_recover<T: ?Sized>(mutex: &Mutex<T>) -> MutexGuard<'_, T> {
    mutex.lock().unwrap_or_else(PoisonError::into_inner)
}

// =====================================================================
// model-check implementation
// =====================================================================

#[cfg(feature = "model-check")]
mod model {
    use std::sync::atomic::{AtomicUsize as StdAtomicUsize, Ordering as O};
    use std::sync::{
        Arc, Condvar as StdCondvar, Mutex as StdMutex,
        MutexGuard as StdMutexGuard, PoisonError, TryLockError, Weak,
    };
    use std::time::Duration;

    /// Hard ceiling on scheduling decisions per run — past it the run is
    /// declared a livelock (e.g. an unbounded retry loop).
    const STEP_LIMIT: u64 = 200_000;
    /// "Woken by timeout" grants one thread may receive per run before
    /// its timeout stops being a scheduling candidate (unless nothing
    /// else can run).  Bounds the decision tree of `pop_timeout`-style
    /// retry loops so exhaustive exploration terminates; timeouts past
    /// the cap still fire when the thread is the only way forward.
    const TIMEOUT_CAP: u32 = 2;

    fn splitmix64(state: &mut u64) -> u64 {
        *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = *state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    // ------------------------------------------------------- decisions

    /// Where scheduling choices come from.  `choose` is only consulted
    /// when more than one grant is possible, so forced moves do not
    /// burn tree depth or random draws.
    enum Decisions {
        /// Seeded stream — replayable from the seed alone.
        Random { state: u64 },
        /// DFS mode: follow `prefix`, then always pick branch 0; every
        /// consulted choice is recorded with its arity so the caller
        /// can backtrack to the next unexplored branch.
        Trace {
            prefix: Vec<usize>,
            recorded: Vec<(usize, usize)>,
            cursor: usize,
        },
    }

    impl Decisions {
        fn choose(&mut self, n: usize) -> usize {
            if n <= 1 {
                return 0;
            }
            match self {
                Self::Random { state } => {
                    (splitmix64(state) % n as u64) as usize
                }
                Self::Trace {
                    prefix,
                    recorded,
                    cursor,
                } => {
                    let pick = if *cursor < prefix.len() {
                        prefix[*cursor].min(n - 1)
                    } else {
                        0
                    };
                    *cursor += 1;
                    recorded.push((pick, n));
                    pick
                }
            }
        }
    }

    // ------------------------------------------------------- scheduler

    /// What a registered thread is waiting on (or `Runnable`).
    #[derive(Clone, Copy, PartialEq, Eq, Debug)]
    enum Waiting {
        Runnable,
        /// Blocked acquiring the shim mutex with this object id.
        Mutex(usize),
        /// Waiting on a condvar; `notified` set by notify_one/all.
        Condvar { cv: usize, notified: bool },
        /// Waiting to receive on a channel; `woken` set by a send or a
        /// disconnect, `can_timeout` when the wait has a deadline.
        Chan {
            chan: usize,
            can_timeout: bool,
            woken: bool,
        },
        /// Joining thread with this slot index.
        Join(usize),
        Finished,
    }

    /// How a blocked thread was granted the token.
    #[derive(Clone, Copy, PartialEq, Eq, Debug)]
    enum Wake {
        Normal,
        Notified,
        TimedOut,
    }

    struct Slot {
        waiting: Waiting,
        granted: bool,
        wake: Wake,
        /// `TimedOut` grants received this run (see [`TIMEOUT_CAP`]).
        timeouts: u32,
    }

    struct State {
        slots: Vec<Slot>,
        decisions: Decisions,
        steps: u64,
        failure: Option<String>,
        abort: bool,
    }

    /// The per-run scheduler.  Exactly one registered thread holds the
    /// execution token (`granted`) at a time; every sync point hands
    /// the token back and lets `pick_next` decide who runs.
    struct Sched {
        state: StdMutex<State>,
        cv: StdCondvar,
    }

    fn candidates(st: &State, respect_cap: bool) -> Vec<(usize, Wake)> {
        let mut out = Vec::new();
        for (i, s) in st.slots.iter().enumerate() {
            if s.granted {
                continue;
            }
            let timeout_ok = !respect_cap || s.timeouts < TIMEOUT_CAP;
            match s.waiting {
                Waiting::Runnable => out.push((i, Wake::Normal)),
                Waiting::Condvar { notified: true, .. } => {
                    out.push((i, Wake::Notified))
                }
                Waiting::Condvar {
                    notified: false, ..
                } if timeout_ok => out.push((i, Wake::TimedOut)),
                Waiting::Chan { woken: true, .. } => {
                    out.push((i, Wake::Normal))
                }
                Waiting::Chan {
                    woken: false,
                    can_timeout: true,
                    ..
                } if timeout_ok => out.push((i, Wake::TimedOut)),
                _ => {}
            }
        }
        out
    }

    impl Sched {
        fn new(decisions: Decisions) -> Self {
            Self {
                state: StdMutex::new(State {
                    slots: Vec::new(),
                    decisions,
                    steps: 0,
                    failure: None,
                    abort: false,
                }),
                cv: StdCondvar::new(),
            }
        }

        fn lock_state(&self) -> StdMutexGuard<'_, State> {
            self.state.lock().unwrap_or_else(PoisonError::into_inner)
        }

        fn register_thread(&self) -> usize {
            let mut st = self.lock_state();
            st.slots.push(Slot {
                waiting: Waiting::Runnable,
                granted: false,
                wake: Wake::Normal,
                timeouts: 0,
            });
            st.slots.len() - 1
        }

        /// Pick the next thread to grant the token to.  Timeout wakes
        /// respect [`TIMEOUT_CAP`] unless nothing else can run; no
        /// candidate at all (with unfinished threads) is a deadlock.
        fn pick_next(&self, st: &mut State) {
            if st.abort {
                self.cv.notify_all();
                return;
            }
            let mut cands = candidates(st, true);
            if cands.is_empty() {
                cands = candidates(st, false);
            }
            if cands.is_empty() {
                let all_done = st
                    .slots
                    .iter()
                    .all(|s| matches!(s.waiting, Waiting::Finished));
                if !all_done {
                    let stuck: Vec<String> = st
                        .slots
                        .iter()
                        .enumerate()
                        .filter(|(_, s)| {
                            !matches!(s.waiting, Waiting::Finished)
                        })
                        .map(|(i, s)| format!("t{i}={:?}", s.waiting))
                        .collect();
                    if st.failure.is_none() {
                        st.failure = Some(format!(
                            "deadlock: no runnable thread ({})",
                            stuck.join(", ")
                        ));
                    }
                    st.abort = true;
                }
                self.cv.notify_all();
                return;
            }
            st.steps += 1;
            if st.steps > STEP_LIMIT {
                if st.failure.is_none() {
                    st.failure = Some(format!(
                        "livelock: exceeded {STEP_LIMIT} scheduling steps"
                    ));
                }
                st.abort = true;
                self.cv.notify_all();
                return;
            }
            let choice = st.decisions.choose(cands.len());
            let (idx, wake) = cands[choice];
            let slot = &mut st.slots[idx];
            slot.granted = true;
            slot.wake = wake;
            if wake == Wake::TimedOut {
                slot.timeouts += 1;
            }
            self.cv.notify_all();
        }

        /// Wait until this thread is granted the token (or the run
        /// aborts, in which case unwind — unless already unwinding).
        fn wait_granted(
            &self,
            mut st: StdMutexGuard<'_, State>,
            me: usize,
        ) -> Wake {
            loop {
                if st.abort {
                    drop(st);
                    if std::thread::panicking() {
                        return Wake::TimedOut;
                    }
                    panic!("model-check: run aborted");
                }
                if st.slots[me].granted {
                    let wake = st.slots[me].wake;
                    st.slots[me].waiting = Waiting::Runnable;
                    return wake;
                }
                st = self
                    .cv
                    .wait(st)
                    .unwrap_or_else(PoisonError::into_inner);
            }
        }

        /// A preemption point: give up the token, let the scheduler
        /// pick anyone (possibly us again), wait for our grant.
        fn yield_point(&self, me: usize) {
            let mut st = self.lock_state();
            if st.abort {
                drop(st);
                if std::thread::panicking() {
                    return;
                }
                panic!("model-check: run aborted");
            }
            st.slots[me].granted = false;
            st.slots[me].waiting = Waiting::Runnable;
            self.pick_next(&mut st);
            self.wait_granted(st, me);
        }

        /// Block as `waiting`; `while_locked` runs under the scheduler
        /// state lock *atomically with the transition* (e.g. a condvar
        /// wait releases its mutex in there, so no wakeup can slip
        /// between release and registration — real condvar semantics).
        fn block(
            &self,
            me: usize,
            waiting: Waiting,
            while_locked: impl FnOnce(&mut State),
        ) -> Wake {
            let mut st = self.lock_state();
            while_locked(&mut st);
            if st.abort {
                drop(st);
                if std::thread::panicking() {
                    return Wake::TimedOut;
                }
                panic!("model-check: run aborted");
            }
            st.slots[me].granted = false;
            st.slots[me].waiting = waiting;
            self.pick_next(&mut st);
            self.wait_granted(st, me)
        }

        /// Mark every thread blocked on mutex `id` runnable again.
        fn unlock_wake(&self, id: usize) {
            let mut st = self.lock_state();
            wake_mutex_waiters(&mut st, id);
        }

        fn notify_cv(&self, cv: usize, all: bool) {
            let mut st = self.lock_state();
            for slot in st.slots.iter_mut() {
                if let Waiting::Condvar { cv: c, notified } =
                    &mut slot.waiting
                {
                    if *c == cv && !*notified {
                        *notified = true;
                        if !all {
                            break;
                        }
                    }
                }
            }
        }

        fn wake_chan(&self, chan: usize) {
            let mut st = self.lock_state();
            for slot in st.slots.iter_mut() {
                if let Waiting::Chan { chan: c, woken, .. } =
                    &mut slot.waiting
                {
                    if *c == chan {
                        *woken = true;
                    }
                }
            }
        }

        fn record_panic(&self, me: usize, msg: String) {
            let mut st = self.lock_state();
            if st.failure.is_none() {
                st.failure = Some(format!("thread t{me} panicked: {msg}"));
            }
            st.abort = true;
            self.cv.notify_all();
        }

        fn thread_exit(&self, me: usize) {
            let mut st = self.lock_state();
            st.slots[me].granted = false;
            st.slots[me].waiting = Waiting::Finished;
            for slot in st.slots.iter_mut() {
                if slot.waiting == Waiting::Join(me) {
                    slot.waiting = Waiting::Runnable;
                }
            }
            self.pick_next(&mut st);
        }

        fn join_wait(&self, me: usize, child: usize) {
            {
                let mut st = self.lock_state();
                if !matches!(st.slots[child].waiting, Waiting::Finished) {
                    if st.abort {
                        drop(st);
                        if std::thread::panicking() {
                            return;
                        }
                        panic!("model-check: run aborted");
                    }
                    st.slots[me].granted = false;
                    st.slots[me].waiting = Waiting::Join(child);
                    self.pick_next(&mut st);
                    self.wait_granted(st, me);
                    return;
                }
            }
            // Child already finished: still a sync point.
            self.yield_point(me);
        }
    }

    fn wake_mutex_waiters(st: &mut State, id: usize) {
        for slot in st.slots.iter_mut() {
            if slot.waiting == Waiting::Mutex(id) {
                slot.waiting = Waiting::Runnable;
            }
        }
    }

    // --------------------------------------------------- registration

    thread_local! {
        /// (scheduler, slot index) of the current thread, when it was
        /// spawned inside an exploration.
        static CURRENT: std::cell::RefCell<Option<(Arc<Sched>, usize)>> =
            const { std::cell::RefCell::new(None) };
    }

    fn current() -> Option<(Arc<Sched>, usize)> {
        CURRENT.with(|c| c.borrow().clone())
    }

    /// The scheduler of the exploration currently running (runs are
    /// globally serialized).  Unregistered threads use it to wake model
    /// waiters when they unlock/notify/send.
    static ACTIVE: StdMutex<Option<Weak<Sched>>> = StdMutex::new(None);

    fn active() -> Option<Arc<Sched>> {
        ACTIVE
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .as_ref()
            .and_then(Weak::upgrade)
    }

    fn maybe_yield() {
        if let Some((sched, me)) = current() {
            sched.yield_point(me);
        }
    }

    static NEXT_OBJ: StdAtomicUsize = StdAtomicUsize::new(1);

    fn next_obj_id() -> usize {
        NEXT_OBJ.fetch_add(1, O::Relaxed)
    }

    fn payload_str(p: &(dyn std::any::Any + Send)) -> String {
        if let Some(s) = p.downcast_ref::<&'static str>() {
            (*s).to_string()
        } else if let Some(s) = p.downcast_ref::<String>() {
            s.clone()
        } else {
            "non-string panic payload".to_string()
        }
    }

    // ------------------------------------------------------------ Mutex

    /// Instrumented mutex: wraps a real `std::sync::Mutex` (registered
    /// threads only ever `try_lock` it, so holding it across a model
    /// suspension cannot wedge the scheduler) plus an owner tag —
    /// 0 = free, 1 = held by an unregistered thread, 2+k = held by
    /// registered thread k.
    pub struct Mutex<T: ?Sized> {
        id: usize,
        owner: StdAtomicUsize,
        inner: StdMutex<T>,
    }

    pub struct MutexGuard<'a, T: ?Sized> {
        lock: &'a Mutex<T>,
        inner: Option<StdMutexGuard<'a, T>>,
    }

    impl<T> Mutex<T> {
        pub fn new(value: T) -> Self {
            Self {
                id: next_obj_id(),
                owner: StdAtomicUsize::new(0),
                inner: StdMutex::new(value),
            }
        }

        pub fn into_inner(
            self,
        ) -> Result<T, PoisonError<T>> {
            self.inner.into_inner()
        }
    }

    impl<T: ?Sized> Mutex<T> {
        fn guard<'a>(
            &'a self,
            inner: StdMutexGuard<'a, T>,
            tag: usize,
        ) -> MutexGuard<'a, T> {
            self.owner.store(tag, O::SeqCst);
            MutexGuard {
                lock: self,
                inner: Some(inner),
            }
        }

        /// Real blocking acquisition — unregistered threads, or a
        /// registered thread contending with an unregistered holder
        /// (who makes progress independently of the scheduler).
        fn lock_real<'a>(
            &'a self,
            tag: usize,
        ) -> Result<MutexGuard<'a, T>, PoisonError<MutexGuard<'a, T>>>
        {
            match self.inner.lock() {
                Ok(g) => Ok(self.guard(g, tag)),
                Err(poison) => Err(PoisonError::new(
                    self.guard(poison.into_inner(), tag),
                )),
            }
        }

        pub fn lock(
            &self,
        ) -> Result<MutexGuard<'_, T>, PoisonError<MutexGuard<'_, T>>>
        {
            let Some((sched, me)) = current() else {
                return self.lock_real(1);
            };
            loop {
                sched.yield_point(me);
                match self.inner.try_lock() {
                    Ok(g) => return Ok(self.guard(g, 2 + me)),
                    Err(TryLockError::Poisoned(poison)) => {
                        return Err(PoisonError::new(
                            self.guard(poison.into_inner(), 2 + me),
                        ))
                    }
                    Err(TryLockError::WouldBlock) => {
                        if self.owner.load(O::SeqCst) >= 2 {
                            // Registered holder: it cannot release until
                            // scheduled, so model-block (woken when its
                            // guard drops).
                            sched.block(me, Waiting::Mutex(self.id), |_| {});
                        } else {
                            // Unregistered holder: block for real — it
                            // is not scheduler-gated.
                            return self.lock_real(2 + me);
                        }
                    }
                }
            }
        }
    }

    impl<T: ?Sized> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("guard is live")
        }
    }

    impl<T: ?Sized> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("guard is live")
        }
    }

    impl<T: ?Sized> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            let Some(inner) = self.inner.take() else {
                // Already released (condvar wait consumed the guard).
                return;
            };
            self.lock.owner.store(0, O::SeqCst);
            drop(inner);
            if let Some(sched) = active() {
                sched.unlock_wake(self.lock.id);
            }
            maybe_yield();
        }
    }

    // ---------------------------------------------------------- Condvar

    /// Mirrors `std::sync::WaitTimeoutResult` (which has no public
    /// constructor).  Only `timed_out` is provided.
    #[derive(Clone, Copy, Debug)]
    pub struct WaitTimeoutResult(bool);

    impl WaitTimeoutResult {
        pub fn timed_out(&self) -> bool {
            self.0
        }
    }

    pub struct Condvar {
        id: usize,
        inner: StdCondvar,
    }

    impl Default for Condvar {
        fn default() -> Self {
            Self::new()
        }
    }

    impl Condvar {
        pub fn new() -> Self {
            Self {
                id: next_obj_id(),
                inner: StdCondvar::new(),
            }
        }

        pub fn notify_one(&self) {
            self.notify(false)
        }

        pub fn notify_all(&self) {
            self.notify(true)
        }

        fn notify(&self, all: bool) {
            if all {
                self.inner.notify_all();
            } else {
                self.inner.notify_one();
            }
            if let Some(sched) = active() {
                sched.notify_cv(self.id, all);
            }
            maybe_yield();
        }

        /// Timed wait.  For registered threads the duration is ignored:
        /// whether the wait ends by notification or "timeout" is a
        /// scheduler decision (which also models spurious wakeups —
        /// both re-enter the caller's retry loop the same way).
        #[allow(clippy::type_complexity)]
        pub fn wait_timeout<'a, T>(
            &self,
            mut guard: MutexGuard<'a, T>,
            dur: Duration,
        ) -> Result<
            (MutexGuard<'a, T>, WaitTimeoutResult),
            PoisonError<(MutexGuard<'a, T>, WaitTimeoutResult)>,
        > {
            let lock = guard.lock;
            let Some((sched, me)) = current() else {
                // Unregistered: real timed wait on the inner guard.
                let inner =
                    guard.inner.take().expect("guard is live");
                lock.owner.store(0, O::SeqCst);
                drop(guard);
                let (res, poisoned) =
                    match self.inner.wait_timeout(inner, dur) {
                        Ok(pair) => (pair, false),
                        Err(poison) => (poison.into_inner(), true),
                    };
                let (inner, wtr) = res;
                let out = (
                    lock.guard(inner, 1),
                    WaitTimeoutResult(wtr.timed_out()),
                );
                return if poisoned {
                    Err(PoisonError::new(out))
                } else {
                    Ok(out)
                };
            };

            // Registered: release the mutex and register as a waiter
            // atomically (under the scheduler state lock), so a notify
            // between release and registration is impossible — the
            // shim cannot introduce lost wakeups the real condvar
            // doesn't have.
            let inner = guard.inner.take();
            let cv_id = self.id;
            let lock_id = lock.id;
            let wake =
                sched.block(
                    me,
                    Waiting::Condvar {
                        cv: cv_id,
                        notified: false,
                    },
                    move |st| {
                        lock.owner.store(0, O::SeqCst);
                        drop(inner);
                        wake_mutex_waiters(st, lock_id);
                    },
                );
            drop(guard); // inner already taken: no-op
            let timed_out = WaitTimeoutResult(wake == Wake::TimedOut);
            match lock.lock() {
                Ok(g) => Ok((g, timed_out)),
                Err(poison) => Err(PoisonError::new((
                    poison.into_inner(),
                    timed_out,
                ))),
            }
        }
    }

    // --------------------------------------------------------- channels

    pub mod mpsc {
        //! Instrumented mpsc slice: `channel`, `sync_channel`, and the
        //! operations the fabric uses (`send`, `try_send`, `recv`,
        //! `recv_timeout`, `try_recv`).  Blocking `SyncSender::send` is
        //! deliberately absent — the fabric never blocks a producer.

        use super::{
            active, current, maybe_yield, next_obj_id, PoisonError,
            Sched, StdCondvar, StdMutex, Wake, Waiting,
        };
        use std::collections::VecDeque;
        use std::sync::Arc;
        use std::time::{Duration, Instant};

        pub struct SendError<T>(pub T);

        // Manual Debug, like std's: the payload may not be Debug (the
        // worker pool sends boxed closures).
        impl<T> std::fmt::Debug for SendError<T> {
            fn fmt(
                &self,
                f: &mut std::fmt::Formatter<'_>,
            ) -> std::fmt::Result {
                f.write_str("SendError(..)")
            }
        }

        pub enum TrySendError<T> {
            Full(T),
            Disconnected(T),
        }

        impl<T> std::fmt::Debug for TrySendError<T> {
            fn fmt(
                &self,
                f: &mut std::fmt::Formatter<'_>,
            ) -> std::fmt::Result {
                match self {
                    Self::Full(_) => f.write_str("Full(..)"),
                    Self::Disconnected(_) => {
                        f.write_str("Disconnected(..)")
                    }
                }
            }
        }

        #[derive(Debug, PartialEq, Eq)]
        pub struct RecvError;

        #[derive(Debug, PartialEq, Eq)]
        pub enum TryRecvError {
            Empty,
            Disconnected,
        }

        #[derive(Debug, PartialEq, Eq)]
        pub enum RecvTimeoutError {
            Timeout,
            Disconnected,
        }

        struct ChanState<T> {
            queue: VecDeque<T>,
            senders: usize,
            receiver_alive: bool,
        }

        struct ChanCore<T> {
            id: usize,
            bound: Option<usize>,
            state: StdMutex<ChanState<T>>,
            /// Real-thread wakeups for unregistered receivers.
            cv: StdCondvar,
        }

        impl<T> ChanCore<T> {
            fn new(bound: Option<usize>) -> Arc<Self> {
                Arc::new(Self {
                    id: next_obj_id(),
                    bound,
                    state: StdMutex::new(ChanState {
                        queue: VecDeque::new(),
                        senders: 1,
                        receiver_alive: true,
                    }),
                    cv: StdCondvar::new(),
                })
            }

            fn lock(
                &self,
            ) -> std::sync::MutexGuard<'_, ChanState<T>> {
                self.state
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
            }

            fn wake_receivers(&self) {
                self.cv.notify_all();
                if let Some(sched) = active() {
                    sched.wake_chan(self.id);
                }
            }

            fn push(&self, value: T) -> Result<(), TrySendError<T>> {
                {
                    let mut st = self.lock();
                    if !st.receiver_alive {
                        return Err(TrySendError::Disconnected(value));
                    }
                    if let Some(bound) = self.bound {
                        if st.queue.len() >= bound {
                            return Err(TrySendError::Full(value));
                        }
                    }
                    st.queue.push_back(value);
                }
                self.wake_receivers();
                maybe_yield();
                Ok(())
            }

            fn recv_registered(
                &self,
                sched: &Arc<Sched>,
                me: usize,
                can_timeout: bool,
            ) -> Result<T, RecvTimeoutError> {
                loop {
                    {
                        let mut st = self.lock();
                        if let Some(v) = st.queue.pop_front() {
                            drop(st);
                            maybe_yield();
                            return Ok(v);
                        }
                        if st.senders == 0 {
                            return Err(
                                RecvTimeoutError::Disconnected,
                            );
                        }
                    }
                    let wake = sched.block(
                        me,
                        Waiting::Chan {
                            chan: self.id,
                            can_timeout,
                            woken: false,
                        },
                        |_| {},
                    );
                    if can_timeout && wake == Wake::TimedOut {
                        // Model timeout: one last look for an item that
                        // raced in (the timed-out-with-item window).
                        let mut st = self.lock();
                        if let Some(v) = st.queue.pop_front() {
                            return Ok(v);
                        }
                        if st.senders == 0 {
                            return Err(
                                RecvTimeoutError::Disconnected,
                            );
                        }
                        return Err(RecvTimeoutError::Timeout);
                    }
                }
            }

            fn recv_real(
                &self,
                deadline: Option<Instant>,
            ) -> Result<T, RecvTimeoutError> {
                let mut st = self.lock();
                loop {
                    if let Some(v) = st.queue.pop_front() {
                        return Ok(v);
                    }
                    if st.senders == 0 {
                        return Err(RecvTimeoutError::Disconnected);
                    }
                    match deadline {
                        None => {
                            st = self
                                .cv
                                .wait(st)
                                .unwrap_or_else(PoisonError::into_inner);
                        }
                        Some(deadline) => {
                            let now = Instant::now();
                            if now >= deadline {
                                return Err(RecvTimeoutError::Timeout);
                            }
                            let (g, _) = self
                                .cv
                                .wait_timeout(st, deadline - now)
                                .unwrap_or_else(
                                    PoisonError::into_inner,
                                );
                            st = g;
                        }
                    }
                }
            }

            fn recv(
                &self,
                timeout: Option<Duration>,
            ) -> Result<T, RecvTimeoutError> {
                if let Some((sched, me)) = current() {
                    self.recv_registered(&sched, me, timeout.is_some())
                } else {
                    self.recv_real(timeout.map(|d| Instant::now() + d))
                }
            }
        }

        pub struct Sender<T> {
            core: Arc<ChanCore<T>>,
        }

        pub struct SyncSender<T> {
            core: Arc<ChanCore<T>>,
        }

        pub struct Receiver<T> {
            core: Arc<ChanCore<T>>,
        }

        fn clone_sender<T>(core: &Arc<ChanCore<T>>) -> Arc<ChanCore<T>> {
            core.lock().senders += 1;
            core.clone()
        }

        fn drop_sender<T>(core: &ChanCore<T>) {
            let remaining = {
                let mut st = core.lock();
                st.senders -= 1;
                st.senders
            };
            if remaining == 0 {
                core.wake_receivers();
            }
        }

        impl<T> Clone for Sender<T> {
            fn clone(&self) -> Self {
                Self {
                    core: clone_sender(&self.core),
                }
            }
        }

        impl<T> Clone for SyncSender<T> {
            fn clone(&self) -> Self {
                Self {
                    core: clone_sender(&self.core),
                }
            }
        }

        impl<T> Drop for Sender<T> {
            fn drop(&mut self) {
                drop_sender(&self.core);
            }
        }

        impl<T> Drop for SyncSender<T> {
            fn drop(&mut self) {
                drop_sender(&self.core);
            }
        }

        impl<T> Drop for Receiver<T> {
            fn drop(&mut self) {
                self.core.lock().receiver_alive = false;
            }
        }

        impl<T> Sender<T> {
            pub fn send(&self, value: T) -> Result<(), SendError<T>> {
                // Unbounded channel: only disconnection can fail.
                match self.core.push(value) {
                    Ok(()) => Ok(()),
                    Err(TrySendError::Disconnected(v))
                    | Err(TrySendError::Full(v)) => Err(SendError(v)),
                }
            }
        }

        impl<T> SyncSender<T> {
            pub fn try_send(
                &self,
                value: T,
            ) -> Result<(), TrySendError<T>> {
                self.core.push(value)
            }
        }

        impl<T> Receiver<T> {
            pub fn recv(&self) -> Result<T, RecvError> {
                match self.core.recv(None) {
                    Ok(v) => Ok(v),
                    Err(_) => Err(RecvError),
                }
            }

            pub fn recv_timeout(
                &self,
                timeout: Duration,
            ) -> Result<T, RecvTimeoutError> {
                self.core.recv(Some(timeout))
            }

            pub fn try_recv(&self) -> Result<T, TryRecvError> {
                maybe_yield();
                let mut st = self.core.lock();
                if let Some(v) = st.queue.pop_front() {
                    return Ok(v);
                }
                if st.senders == 0 {
                    return Err(TryRecvError::Disconnected);
                }
                Err(TryRecvError::Empty)
            }
        }

        pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
            let core = ChanCore::new(None);
            (
                Sender { core: core.clone() },
                Receiver { core },
            )
        }

        pub fn sync_channel<T>(
            bound: usize,
        ) -> (SyncSender<T>, Receiver<T>) {
            let core = ChanCore::new(Some(bound));
            (
                SyncSender { core: core.clone() },
                Receiver { core },
            )
        }
    }

    // ----------------------------------------------------------- thread

    pub mod thread {
        //! Instrumented `std::thread` slice: threads spawned here are
        //! registered with the running scheduler (inheriting it from
        //! the spawning thread), and sleep/yield/join become decision
        //! points.

        use super::{current, Arc, Sched, CURRENT};
        use std::time::Duration;

        struct ExitGuard {
            sched: Arc<Sched>,
            id: usize,
        }

        impl Drop for ExitGuard {
            fn drop(&mut self) {
                self.sched.thread_exit(self.id);
            }
        }

        pub struct JoinHandle<T> {
            inner: std::thread::JoinHandle<T>,
            model: Option<(Arc<Sched>, usize)>,
        }

        impl<T> JoinHandle<T> {
            pub fn join(self) -> std::thread::Result<T> {
                if let Some((sched, child)) = &self.model {
                    if let Some((mine, me)) = current() {
                        if Arc::ptr_eq(sched, &mine) {
                            mine.join_wait(me, *child);
                        }
                    }
                }
                self.inner.join()
            }

            pub fn is_finished(&self) -> bool {
                super::maybe_yield();
                self.inner.is_finished()
            }
        }

        #[derive(Default)]
        pub struct Builder {
            name: Option<String>,
        }

        impl Builder {
            pub fn new() -> Self {
                Self::default()
            }

            pub fn name(mut self, name: String) -> Self {
                self.name = Some(name);
                self
            }

            pub fn spawn<F, T>(
                self,
                f: F,
            ) -> std::io::Result<JoinHandle<T>>
            where
                F: FnOnce() -> T + Send + 'static,
                T: Send + 'static,
            {
                let mut builder = std::thread::Builder::new();
                if let Some(name) = self.name {
                    builder = builder.name(name);
                }
                let Some((sched, _me)) = current() else {
                    return builder
                        .spawn(f)
                        .map(|inner| JoinHandle { inner, model: None });
                };
                // Register the child on the *parent's* thread so slot
                // ids are deterministic regardless of OS start order.
                let child = sched.register_thread();
                let child_sched = sched.clone();
                let inner = builder.spawn(move || {
                    CURRENT.with(|c| {
                        *c.borrow_mut() =
                            Some((child_sched.clone(), child));
                    });
                    let _exit = ExitGuard {
                        sched: child_sched.clone(),
                        id: child,
                    };
                    // Wait for our first grant before touching
                    // anything.
                    {
                        let st = child_sched.lock_state();
                        child_sched.wait_granted(st, child);
                    }
                    match std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(f),
                    ) {
                        Ok(value) => value,
                        Err(payload) => {
                            child_sched.record_panic(
                                child,
                                super::payload_str(&*payload),
                            );
                            std::panic::resume_unwind(payload)
                        }
                    }
                })?;
                Ok(JoinHandle {
                    inner,
                    model: Some((sched, child)),
                })
            }
        }

        pub fn spawn<F, T>(f: F) -> JoinHandle<T>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            Builder::new().spawn(f).expect("failed to spawn thread")
        }

        /// Registered threads never really sleep — a sleep is just a
        /// preemption point (model time is scheduling order).
        pub fn sleep(dur: Duration) {
            if current().is_some() {
                super::maybe_yield();
            } else {
                std::thread::sleep(dur);
            }
        }

        pub fn yield_now() {
            if current().is_some() {
                super::maybe_yield();
            } else {
                std::thread::yield_now();
            }
        }
    }

    // ---------------------------------------------------------- atomics

    pub mod atomic {
        //! Yield-instrumented atomics: every operation is a preemption
        //! point, so interleavings around flag checks and counter
        //! updates are explored.  Orderings pass through to the real
        //! atomic underneath.

        pub use std::sync::atomic::Ordering;

        macro_rules! model_atomic {
            ($name:ident, $std:ty, $prim:ty) => {
                pub struct $name {
                    inner: $std,
                }

                impl $name {
                    pub fn new(value: $prim) -> Self {
                        Self {
                            inner: <$std>::new(value),
                        }
                    }

                    pub fn load(&self, order: Ordering) -> $prim {
                        super::maybe_yield();
                        self.inner.load(order)
                    }

                    pub fn store(&self, value: $prim, order: Ordering) {
                        super::maybe_yield();
                        self.inner.store(value, order);
                    }
                }
            };
        }

        model_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
        model_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
        model_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

        macro_rules! model_atomic_arith {
            ($name:ident, $prim:ty) => {
                impl $name {
                    pub fn fetch_add(
                        &self,
                        value: $prim,
                        order: Ordering,
                    ) -> $prim {
                        super::maybe_yield();
                        self.inner.fetch_add(value, order)
                    }

                    pub fn fetch_sub(
                        &self,
                        value: $prim,
                        order: Ordering,
                    ) -> $prim {
                        super::maybe_yield();
                        self.inner.fetch_sub(value, order)
                    }

                    pub fn fetch_max(
                        &self,
                        value: $prim,
                        order: Ordering,
                    ) -> $prim {
                        super::maybe_yield();
                        self.inner.fetch_max(value, order)
                    }
                }
            };
        }

        model_atomic_arith!(AtomicU64, u64);
        model_atomic_arith!(AtomicUsize, usize);
    }

    // ---------------------------------------------------------- harness

    pub mod check {
        //! The exploration harness: run a scenario closure under the
        //! model scheduler, many times, over different decision
        //! streams.
        //!
        //! * [`explore_exhaustive`] — iterative-deepening DFS over the
        //!   decision tree (branch 0 first, backtrack the deepest
        //!   unexplored branch).  Complete for small scenarios; a
        //!   `max_runs` cap bounds the walk and is *logged* when hit.
        //! * [`explore_random`] — `runs` seeded-random schedules from
        //!   `base_seed` (for fabrics too big to enumerate).
        //!
        //! On failure both panic with the failure message and a replay
        //! line; setting `MODEL_CHECK_TRACE` (a comma-separated branch
        //! list) or `MODEL_CHECK_SEED` re-runs exactly that
        //! interleaving.

        use super::{
            payload_str, Arc, Decisions, PoisonError, Sched, StdMutex,
            Waiting, ACTIVE, CURRENT,
        };

        /// One exploration at a time, process-wide: the ACTIVE
        /// scheduler hook is global, and serialized runs are what make
        /// decision traces deterministic.
        static RUN_LOCK: StdMutex<()> = StdMutex::new(());

        fn run_once<F>(
            scenario: &F,
            decisions: Decisions,
        ) -> (Option<String>, Vec<(usize, usize)>)
        where
            F: Fn() + Sync,
        {
            let _serial = RUN_LOCK
                .lock()
                .unwrap_or_else(PoisonError::into_inner);
            // Explored interleavings panic *by design* (an aborted run
            // unwinds every model thread); silence the default hook for
            // the duration so passing explorations stay quiet.  Runs
            // are globally serialized, so swapping the process hook is
            // race-free among explorations.  (Restored below; run_once
            // itself never unwinds — scenario panics are caught.)
            let prev_hook = std::panic::take_hook();
            std::panic::set_hook(Box::new(|_| {}));
            let sched = Arc::new(Sched::new(decisions));
            *ACTIVE
                .lock()
                .unwrap_or_else(PoisonError::into_inner) =
                Some(Arc::downgrade(&sched));

            let root = sched.register_thread();
            sched.lock_state().slots[root].granted = true;
            let root_sched = sched.clone();
            std::thread::scope(|scope| {
                let handle = scope.spawn(|| {
                    CURRENT.with(|c| {
                        *c.borrow_mut() =
                            Some((root_sched.clone(), root));
                    });
                    let result = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(scenario),
                    );
                    // Record the root panic *before* thread_exit: the
                    // exit's pick_next may diagnose a (secondary)
                    // deadlock and must not mask the real failure.
                    if let Err(payload) = result {
                        let msg = payload_str(&*payload);
                        let mut st = root_sched.lock_state();
                        if st.failure.is_none() {
                            st.failure = Some(format!(
                                "scenario panicked: {msg}"
                            ));
                        }
                        st.abort = true;
                        root_sched.cv.notify_all();
                    }
                    root_sched.thread_exit(root);
                });
                let _ = handle.join();
            });

            let (failure, recorded) = {
                let mut st = sched.lock_state();
                if st.failure.is_none() {
                    let leaked = st.slots.iter().position(|s| {
                        !matches!(s.waiting, Waiting::Finished)
                    });
                    if let Some(i) = leaked {
                        st.failure = Some(format!(
                            "thread t{i} leaked past the scenario \
                             (never joined, still blocked)"
                        ));
                    }
                }
                // Release any stragglers so their OS threads die.
                st.abort = true;
                sched.cv.notify_all();
                let recorded = match &st.decisions {
                    Decisions::Trace { recorded, .. } => {
                        recorded.clone()
                    }
                    Decisions::Random { .. } => Vec::new(),
                };
                (st.failure.clone(), recorded)
            };
            *ACTIVE
                .lock()
                .unwrap_or_else(PoisonError::into_inner) = None;
            std::panic::set_hook(prev_hook);
            (failure, recorded)
        }

        fn parse_trace(s: &str) -> Vec<usize> {
            s.split(',')
                .filter(|part| !part.trim().is_empty())
                .map(|part| {
                    part.trim()
                        .parse()
                        .expect("MODEL_CHECK_TRACE: comma-separated ints")
                })
                .collect()
        }

        fn trace_string(recorded: &[(usize, usize)]) -> String {
            recorded
                .iter()
                .map(|(choice, _)| choice.to_string())
                .collect::<Vec<_>>()
                .join(",")
        }

        /// Replay one exact interleaving; returns its failure, if any.
        pub fn replay<F>(trace: &[usize], scenario: F) -> Option<String>
        where
            F: Fn() + Sync,
        {
            run_once(
                &scenario,
                Decisions::Trace {
                    prefix: trace.to_vec(),
                    recorded: Vec::new(),
                    cursor: 0,
                },
            )
            .0
        }

        /// DFS the decision tree; returns the first failure with its
        /// replay trace instead of panicking (the checker's own tests
        /// use this).  `None` = explored clean (or cap reached).
        pub fn exhaustive_failure<F>(
            name: &str,
            max_runs: usize,
            scenario: F,
        ) -> Option<(String, Vec<usize>)>
        where
            F: Fn() + Sync,
        {
            let mut prefix: Vec<usize> = Vec::new();
            let mut runs = 0usize;
            loop {
                runs += 1;
                let (failure, recorded) = run_once(
                    &scenario,
                    Decisions::Trace {
                        prefix: prefix.clone(),
                        recorded: Vec::new(),
                        cursor: 0,
                    },
                );
                if let Some(msg) = failure {
                    let msg = format!(
                        "model check '{name}' failed on run {runs}: {msg}"
                    );
                    let trace =
                        recorded.iter().map(|&(choice, _)| choice).collect();
                    return Some((msg, trace));
                }
                // Backtrack: bump the deepest choice with an
                // unexplored sibling, truncating everything after it.
                let next = recorded
                    .iter()
                    .rposition(|&(choice, arity)| choice + 1 < arity)
                    .map(|i| {
                        let mut p: Vec<usize> = recorded[..i]
                            .iter()
                            .map(|&(choice, _)| choice)
                            .collect();
                        p.push(recorded[i].0 + 1);
                        p
                    });
                match next {
                    Some(p) if runs < max_runs => prefix = p,
                    Some(_) => {
                        eprintln!(
                            "model check '{name}': run cap {max_runs} \
                             reached after {runs} runs — coverage is \
                             partial, not exhaustive"
                        );
                        return None;
                    }
                    None => {
                        eprintln!(
                            "model check '{name}': explored all \
                             {runs} interleavings"
                        );
                        return None;
                    }
                }
            }
        }

        /// Bounded-exhaustive exploration; panics (with a replay line)
        /// on the first failing interleaving.  With `MODEL_CHECK_TRACE`
        /// set, replays exactly that interleaving instead.
        pub fn explore_exhaustive<F>(
            name: &str,
            max_runs: usize,
            scenario: F,
        ) where
            F: Fn() + Sync,
        {
            if let Ok(trace) = std::env::var("MODEL_CHECK_TRACE") {
                let trace = parse_trace(&trace);
                if let Some(msg) = replay(&trace, scenario) {
                    panic!(
                        "model check '{name}' (replayed trace): {msg}"
                    );
                }
                eprintln!(
                    "model check '{name}': replayed trace passed"
                );
                return;
            }
            if let Some((msg, trace)) =
                exhaustive_failure(name, max_runs, scenario)
            {
                let trace = trace
                    .iter()
                    .map(usize::to_string)
                    .collect::<Vec<_>>()
                    .join(",");
                panic!(
                    "{msg}\n  replay with: MODEL_CHECK_TRACE={trace} \
                     cargo test --features model-check {name}"
                );
            }
        }

        /// `runs` seeded-random schedules (seeds `base_seed + i`);
        /// panics with the failing seed.  With `MODEL_CHECK_SEED` set,
        /// runs exactly that seed instead.
        pub fn explore_random<F>(
            name: &str,
            base_seed: u64,
            runs: usize,
            scenario: F,
        ) where
            F: Fn() + Sync,
        {
            let seeds: Vec<u64> = match std::env::var("MODEL_CHECK_SEED")
            {
                Ok(s) => vec![s
                    .trim()
                    .parse()
                    .expect("MODEL_CHECK_SEED: an integer seed")],
                Err(_) => {
                    (0..runs as u64).map(|i| base_seed + i).collect()
                }
            };
            for seed in seeds {
                let (failure, _) = run_once(
                    &scenario,
                    Decisions::Random { state: seed },
                );
                if let Some(msg) = failure {
                    panic!(
                        "model check '{name}' failed at seed {seed}: \
                         {msg}\n  replay with: MODEL_CHECK_SEED={seed} \
                         cargo test --features model-check {name}"
                    );
                }
            }
        }
    }
}

#[cfg(all(test, feature = "model-check"))]
mod model_tests {
    //! The checker checking itself: a seeded lost-update bug must be
    //! *found* (the negative test that proves exploration works), the
    //! corrected version must pass, and a found failure must replay
    //! deterministically from its trace.

    use super::{check, lock_or_recover, thread, Mutex};
    use std::sync::Arc;

    /// Classic lost update: read under one lock acquisition, write
    /// under another — the increment is not atomic and a preemption in
    /// between loses one of the two updates.
    fn racy_increments() {
        let counter = Arc::new(Mutex::new(0u32));
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let counter = counter.clone();
                thread::spawn(move || {
                    let seen = *lock_or_recover(&counter);
                    *lock_or_recover(&counter) = seen + 1;
                })
            })
            .collect();
        for handle in workers {
            handle.join().unwrap();
        }
        assert_eq!(*lock_or_recover(&counter), 2, "lost update");
    }

    #[test]
    fn exhaustive_search_finds_the_lost_update() {
        let failure = check::exhaustive_failure(
            "lost_update_negative",
            2000,
            racy_increments,
        );
        let (msg, trace) =
            failure.expect("the checker must find the lost update");
        assert!(msg.contains("lost update"), "{msg}");
        // Determinism: the recorded trace replays the same failure.
        let replayed = check::replay(&trace, racy_increments)
            .expect("trace must replay the failure");
        assert!(replayed.contains("lost update"), "{replayed}");
    }

    #[test]
    fn exhaustive_search_passes_the_correct_version() {
        check::explore_exhaustive("atomic_increment_positive", 2000, || {
            let counter = Arc::new(Mutex::new(0u32));
            let workers: Vec<_> = (0..2)
                .map(|_| {
                    let counter = counter.clone();
                    thread::spawn(move || {
                        *lock_or_recover(&counter) += 1;
                    })
                })
                .collect();
            for handle in workers {
                handle.join().unwrap();
            }
            assert_eq!(*lock_or_recover(&counter), 2);
        });
    }

    #[test]
    fn deadlocks_are_detected_not_hung() {
        let failure = check::exhaustive_failure("deadlock_negative", 200, || {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a2, b2) = (a.clone(), b.clone());
            let t1 = thread::spawn(move || {
                let _ga = lock_or_recover(&a2);
                let _gb = lock_or_recover(&b2);
            });
            let (a3, b3) = (a.clone(), b.clone());
            let t2 = thread::spawn(move || {
                let _gb = lock_or_recover(&b3);
                let _ga = lock_or_recover(&a3);
            });
            t1.join().unwrap();
            t2.join().unwrap();
        });
        let (msg, _) = failure.expect("AB-BA must deadlock somewhere");
        assert!(msg.contains("deadlock"), "{msg}");
    }

    #[test]
    fn random_mode_is_seed_deterministic() {
        // A passing scenario under random schedules: just exercises the
        // seeded path end to end (failures print the seed; determinism
        // of the stream is by construction — splitmix64 on the seed).
        check::explore_random("random_smoke", 7, 5, || {
            let counter = Arc::new(Mutex::new(0u32));
            let workers: Vec<_> = (0..3)
                .map(|_| {
                    let counter = counter.clone();
                    thread::spawn(move || {
                        *lock_or_recover(&counter) += 1;
                    })
                })
                .collect();
            for handle in workers {
                handle.join().unwrap();
            }
            assert_eq!(*lock_or_recover(&counter), 3);
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `lock_or_recover` hands back a usable guard after a panic
    /// poisoned the mutex — the single panicking worker must not
    /// cascade.
    #[test]
    fn lock_or_recover_recovers_a_poisoned_mutex() {
        let mutex = std::sync::Arc::new(Mutex::new(7u32));
        let poisoner = mutex.clone();
        let _ = std::thread::spawn(move || {
            let _guard = lock_or_recover(&poisoner);
            panic!("poison it");
        })
        .join();
        *lock_or_recover(&mutex) += 1;
        assert_eq!(*lock_or_recover(&mutex), 8);
    }
}
