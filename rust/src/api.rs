//! The stable serving API surface: one canonical import path for the
//! types a serving client touches, plus the **stable numeric error
//! codes** shared by in-process callers and the wire protocol.
//!
//! In-process embedders and network clients must agree on what a
//! rejection *means*: a [`SubmitError::Full`] surfaced to a library
//! caller and a `SHED` frame surfaced to a TCP client are the same
//! event, so both are derived from one mapping ([`SubmitError::code`])
//! with numeric values that are frozen — the wire protocol
//! ([`crate::ingest::wire`]) encodes `ErrorCode as u8` directly, and a
//! renumbering would silently change what deployed clients observe.
//!
//! Prefer these re-exports over the bare `coordinator::session` paths
//! (`use rnn_hls::api::{Completion, SubmitError}`): the coordinator
//! module tree is a layout detail and may move; this module is the
//! contract.

pub use crate::coordinator::session::{
    BackendKind, Completion, Output, ServingPlan, ServingSpec, Session,
    SessionHandle, SubmitError,
};

/// Stable numeric rejection codes, shared by the in-process API and the
/// wire protocol's `WireError` frames.  The discriminants are part of
/// the serialized protocol — append new codes, never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// Backpressure: the target shard's bounded queue was full and the
    /// request was shed (maps from [`SubmitError::Full`]).  Retryable.
    Shed = 1,
    /// The session is shutting down or closed (maps from
    /// [`SubmitError::Closed`]).  Not retryable on this session.
    Closed = 2,
    /// The network front-end refused the *connection* (worker pool and
    /// backlog saturated) — nothing reached the session.  Retryable
    /// against another replica or after backoff.
    Busy = 3,
    /// The peer sent a frame the server could not parse; the connection
    /// is dropped after this answer.
    Malformed = 4,
}

impl ErrorCode {
    /// Decode a wire byte back into a code (`None` for unknown bytes —
    /// a frame from a future protocol revision, surfaced as a framing
    /// error rather than a panic).
    pub fn from_u8(byte: u8) -> Option<Self> {
        match byte {
            1 => Some(Self::Shed),
            2 => Some(Self::Closed),
            3 => Some(Self::Busy),
            4 => Some(Self::Malformed),
            _ => None,
        }
    }

    /// Human-readable name (metrics endpoint + log lines).
    pub fn name(self) -> &'static str {
        match self {
            Self::Shed => "shed",
            Self::Closed => "closed",
            Self::Busy => "busy",
            Self::Malformed => "malformed",
        }
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl SubmitError {
    /// The stable numeric code of this rejection — the one mapping both
    /// the wire protocol and in-process callers use to distinguish shed
    /// (retryable backpressure) from closed (session gone).
    pub fn code(&self) -> ErrorCode {
        match self {
            Self::Full { .. } => ErrorCode::Shed,
            Self::Closed { .. } => ErrorCode::Closed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Request;
    use std::time::Instant;

    fn req() -> Request {
        Request {
            id: 1,
            features: vec![0.0; 4],
            label: 0,
            route_key: 0,
            enqueued_at: Instant::now(),
        }
    }

    /// The discriminants are frozen protocol constants: a renumbering
    /// must fail here, not in a deployed client.
    #[test]
    fn error_codes_are_stable() {
        assert_eq!(ErrorCode::Shed as u8, 1);
        assert_eq!(ErrorCode::Closed as u8, 2);
        assert_eq!(ErrorCode::Busy as u8, 3);
        assert_eq!(ErrorCode::Malformed as u8, 4);
        for code in [
            ErrorCode::Shed,
            ErrorCode::Closed,
            ErrorCode::Busy,
            ErrorCode::Malformed,
        ] {
            assert_eq!(ErrorCode::from_u8(code as u8), Some(code));
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(ErrorCode::from_u8(255), None);
    }

    #[test]
    fn submit_errors_map_to_their_codes() {
        let full = SubmitError::Full {
            shard: 0,
            request: req(),
        };
        assert_eq!(full.code(), ErrorCode::Shed);
        let closed = SubmitError::Closed { request: req() };
        assert_eq!(closed.code(), ErrorCode::Closed);
    }
}
