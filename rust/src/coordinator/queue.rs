//! Bounded MPMC queue with trigger-style overflow: when full, `push`
//! fails immediately (the caller counts a drop) instead of blocking the
//! producer — a detector never waits for the DAQ.
//!
//! Sync primitives come from [`crate::util::sync`], so the queue runs
//! under the model checker unchanged (`tests/model_check.rs` drives
//! this exact code through adversarial interleavings).  Lock
//! acquisitions recover from poisoning ([`lock_or_recover`]): a
//! panicking worker must not wedge the drain/close paths that other
//! threads rely on for shutdown.

use crate::util::sync::{lock_or_recover, Condvar, Mutex};
use std::collections::VecDeque;
use std::sync::PoisonError;
use std::time::Duration;

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(capacity),
                closed: false,
            }),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    /// Non-blocking push; `Err(item)` when full or closed (drop + count).
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut inner = lock_or_recover(&self.inner);
        if inner.closed || inner.items.len() >= self.capacity {
            return Err(item);
        }
        inner.items.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pop one item, waiting up to `timeout`.  `None` on timeout, or when
    /// the queue is closed AND drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let mut inner = lock_or_recover(&self.inner);
        loop {
            if let Some(item) = inner.items.pop_front() {
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            let (guard, result) = self
                .not_empty
                .wait_timeout(inner, timeout)
                .unwrap_or_else(PoisonError::into_inner);
            inner = guard;
            if result.timed_out() {
                // An item may have raced in between the timeout firing
                // and this thread reacquiring the lock — deliver it
                // rather than reporting an empty timeout.
                return inner.items.pop_front();
            }
        }
    }

    /// Non-blocking pop: `None` when the queue is currently empty
    /// (whether open or closed) — the virtual-clock wait primitive.
    pub fn try_pop(&self) -> Option<T> {
        lock_or_recover(&self.inner).items.pop_front()
    }

    /// Drain up to `max` items without blocking (batcher top-up).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut inner = lock_or_recover(&self.inner);
        let take = max.min(inner.items.len());
        inner.items.drain(..take).collect()
    }

    pub fn len(&self) -> usize {
        lock_or_recover(&self.inner).items.len()
    }

    /// Capacity the queue was built with (push fails beyond it).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Close: producers fail, consumers drain then get `None`.
    pub fn close(&self) {
        lock_or_recover(&self.inner).closed = true;
        self.not_empty.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        lock_or_recover(&self.inner).closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        for i in 0..4 {
            assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(i));
        }
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
    }

    #[test]
    fn overflow_rejects_instead_of_blocking() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.capacity(), 2);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.push(3), Err(3));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn closed_queue_rejects_push_but_drains() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.push(2), Err(2));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
    }

    #[test]
    fn try_pop_never_blocks() {
        let q = BoundedQueue::new(4);
        assert_eq!(q.try_pop(), None);
        q.push(9).unwrap();
        assert_eq!(q.try_pop(), Some(9));
        q.close();
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn drain_up_to_takes_prefix() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.drain_up_to(3), vec![0, 1, 2]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.drain_up_to(10), vec![3, 4]);
    }

    #[test]
    fn cross_thread_handoff() {
        let q = Arc::new(BoundedQueue::new(1024));
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..1000 {
                    while q.push(i).is_err() {
                        std::thread::yield_now();
                    }
                }
                q.close();
            })
        };
        let mut got = Vec::new();
        while let Some(v) = q.pop_timeout(Duration::from_millis(100)) {
            got.push(v);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn pop_timeout_times_out() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(20)), None);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    /// A spurious wakeup (notify with nothing enqueued) must re-enter
    /// the wait, not return early — the later real push is delivered
    /// within the same `pop_timeout` call.
    #[test]
    fn pop_timeout_survives_spurious_wakeup() {
        let q = Arc::new(BoundedQueue::new(4));
        let poker = {
            let q = q.clone();
            std::thread::spawn(move || {
                // Spurious: nothing enqueued yet.
                for _ in 0..10 {
                    q.not_empty.notify_all();
                    std::thread::sleep(Duration::from_millis(1));
                }
                q.push(42u32).unwrap();
            })
        };
        // Far longer than the poker takes: a premature `None` (treating
        // the spurious wake as a timeout) would fail the assert.
        assert_eq!(q.pop_timeout(Duration::from_secs(5)), Some(42));
        poker.join().unwrap();
    }

    /// An item that races in exactly as the wait times out is
    /// delivered, not stranded: the timed-out branch re-checks the
    /// queue under the reacquired lock.
    #[test]
    fn pop_timeout_delivers_item_racing_the_timeout() {
        let q = Arc::new(BoundedQueue::new(4));
        let pusher = {
            let q = q.clone();
            std::thread::spawn(move || {
                // Land close to the 20ms deadline; whichever side of it
                // the push falls on, the item must not be lost.
                std::thread::sleep(Duration::from_millis(18));
                q.push(7u32).unwrap();
            })
        };
        let got = q.pop_timeout(Duration::from_millis(20));
        pusher.join().unwrap();
        match got {
            Some(7) => {}
            Some(other) => panic!("wrong item: {other}"),
            // Timed out before the push landed: the item must still be
            // in the queue — stranding it would be the bug.
            None => assert_eq!(q.try_pop(), Some(7)),
        }
    }

    /// A producer that panics while holding the queue lock poisons it;
    /// every path (push, pop, close, len) must keep working so shutdown
    /// can still drain and report.
    #[test]
    fn poisoned_lock_still_drains_and_closes() {
        let q = Arc::new(BoundedQueue::new(4));
        q.push(1u32).unwrap();
        let poisoner = {
            let q = q.clone();
            std::thread::spawn(move || {
                let _guard = lock_or_recover(&q.inner);
                panic!("worker dies holding the queue lock");
            })
        };
        assert!(poisoner.join().is_err());
        // Queue is now poisoned; all operations must recover.
        q.push(2u32).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(1));
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.push(3), Err(3));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Some(2));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), None);
    }
}
