//! Trigger-style serving coordinator — the L3 request path.
//!
//! The paper's deployment scenario is the LHC trigger: events arrive at a
//! fixed, unforgiving rate and each must be classified within a latency
//! budget or dropped (§1).  This module is that scenario as a software
//! system:
//!
//! ```text
//! submitters ──► bounded queue ──► Batcher ──► engine worker threads
//!  (live Session   (backpressure:    (size +      (each owns a PJRT
//!   handles, or     typed error /     deadline)     executable set)
//!   replay source)  drop + count)                       │
//!                        Metrics ◄──────────────────────┘
//!            (drop rate, p50/p99 latency, throughput)
//! ```
//!
//! ## Request-driven serving: the Session lifecycle
//!
//! The primary API is [`session`]: **spec → start → submit → snapshot →
//! shutdown**.
//!
//! 1. Describe the session with a typed [`ServingSpec`] (backend kinds,
//!    shards, routing, tier mix, per-shard batching, workers, queue
//!    depth, clock).  [`ServingSpec::build`] is the single validation
//!    point — shard ≥ 1, batch ≥ 1, mix sums to 1, backends arity,
//!    per-label batcher consistency — with uniform error messages; the
//!    CLI parses its flags straight into this struct.
//! 2. [`Session::start`] spins up the sharded queue+batcher+worker
//!    fabric and returns a live handle.
//! 3. Any number of threads [`submit`](Session::submit) requests through
//!    [`SessionHandle`] clones (many sources, one fabric); a full shard
//!    queue surfaces as a typed [`SubmitError`] instead of blocking the
//!    detector.
//! 4. [`Session::recv`] / [`Session::drain`] yield per-request
//!    [`Completion`]s (output, id, enqueue/complete instants);
//!    [`Session::snapshot`] rolls live metrics up mid-flight.
//! 5. [`Session::shutdown`] drains, closes, joins, and returns the final
//!    [`ShardedReport`].
//!
//! The classic replay-to-completion entry points — [`Server::run`],
//! [`ShardedServer::run`] — are thin wrappers: start a session, replay
//! the spec's synthetic source through `submit`
//! ([`Session::replay`]), shut down.  One fabric serves both modes, so
//! the equivalence suites (shard, backend, batching) cover the live
//! path by construction.
//!
//! Design notes:
//!
//! * The PJRT client is `Rc`-based (not `Send`), so executables cannot be
//!   shared across threads.  Each worker thread *constructs* its own
//!   engine via a factory closure — the same pattern as one-engine-per-
//!   accelerator in a GPU serving stack, and it mirrors the paper's
//!   replicated-FPGA-kernel deployment.
//! * Batch buckets mirror the AOT artifacts (1/10/100): the batcher packs
//!   up to `max_batch` requests or flushes on `max_wait`, the worker picks
//!   the smallest bucket ≥ the batch.
//! * The queue is bounded; when full, new events are **dropped and
//!   counted** — exactly what a trigger does when the downstream is
//!   saturated (it never blocks the detector).
//!
//! ## Parallelism knobs
//!
//! Throughput is governed by three independent levers:
//!
//! * **`ServerConfig::workers`** — engine-worker threads, each owning its
//!   own runner (engine replica) and pulling whole batches off the queue.
//! * **`BatcherConfig::max_batch` / `max_wait`** — the batch-vs-latency
//!   trade: how many requests a worker takes per pull and how long the
//!   batcher holds a partial batch.  The deadline anchors to *pop* time,
//!   so aged requests under backlog do not collapse the batching window;
//!   `max_wait = 0` is the trigger regime (batch-1, never wait).
//! * **engine parallelism** — *within* one batch, the rust engines fan
//!   samples across a worker pool (`FloatEngine::with_parallelism`,
//!   `FixedEngine::with_parallelism`; CLI `--engine-parallelism`).
//!   Whole batches reach the engine via [`server::EngineRunner`] and the
//!   packed buffers of [`Batch::packed_features`], so the batcher is a
//!   real throughput lever, not just queueing policy.
//!
//! `workers × engine-parallelism` should not exceed the core count;
//! prefer `workers` for many small batches (small models) and engine
//! parallelism for large batches on heavy models.
//!
//! ## Horizontal scaling: coordinator shards
//!
//! One [`Server`] owns one queue, one batcher clock, and one metrics
//! block — a single-coordinator ceiling.  [`ShardedServer`] goes
//! horizontal the way parallel-IO duplication scales the paper's trigger
//! designs: N independent shards (each its own `BoundedQueue` + batcher
//! loop + engine workers), a [`Router`] in front (hash-of-id,
//! round-robin, or model-key [`ShardPolicy`]), and a shared metrics
//! roll-up ([`ServerMetrics::merge`] /
//! [`LatencyHistogram::merge`]) that folds per-shard counters and
//! histogram buckets into one [`ServerReport`].  A single-shard
//! configuration reproduces [`Server`] exactly (the shard-equivalence
//! suite asserts it), so `shards` is a fourth independent throughput
//! lever on top of the three above.
//!
//! ## Heterogeneous multi-backend serving
//!
//! Shards need not be clones: the paper's deployment is *two-tiered* —
//! bit-accurate fixed-point designs on the trigger path, full-precision
//! models for whatever tolerates latency.  [`ShardedServer`] serves both
//! tiers in one session:
//!
//! * the source stamps every request with a traffic class from a
//!   configurable [`TierMix`] (e.g. 90 % trigger-tier, 10 % offline-tier)
//!   — a pure `(seed, id)` hash on [`Request::route_key`], so streams and
//!   every tier sub-stream replay deterministically;
//! * [`ShardPolicy::ModelKey`] routes tier `t` to shard `t % shards`,
//!   and each shard's factory builds that shard's backend (resolved by
//!   name through `nn::BackendSpec` — `fixed`, `float`, or the reserved
//!   `pjrt` slot);
//! * labelled shards ([`ShardedConfig::shard_backends`]) get a
//!   per-backend metrics split in the roll-up
//!   ([`sharded::BackendTierStats`]): per-tier p50/p99 and throughput
//!   rather than a blended number.
//!
//! Mixing backends has zero semantic footprint: each request's output is
//! bitwise identical to serving the same seeded stream through that
//! backend's standalone [`Server`] (`tests/backend_routing.rs` asserts
//! it), exactly as sharding and batching are semantics-free
//! (`tests/shard_equivalence.rs`, `tests/batch_equivalence.rs`).
//!
//! ## Tier-aware batching
//!
//! Tiers differ in more than their backend: they sit at *opposite ends*
//! of the §5.2 batch-vs-latency curve.  Every shard therefore owns its
//! own [`BatcherConfig`] ([`ShardedConfig::shard_batchers`]): the
//! trigger tier is pinned at **strict batch-1** (`max_wait = 0` — a
//! trigger-tier request is *never* co-batched, not even with requests
//! already queued behind it), while the offline tier batches deep
//! (64 requests or a 2 ms deadline).  Defaults resolve from each
//! backend's [`tier::TierClass`]; the CLI pins them explicitly with
//! `--batch-policy trigger:1:0,offline:64:2000`
//! (`<name>:<max_batch>:<max_wait_us>` per shard — see
//! [`tier::TierPolicy`]).  An empty `shard_batchers` reproduces the
//! shared-config behavior bit for bit, so homogeneous sessions are
//! untouched (`tests/shard_equivalence.rs` asserts it).
//!
//! ## Network serving
//!
//! [`net`] puts a TCP edge on the live session — the paper's events
//! arrive over the wire, not from an in-process loop.  Name a listener
//! in the spec ([`ServingSpec::with_listener`], plus
//! `with_metrics_listener` / `with_max_connections`), start the session
//! as usual, then hand it to [`Session::serve_listener`]; the returned
//! [`NetServer`] owns the accept loop, the bounded connection-worker
//! pool, and the completion dispatcher, and its
//! [`shutdown`](NetServer::shutdown) runs the same drain-then-close
//! protocol as in-process.
//!
//! The protocol is [`crate::ingest::wire`]: length-prefixed binary
//! frames with an 8-byte header —
//!
//! ```text
//! offset  size  field
//! 0       2     magic 0x4852 ("RH", little-endian)
//! 2       1     version (currently 1)
//! 3       1     frame type: 1 = Request, 2 = Response, 3 = Error
//! 4       4     payload length (LE u32, ≤ 1 MiB)
//! ```
//!
//! Request payloads carry `seq · label · features[]`; Response payloads
//! `seq · id · shard · outputs[]`; Error payloads `seq · code`, where
//! `code` is the **stable** [`crate::api::ErrorCode`] numeric space —
//! `SHED` (1, queue full: retryable backpressure), `CLOSED` (2, session
//! gone), `BUSY` (3, connection cap hit at admission), `MALFORMED` (4,
//! unparseable bytes; the connection is dropped after the answer).  A
//! TCP client and a library embedder observe the *same* rejection
//! taxonomy, derived from one mapping (`SubmitError::code`).
//!
//! The serving semantics are unchanged by the socket: the TCP path's
//! outputs are bitwise identical to in-process `submit` for the same
//! requests (`tests/net_ingest.rs` asserts it, for 1 and 4 shards),
//! and the accounting identity holds end-to-end.  Drive a listener with
//! the `loadgen` binary (open-loop Poisson or bursty arrivals over many
//! connections):
//!
//! ```text
//! rnn-hls serve --engine float --listen 127.0.0.1:7432 &
//! loadgen --addr 127.0.0.1:7432 --clients 10000 --rate 100000
//! loadgen                      # no --addr: self-serves a session
//! ```
//!
//! ## Deterministic time: the serving clock
//!
//! Every time-dependent decision — the batcher deadline in
//! [`batcher::next_batch`], the completion instant
//! [`server::worker_loop`] hands to [`ServerMetrics::observe_batch`],
//! the `enqueued_at` stamp percentiles anchor to — reads a
//! [`Clock`].  Production uses [`SystemClock`]; `tests/tier_batching.rs`
//! passes a [`VirtualClock`], whose timeline only moves when the test
//! advances it (an idle deadline wait *auto-advances* to the deadline),
//! so size-or-deadline flush semantics and per-tier p50/p99 are asserted
//! against hand-computed values without one `std::thread::sleep`.
//! Arrival *pacing* stays real time — a virtual clock can reshape the
//! latency ledger, never stall the detector.
//!
//! ## Concurrency invariants
//!
//! The fabric's cross-thread contracts, stated once.  Plain tests pin
//! them under real threads; `tests/model_check.rs` explores them under
//! adversarial schedules (`--features model-check`); `tools/lint`
//! rejects code that could erode them.
//!
//! * **The accounting identity.**  At shutdown,
//!   `generated == completed + dropped` exactly.  `submit` counts
//!   `generated` *before* the push; a `Full` rejection adds one
//!   `dropped`; a push that loses the race with shutdown (closed-flag
//!   check passed, queue closed underneath) *un-counts* `generated` and
//!   reports `Closed` — so a `Closed` rejection is counted nowhere.
//!   All writes to the identity's counters (`generated`, `dropped`,
//!   `completed`, and the egress `lost`) are `SeqCst`; relaxed loads
//!   for display are fine, relaxed writes are a lint error.
//! * **Queue close protocol.**  [`BoundedQueue::close`] flips `closed`
//!   under the lock and `notify_all`s; producers then fail fast,
//!   consumers drain the backlog and only then see `None`.  A timed-out
//!   `pop_timeout` re-checks the queue under the reacquired lock, so an
//!   item racing the timeout is delivered, not stranded.
//! * **Lock discipline.**  Every sync primitive enters through
//!   [`crate::util::sync`] (the model checker's instrumentation point),
//!   and locks are acquired with
//!   [`lock_or_recover`](crate::util::sync::lock_or_recover): a
//!   panicking worker is *reported* — it must never cascade poisoning
//!   into the drain/close/Drop paths other threads need for shutdown.
//!   No lock is held across an engine call or a channel send; condvar
//!   waits re-check their predicate in a loop (spurious wakeups are
//!   routine, and the model checker injects them deliberately).
//! * **Shutdown linearizability.**  `shutdown` stores `closed`
//!   (SeqCst), waits for every shard to settle (queue empty or workers
//!   gone), closes the queues, joins the workers.  A `Session` dropped
//!   without `shutdown` still stops admission and closes every queue —
//!   workers drain and exit detached; `Drop` never blocks.
//! * **Egress shedding.**  The completion channel is bounded;
//!   `try_send` sheds on overflow and counts `lost` — a worker never
//!   blocks on a slow consumer, and `sent == delivered + lost`.
//!
//! ## Buffer recycling: the zero-allocation steady state
//!
//! The hot path (`submit` → batch → forward → completion) recycles
//! every buffer it touches, so a warm session serves without heap
//! traffic.  The lifecycle, stage by stage:
//!
//! * **Request features.**  Submitters draw `Vec<f32>` buffers from the
//!   session's feature pool ([`Session::recycled_features`]) instead of
//!   allocating; after a worker packs a batch it clears each served
//!   request's `features` and parks it back in the pool — *before*
//!   sending the completion, so a submit → recv → submit ping-pong
//!   always finds its previous buffer waiting.  Rejected submits
//!   re-enter the pool via [`Session::recycle_features`].  The pool is
//!   bounded (aggregate queue capacity, capped), counts hits/misses
//!   ([`crate::util::pool::BufferPool`]), and surfaces both in
//!   [`Session::snapshot`] and the metrics-endpoint grammar
//!   (`pool_hits` / `pool_misses` / `pool_occupancy`): in steady state
//!   misses plateau while hits climb.
//! * **Batch packing.**  Each worker owns one packing buffer, refilled
//!   by [`Batch::pack_features_into`] (capacity retained), and one
//!   [`crate::nn::PackedOut`] the runner fills via
//!   [`server::BatchRunner::run_into`] — no per-batch `Vec<Vec<f32>>`.
//! * **Engine scratch.**  The engines keep per-worker scratch
//!   (activations, gate buffers, packed transposes) in bounded pools
//!   (`FloatEngine::scratch_stats`, `FixedEngine::scratch_stats`);
//!   after warm-up every `forward_packed_into` is a pool hit.
//! * **Completion outputs.**  One shared `Arc<[f32]>` per *batch*
//!   backs every completion's [`session::Output`] (a window, not a
//!   copy) — the single remaining steady-state allocation on the path,
//!   one per batch rather than one per request, and built only when a
//!   completion channel is attached.  The copy to an owned `Vec<f32>`
//!   happens only at serialization boundaries (the wire frame).
//!
//! `tests/kernel_equivalence.rs` pins the contract: after warm-up the
//! feature-pool and scratch-pool miss counters stop moving.

pub mod batcher;
pub mod clock;
pub mod metrics;
pub mod net;
pub mod queue;
pub mod server;
pub mod session;
pub mod sharded;
pub mod source;
pub mod tier;

pub use batcher::{Batch, BatcherConfig};
pub use clock::{Clock, SystemClock, VirtualClock};
pub use metrics::{LatencyHistogram, ServerMetrics};
pub use net::{NetConfig, NetReport, NetServer};
pub use queue::BoundedQueue;
pub use server::{
    worker_loop, BatchRunner, EngineRunner, Server, ServerConfig,
    ServerReport,
};
pub use session::{
    BackendKind, Completion, ListenerSpec, Output, ServingPlan,
    ServingSpec, Session, SessionHandle, SubmitError,
};
pub use sharded::{
    BackendTierStats, Router, ShardPolicy, ShardStats, ShardedConfig,
    ShardedReport, ShardedServer,
};
pub use source::SourceConfig;
pub use tier::{TierBatch, TierClass, TierMix, TierPolicy};

use std::time::Instant;

/// One inference request in flight.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Flat `[seq_len * input_size]` features.
    pub features: Vec<f32>,
    /// Ground-truth label carried through for online accuracy accounting.
    pub label: u32,
    /// Traffic-class key — [`ShardPolicy::ModelKey`] partitions the
    /// stream on `route_key % shards`.  Sources stamp it from the
    /// session's [`TierMix`] (a pure `(seed, id)` hash), so in a
    /// heterogeneous session the key names the tier/backend a request
    /// wants and each shard owns one backend.  The single-class mix
    /// stamps every request `0` (homogeneous sessions).
    pub route_key: u64,
    pub enqueued_at: Instant,
}
