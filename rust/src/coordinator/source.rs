//! Event source: generates benchmark events at a configured arrival rate
//! (Poisson or fixed-interval), pushing into the bounded queue; overflow
//! is dropped and counted — trigger semantics.
//!
//! This is the *replay* producer: [`run_with`] backs
//! [`Session::replay`](super::session::Session::replay), which the
//! `Server::run` / `ShardedServer::run` wrappers drive to completion.
//! Live deployments submit through the session API instead; the replay
//! contract below (generation is sink-independent) is what makes the
//! submit-vs-replay equivalence suite (`tests/session_api.rs`) exact.

use std::time::{Duration, Instant};

use crate::data::generators::Generator;
use crate::util::rng::Rng;

use super::clock::Clock;
use super::tier::TierMix;
use super::Request;

#[derive(Debug, Clone, Copy)]
pub struct SourceConfig {
    /// Mean arrival rate in events/second.
    pub rate_hz: f64,
    /// Poisson arrivals (exponential gaps) vs fixed interval.
    pub poisson: bool,
    /// Total events to emit.
    pub n_events: usize,
}

impl Default for SourceConfig {
    fn default() -> Self {
        Self {
            rate_hz: 20_000.0,
            poisson: true,
            n_events: 50_000,
        }
    }
}

/// Run the source to completion on the current thread, handing each paced
/// request to `sink` (which owns admission: queue push, drop counting,
/// shard routing).  The generation order, ids, and arrival pacing depend
/// only on `(generator, cfg, seed)` — never on the sink — so the same
/// seed replays the identical request stream into any topology; this is
/// what makes the 1-shard vs N-shard equivalence suite meaningful.
///
/// `tiers` is the traffic-class layer: each request's
/// [`Request::route_key`] is stamped with `tiers.stamp(id)` — the tier
/// (trigger / offline / …) the request belongs to, which
/// [`super::ShardPolicy::ModelKey`] then routes to the matching backend
/// shard.  Stamping is a pure hash of `(tier seed, id)`, so it neither
/// consumes from the pacing RNG nor couples requests: the stream replay
/// contract above extends to every tier sub-stream ([`TierMix::single`]
/// reproduces the old all-zero keys bit for bit).
///
/// `clock` stamps each request's `enqueued_at` (the anchor of every
/// latency percentile) so virtual-clock sessions stay on one timeline;
/// arrival *pacing* is always real time — a virtual clock must never be
/// able to stall the detector.
///
/// Returns the number of generated events.
pub fn run_with<F>(
    mut generator: Box<dyn Generator>,
    cfg: SourceConfig,
    seed: u64,
    tiers: &TierMix,
    clock: &dyn Clock,
    mut sink: F,
) -> usize
where
    F: FnMut(Request),
{
    let mut rng = Rng::new(seed);
    let interval = Duration::from_secs_f64(1.0 / cfg.rate_hz.max(1e-9));
    let start = Instant::now();
    let mut next_emit = start;
    for id in 0..cfg.n_events {
        // Pace: spin/sleep until the scheduled arrival instant.
        let now = Instant::now();
        if next_emit > now {
            let wait = next_emit - now;
            if wait > Duration::from_micros(200) {
                std::thread::sleep(wait - Duration::from_micros(100));
            }
            while Instant::now() < next_emit {
                std::hint::spin_loop();
            }
        }
        let gap = if cfg.poisson {
            Duration::from_secs_f64(rng.exponential(interval.as_secs_f64()))
        } else {
            interval
        };
        next_emit += gap;

        let event = generator.generate();
        sink(Request {
            id: id as u64,
            features: event.features,
            label: event.label,
            route_key: tiers.stamp(id as u64),
            enqueued_at: clock.now(),
        });
    }
    cfg.n_events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::clock::SystemClock;
    use crate::coordinator::metrics::ServerMetrics;
    use crate::coordinator::queue::BoundedQueue;
    use crate::data::generators::TopTagging;
    use std::sync::atomic::Ordering;
    use std::sync::Arc;

    /// The single-queue admission sink the serving session applies on
    /// every submit (count generated, push, count overflow as a drop) —
    /// spelled out here so the source tests exercise the same trigger
    /// semantics without depending on the session layer.
    fn admit<'a>(
        queue: &'a Arc<BoundedQueue<Request>>,
        metrics: &'a Arc<ServerMetrics>,
    ) -> impl FnMut(Request) + 'a {
        move |request| {
            metrics.generated.fetch_add(1, Ordering::SeqCst);
            if queue.push(request).is_err() {
                metrics.dropped.fetch_add(1, Ordering::SeqCst);
            }
        }
    }

    #[test]
    fn source_emits_all_events_and_paces() {
        let queue = Arc::new(BoundedQueue::new(100_000));
        let metrics = Arc::new(ServerMetrics::new());
        let cfg = SourceConfig {
            rate_hz: 50_000.0,
            poisson: false,
            n_events: 500,
        };
        let t0 = Instant::now();
        let n = run_with(
            Box::new(TopTagging::new(1)),
            cfg,
            2,
            &TierMix::single(),
            &SystemClock,
            admit(&queue, &metrics),
        );
        let elapsed = t0.elapsed();
        assert_eq!(n, 500);
        assert_eq!(metrics.generated.load(Ordering::Relaxed), 500);
        assert_eq!(queue.len(), 500);
        // 500 events at 50 kHz ≈ 10 ms; generation cost may stretch it.
        assert!(elapsed >= Duration::from_millis(9), "{elapsed:?}");
    }

    /// The stream replay contract behind the shard-equivalence suite:
    /// generation is a pure function of (generator seed, cfg, source
    /// seed), independent of what the sink does with each request.
    #[test]
    fn run_with_replays_identical_streams() {
        let cfg = SourceConfig {
            rate_hz: 1e9,
            poisson: true,
            n_events: 64,
        };
        let collect = |drop_odd: bool| {
            let mut got: Vec<(u64, Vec<f32>, u32)> = Vec::new();
            let tiers = TierMix::single();
            run_with(
                Box::new(TopTagging::new(9)),
                cfg,
                77,
                &tiers,
                &SystemClock,
                |r| {
                    if !(drop_odd && r.id % 2 == 1) {
                        got.push((r.id, r.features, r.label));
                    }
                },
            );
            got
        };
        let all = collect(false);
        let evens = collect(true);
        assert_eq!(all.len(), 64);
        assert_eq!(evens.len(), 32);
        for (i, kept) in evens.iter().enumerate() {
            assert_eq!(kept, &all[i * 2], "sink behavior leaked into stream");
        }
    }

    /// The traffic-class layer: route keys come from the tier mix's pure
    /// `(seed, id)` hash — per-id reproducible, all tiers represented,
    /// and never perturbing the generated stream.
    #[test]
    fn tier_mix_stamps_route_keys_deterministically() {
        let cfg = SourceConfig {
            rate_hz: 1e9,
            poisson: false,
            n_events: 256,
        };
        let mix = TierMix::new(&[0.75, 0.25], 9).unwrap();
        let mut keys = Vec::new();
        run_with(
            Box::new(TopTagging::new(1)),
            cfg,
            5,
            &mix,
            &SystemClock,
            |r| {
                keys.push((r.id, r.route_key));
            },
        );
        assert_eq!(keys.len(), 256);
        assert!(keys.iter().all(|&(_, k)| k < 2));
        assert!(keys.iter().any(|&(_, k)| k == 0));
        assert!(keys.iter().any(|&(_, k)| k == 1));
        for &(id, key) in &keys {
            assert_eq!(key, mix.stamp(id), "id {id}");
        }
    }

    #[test]
    fn overflow_counts_drops() {
        let queue = Arc::new(BoundedQueue::new(10));
        let metrics = Arc::new(ServerMetrics::new());
        let cfg = SourceConfig {
            rate_hz: 1e9, // as fast as possible
            poisson: false,
            n_events: 100,
        };
        run_with(
            Box::new(TopTagging::new(3)),
            cfg,
            4,
            &TierMix::single(),
            &SystemClock,
            admit(&queue, &metrics),
        );
        assert_eq!(metrics.generated.load(Ordering::Relaxed), 100);
        assert_eq!(metrics.dropped.load(Ordering::Relaxed), 90);
        assert_eq!(queue.len(), 10);
    }
}
