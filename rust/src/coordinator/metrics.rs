//! Lock-free serving metrics: counters + a log-bucketed latency
//! histogram (atomics only on the hot path).
//!
//! Time enters this module only as caller-supplied [`Instant`]s (a
//! batch's `formed_at`, the completion instant from the serving
//! [`Clock`](super::clock::Clock)) — never via `Instant::now()` — so a
//! virtual clock drives every recorded latency deterministically and
//! percentiles can be asserted against hand-computed values
//! (`tests/tier_batching.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::batcher::Batch;

/// Log-spaced latency histogram: [`Self::N_BOUNDS`] bucket bounds at 1 µs
/// × 1.5ᵏ (so the top bound is ≈ 1.5³⁹ µs ≈ 7.4 s), plus one overflow
/// bucket — `N_BOUNDS + 1` buckets total.  Latencies below 1 µs land in
/// the first bucket, above the top bound in the overflow bucket.
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    bounds_us: Vec<f64>,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    /// Number of finite bucket bounds (one extra bucket holds overflow).
    pub const N_BOUNDS: usize = 40;

    pub fn new() -> Self {
        let mut bounds_us = Vec::new();
        let mut b = 1.0f64;
        while bounds_us.len() < Self::N_BOUNDS {
            bounds_us.push(b);
            b *= 1.5;
        }
        let buckets = (0..=bounds_us.len()).map(|_| AtomicU64::new(0)).collect();
        Self { buckets, bounds_us }
    }

    /// Merge `other` into `self`, bucket-wise.  Both histograms share the
    /// fixed bucket layout, so the merged quantiles are exactly what a
    /// single histogram would have recorded — this is the cross-shard
    /// metrics roll-up primitive.
    pub fn merge(&self, other: &LatencyHistogram) {
        debug_assert_eq!(self.buckets.len(), other.buckets.len());
        for (mine, theirs) in self.buckets.iter().zip(&other.buckets) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn record(&self, latency: Duration) {
        let us = latency.as_secs_f64() * 1e6;
        let idx = self
            .bounds_us
            .iter()
            .position(|&b| us < b)
            .unwrap_or(self.bounds_us.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Approximate quantile (upper bucket bound), `q ∈ [0, 1]`.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return if i < self.bounds_us.len() {
                    self.bounds_us[i]
                } else {
                    self.bounds_us[self.bounds_us.len() - 1] * 1.5
                };
            }
        }
        self.bounds_us[self.bounds_us.len() - 1]
    }
}

/// All counters for one server run.
#[derive(Default)]
pub struct ServerMetrics {
    pub generated: AtomicU64,
    pub dropped: AtomicU64,
    pub completed: AtomicU64,
    pub correct: AtomicU64,
    pub batches: AtomicU64,
    pub batch_samples: AtomicU64,
    pub queue_latency: LatencyHistogram,
    pub total_latency: LatencyHistogram,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self {
            queue_latency: LatencyHistogram::new(),
            total_latency: LatencyHistogram::new(),
            ..Default::default()
        }
    }

    #[inline]
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    /// Merge `other` into `self`: counters are summed and the latency
    /// histograms merged bucket-wise.  Used by the sharded coordinator to
    /// roll per-shard metrics up into one report.
    pub fn merge(&self, other: &ServerMetrics) {
        for (mine, theirs) in [
            (&self.generated, &other.generated),
            (&self.dropped, &other.dropped),
            (&self.completed, &other.completed),
            (&self.correct, &other.correct),
            (&self.batches, &other.batches),
            (&self.batch_samples, &other.batch_samples),
        ] {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.queue_latency.merge(&other.queue_latency);
        self.total_latency.merge(&other.total_latency);
    }

    /// Record one completed batch: per-request queue latency
    /// (`formed_at - enqueued_at`), total latency (`done - enqueued_at`),
    /// batch counters, completion and accuracy counts.  `done` is the
    /// completion instant on the *serving clock* — the worker loop passes
    /// `clock.now()`, so under a `VirtualClock` every recorded latency is
    /// an exact, hand-computable value.  Subtractions saturate at zero so
    /// a mis-driven virtual timeline degrades to a 0 µs sample instead of
    /// panicking.
    pub fn observe_batch(
        &self,
        batch: &Batch,
        outputs: &[Vec<f32>],
        done: Instant,
    ) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_samples
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        for (r, probs) in batch.requests.iter().zip(outputs) {
            self.observe_row(r, probs, batch.formed_at, done);
        }
    }

    /// [`Self::observe_batch`] over a packed output buffer — the worker
    /// loop's allocation-free form.  Row semantics (and every recorded
    /// value) are identical; only the output layout differs.
    pub fn observe_batch_packed(
        &self,
        batch: &Batch,
        outputs: &crate::nn::PackedOut,
        done: Instant,
    ) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_samples
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        for (r, probs) in batch.requests.iter().zip(outputs.iter_rows()) {
            self.observe_row(r, probs, batch.formed_at, done);
        }
    }

    /// One request's completion record: queue latency
    /// (`formed_at - enqueued_at`), total latency (`done - enqueued_at`),
    /// completion and accuracy counts.
    #[inline]
    fn observe_row(
        &self,
        r: &super::Request,
        probs: &[f32],
        formed_at: Instant,
        done: Instant,
    ) {
        self.queue_latency
            .record(formed_at.saturating_duration_since(r.enqueued_at));
        self.total_latency
            .record(done.saturating_duration_since(r.enqueued_at));
        // SeqCst: `completed` is one leg of the cross-thread
        // accounting identity (generated == completed + dropped)
        // that shutdown and the model checker assert.
        self.completed.fetch_add(1, Ordering::SeqCst);
        if super::server::predicted_label(probs) == r.label {
            self.correct.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let batches = self.batches.load(Ordering::Relaxed);
        if batches == 0 {
            return 0.0;
        }
        self.batch_samples.load(Ordering::Relaxed) as f64 / batches as f64
    }

    pub fn drop_fraction(&self) -> f64 {
        let gen = self.generated.load(Ordering::Relaxed);
        if gen == 0 {
            return 0.0;
        }
        self.dropped.load(Ordering::Relaxed) as f64 / gen as f64
    }

    pub fn accuracy(&self) -> f64 {
        let done = self.completed.load(Ordering::Relaxed);
        if done == 0 {
            return 0.0;
        }
        self.correct.load(Ordering::Relaxed) as f64 / done as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_ordered() {
        let h = LatencyHistogram::new();
        for us in [10u64, 20, 30, 50, 100, 200, 500, 1000, 5000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 9);
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99, "p50 {p50} p99 {p99}");
        assert!(p50 >= 30.0 && p50 <= 200.0, "p50 {p50}");
        assert!(p99 >= 1000.0, "p99 {p99}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.5), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn extreme_latencies_clamp_to_edge_buckets() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1)); // below first bound
        h.record(Duration::from_secs(3600)); // above last bound
        assert_eq!(h.count(), 2);
    }

    /// The roll-up contract: merging two histograms is equivalent to
    /// recording every sample into one histogram (same fixed buckets).
    #[test]
    fn histogram_merge_is_bucketwise_sum() {
        let a = LatencyHistogram::new();
        let b = LatencyHistogram::new();
        let combined = LatencyHistogram::new();
        for us in [5u64, 50, 500] {
            a.record(Duration::from_micros(us));
            combined.record(Duration::from_micros(us));
        }
        for us in [10u64, 100, 1000, 10_000] {
            b.record(Duration::from_micros(us));
            combined.record(Duration::from_micros(us));
        }
        let merged = LatencyHistogram::new();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.count(), 7);
        assert_eq!(merged.count(), combined.count());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(
                merged.quantile_us(q),
                combined.quantile_us(q),
                "quantile {q} differs from single-histogram recording"
            );
        }
        // Merging an empty histogram is a no-op.
        merged.merge(&LatencyHistogram::new());
        assert_eq!(merged.count(), 7);
    }

    #[test]
    fn server_metrics_merge_sums_counters_and_histograms() {
        let a = ServerMetrics::new();
        a.generated.store(60, Ordering::SeqCst);
        a.dropped.store(10, Ordering::SeqCst);
        a.completed.store(50, Ordering::SeqCst);
        a.correct.store(40, Ordering::Relaxed);
        a.batches.store(5, Ordering::Relaxed);
        a.batch_samples.store(50, Ordering::Relaxed);
        a.total_latency.record(Duration::from_micros(100));
        let b = ServerMetrics::new();
        b.generated.store(40, Ordering::SeqCst);
        b.dropped.store(0, Ordering::SeqCst);
        b.completed.store(40, Ordering::SeqCst);
        b.correct.store(20, Ordering::Relaxed);
        b.batches.store(5, Ordering::Relaxed);
        b.batch_samples.store(40, Ordering::Relaxed);
        b.queue_latency.record(Duration::from_micros(20));

        let total = ServerMetrics::new();
        total.merge(&a);
        total.merge(&b);
        assert_eq!(total.generated.load(Ordering::Relaxed), 100);
        assert_eq!(total.dropped.load(Ordering::Relaxed), 10);
        assert_eq!(total.completed.load(Ordering::Relaxed), 90);
        assert_eq!(total.correct.load(Ordering::Relaxed), 60);
        assert!((total.mean_batch_size() - 9.0).abs() < 1e-12);
        assert!((total.accuracy() - 60.0 / 90.0).abs() < 1e-12);
        assert_eq!(total.total_latency.count(), 1);
        assert_eq!(total.queue_latency.count(), 1);
    }

    /// `observe_batch` records exactly the caller-supplied instants: a
    /// batch formed 20 µs after enqueue and completed 100 µs after it
    /// must land in the 20 µs / 100 µs buckets — no hidden `now()`.
    #[test]
    fn observe_batch_uses_supplied_instants_only() {
        use crate::coordinator::batcher::Batch;
        use crate::coordinator::Request;

        let t0 = std::time::Instant::now();
        let m = ServerMetrics::new();
        let batch = Batch {
            requests: vec![
                Request {
                    id: 0,
                    features: vec![0.0; 2],
                    label: 1,
                    route_key: 0,
                    enqueued_at: t0,
                },
                Request {
                    id: 1,
                    features: vec![0.0; 2],
                    label: 0,
                    route_key: 0,
                    enqueued_at: t0 + Duration::from_micros(10),
                },
            ],
            formed_at: t0 + Duration::from_micros(30),
        };
        // Outputs: request 0 predicted 1 (correct), request 1 predicted
        // 1 (wrong) -> accuracy 1/2.
        let outputs = vec![vec![0.9f32], vec![0.9f32]];
        let done = t0 + Duration::from_micros(100);
        m.observe_batch(&batch, &outputs, done);
        assert_eq!(m.completed.load(Ordering::Relaxed), 2);
        assert_eq!(m.batches.load(Ordering::Relaxed), 1);
        assert_eq!(m.batch_samples.load(Ordering::Relaxed), 2);
        assert!((m.accuracy() - 0.5).abs() < 1e-12);
        assert_eq!(m.total_latency.count(), 2);
        assert_eq!(m.queue_latency.count(), 2);
        // Hand-computed buckets: total latencies are 100 µs and 90 µs,
        // both inside (86.49, 129.7] -> p50 == p99 == 1.5^12 µs.
        let bound_12 = 1.5f64.powi(12);
        assert_eq!(m.total_latency.quantile_us(0.5), bound_12);
        assert_eq!(m.total_latency.quantile_us(0.99), bound_12);
    }

    #[test]
    fn metrics_ratios() {
        let m = ServerMetrics::new();
        m.generated.store(100, Ordering::SeqCst);
        m.dropped.store(25, Ordering::SeqCst);
        m.completed.store(75, Ordering::SeqCst);
        m.correct.store(60, Ordering::Relaxed);
        m.batches.store(15, Ordering::Relaxed);
        m.batch_samples.store(75, Ordering::Relaxed);
        assert!((m.drop_fraction() - 0.25).abs() < 1e-12);
        assert!((m.accuracy() - 0.8).abs() < 1e-12);
        assert!((m.mean_batch_size() - 5.0).abs() < 1e-12);
    }
}
