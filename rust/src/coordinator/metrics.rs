//! Lock-free serving metrics: counters + a log-bucketed latency
//! histogram (atomics only on the hot path).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-spaced latency histogram from 1 µs to ~17 s (64 buckets, ×1.5).
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    bounds_us: Vec<f64>,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        let mut bounds_us = Vec::new();
        let mut b = 1.0f64;
        while bounds_us.len() < 40 {
            bounds_us.push(b);
            b *= 1.5;
        }
        let buckets = (0..=bounds_us.len()).map(|_| AtomicU64::new(0)).collect();
        Self { buckets, bounds_us }
    }

    #[inline]
    pub fn record(&self, latency: Duration) {
        let us = latency.as_secs_f64() * 1e6;
        let idx = self
            .bounds_us
            .iter()
            .position(|&b| us < b)
            .unwrap_or(self.bounds_us.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Approximate quantile (upper bucket bound), `q ∈ [0, 1]`.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return if i < self.bounds_us.len() {
                    self.bounds_us[i]
                } else {
                    self.bounds_us[self.bounds_us.len() - 1] * 1.5
                };
            }
        }
        self.bounds_us[self.bounds_us.len() - 1]
    }
}

/// All counters for one server run.
#[derive(Default)]
pub struct ServerMetrics {
    pub generated: AtomicU64,
    pub dropped: AtomicU64,
    pub completed: AtomicU64,
    pub correct: AtomicU64,
    pub batches: AtomicU64,
    pub batch_samples: AtomicU64,
    pub queue_latency: LatencyHistogram,
    pub total_latency: LatencyHistogram,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self {
            queue_latency: LatencyHistogram::new(),
            total_latency: LatencyHistogram::new(),
            ..Default::default()
        }
    }

    #[inline]
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn mean_batch_size(&self) -> f64 {
        let batches = self.batches.load(Ordering::Relaxed);
        if batches == 0 {
            return 0.0;
        }
        self.batch_samples.load(Ordering::Relaxed) as f64 / batches as f64
    }

    pub fn drop_fraction(&self) -> f64 {
        let gen = self.generated.load(Ordering::Relaxed);
        if gen == 0 {
            return 0.0;
        }
        self.dropped.load(Ordering::Relaxed) as f64 / gen as f64
    }

    pub fn accuracy(&self) -> f64 {
        let done = self.completed.load(Ordering::Relaxed);
        if done == 0 {
            return 0.0;
        }
        self.correct.load(Ordering::Relaxed) as f64 / done as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_ordered() {
        let h = LatencyHistogram::new();
        for us in [10u64, 20, 30, 50, 100, 200, 500, 1000, 5000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 9);
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99, "p50 {p50} p99 {p99}");
        assert!(p50 >= 30.0 && p50 <= 200.0, "p50 {p50}");
        assert!(p99 >= 1000.0, "p99 {p99}");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.5), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn extreme_latencies_clamp_to_edge_buckets() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_nanos(1)); // below first bound
        h.record(Duration::from_secs(3600)); // above last bound
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn metrics_ratios() {
        let m = ServerMetrics::new();
        m.generated.store(100, Ordering::Relaxed);
        m.dropped.store(25, Ordering::Relaxed);
        m.completed.store(75, Ordering::Relaxed);
        m.correct.store(60, Ordering::Relaxed);
        m.batches.store(15, Ordering::Relaxed);
        m.batch_samples.store(75, Ordering::Relaxed);
        assert!((m.drop_fraction() - 0.25).abs() < 1e-12);
        assert!((m.accuracy() - 0.8).abs() < 1e-12);
        assert!((m.mean_batch_size() - 5.0).abs() < 1e-12);
    }
}
