//! Sharded multi-coordinator serving: N independent coordinator shards
//! behind a routing layer, with a shared metrics roll-up.
//!
//! ```text
//!                      ┌► shard 0: queue ─ batcher ─ workers ─ metrics ┐
//! EventSource ─ Router ┼► shard 1: queue ─ batcher ─ workers ─ metrics ┼─► roll-up
//!                      └► shard N: queue ─ batcher ─ workers ─ metrics ┘
//! ```
//!
//! One [`Server`](super::Server) owns one queue, one batcher deadline
//! clock, one metrics block, and one shutdown signal; past a few workers
//! every pull contends on that single queue lock.  Sharding converts each
//! of those single-owner assumptions into a per-shard one — the software
//! analog of the parallel-IO duplication used to scale sub-microsecond
//! trigger designs: replicate the whole pipeline, split the input stream,
//! and merge only the monitoring.
//!
//! Design notes:
//!
//! * **Routing** happens at admission, on the source thread.  Policies are
//!   deliberately cheap and deterministic (no load feedback): a trigger
//!   router cannot afford to inspect downstream state per event.
//! * **Isolation**: a shard's queue, deadline clock, and metrics are
//!   private to it, so shards never contend on locks; the only shared
//!   state is the roll-up, which runs once after shutdown.
//! * **Equivalence**: with `shards = 1` every policy routes to shard 0 and
//!   the pipeline is exactly [`Server::run`](super::Server::run) — same
//!   source seed, same worker loop, same drain-then-close shutdown.  The
//!   shard-equivalence suite (`tests/shard_equivalence.rs`) asserts the
//!   per-request outputs and merged totals match.
//! * **Shutdown** is coordinated: the source finishes, then each shard is
//!   allowed to drain (or declared dead if all its workers exited), then
//!   all queues close together and every worker is joined.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::data::generators::Generator;

use super::metrics::ServerMetrics;
use super::queue::BoundedQueue;
use super::server::{worker_loop, BatchRunner, ServerConfig, ServerReport};
use super::source;
use super::Request;

/// How the router assigns an incoming request to a shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// splitmix64 hash of the request id: stateless, uniform in
    /// expectation, and sticky (the same id always lands on the same
    /// shard — what a keyed production router gives you).
    HashId,
    /// Strict rotation over shards: perfectly balanced for a steady
    /// stream, at the cost of carrying one counter of router state.
    RoundRobin,
    /// Route on [`Request::route_key`] (`key % shards`): the multi-backend
    /// seam.  When one session mixes engines (fixed-point trigger tier +
    /// float offline tier), the key names the backend and each shard owns
    /// one engine kind.  Sources emit key 0 today, so this degenerates to
    /// shard 0 until the multi-backend item lands.
    ModelKey,
}

impl ShardPolicy {
    /// Parse a CLI spelling (`hash | round-robin | model-key`).
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        match name {
            "hash" => Ok(Self::HashId),
            "round-robin" | "rr" => Ok(Self::RoundRobin),
            "model-key" => Ok(Self::ModelKey),
            other => anyhow::bail!(
                "unknown shard policy {other:?} (hash|round-robin|model-key)"
            ),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::HashId => "hash",
            Self::RoundRobin => "round-robin",
            Self::ModelKey => "model-key",
        }
    }
}

/// One splitmix64 step from `state = id` — the same mix `util::rng` seeds
/// with; enough to decorrelate sequential ids across shards.
fn hash_id(id: u64) -> u64 {
    let mut state = id;
    crate::util::rng::splitmix64(&mut state)
}

/// The routing layer in front of the shard queues.  Runs on the source
/// thread (single-threaded), so round-robin state is a plain counter.
pub struct Router {
    policy: ShardPolicy,
    shards: usize,
    rr_next: u64,
}

impl Router {
    pub fn new(policy: ShardPolicy, shards: usize) -> Self {
        assert!(shards >= 1, "router needs at least one shard");
        Self {
            policy,
            shards,
            rr_next: 0,
        }
    }

    /// Shard index for `request`, in `0..shards`.
    pub fn route(&mut self, request: &Request) -> usize {
        match self.policy {
            ShardPolicy::HashId => {
                (hash_id(request.id) % self.shards as u64) as usize
            }
            ShardPolicy::RoundRobin => {
                let shard = (self.rr_next % self.shards as u64) as usize;
                self.rr_next += 1;
                shard
            }
            ShardPolicy::ModelKey => {
                (request.route_key % self.shards as u64) as usize
            }
        }
    }
}

/// Sharded serving session configuration.  `server` holds the *per-shard*
/// knobs (`workers`, `queue_capacity`, `batcher`) plus the shared source;
/// total engine threads are `shards × server.workers`.
#[derive(Debug, Clone, Copy)]
pub struct ShardedConfig {
    pub shards: usize,
    pub policy: ShardPolicy,
    pub server: ServerConfig,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            policy: ShardPolicy::HashId,
            server: ServerConfig::default(),
        }
    }
}

/// Per-shard slice of the final report (from that shard's own metrics).
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub shard: usize,
    /// Events the router admitted to this shard (its `generated` count).
    pub routed: u64,
    pub dropped: u64,
    pub completed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p99_latency_us: f64,
}

/// Roll-up of one sharded run: the merged cross-shard report (counters
/// summed, histogram buckets merged bucket-wise — so merged percentiles
/// are exact, not averages of percentiles) plus the per-shard breakdown.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    pub shards: usize,
    pub policy: ShardPolicy,
    pub merged: ServerReport,
    pub per_shard: Vec<ShardStats>,
}

impl ShardedReport {
    pub fn render(&self) -> String {
        let mut out = self.merged.render();
        if self.shards > 1 {
            out.push_str(&format!(
                "\nshards             {} ({} routing)",
                self.shards,
                self.policy.name()
            ));
            for s in &self.per_shard {
                out.push_str(&format!(
                    "\n  shard {}: routed {} dropped {} completed {} \
                     mean batch {:.2} p99 {:.1} µs",
                    s.shard,
                    s.routed,
                    s.dropped,
                    s.completed,
                    s.mean_batch,
                    s.p99_latency_us,
                ));
            }
        }
        out
    }
}

pub struct ShardedServer;

impl ShardedServer {
    /// Run one sharded serving session to completion.
    ///
    /// `runner_factory` is invoked once per worker, *inside* that worker's
    /// thread (non-`Send` engines stay legal), and receives the worker's
    /// shard index — the hook where a multi-backend deployment hands each
    /// shard a different engine.
    pub fn run<F>(
        cfg: ShardedConfig,
        generator: Box<dyn Generator>,
        runner_factory: F,
    ) -> anyhow::Result<ShardedReport>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn BatchRunner>> + Send + Sync,
    {
        anyhow::ensure!(cfg.shards >= 1, "need at least one shard");
        anyhow::ensure!(
            cfg.server.workers >= 1,
            "need at least one worker per shard"
        );
        let queues: Vec<Arc<BoundedQueue<Request>>> = (0..cfg.shards)
            .map(|_| Arc::new(BoundedQueue::new(cfg.server.queue_capacity)))
            .collect();
        let metrics: Vec<Arc<ServerMetrics>> = (0..cfg.shards)
            .map(|_| Arc::new(ServerMetrics::new()))
            .collect();
        let t0 = Instant::now();

        // Same readiness gate as `Server::run`: the tap opens only after
        // every worker on every shard has built its engine.
        let total_workers = cfg.shards * cfg.server.workers;
        let ready = Arc::new(AtomicUsize::new(0));

        let run = std::thread::scope(|scope| -> anyhow::Result<()> {
            // handles[shard][worker]
            let mut handles = Vec::with_capacity(cfg.shards);
            for shard in 0..cfg.shards {
                let mut shard_handles = Vec::with_capacity(cfg.server.workers);
                for worker in 0..cfg.server.workers {
                    let queue = queues[shard].clone();
                    let shard_metrics = metrics[shard].clone();
                    let factory = &runner_factory;
                    let batcher_cfg = cfg.server.batcher;
                    let ready = ready.clone();
                    shard_handles.push(scope.spawn(
                        move || -> anyhow::Result<()> {
                            let runner_or = factory(shard).map_err(|e| {
                                anyhow::anyhow!(
                                    "shard {shard} worker {worker}: \
                                     engine init: {e}"
                                )
                            });
                            ready.fetch_add(1, Ordering::SeqCst);
                            let mut runner = runner_or?;
                            worker_loop(
                                runner.as_mut(),
                                &queue,
                                &shard_metrics,
                                &batcher_cfg,
                            )
                        },
                    ));
                }
                handles.push(shard_handles);
            }

            while ready.load(Ordering::SeqCst) < total_workers {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }

            // Source + router run on this thread.  Admission counts into
            // the *target shard's* metrics so the roll-up stays a pure
            // sum.  The source seed matches `Server::run`, so any shard
            // count replays the identical request stream.
            let mut router = Router::new(cfg.policy, cfg.shards);
            source::run_with(generator, cfg.server.source, 0xEE77, |request| {
                let shard = router.route(&request);
                metrics[shard].generated.fetch_add(1, Ordering::Relaxed);
                if queues[shard].push(request).is_err() {
                    metrics[shard].dropped.fetch_add(1, Ordering::Relaxed);
                }
            });

            // Coordinated shutdown: a shard is settled once its queue is
            // drained — or abandoned when all its workers have exited
            // (e.g. engine-init failure), so one dead shard cannot wedge
            // the rest.  Then close every queue and join every worker.
            let settled = |shard: usize| {
                queues[shard].is_empty()
                    || handles[shard].iter().all(|w| w.is_finished())
            };
            while !(0..cfg.shards).all(settled) {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
            for queue in &queues {
                queue.close();
            }
            for shard_handles in handles {
                for handle in shard_handles {
                    handle.join().expect("worker panicked")?;
                }
            }
            Ok(())
        });
        run?;
        let wall = t0.elapsed().as_secs_f64();

        // Shared roll-up: counters summed, histogram buckets merged.
        let merged = ServerMetrics::new();
        for shard_metrics in &metrics {
            merged.merge(shard_metrics);
        }
        let per_shard = metrics
            .iter()
            .enumerate()
            .map(|(shard, m)| ShardStats {
                shard,
                routed: m.generated.load(Ordering::Relaxed),
                dropped: m.dropped.load(Ordering::Relaxed),
                completed: m.completed.load(Ordering::Relaxed),
                batches: m.batches.load(Ordering::Relaxed),
                mean_batch: m.mean_batch_size(),
                p99_latency_us: m.total_latency.quantile_us(0.99),
            })
            .collect();
        Ok(ShardedReport {
            shards: cfg.shards,
            policy: cfg.policy,
            merged: ServerReport::from_metrics(&merged, wall),
            per_shard,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, SourceConfig};
    use crate::data::generators::TopTagging;
    use std::time::Duration;

    fn req(id: u64, route_key: u64) -> Request {
        Request {
            id,
            features: vec![0.0; 4],
            label: 0,
            route_key,
            enqueued_at: Instant::now(),
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for (text, want) in [
            ("hash", ShardPolicy::HashId),
            ("round-robin", ShardPolicy::RoundRobin),
            ("rr", ShardPolicy::RoundRobin),
            ("model-key", ShardPolicy::ModelKey),
        ] {
            assert_eq!(ShardPolicy::parse(text).unwrap(), want);
        }
        assert!(ShardPolicy::parse("nope").is_err());
        assert_eq!(ShardPolicy::parse("hash").unwrap().name(), "hash");
    }

    #[test]
    fn hash_routing_is_sticky_and_covers_shards() {
        let mut router = Router::new(ShardPolicy::HashId, 4);
        let mut seen = [false; 4];
        for id in 0..256 {
            let a = router.route(&req(id, 0));
            let b = router.route(&req(id, 0));
            assert_eq!(a, b, "hash routing must be sticky per id");
            assert!(a < 4);
            seen[a] = true;
        }
        assert!(seen.iter().all(|&s| s), "256 ids must hit all 4 shards");
    }

    #[test]
    fn round_robin_is_perfectly_balanced() {
        let mut router = Router::new(ShardPolicy::RoundRobin, 3);
        let mut counts = [0u32; 3];
        for id in 0..300 {
            counts[router.route(&req(id, 0))] += 1;
        }
        assert_eq!(counts, [100, 100, 100]);
    }

    #[test]
    fn model_key_routes_by_key_modulo_shards() {
        let mut router = Router::new(ShardPolicy::ModelKey, 4);
        for key in 0..16u64 {
            assert_eq!(router.route(&req(0, key)), (key % 4) as usize);
        }
    }

    #[test]
    fn every_policy_degenerates_to_shard_zero_with_one_shard() {
        for policy in [
            ShardPolicy::HashId,
            ShardPolicy::RoundRobin,
            ShardPolicy::ModelKey,
        ] {
            let mut router = Router::new(policy, 1);
            for id in 0..32 {
                assert_eq!(router.route(&req(id, id)), 0);
            }
        }
    }

    /// Mock runner mirroring the one in `server.rs` tests: output depends
    /// only on the input features.
    struct ConstRunner;
    impl BatchRunner for ConstRunner {
        fn max_batch(&self) -> usize {
            8
        }
        fn run(
            &mut self,
            xs: &[f32],
            n: usize,
        ) -> anyhow::Result<Vec<Vec<f32>>> {
            let stride = xs.len() / n.max(1);
            Ok((0..n)
                .map(|i| vec![if xs[i * stride] > 0.0 { 0.9 } else { 0.1 }])
                .collect())
        }
    }

    #[test]
    fn sharded_end_to_end_accounts_for_every_event() {
        for shards in [1usize, 3] {
            let cfg = ShardedConfig {
                shards,
                policy: ShardPolicy::RoundRobin,
                server: ServerConfig {
                    workers: 2,
                    queue_capacity: 8192,
                    batcher: BatcherConfig {
                        max_batch: 8,
                        max_wait: Duration::from_micros(100),
                    },
                    source: SourceConfig {
                        rate_hz: 300_000.0,
                        poisson: true,
                        n_events: 2000,
                    },
                },
            };
            let report =
                ShardedServer::run(cfg, Box::new(TopTagging::new(3)), |_| {
                    Ok(Box::new(ConstRunner))
                })
                .unwrap();
            assert_eq!(report.merged.generated, 2000, "shards={shards}");
            assert_eq!(
                report.merged.completed + report.merged.dropped,
                2000,
                "shards={shards}"
            );
            assert!(report.merged.completed > 0);
            assert_eq!(report.per_shard.len(), shards);
            let routed: u64 = report.per_shard.iter().map(|s| s.routed).sum();
            assert_eq!(routed, 2000);
            let completed: u64 =
                report.per_shard.iter().map(|s| s.completed).sum();
            assert_eq!(completed, report.merged.completed);
            if shards > 1 {
                // Round-robin: every shard sees ~1/shards of the stream.
                for s in &report.per_shard {
                    assert!(
                        s.routed > 0,
                        "shard {} starved under round-robin",
                        s.shard
                    );
                }
                assert!(report.render().contains("shard 1:"));
            }
        }
    }

    #[test]
    fn engine_init_failure_on_one_shard_propagates() {
        let cfg = ShardedConfig {
            shards: 2,
            policy: ShardPolicy::HashId,
            server: ServerConfig {
                source: SourceConfig {
                    rate_hz: 1e6,
                    poisson: false,
                    n_events: 50,
                },
                ..Default::default()
            },
        };
        let result =
            ShardedServer::run(cfg, Box::new(TopTagging::new(1)), |shard| {
                anyhow::ensure!(shard != 1, "shard 1 has no engine");
                Ok(Box::new(ConstRunner) as Box<dyn BatchRunner>)
            });
        let err = format!("{:#}", result.unwrap_err());
        assert!(err.contains("shard 1"), "error was: {err}");
    }

}
