//! Sharded multi-coordinator serving: N independent coordinator shards
//! behind a routing layer, with a shared metrics roll-up.  Shards may be
//! clones of one engine (horizontal scaling) or own *distinct backends*
//! (heterogeneous serving: fixed-point trigger tier + float offline
//! tier in one session), with the [`TierMix`] traffic classes steering
//! each request to its tier's shard via [`ShardPolicy::ModelKey`].
//!
//! ```text
//!                      ┌► shard 0: queue ─ batcher ─ workers ─ metrics ┐
//! EventSource ─ Router ┼► shard 1: queue ─ batcher ─ workers ─ metrics ┼─► roll-up
//!                      └► shard N: queue ─ batcher ─ workers ─ metrics ┘
//! ```
//!
//! One [`Server`](super::Server) owns one queue, one batcher deadline
//! clock, one metrics block, and one shutdown signal; past a few workers
//! every pull contends on that single queue lock.  Sharding converts each
//! of those single-owner assumptions into a per-shard one — the software
//! analog of the parallel-IO duplication used to scale sub-microsecond
//! trigger designs: replicate the whole pipeline, split the input stream,
//! and merge only the monitoring.
//!
//! Design notes:
//!
//! * **Routing** happens at admission, on the source thread.  Policies are
//!   deliberately cheap and deterministic (no load feedback): a trigger
//!   router cannot afford to inspect downstream state per event.
//! * **Isolation**: a shard's queue, deadline clock, and metrics are
//!   private to it, so shards never contend on locks; the only shared
//!   state is the roll-up, which runs once after shutdown.
//! * **Equivalence**: with `shards = 1` every policy routes to shard 0 and
//!   the pipeline is exactly [`Server::run`](super::Server::run) — same
//!   source seed, same worker loop, same drain-then-close shutdown.  The
//!   shard-equivalence suite (`tests/shard_equivalence.rs`) asserts the
//!   per-request outputs and merged totals match.
//! * **Shutdown** is coordinated: the source finishes, then each shard is
//!   allowed to drain (or declared dead if all its workers exited), then
//!   all queues close together and every worker is joined.
//! * **Per-backend metrics**: when shards are labelled with backends
//!   ([`ShardedConfig::shard_backends`]), the roll-up additionally merges
//!   metrics per label ([`BackendTierStats`]) so a heterogeneous report
//!   shows *per-tier* p50/p99 and throughput — a blended percentile over
//!   a 2 µs trigger tier and a 200 µs offline tier describes neither.

use std::str::FromStr;
use std::sync::Arc;

use crate::data::generators::Generator;

use super::batcher::BatcherConfig;
use super::clock::{Clock, SystemClock};
use super::server::{BatchRunner, ServerConfig, ServerReport};
use super::session::Session;
use super::tier::TierMix;
use super::Request;

/// How the router assigns an incoming request to a shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// splitmix64 hash of the request id: stateless, uniform in
    /// expectation, and sticky (the same id always lands on the same
    /// shard — what a keyed production router gives you).
    HashId,
    /// Strict rotation over shards: perfectly balanced for a steady
    /// stream, at the cost of carrying one counter of router state.
    RoundRobin,
    /// Route on [`Request::route_key`] (`key % shards`): the multi-backend
    /// policy.  Sources stamp the key from the session's [`TierMix`]
    /// (trigger-tier requests get the fixed shard's tier index, offline
    /// tier the float shard's, …), so each traffic class lands on the
    /// shard owning its backend.  Under the single-class mix every key is
    /// 0 and this degenerates to shard 0.
    ModelKey,
}

impl ShardPolicy {
    /// Parse a CLI spelling (`hash | round-robin | model-key`).
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        match name {
            "hash" => Ok(Self::HashId),
            "round-robin" | "rr" => Ok(Self::RoundRobin),
            "model-key" => Ok(Self::ModelKey),
            other => anyhow::bail!(
                "unknown shard policy {other:?} (hash|round-robin|model-key)"
            ),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::HashId => "hash",
            Self::RoundRobin => "round-robin",
            Self::ModelKey => "model-key",
        }
    }
}

impl FromStr for ShardPolicy {
    type Err = anyhow::Error;

    /// [`ShardPolicy::parse`] as `FromStr`, so the CLI reads policies
    /// with `.parse()` like every other typed `ServingSpec` field.
    fn from_str(name: &str) -> anyhow::Result<Self> {
        Self::parse(name)
    }
}

impl ShardPolicy {
    /// Stateless shard index for `request`, or `None` for the one
    /// policy that carries router state (round-robin).  Pure in the
    /// request, so concurrent submitters can route without a lock; the
    /// maths are identical to [`Router::route`] (which delegates here).
    pub fn route_stateless(
        self,
        request: &Request,
        shards: usize,
    ) -> Option<usize> {
        match self {
            Self::HashId => {
                Some((hash_id(request.id) % shards as u64) as usize)
            }
            Self::ModelKey => {
                Some((request.route_key % shards as u64) as usize)
            }
            Self::RoundRobin => None,
        }
    }
}

/// One splitmix64 step from `state = id` — the same mix `util::rng` seeds
/// with; enough to decorrelate sequential ids across shards.
fn hash_id(id: u64) -> u64 {
    let mut state = id;
    crate::util::rng::splitmix64(&mut state)
}

/// The routing layer in front of the shard queues.  Runs on the source
/// thread (single-threaded), so round-robin state is a plain counter.
pub struct Router {
    policy: ShardPolicy,
    shards: usize,
    rr_next: u64,
}

impl Router {
    pub fn new(policy: ShardPolicy, shards: usize) -> Self {
        assert!(shards >= 1, "router needs at least one shard");
        Self {
            policy,
            shards,
            rr_next: 0,
        }
    }

    /// Shard index for `request`, in `0..shards`.
    pub fn route(&mut self, request: &Request) -> usize {
        if let Some(shard) =
            self.policy.route_stateless(request, self.shards)
        {
            return shard;
        }
        // Round-robin: the one stateful policy.
        let shard = (self.rr_next % self.shards as u64) as usize;
        self.rr_next += 1;
        shard
    }
}

/// Sharded serving session configuration.  `server` holds the *per-shard*
/// knobs (`workers`, `queue_capacity`, `batcher`) plus the shared source;
/// total engine threads are `shards × server.workers`.
#[derive(Debug, Clone)]
pub struct ShardedConfig {
    pub shards: usize,
    pub policy: ShardPolicy,
    /// Traffic-class mix the source stamps onto [`Request::route_key`]
    /// (see [`TierMix`]).  Meaningful with [`ShardPolicy::ModelKey`],
    /// where tier `t` routes to shard `t % shards`; the default
    /// single-class mix keys every request 0 (the pre-tier behavior).
    pub tier_mix: TierMix,
    /// Backend label per shard for heterogeneous sessions (one entry per
    /// shard, e.g. `["fixed", "float"]`).  Labels drive the per-backend
    /// metrics roll-up ([`BackendTierStats`]); shards sharing a label are
    /// merged.  Empty = homogeneous session, no per-backend split.
    pub shard_backends: Vec<String>,
    /// Per-shard batching policy (tier-aware batching): entry *i* is
    /// shard *i*'s [`BatcherConfig`], letting a heterogeneous session
    /// pin its trigger tier at strict batch-1 (`max_wait = 0`) while the
    /// offline tier batches deep — both ends of the latency/throughput
    /// curve in one session.  Resolve from backend tiers with
    /// [`TierPolicy::for_backends`](super::tier::TierPolicy::for_backends)
    /// or spell it explicitly (CLI `--batch-policy`).  Empty = every
    /// shard uses `server.batcher` (the pre-tier behavior, bit for bit).
    pub shard_batchers: Vec<BatcherConfig>,
    pub server: ServerConfig,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        Self {
            shards: 1,
            policy: ShardPolicy::HashId,
            tier_mix: TierMix::single(),
            shard_backends: Vec::new(),
            shard_batchers: Vec::new(),
            server: ServerConfig::default(),
        }
    }
}

impl ShardedConfig {
    /// The batcher shard `shard` serves under: its `shard_batchers`
    /// entry, or the shared `server.batcher` when none is set.
    pub fn batcher_for(&self, shard: usize) -> BatcherConfig {
        self.shard_batchers
            .get(shard)
            .copied()
            .unwrap_or(self.server.batcher)
    }
}

/// Per-shard slice of the final report (from that shard's own metrics).
#[derive(Debug, Clone)]
pub struct ShardStats {
    pub shard: usize,
    /// Backend label this shard serves (empty in homogeneous sessions).
    pub backend: String,
    /// The batching policy this shard served under (tier-resolved).
    pub batcher: BatcherConfig,
    /// Events the router admitted to this shard (its `generated` count).
    pub routed: u64,
    pub dropped: u64,
    pub completed: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p99_latency_us: f64,
}

/// Per-backend slice of a heterogeneous run: the metrics of every shard
/// sharing one backend label, merged exactly (counters summed, histogram
/// buckets merged bucket-wise), so each tier's p50/p99 and throughput are
/// true percentiles of that tier — not a blend across backends.
#[derive(Debug, Clone)]
pub struct BackendTierStats {
    /// Backend label (e.g. `"fixed"`).
    pub backend: String,
    /// Shard indices owning this backend.
    pub shards: Vec<usize>,
    /// The batching policy this backend's shards served under (the
    /// group's first shard — tier groups share one policy), so bench
    /// rows can carry per-backend batcher columns.
    pub batcher: BatcherConfig,
    /// Exact merged report over those shards' metrics.
    pub report: ServerReport,
}

/// Roll-up of one sharded run: the merged cross-shard report (counters
/// summed, histogram buckets merged bucket-wise — so merged percentiles
/// are exact, not averages of percentiles) plus the per-shard breakdown
/// and, for heterogeneous sessions, the per-backend tier split.
#[derive(Debug, Clone)]
pub struct ShardedReport {
    pub shards: usize,
    pub policy: ShardPolicy,
    pub merged: ServerReport,
    pub per_shard: Vec<ShardStats>,
    /// Per-backend roll-up; empty unless the session labelled its shards
    /// ([`ShardedConfig::shard_backends`]).
    pub per_backend: Vec<BackendTierStats>,
    /// Feature-buffer pool counters at snapshot time (the
    /// zero-allocation steady state: after warm-up, `misses` plateaus
    /// while `hits` keeps climbing).
    pub pool: crate::util::pool::PoolStats,
}

impl ShardedReport {
    pub fn render(&self) -> String {
        let mut out = self.merged.render();
        out.push_str(&format!(
            "\nfeature pool       {} hits / {} misses ({} parked, cap {})",
            self.pool.hits,
            self.pool.misses,
            self.pool.occupancy,
            self.pool.capacity,
        ));
        if self.shards > 1 {
            out.push_str(&format!(
                "\nshards             {} ({} routing)",
                self.shards,
                self.policy.name()
            ));
            for s in &self.per_shard {
                let label = if s.backend.is_empty() {
                    String::new()
                } else {
                    format!(" [{}]", s.backend)
                };
                out.push_str(&format!(
                    "\n  shard {}{}: batch<= {} wait {} µs, routed {} \
                     dropped {} completed {} mean batch {:.2} p99 {:.1} µs",
                    s.shard,
                    label,
                    s.batcher.max_batch,
                    s.batcher.max_wait.as_micros(),
                    s.routed,
                    s.dropped,
                    s.completed,
                    s.mean_batch,
                    s.p99_latency_us,
                ));
            }
        }
        for b in &self.per_backend {
            out.push_str(&format!(
                "\nbackend {} (shards {:?}, batch<= {} wait {} µs): \
                 completed {} dropped {} \
                 p50 {:.1} µs p99 {:.1} µs throughput {:.0} ev/s",
                b.backend,
                b.shards,
                b.batcher.max_batch,
                b.batcher.max_wait.as_micros(),
                b.report.completed,
                b.report.dropped,
                b.report.p50_latency_us,
                b.report.p99_latency_us,
                b.report.throughput_hz,
            ));
        }
        out
    }
}

pub struct ShardedServer;

impl ShardedServer {
    /// Run one sharded serving session to completion — a thin wrapper
    /// over the live [`Session`] API: start the fabric, replay the
    /// configured synthetic source through `Session::submit`, shut down.
    /// The validation, admission accounting, worker loop, and metrics
    /// roll-up are all the session's, so replay runs and live
    /// request-driven runs share one code path.
    ///
    /// `runner_factory` is invoked once per worker, *inside* that worker's
    /// thread (non-`Send` engines stay legal), and receives the worker's
    /// shard index — the hook where a heterogeneous deployment hands each
    /// shard a different backend (pair it with
    /// [`ShardedConfig::shard_backends`] labels so the report splits
    /// per backend).
    pub fn run<F>(
        cfg: ShardedConfig,
        generator: Box<dyn Generator>,
        runner_factory: F,
    ) -> anyhow::Result<ShardedReport>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn BatchRunner>>
            + Send
            + Sync
            + 'static,
    {
        Self::run_with_clock(
            cfg,
            generator,
            runner_factory,
            Arc::new(SystemClock),
        )
    }

    /// [`ShardedServer::run`] with an explicit serving [`Clock`] (the
    /// deadline/latency timeline; arrival pacing stays real time).
    pub fn run_with_clock<F>(
        cfg: ShardedConfig,
        generator: Box<dyn Generator>,
        runner_factory: F,
        clock: Arc<dyn Clock>,
    ) -> anyhow::Result<ShardedReport>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn BatchRunner>>
            + Send
            + Sync
            + 'static,
    {
        let session =
            Session::start_config(cfg, clock, false, runner_factory)?;
        session.replay(generator);
        session.shutdown()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SourceConfig;
    use crate::data::generators::TopTagging;
    use std::time::{Duration, Instant};

    fn req(id: u64, route_key: u64) -> Request {
        Request {
            id,
            features: vec![0.0; 4],
            label: 0,
            route_key,
            enqueued_at: Instant::now(),
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for (text, want) in [
            ("hash", ShardPolicy::HashId),
            ("round-robin", ShardPolicy::RoundRobin),
            ("rr", ShardPolicy::RoundRobin),
            ("model-key", ShardPolicy::ModelKey),
        ] {
            assert_eq!(ShardPolicy::parse(text).unwrap(), want);
        }
        assert!(ShardPolicy::parse("nope").is_err());
        assert_eq!(ShardPolicy::parse("hash").unwrap().name(), "hash");
    }

    #[test]
    fn hash_routing_is_sticky_and_covers_shards() {
        let mut router = Router::new(ShardPolicy::HashId, 4);
        let mut seen = [false; 4];
        for id in 0..256 {
            let a = router.route(&req(id, 0));
            let b = router.route(&req(id, 0));
            assert_eq!(a, b, "hash routing must be sticky per id");
            assert!(a < 4);
            seen[a] = true;
        }
        assert!(seen.iter().all(|&s| s), "256 ids must hit all 4 shards");
    }

    #[test]
    fn round_robin_is_perfectly_balanced() {
        let mut router = Router::new(ShardPolicy::RoundRobin, 3);
        let mut counts = [0u32; 3];
        for id in 0..300 {
            counts[router.route(&req(id, 0))] += 1;
        }
        assert_eq!(counts, [100, 100, 100]);
    }

    #[test]
    fn model_key_routes_by_key_modulo_shards() {
        let mut router = Router::new(ShardPolicy::ModelKey, 4);
        for key in 0..16u64 {
            assert_eq!(router.route(&req(0, key)), (key % 4) as usize);
        }
    }

    #[test]
    fn every_policy_degenerates_to_shard_zero_with_one_shard() {
        for policy in [
            ShardPolicy::HashId,
            ShardPolicy::RoundRobin,
            ShardPolicy::ModelKey,
        ] {
            let mut router = Router::new(policy, 1);
            for id in 0..32 {
                assert_eq!(router.route(&req(id, id)), 0);
            }
        }
    }

    /// Mock runner mirroring the one in `server.rs` tests: output depends
    /// only on the input features.
    struct ConstRunner;
    impl BatchRunner for ConstRunner {
        fn max_batch(&self) -> usize {
            8
        }
        fn run(
            &mut self,
            xs: &[f32],
            n: usize,
        ) -> anyhow::Result<Vec<Vec<f32>>> {
            let stride = xs.len() / n.max(1);
            Ok((0..n)
                .map(|i| vec![if xs[i * stride] > 0.0 { 0.9 } else { 0.1 }])
                .collect())
        }
    }

    #[test]
    fn sharded_end_to_end_accounts_for_every_event() {
        for shards in [1usize, 3] {
            let cfg = ShardedConfig {
                shards,
                policy: ShardPolicy::RoundRobin,
                tier_mix: TierMix::single(),
                shard_backends: Vec::new(),
                shard_batchers: Vec::new(),
                server: ServerConfig {
                    workers: 2,
                    queue_capacity: 8192,
                    batcher: BatcherConfig {
                        max_batch: 8,
                        max_wait: Duration::from_micros(100),
                    },
                    source: SourceConfig {
                        rate_hz: 300_000.0,
                        poisson: true,
                        n_events: 2000,
                    },
                },
            };
            let report =
                ShardedServer::run(cfg, Box::new(TopTagging::new(3)), |_| {
                    Ok(Box::new(ConstRunner))
                })
                .unwrap();
            assert_eq!(report.merged.generated, 2000, "shards={shards}");
            assert_eq!(
                report.merged.completed + report.merged.dropped,
                2000,
                "shards={shards}"
            );
            assert!(report.merged.completed > 0);
            assert_eq!(report.per_shard.len(), shards);
            let routed: u64 = report.per_shard.iter().map(|s| s.routed).sum();
            assert_eq!(routed, 2000);
            let completed: u64 =
                report.per_shard.iter().map(|s| s.completed).sum();
            assert_eq!(completed, report.merged.completed);
            if shards > 1 {
                // Round-robin: every shard sees ~1/shards of the stream.
                for s in &report.per_shard {
                    assert!(
                        s.routed > 0,
                        "shard {} starved under round-robin",
                        s.shard
                    );
                }
                assert!(report.render().contains("shard 1:"));
            }
        }
    }

    /// Heterogeneous session bookkeeping: labelled shards fed by a tier
    /// mix through model-key routing produce a per-backend roll-up that
    /// exactly partitions the merged totals.
    #[test]
    fn per_backend_rollup_partitions_by_label() {
        let cfg = ShardedConfig {
            shards: 2,
            policy: ShardPolicy::ModelKey,
            tier_mix: TierMix::new(&[0.75, 0.25], 0xC1A5).unwrap(),
            shard_backends: vec!["fixed".into(), "float".into()],
            shard_batchers: Vec::new(),
            server: ServerConfig {
                workers: 1,
                queue_capacity: 8192,
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: Duration::from_micros(100),
                },
                source: SourceConfig {
                    rate_hz: 1_000_000.0,
                    poisson: false,
                    n_events: 2000,
                },
            },
        };
        let report =
            ShardedServer::run(cfg, Box::new(TopTagging::new(3)), |_| {
                Ok(Box::new(ConstRunner))
            })
            .unwrap();
        assert_eq!(report.per_backend.len(), 2);
        assert_eq!(report.per_backend[0].backend, "fixed");
        assert_eq!(report.per_backend[0].shards, vec![0]);
        assert_eq!(report.per_backend[1].backend, "float");
        assert_eq!(report.per_backend[1].shards, vec![1]);
        let routed: u64 = report
            .per_backend
            .iter()
            .map(|b| b.report.generated)
            .sum();
        assert_eq!(routed, 2000);
        let completed: u64 = report
            .per_backend
            .iter()
            .map(|b| b.report.completed)
            .sum();
        assert_eq!(completed, report.merged.completed);
        // 75/25 mix: the trigger tier takes the bulk of the stream.
        assert!(
            report.per_backend[0].report.generated
                > report.per_backend[1].report.generated
        );
        assert!(report.per_backend[1].report.generated > 0);
        // Per-shard stats carry the labels; per-backend == per-shard here
        // (one shard per label).
        for (s, b) in report.per_shard.iter().zip(&report.per_backend) {
            assert_eq!(s.backend, b.backend);
            assert_eq!(s.completed, b.report.completed);
        }
        let rendered = report.render();
        assert!(rendered.contains("backend fixed"), "{rendered}");
        assert!(rendered.contains("[float]"), "{rendered}");
    }

    /// Tier-aware batching: a shard under a batch-1 policy must form
    /// exactly one batch per request while its sibling batches deeper —
    /// one session holding both ends of the latency/throughput curve.
    #[test]
    fn per_shard_batchers_pin_trigger_shard_at_batch_one() {
        use crate::coordinator::tier::TierPolicy;
        let backends = vec!["fixed".to_string(), "float".to_string()];
        let cfg = ShardedConfig {
            shards: 2,
            policy: ShardPolicy::ModelKey,
            tier_mix: TierMix::new(&[0.75, 0.25], 0xC1A5).unwrap(),
            shard_backends: backends.clone(),
            shard_batchers: TierPolicy::for_backends(&backends).batchers(),
            server: ServerConfig {
                workers: 1,
                queue_capacity: 8192,
                batcher: BatcherConfig {
                    max_batch: 8,
                    max_wait: Duration::from_micros(100),
                },
                source: SourceConfig {
                    rate_hz: 1_000_000.0,
                    poisson: false,
                    n_events: 1500,
                },
            },
        };
        let report =
            ShardedServer::run(cfg, Box::new(TopTagging::new(3)), |_| {
                Ok(Box::new(ConstRunner))
            })
            .unwrap();
        let trigger = &report.per_shard[0];
        assert_eq!(trigger.batcher.max_batch, 1);
        assert!(trigger.batcher.max_wait.is_zero());
        assert_eq!(
            trigger.batches, trigger.completed,
            "trigger shard must serve strict batch-1"
        );
        if trigger.completed > 0 {
            assert!((trigger.mean_batch - 1.0).abs() < 1e-12);
        }
        let offline = &report.per_shard[1];
        assert_eq!(offline.batcher.max_batch, 64);
        assert_eq!(report.per_backend[0].batcher.max_batch, 1);
        assert_eq!(report.per_backend[1].batcher.max_batch, 64);
        let rendered = report.render();
        assert!(rendered.contains("batch<= 1 wait 0 µs"), "{rendered}");
    }

    #[test]
    fn batchers_must_cover_every_shard_and_be_flushable() {
        let cfg = ShardedConfig {
            shards: 2,
            shard_batchers: vec![BatcherConfig::default()],
            ..Default::default()
        };
        let result =
            ShardedServer::run(cfg, Box::new(TopTagging::new(1)), |_| {
                Ok(Box::new(ConstRunner) as Box<dyn BatchRunner>)
            });
        let err = format!("{:#}", result.unwrap_err());
        assert!(err.contains("one batcher per shard"), "{err}");

        // Regression: max_batch = 0 must be rejected up front, not spin
        // or silently degrade at serve time.
        let cfg = ShardedConfig {
            shards: 1,
            shard_batchers: vec![BatcherConfig {
                max_batch: 0,
                max_wait: Duration::ZERO,
            }],
            ..Default::default()
        };
        let result =
            ShardedServer::run(cfg, Box::new(TopTagging::new(1)), |_| {
                Ok(Box::new(ConstRunner) as Box<dyn BatchRunner>)
            });
        let err = format!("{:#}", result.unwrap_err());
        assert!(err.contains("max_batch must be >= 1"), "{err}");
    }

    /// Shards replicating one backend label must share a batching
    /// policy: the per-backend roll-up reports one batcher per label.
    #[test]
    fn shards_sharing_a_label_must_share_a_batcher() {
        let cfg = ShardedConfig {
            shards: 2,
            shard_backends: vec!["fixed".into(), "fixed".into()],
            shard_batchers: vec![
                BatcherConfig {
                    max_batch: 1,
                    max_wait: Duration::ZERO,
                },
                BatcherConfig {
                    max_batch: 64,
                    max_wait: Duration::from_micros(2_000),
                },
            ],
            ..Default::default()
        };
        let result =
            ShardedServer::run(cfg, Box::new(TopTagging::new(1)), |_| {
                Ok(Box::new(ConstRunner) as Box<dyn BatchRunner>)
            });
        let err = format!("{:#}", result.unwrap_err());
        assert!(err.contains("one policy per label"), "{err}");

        // ... while replicated labels under one shared policy are fine.
        let cfg = ShardedConfig {
            shards: 2,
            policy: ShardPolicy::RoundRobin,
            shard_backends: vec!["fixed".into(), "fixed".into()],
            server: ServerConfig {
                source: SourceConfig {
                    rate_hz: 1e6,
                    poisson: false,
                    n_events: 100,
                },
                ..Default::default()
            },
            ..Default::default()
        };
        let report =
            ShardedServer::run(cfg, Box::new(TopTagging::new(1)), |_| {
                Ok(Box::new(ConstRunner) as Box<dyn BatchRunner>)
            })
            .unwrap();
        assert_eq!(report.per_backend.len(), 1);
        assert_eq!(report.per_backend[0].shards, vec![0, 1]);
    }

    #[test]
    fn labels_must_cover_every_shard() {
        let cfg = ShardedConfig {
            shards: 3,
            shard_backends: vec!["fixed".into()],
            ..Default::default()
        };
        let result =
            ShardedServer::run(cfg, Box::new(TopTagging::new(1)), |_| {
                Ok(Box::new(ConstRunner) as Box<dyn BatchRunner>)
            });
        let err = format!("{:#}", result.unwrap_err());
        assert!(err.contains("one label per shard"), "{err}");
    }

    #[test]
    fn engine_init_failure_on_one_shard_propagates() {
        let cfg = ShardedConfig {
            shards: 2,
            policy: ShardPolicy::HashId,
            server: ServerConfig {
                source: SourceConfig {
                    rate_hz: 1e6,
                    poisson: false,
                    n_events: 50,
                },
                ..Default::default()
            },
            ..Default::default()
        };
        let result =
            ShardedServer::run(cfg, Box::new(TopTagging::new(1)), |shard| {
                anyhow::ensure!(shard != 1, "shard 1 has no engine");
                Ok(Box::new(ConstRunner) as Box<dyn BatchRunner>)
            });
        let err = format!("{:#}", result.unwrap_err());
        assert!(err.contains("shard 1"), "error was: {err}");
    }

}
