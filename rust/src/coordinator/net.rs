//! The network ingest front-end: a TCP edge on the live [`Session`].
//!
//! The paper's premise is serving under a hard *ingest* budget — events
//! arrive over the wire, not from an in-process loop.  This module puts
//! that process boundary in front of the serving fabric while keeping
//! the fabric's contracts intact: the accounting identity
//! (`generated == completed + dropped`), typed backpressure, and
//! drain-then-close shutdown all hold end-to-end across the socket.
//!
//! ```text
//!  clients ──TCP──► accept loop ──► BoundedQueue<TcpStream> ──► conn
//!  (ingest::wire     (admission:      (accept backlog)          workers
//!   frames)           BUSY beyond                                 │
//!                      max_connections)        prepare_event ─────┤
//!                                              register route     │
//!                                              submit ────────────┼──► Session
//!  replies ◄── per-conn writer ◄── dispatcher ◄── Session::recv ──┘
//!  (Response/         (Mutex<TcpStream>,   (routes: id → seq+writer)
//!   Error frames)      shared clone)
//! ```
//!
//! Design rules, in order of importance:
//!
//! * **No external deps.**  Thread-per-listener with a blocking accept
//!   loop and a *bounded* connection-worker pool over std sockets — no
//!   epoll, no async runtime.  Shutdown wakes the blocking accepts with
//!   a self-connect.
//! * **Register before submit.**  The dispatcher routes completions by
//!   session id, so the conn worker builds the request with
//!   [`Session::prepare_event`] (learning the id), registers the reply
//!   route, *then* submits.  A completion can never arrive for an id
//!   the route table has not seen.
//! * **Typed rejections, never silence.**  A full shard queue answers
//!   `SHED`, a closing session `CLOSED`, a saturated accept backlog
//!   `BUSY`, garbage bytes `MALFORMED` — the same
//!   [`ErrorCode`](crate::api::ErrorCode) space in-process callers see.
//! * **Drain-then-close.**  [`NetServer::shutdown`] stops admissions
//!   (accepts first, then the session), waits for in-flight requests to
//!   answer, joins every thread, and only then closes sockets — the
//!   same protocol [`Session::shutdown`] runs in-process.
//! * **Never wedged by a peer.**  Every socket carries read *and*
//!   write timeouts.  A client that stops reading is marked dead on
//!   its first timed-out reply write and its connection is closed (the
//!   lost reply counts into [`NetReport::stranded`]), so the single
//!   dispatcher thread can never be head-of-line-blocked behind one
//!   peer's full send buffer.
//!
//! The optional **metrics endpoint** (second listener) answers every
//! connection with one line-oriented [`Session::snapshot`] roll-up and
//! closes.  Grammar (one `key value...` pair per line, floats in
//! microseconds, terminated by `end`):
//!
//! ```text
//! generated <u64>
//! completed <u64>
//! dropped <u64>
//! shed_completions <u64>
//! connections_accepted <u64>
//! connections_refused <u64>
//! p50_us <f64>
//! p99_us <f64>
//! throughput_hz <f64>
//! pool_hits <u64>
//! pool_misses <u64>
//! pool_occupancy <u64>
//! backend <name> completed <u64> dropped <u64> p50_us <f64> p99_us <f64>
//! end
//! ```
//! (`backend` lines appear once per labelled tier, heterogeneous
//! sessions only.  The `pool_*` lines are the session's feature-buffer
//! pool: in a warm steady state `pool_misses` plateaus while
//! `pool_hits` keeps climbing — a rising miss rate means request
//! buffers are leaking out of the recycle loop.)

use std::collections::HashMap;
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::ErrorCode;
use crate::ingest::wire::{
    read_frame_pooled, write_frame, Frame, WireError, WireResponse,
};
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::thread::{self, JoinHandle};
use crate::util::sync::{lock_or_recover, Mutex};

use super::queue::BoundedQueue;
use super::session::{ListenerSpec, Session};
use super::sharded::ShardedReport;

/// Poll tick for blocking reads: how often an idle conn worker re-checks
/// the closing flag.
const POLL_TICK: Duration = Duration::from_millis(50);
/// Once bytes are visible on a connection, the whole frame must follow
/// within this budget — a peer trickling a frame slower is dropped.
const FRAME_READ_TIMEOUT: Duration = Duration::from_secs(1);
/// A reply write must complete within this budget.  Every reply is
/// written by the single dispatcher thread, so a client that stops
/// reading (full kernel send buffer) would otherwise head-of-line-block
/// every other connection — and wedge `shutdown` on the dispatcher
/// join.  A timed-out write marks the peer dead.
const WRITE_TIMEOUT: Duration = Duration::from_secs(1);
/// How long a closing connection waits for its in-flight requests to
/// answer before giving up (shed completions would otherwise wedge it).
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);

// ------------------------------------------------------------ NetConfig

/// Front-end knobs beyond the [`ListenerSpec`] itself.
#[derive(Debug, Clone, Copy)]
pub struct NetConfig {
    /// Resolved listener settings (bind addresses + connection bound).
    pub listener: ListenerSpec,
    /// Connection-worker threads (each serves one connection at a time;
    /// the pool bound is what keeps a connection flood from spawning
    /// unbounded threads).
    pub conn_workers: usize,
}

impl NetConfig {
    /// Default worker pool over a listener spec: 8 conn workers, never
    /// more than the connection bound itself.
    pub fn for_listener(listener: ListenerSpec) -> Self {
        Self {
            listener,
            conn_workers: listener.max_connections.min(8).max(1),
        }
    }
}

// ----------------------------------------------------------- shared state

/// A connection's write half, shared between its conn worker (error
/// replies) and the dispatcher (response replies).  The mutex serializes
/// frame writes so concurrent repliers cannot interleave bytes.
struct ConnWriter {
    stream: Mutex<TcpStream>,
    /// Requests admitted on this connection whose reply has not been
    /// written yet — the connection's drain phase waits for zero.
    pending: AtomicU64,
    /// Set on the first failed/timed-out write: the peer stopped
    /// reading or hung up.  Later sends return immediately and the
    /// conn worker skips the drain wait — a dead peer must never hold
    /// the dispatcher (or shutdown) hostage.
    dead: AtomicBool,
}

impl ConnWriter {
    /// Best-effort frame write (a peer that hung up loses its reply;
    /// serving is unaffected).  A failed or timed-out write marks the
    /// connection dead and closes it, so the blocked reply is the last
    /// time anyone waits on this peer.
    fn send(&self, frame: &Frame) -> bool {
        if self.dead.load(Ordering::SeqCst) {
            return false;
        }
        let mut stream = lock_or_recover(&self.stream);
        match write_frame(&mut *stream, frame) {
            Ok(()) => true,
            Err(_) => {
                self.dead.store(true, Ordering::SeqCst);
                // Kick the reader half out of its poll too: the conn
                // worker sees the closed socket and retires the
                // connection instead of serving a dead peer.
                let _ = stream.shutdown(Shutdown::Both);
                false
            }
        }
    }
}

/// Reply route for one in-flight request: which connection (and which
/// client-side `seq`) the completion with this session id answers.
struct Route {
    seq: u64,
    writer: Arc<ConnWriter>,
}

/// State shared by the accept loop, conn workers, dispatcher, and
/// metrics thread.
struct NetShared {
    session: Arc<Session>,
    closing: AtomicBool,
    /// Accepted connections waiting for a conn worker.
    conns: Arc<BoundedQueue<TcpStream>>,
    /// session id → reply route, registered *before* submit.
    routes: Mutex<HashMap<u64, Route>>,
    /// Accepted-but-unfinished connections (admission control).
    active: AtomicU64,
    max_connections: u64,
    accepted: AtomicU64,
    refused: AtomicU64,
    /// Request frames parsed off the wire.
    requests: AtomicU64,
    /// Response frames written back.
    replies: AtomicU64,
    /// Error frames written back (shed/closed/busy/malformed).
    wire_errors: AtomicU64,
    /// Connections dropped for unparseable input.
    malformed: AtomicU64,
    /// Replies whose write failed or timed out (peer stopped reading
    /// or vanished) — folded into `NetReport::stranded`.
    undeliverable: AtomicU64,
}

// ------------------------------------------------------------- NetServer

/// Final report of a network serving run: the session's serving report
/// plus the front-end's own books.
#[derive(Debug)]
pub struct NetReport {
    /// The session's drain-then-close report (the accounting identity
    /// `generated == completed + dropped` holds here as in-process).
    pub serving: ShardedReport,
    /// Connections accepted into the fabric.
    pub accepted: u64,
    /// Connections answered `BUSY` at admission.
    pub refused: u64,
    /// Request frames parsed off the wire.
    pub requests: u64,
    /// Response frames written back.
    pub replies: u64,
    /// Error frames written back (shed/closed/busy/malformed).
    pub wire_errors: u64,
    /// Connections dropped for unparseable input.
    pub malformed: u64,
    /// Completions the bounded session channel shed (their clients never
    /// got a reply frame; `stranded` counts their leftover routes).
    pub completions_lost: u64,
    /// Requests whose reply never reached a client: routes still
    /// registered at shutdown (completion shed, client gone before its
    /// answer) plus replies whose write failed or timed out (peer
    /// stopped reading — the dispatcher drops such peers rather than
    /// block on them).
    pub stranded: u64,
}

/// A live network front-end over a [`Session`] — accept loop, conn
/// workers, completion dispatcher, optional metrics endpoint.  Start it
/// with [`Session::serve_listener`]; stop it with [`Self::shutdown`].
pub struct NetServer {
    shared: Arc<NetShared>,
    local_addr: SocketAddr,
    metrics_addr: Option<SocketAddr>,
    accept_thread: Option<JoinHandle<()>>,
    metrics_thread: Option<JoinHandle<()>>,
    dispatcher: Option<JoinHandle<()>>,
    conn_threads: Vec<JoinHandle<()>>,
}

impl Session {
    /// Put the spec's TCP listener in front of this session: bind,
    /// start the accept loop + conn workers + dispatcher (+ metrics
    /// endpoint when the spec named one), and return the live server.
    /// Fails when the spec named no listener
    /// ([`ServingSpec::with_listener`](super::ServingSpec::with_listener))
    /// or a bind fails.
    pub fn serve_listener(self) -> anyhow::Result<NetServer> {
        let spec = self.listener_spec.ok_or_else(|| {
            anyhow::anyhow!(
                "spec named no listener (ServingSpec::with_listener)"
            )
        })?;
        NetServer::start(self, NetConfig::for_listener(spec))
    }
}

impl NetServer {
    /// Bind and start the front-end over `session`.
    pub fn start(session: Session, config: NetConfig) -> anyhow::Result<Self> {
        let spec = config.listener;
        let listener = TcpListener::bind(spec.addr).map_err(|e| {
            anyhow::anyhow!("bind ingest listener {}: {e}", spec.addr)
        })?;
        let local_addr = listener.local_addr()?;
        let metrics = match spec.metrics_addr {
            Some(addr) => {
                let l = TcpListener::bind(addr).map_err(|e| {
                    anyhow::anyhow!("bind metrics listener {addr}: {e}")
                })?;
                let bound = l.local_addr()?;
                Some((l, bound))
            }
            None => None,
        };

        let shared = Arc::new(NetShared {
            session: Arc::new(session),
            closing: AtomicBool::new(false),
            conns: Arc::new(BoundedQueue::new(spec.max_connections)),
            routes: Mutex::new(HashMap::new()),
            active: AtomicU64::new(0),
            max_connections: spec.max_connections as u64,
            accepted: AtomicU64::new(0),
            refused: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            replies: AtomicU64::new(0),
            wire_errors: AtomicU64::new(0),
            malformed: AtomicU64::new(0),
            undeliverable: AtomicU64::new(0),
        });

        let accept_shared = shared.clone();
        let accept_thread =
            thread::spawn(move || accept_loop(&accept_shared, listener));

        let metrics_addr = metrics.as_ref().map(|(_, addr)| *addr);
        let metrics_thread = metrics.map(|(listener, _)| {
            let shared = shared.clone();
            thread::spawn(move || metrics_loop(&shared, listener))
        });

        let dispatcher_shared = shared.clone();
        let dispatcher =
            thread::spawn(move || dispatch_loop(&dispatcher_shared));

        let conn_threads = (0..config.conn_workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                thread::spawn(move || conn_worker_loop(&shared))
            })
            .collect();

        Ok(Self {
            shared,
            local_addr,
            metrics_addr,
            accept_thread: Some(accept_thread),
            metrics_thread,
            dispatcher: Some(dispatcher),
            conn_threads,
        })
    }

    /// The ingest listener's bound address (resolves port 0 binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// The metrics listener's bound address, when the spec named one.
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_addr
    }

    /// Live serving roll-up (same maths as [`Session::snapshot`]).
    pub fn snapshot(&self) -> ShardedReport {
        self.shared.session.snapshot()
    }

    /// Drain-then-close shutdown of the whole edge: stop accepting,
    /// let every admitted connection answer its in-flight requests,
    /// join every thread, shut the session down, and report.  The
    /// ordering matters — accepts close *before* the session so no
    /// request is admitted into a dying fabric, and the session drains
    /// *before* the dispatcher exits so every deliverable reply is
    /// written.
    pub fn shutdown(self) -> anyhow::Result<NetReport> {
        let Self {
            shared,
            local_addr,
            metrics_addr,
            accept_thread,
            metrics_thread,
            dispatcher,
            conn_threads,
        } = self;

        // 1. Stop admissions at the edge; wake the blocking accepts.
        shared.closing.store(true, Ordering::SeqCst);
        shared.conns.close();
        let _ = TcpStream::connect(local_addr);
        if let Some(handle) = accept_thread {
            handle.join().expect("accept loop panicked");
        }
        if let Some(handle) = metrics_thread {
            if let Some(addr) = metrics_addr {
                let _ = TcpStream::connect(addr);
            }
            handle.join().expect("metrics loop panicked");
        }

        // 2. Conn workers observe `closing` on their next poll tick,
        //    drain their in-flight replies, and exit.
        for handle in conn_threads {
            handle.join().expect("conn worker panicked");
        }

        // 3. Now the session: drain the shard queues, close them; the
        //    dispatcher keeps writing replies until `recv` reports
        //    end-of-stream, then exits.
        shared.session.begin_shutdown();
        if let Some(handle) = dispatcher {
            handle.join().expect("dispatcher panicked");
        }

        let completions_lost = shared.session.completions_lost();
        let stranded = lock_or_recover(&shared.routes).len() as u64
            + shared.undeliverable.load(Ordering::Relaxed);
        let shared = Arc::try_unwrap(shared)
            .map_err(|_| anyhow::anyhow!("front-end state still shared"))?;
        let session = Arc::try_unwrap(shared.session)
            .map_err(|_| anyhow::anyhow!("session still shared"))?;
        let serving = session.shutdown()?;
        Ok(NetReport {
            serving,
            accepted: shared.accepted.load(Ordering::Relaxed),
            refused: shared.refused.load(Ordering::Relaxed),
            requests: shared.requests.load(Ordering::Relaxed),
            replies: shared.replies.load(Ordering::Relaxed),
            wire_errors: shared.wire_errors.load(Ordering::Relaxed),
            malformed: shared.malformed.load(Ordering::Relaxed),
            completions_lost,
            stranded,
        })
    }
}

// ----------------------------------------------------------- accept loop

/// Blocking accept loop: admit into the conn queue, answer `BUSY` when
/// the connection bound or the backlog is saturated.  Woken at shutdown
/// by the self-connect in [`NetServer::shutdown`].
fn accept_loop(shared: &NetShared, listener: TcpListener) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(_) => {
                if shared.closing.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if shared.closing.load(Ordering::SeqCst) {
            return;
        }
        // Admission control: beyond `max_connections`
        // accepted-but-unfinished connections, answer BUSY and drop —
        // connection-level backpressure, distinct from per-request shed.
        if shared.active.load(Ordering::SeqCst) >= shared.max_connections {
            refuse(shared, stream);
            continue;
        }
        shared.active.fetch_add(1, Ordering::SeqCst);
        match shared.conns.push(stream) {
            Ok(()) => {
                shared.accepted.fetch_add(1, Ordering::SeqCst);
            }
            Err(stream) => {
                shared.active.fetch_sub(1, Ordering::SeqCst);
                if shared.closing.load(Ordering::SeqCst) {
                    return;
                }
                refuse(shared, stream);
            }
        }
    }
}

/// Answer `BUSY` (best-effort) and drop the connection.
fn refuse(shared: &NetShared, mut stream: TcpStream) {
    shared.refused.fetch_add(1, Ordering::SeqCst);
    shared.wire_errors.fetch_add(1, Ordering::SeqCst);
    // This write happens on the accept thread: a flooder that never
    // reads must not stall admissions behind its send buffer.
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let busy = Frame::Error(WireError {
        seq: 0,
        code: ErrorCode::Busy,
    });
    let _ = write_frame(&mut stream, &busy);
}

// ---------------------------------------------------------- conn workers

/// One pool worker: pull accepted connections off the queue, serve each
/// to completion.  Exits when the queue is closed and drained.
fn conn_worker_loop(shared: &NetShared) {
    loop {
        match shared.conns.pop_timeout(POLL_TICK) {
            Some(stream) => {
                serve_conn(shared, stream);
                shared.active.fetch_sub(1, Ordering::SeqCst);
            }
            None => {
                if shared.conns.is_closed() && shared.conns.is_empty() {
                    return;
                }
            }
        }
    }
}

/// Serve one connection: parse request frames, admit them into the
/// session (route registered before submit), answer rejections inline;
/// the dispatcher writes the responses.  On clean EOF or server
/// shutdown, drain in-flight replies before closing.
fn serve_conn(shared: &NetShared, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(POLL_TICK));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let mut reader = match stream.try_clone() {
        Ok(reader) => reader,
        Err(_) => return,
    };
    let writer = Arc::new(ConnWriter {
        stream: Mutex::new(stream),
        pending: AtomicU64::new(0),
        dead: AtomicBool::new(false),
    });

    // Per-connection recycled buffers: `payload` is this connection's
    // raw-bytes scratch; `features` is drawn from the session's feature
    // pool so a steady-state connection decodes straight into a buffer
    // a worker already served and returned — the zero-allocation ingest
    // loop (decode → submit → complete → pool → decode).
    let mut payload = Vec::new();
    let mut features = shared.session.recycled_features();

    let mut clean = true;
    loop {
        // Shutdown check before every frame, not only on idle ticks — a
        // client streaming back-to-back frames must not hold a conn
        // worker (and the shutdown join) hostage.
        if shared.closing.load(Ordering::SeqCst) {
            break;
        }
        // Idle-poll with `peek` so a tick mid-frame cannot desync the
        // framing: bytes are only consumed once at least one is visible,
        // and then the whole frame must arrive within the frame budget.
        let mut probe = [0u8; 1];
        match reader.peek(&mut probe) {
            Ok(0) => break, // clean EOF at a frame boundary
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                if shared.closing.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                continue;
            }
            Err(_) => {
                clean = false;
                break;
            }
        }
        let _ = reader.set_read_timeout(Some(FRAME_READ_TIMEOUT));
        let frame =
            read_frame_pooled(&mut reader, &mut payload, &mut features);
        let _ = reader.set_read_timeout(Some(POLL_TICK));
        match frame {
            Ok(Some(Frame::Request(request))) => {
                shared.requests.fetch_add(1, Ordering::SeqCst);
                admit(shared, &writer, request.seq, request);
                // The request took the features buffer (admit recycles
                // it on rejection); redraw from the pool for the next
                // frame.
                features = shared.session.recycled_features();
            }
            // A read timeout mid-frame is a slow-trickling (but maybe
            // well-formed) peer, not garbage: drop the connection
            // without the MALFORMED answer or counter — the frame
            // budget is a liveness bound, not a parse verdict.
            Err(ref e) if e.is_timeout() => {
                clean = false;
                break;
            }
            // Clients speak Requests; a Response/Error from a client is
            // a protocol violation — answer MALFORMED and drop.
            Ok(Some(_)) | Err(_) => {
                shared.malformed.fetch_add(1, Ordering::SeqCst);
                shared.wire_errors.fetch_add(1, Ordering::SeqCst);
                writer.send(&Frame::Error(WireError {
                    seq: 0,
                    code: ErrorCode::Malformed,
                }));
                clean = false;
                break;
            }
            Ok(None) => break, // clean EOF
        }
    }
    // Park the buffer drawn for the frame that never came.
    shared.session.recycle_features(features);

    // Drain phase: a cleanly-closing connection waits for its admitted
    // requests to answer (the dispatcher decrements `pending` as it
    // writes), bounded by the drain deadline — a shed completion must
    // not wedge the worker forever.
    if clean {
        let deadline = Instant::now() + DRAIN_DEADLINE;
        while writer.pending.load(Ordering::SeqCst) > 0
            && !writer.dead.load(Ordering::SeqCst)
            && Instant::now() < deadline
        {
            thread::sleep(Duration::from_millis(1));
        }
    }
    // The stream drops here; the client sees EOF after the last reply.
}

/// Admit one wire request: build it with a session-assigned id,
/// register the reply route *first*, then submit; a rejection unwinds
/// the route and answers the typed error code inline.
fn admit(
    shared: &NetShared,
    writer: &Arc<ConnWriter>,
    seq: u64,
    request: crate::ingest::wire::WireRequest,
) {
    let prepared = shared
        .session
        .prepare_event(request.features, request.label);
    let id = prepared.id;
    lock_or_recover(&shared.routes).insert(
        id,
        Route {
            seq,
            writer: writer.clone(),
        },
    );
    writer.pending.fetch_add(1, Ordering::SeqCst);
    if let Err(err) = shared.session.submit(prepared) {
        lock_or_recover(&shared.routes).remove(&id);
        writer.pending.fetch_sub(1, Ordering::SeqCst);
        shared.wire_errors.fetch_add(1, Ordering::SeqCst);
        let code = err.code();
        // A rejected request never reaches a worker, so its feature
        // buffer re-enters the pool here — shed storms must not bleed
        // capacity out of the recycle loop.
        shared
            .session
            .recycle_features(err.into_request().features);
        writer.send(&Frame::Error(WireError { seq, code }));
    }
}

// ------------------------------------------------------------ dispatcher

/// The completion dispatcher: one thread draining [`Session::recv`] and
/// writing each completion back through its registered route.  Exits at
/// end-of-stream (session closed, workers done, channel drained) — the
/// prompt-`recv` contract is what keeps this exit fast.
fn dispatch_loop(shared: &NetShared) {
    while let Some(completion) = shared.session.recv() {
        let route = lock_or_recover(&shared.routes).remove(&completion.id);
        let Some(Route { seq, writer }) = route else {
            // A completion for an id the edge never admitted (e.g. an
            // in-process submitter sharing the session) is not ours.
            continue;
        };
        let ok = writer.send(&Frame::Response(WireResponse {
            seq,
            id: completion.id,
            shard: completion.shard as u32,
            // The completion's output is a window into the batch's
            // shared buffer; the wire frame owns its floats, so the
            // copy happens here, at the serialization boundary.
            output: completion.output.to_vec(),
        }));
        if ok {
            shared.replies.fetch_add(1, Ordering::SeqCst);
        } else {
            // Dead peer (write failed or timed out): the reply is
            // stranded, the connection is closed by `send` — the
            // dispatcher moves on instead of blocking behind it.
            shared.undeliverable.fetch_add(1, Ordering::SeqCst);
        }
        writer.pending.fetch_sub(1, Ordering::SeqCst);
    }
}

// ------------------------------------------------------- metrics endpoint

/// Answer every metrics connection with one line-oriented snapshot (see
/// the module docs for the grammar) and close.
fn metrics_loop(shared: &NetShared, listener: TcpListener) {
    loop {
        let mut stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(_) => {
                if shared.closing.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if shared.closing.load(Ordering::SeqCst) {
            return;
        }
        // One thread serves all metrics scrapes: a non-reading peer
        // must not block the next one out.
        let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
        let body = render_metrics(shared);
        let _ = stream.write_all(body.as_bytes());
        // Stream drops: one snapshot per connection, like an HTTP GET
        // without the HTTP.
    }
}

/// Render one snapshot in the metrics grammar.
fn render_metrics(shared: &NetShared) -> String {
    let snap = shared.session.snapshot();
    let mut out = String::new();
    out.push_str(&format!("generated {}\n", snap.merged.generated));
    out.push_str(&format!("completed {}\n", snap.merged.completed));
    out.push_str(&format!("dropped {}\n", snap.merged.dropped));
    out.push_str(&format!(
        "shed_completions {}\n",
        shared.session.completions_lost()
    ));
    out.push_str(&format!(
        "connections_accepted {}\n",
        shared.accepted.load(Ordering::Relaxed)
    ));
    out.push_str(&format!(
        "connections_refused {}\n",
        shared.refused.load(Ordering::Relaxed)
    ));
    out.push_str(&format!("p50_us {:.1}\n", snap.merged.p50_latency_us));
    out.push_str(&format!("p99_us {:.1}\n", snap.merged.p99_latency_us));
    out.push_str(&format!(
        "throughput_hz {:.1}\n",
        snap.merged.throughput_hz
    ));
    out.push_str(&format!("pool_hits {}\n", snap.pool.hits));
    out.push_str(&format!("pool_misses {}\n", snap.pool.misses));
    out.push_str(&format!("pool_occupancy {}\n", snap.pool.occupancy));
    for tier in &snap.per_backend {
        out.push_str(&format!(
            "backend {} completed {} dropped {} p50_us {:.1} p99_us {:.1}\n",
            tier.backend,
            tier.report.completed,
            tier.report.dropped,
            tier.report.p50_latency_us,
            tier.report.p99_latency_us
        ));
    }
    out.push_str("end\n");
    out
}
