//! Deterministic time for the serving deadline path.
//!
//! Every time-dependent decision in the coordinator — the batcher's
//! flush deadline, a batch's `formed_at`, the completion instant that
//! latency percentiles are computed from — goes through a [`Clock`].
//! Production uses [`SystemClock`] (plain `Instant::now()` plus real
//! condvar waits); tests use [`VirtualClock`], whose time only moves
//! when the test advances it, so deadline behavior can be driven
//! step-by-step without a single `std::thread::sleep`
//! (`tests/tier_batching.rs`, the batcher property suite).
//!
//! The clock owns the *queue waits* as well as `now()`: "wait until a
//! request arrives or the deadline passes" is the one primitive that
//! couples time to the queue, and it is exactly the piece that differs
//! between real and virtual time.  Under [`VirtualClock`] an empty open
//! queue **auto-advances** virtual time to the deadline (the same
//! semantics as tokio's paused test clock): if no work exists anywhere,
//! the only thing the batcher can be waiting for is the deadline itself,
//! so time jumps there and the batch flushes — deterministically, with
//! zero wall-clock spent.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::queue::BoundedQueue;
use super::Request;

/// The serving time source.  `Send + Sync` so one clock can be shared by
/// every worker thread of a session.
pub trait Clock: Send + Sync {
    /// The current instant on this clock's timeline.
    fn now(&self) -> Instant;

    /// Blocking pop of a batch's *first* request: waits (without a
    /// deadline) until an item arrives or the queue is closed and
    /// drained.  `None` means shutdown — the worker loop exits.
    fn pop_first(&self, queue: &BoundedQueue<Request>) -> Option<Request>;

    /// Pop bounded by `deadline` on this clock's timeline: an item, or
    /// `None` once the deadline passes or the queue closes empty.
    fn pop_until(
        &self,
        queue: &BoundedQueue<Request>,
        deadline: Instant,
    ) -> Option<Request>;
}

/// Real time: `Instant::now()` and genuine condvar waits.
pub struct SystemClock;

impl Clock for SystemClock {
    fn now(&self) -> Instant {
        Instant::now()
    }

    fn pop_first(&self, queue: &BoundedQueue<Request>) -> Option<Request> {
        // Poll in 50 ms slices so a queue that closes while we wait is
        // noticed promptly.  Unlike the pre-clock batcher, an *idle*
        // timeout no longer terminates the worker: only closed-and-
        // drained does, so a slow (e.g. 10 Hz) source can no longer
        // silently kill its workers between arrivals.
        loop {
            match queue.pop_timeout(Duration::from_millis(50)) {
                Some(request) => return Some(request),
                None => {
                    if queue.is_closed() && queue.is_empty() {
                        return None;
                    }
                }
            }
        }
    }

    fn pop_until(
        &self,
        queue: &BoundedQueue<Request>,
        deadline: Instant,
    ) -> Option<Request> {
        let now = Instant::now();
        if now >= deadline {
            return None;
        }
        queue.pop_timeout(deadline - now)
    }
}

/// Test time: an `Instant` timeline anchored at construction whose
/// offset only moves via [`VirtualClock::advance`] (or the batcher's
/// deadline auto-advance).  Monotone by construction — the offset is an
/// atomic that only grows — and safe to share across threads.
///
/// Waiting semantics:
///
/// * [`Clock::pop_until`] on an empty open queue does **not** block: it
///   advances virtual time straight to the deadline and reports the
///   deadline as reached.  This is what makes single-threaded tests of
///   the deadline path total: no producer is needed to unblock them.
/// * [`Clock::pop_first`] has no deadline to jump to, so on an empty
///   open queue it spins (yielding) until a producer on another thread
///   pushes or closes.  Single-threaded tests must therefore only call
///   the batcher with a non-empty or closed queue — the discipline every
///   virtual-clock test in this repo follows.
pub struct VirtualClock {
    base: Instant,
    offset_ns: AtomicU64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self {
            base: Instant::now(),
            offset_ns: AtomicU64::new(0),
        }
    }

    /// Move virtual time forward by `delta`.
    pub fn advance(&self, delta: Duration) {
        self.offset_ns
            .fetch_add(delta.as_nanos() as u64, Ordering::SeqCst);
    }

    /// Move virtual time forward to `target` (no-op if already past it).
    pub fn advance_to(&self, target: Instant) {
        let offset = target.saturating_duration_since(self.base);
        self.offset_ns
            .fetch_max(offset.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Instant {
        self.base
            + Duration::from_nanos(self.offset_ns.load(Ordering::SeqCst))
    }

    fn pop_first(&self, queue: &BoundedQueue<Request>) -> Option<Request> {
        loop {
            if let Some(request) = queue.try_pop() {
                return Some(request);
            }
            if queue.is_closed() {
                return None;
            }
            // A producer on another thread may still be running; yield
            // real time without touching the virtual timeline.
            std::thread::yield_now();
        }
    }

    fn pop_until(
        &self,
        queue: &BoundedQueue<Request>,
        deadline: Instant,
    ) -> Option<Request> {
        if let Some(request) = queue.try_pop() {
            return Some(request);
        }
        if queue.is_closed() {
            return None;
        }
        // Nothing to serve anywhere: the only pending event on this
        // timeline is the deadline itself — jump to it.
        self.advance_to(deadline);
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, enqueued_at: Instant) -> Request {
        Request {
            id,
            features: vec![0.0; 2],
            label: 0,
            route_key: 0,
            enqueued_at,
        }
    }

    #[test]
    fn virtual_clock_advances_only_on_demand() {
        let clock = VirtualClock::new();
        let t0 = clock.now();
        assert_eq!(clock.now(), t0, "time must not move on its own");
        clock.advance(Duration::from_micros(250));
        assert_eq!(clock.now(), t0 + Duration::from_micros(250));
        clock.advance_to(t0 + Duration::from_micros(100)); // backwards: no-op
        assert_eq!(clock.now(), t0 + Duration::from_micros(250));
        clock.advance_to(t0 + Duration::from_millis(1));
        assert_eq!(clock.now(), t0 + Duration::from_millis(1));
    }

    #[test]
    fn virtual_pop_until_auto_advances_to_deadline_when_idle() {
        let clock = VirtualClock::new();
        let queue: BoundedQueue<Request> = BoundedQueue::new(8);
        let deadline = clock.now() + Duration::from_micros(500);
        assert!(clock.pop_until(&queue, deadline).is_none());
        assert_eq!(clock.now(), deadline, "idle wait must jump to deadline");
    }

    #[test]
    fn virtual_pop_until_prefers_queued_work_over_advancing() {
        let clock = VirtualClock::new();
        let queue = BoundedQueue::new(8);
        queue.push(req(7, clock.now())).unwrap();
        let t0 = clock.now();
        let deadline = t0 + Duration::from_micros(500);
        let got = clock.pop_until(&queue, deadline).unwrap();
        assert_eq!(got.id, 7);
        assert_eq!(clock.now(), t0, "queued work must not cost time");
    }

    #[test]
    fn virtual_pop_handles_closed_queue_without_advancing() {
        let clock = VirtualClock::new();
        let queue = BoundedQueue::new(8);
        queue.push(req(1, clock.now())).unwrap();
        queue.close();
        let t0 = clock.now();
        assert_eq!(clock.pop_first(&queue).unwrap().id, 1);
        assert!(clock.pop_first(&queue).is_none());
        let deadline = t0 + Duration::from_micros(100);
        assert!(clock.pop_until(&queue, deadline).is_none());
        assert_eq!(clock.now(), t0, "closed queue must not advance time");
    }

    #[test]
    fn system_pop_first_survives_idle_gaps_until_close() {
        let queue = std::sync::Arc::new(BoundedQueue::new(8));
        let producer = {
            let queue = queue.clone();
            std::thread::spawn(move || {
                // Longer than one 50 ms poll slice: the old batcher
                // entry path would have given up here.
                std::thread::sleep(Duration::from_millis(70));
                queue.push(req(3, Instant::now())).unwrap();
                queue.close();
            })
        };
        let clock = SystemClock;
        assert_eq!(clock.pop_first(&queue).unwrap().id, 3);
        assert!(clock.pop_first(&queue).is_none());
        producer.join().unwrap();
    }
}
