//! Request-driven serving: the typed [`ServingSpec`] and the live
//! [`Session`] handle — the primary serving API of this crate.
//!
//! The paper's trigger premise is a *continuously arriving* event stream
//! served under a fixed latency budget; a serving fabric that can only
//! replay a pre-built synthetic source to completion models the
//! benchmark, not the deployment.  This module turns the sharded
//! queue+batcher+worker fabric into a long-lived service:
//!
//! ```text
//! ServingSpec ──build()──► ServingPlan ──Session::start(spec, factory)
//!                                             │
//!    submitters ──submit(Request)──► router ──┼─► shard queues ─ workers
//!    (any number of threads,                  │          │
//!     SessionHandle clones)                   │          └─► completion
//!                                             │               channel
//!    snapshot() ◄── live metrics roll-up ─────┘               (recv /
//!    shutdown() ◄── drain-then-close ─────────┘                drain)
//! ```
//!
//! Lifecycle: **spec → start → submit → snapshot → shutdown**.
//!
//! * [`ServingSpec`] is the one typed, validated description of a
//!   session: backend kinds, shard count and routing policy, tier mix,
//!   per-shard batching, worker/parallelism knobs, queue depth, the
//!   synthetic-source shape for replay runs, and the serving [`Clock`].
//!   Every check that used to live in `main.rs` or `ShardedServer::run`
//!   (shard ≥ 1, batch ≥ 1, mix sums to 1, backends arity, per-label
//!   policy consistency) happens in [`ServingSpec::build`], with uniform
//!   error messages — the CLI is a thin adapter that parses flags
//!   straight into this struct via `FromStr`.
//! * [`Session::start`] spins the fabric up (one bounded queue, batcher
//!   policy, and metrics block per shard; engine workers built by the
//!   caller's factory *inside* their threads, so non-`Send` engines stay
//!   legal) and returns a live handle.
//! * [`Session::submit`] admits one request: route, count, push.
//!   Backpressure is *surfaced*, not swallowed — a full shard queue
//!   returns [`SubmitError::Full`] with the request handed back, exactly
//!   the drop a trigger would count.  Any number of threads may submit
//!   concurrently through [`SessionHandle`] clones (many sources, one
//!   fabric).
//! * Completions flow out of a channel: [`Session::recv`] /
//!   [`Session::drain`] yield each request's output with its id and its
//!   enqueue/complete instants on the serving clock.
//! * [`Session::snapshot`] rolls the per-shard metrics up into a
//!   [`ShardedReport`] *while the session serves* — live monitoring, the
//!   same exact bucket-merge maths as the final report.
//! * [`Session::shutdown`] runs the drain-then-close protocol (wait for
//!   the queues to empty, close them, join every worker) and returns the
//!   final report.
//!
//! The pre-existing replay entry points are thin wrappers:
//! [`Server::run`](super::Server::run) and
//! [`ShardedServer::run`](super::ShardedServer::run) start a `Session`,
//! drive the spec's synthetic source through [`Session::replay`], and
//! shut down — so the bitwise-equivalence guarantees of the
//! shard/backend/batching suites hold for the live path *by
//! construction*: there is only one fabric.

use std::fmt;
use std::net::SocketAddr;
use std::str::FromStr;
use std::sync::Arc;
use std::time::{Duration, Instant};

// All sync primitives come through the `util::sync` shim (enforced by
// `tools/lint`): zero-cost std re-exports normally, the model checker's
// instrumented types under `--features model-check` — which is what
// lets `tests/model_check.rs` explore the submit/shutdown/Drop races in
// this exact code.
use crate::util::pool::{BufferPool, PoolStats};
use crate::util::sync::atomic::{
    AtomicBool, AtomicU64, AtomicUsize, Ordering,
};
use crate::util::sync::mpsc::{
    self, Receiver, RecvTimeoutError, SyncSender, TryRecvError,
};
use crate::util::sync::thread::{self, JoinHandle};
use crate::util::sync::{lock_or_recover, Mutex};

use crate::data::generators::Generator;
use crate::nn::BackendSpec;

use super::batcher::BatcherConfig;
use super::clock::{Clock, SystemClock};
use super::metrics::ServerMetrics;
use super::queue::BoundedQueue;
use super::server::{
    worker_loop_with_sink, BatchRunner, ServerConfig, ServerReport,
};
use super::sharded::{
    BackendTierStats, Router, ShardPolicy, ShardStats, ShardedConfig,
    ShardedReport,
};
use super::source::{self, SourceConfig};
use super::tier::{TierClass, TierMix, TierPolicy};
use super::Request;

// ------------------------------------------------------------ BackendKind

/// A serving backend, as a type instead of a string.  The kinds mirror
/// the `nn::BackendSpec` registry rows one for one (asserted by a unit
/// test), so resolving a kind to an engine constructor cannot fail —
/// only *building* the engine can (e.g. the stubbed `pjrt` slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Bit-accurate `ap_fixed` datapath — the trigger tier.
    Fixed,
    /// f32 reference engine — the offline tier.
    Float,
    /// PJRT runtime slot (interface stub in this build).
    Pjrt,
}

impl BackendKind {
    /// Registry name of this kind.
    pub fn name(self) -> &'static str {
        match self {
            Self::Fixed => "fixed",
            Self::Float => "float",
            Self::Pjrt => "pjrt",
        }
    }

    /// The registry row this kind resolves to (infallible: the enum and
    /// the registry are kept in sync).
    pub fn spec(self) -> BackendSpec {
        BackendSpec::parse(self.name()).expect("kind registered")
    }

    /// Latency class of this backend (which batching defaults it gets).
    pub fn tier_class(self) -> TierClass {
        TierClass::for_backend(self.name())
    }

    /// Parse a comma-separated backend list (`"fixed,float"`), one entry
    /// per shard.
    pub fn parse_list(csv: &str) -> anyhow::Result<Vec<Self>> {
        anyhow::ensure!(!csv.trim().is_empty(), "backend list is empty");
        csv.split(',').map(|part| part.trim().parse()).collect()
    }
}

impl FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(name: &str) -> anyhow::Result<Self> {
        match name {
            "fixed" => Ok(Self::Fixed),
            "float" => Ok(Self::Float),
            "pjrt" => Ok(Self::Pjrt),
            other => anyhow::bail!(
                "unknown backend {other:?} (registered: {:?})",
                BackendSpec::names()
            ),
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

// ------------------------------------------------------------ ServingSpec

/// Typed, validated description of one serving session — everything the
/// old stringly CLI config (`engine`/`backends`/`tier_mix`/
/// `shard_policy`/`batch_policy` as raw `String`s) expressed, as real
/// types with one validation point ([`Self::build`]).
///
/// Construct with struct-update syntax over [`Default`] or the
/// `with_*` builder methods:
///
/// ```no_run
/// use rnn_hls::coordinator::session::{BackendKind, ServingSpec};
///
/// let spec = ServingSpec::default()
///     .with_engine(BackendKind::Float)
///     .with_shards(2)
///     .with_workers(2);
/// let plan = spec.build().unwrap();
/// assert_eq!(plan.config.shards, 2);
/// ```
#[derive(Clone)]
pub struct ServingSpec {
    /// Homogeneous engine for every shard.  Ignored when `backends` is
    /// non-empty.
    pub engine: BackendKind,
    /// Heterogeneous session: one backend per shard (`backends.len()`
    /// must equal `shards`; mixing kinds requires
    /// [`ShardPolicy::ModelKey`] so tiers reach their backends).  Empty
    /// = homogeneous `engine` everywhere.
    pub backends: Vec<BackendKind>,
    /// Explicit traffic-class mix (one fraction per backend).  `None` =
    /// uniform across `backends`, or the single-class mix when the
    /// session is homogeneous.
    pub tier_mix: Option<TierMix>,
    /// Seed of the tier-stamping hash (same seed, same partition of the
    /// id space into tiers).  Used when `tier_mix` is `None` and the
    /// session is heterogeneous.
    pub tier_seed: u64,
    /// Coordinator shards (independent queue+batcher+worker pipelines).
    pub shards: usize,
    /// Routing policy in front of the shards.
    pub shard_policy: ShardPolicy,
    /// Explicit per-shard batching policy (one entry per shard).  `None`
    /// = each backend's tier default for heterogeneous sessions, the
    /// shared `batcher` otherwise.
    pub batch_policy: Option<TierPolicy>,
    /// Engine-worker threads per shard.
    pub workers: usize,
    /// Per-batch worker threads inside each rust engine (1 = inline).
    pub engine_parallelism: usize,
    /// Shared batching policy (the per-shard fallback).
    pub batcher: BatcherConfig,
    /// Per-shard bounded-queue capacity (submits beyond it fail with
    /// [`SubmitError::Full`]).
    pub queue_capacity: usize,
    /// Synthetic-source shape for replay runs ([`Session::replay`], the
    /// `Server::run` / `ShardedServer::run` wrappers).  Live submitters
    /// ignore it.
    pub source: SourceConfig,
    /// The serving clock (deadline + latency timeline).  Production uses
    /// [`SystemClock`]; tests may share a
    /// [`VirtualClock`](super::clock::VirtualClock).
    pub clock: Arc<dyn Clock>,
    /// Record per-request completions on the session channel.  The
    /// channel is bounded (see `completion_capacity`): overflow is shed
    /// and counted ([`Session::completions_lost`]) rather than stalling
    /// workers or growing without bound.  Replay wrappers switch this
    /// off (nothing drains the channel there).
    pub completions: bool,
    /// Explicit completion-channel capacity.  `None` = the automatic
    /// bound (4× the aggregate queue capacity, at least 4096);
    /// `Some(0)` is rejected at [`Self::build`] — a zero-capacity
    /// channel would shed every completion.
    pub completion_capacity: Option<usize>,
    /// Bind a TCP ingest listener here ([`Session::serve_listener`]);
    /// port 0 binds an ephemeral port.  `None` = in-process serving
    /// only.
    pub listener: Option<SocketAddr>,
    /// Expose live [`Session::snapshot`] roll-ups as a line-oriented
    /// metrics endpoint on this second port (only meaningful with
    /// `listener`).
    pub metrics_listener: Option<SocketAddr>,
    /// Bound on accepted-but-unfinished connections at the ingest
    /// listener (the accept loop answers `BUSY` beyond it — connection
    /// admission control, distinct from per-request shed).
    pub max_connections: usize,
}

/// Listener settings a spec resolved for its session — what
/// [`crate::coordinator::net`] consumes when the accept loop starts.
#[derive(Debug, Clone, Copy)]
pub struct ListenerSpec {
    /// Ingest bind address (port 0 = ephemeral).
    pub addr: SocketAddr,
    /// Optional metrics bind address.
    pub metrics_addr: Option<SocketAddr>,
    /// Accepted-connection bound (`BUSY` beyond it).
    pub max_connections: usize,
}

impl Default for ServingSpec {
    /// The `serve` subcommand's defaults — the single coordinator,
    /// single-class session.
    fn default() -> Self {
        Self {
            engine: BackendKind::Pjrt,
            backends: Vec::new(),
            tier_mix: None,
            tier_seed: 0,
            shards: 1,
            shard_policy: ShardPolicy::HashId,
            batch_policy: None,
            workers: 2,
            engine_parallelism: 1,
            batcher: BatcherConfig {
                max_batch: 10,
                max_wait: Duration::from_micros(200),
            },
            queue_capacity: 4096,
            source: SourceConfig {
                rate_hz: 20_000.0,
                poisson: true,
                n_events: 50_000,
            },
            clock: Arc::new(SystemClock),
            completions: true,
            completion_capacity: None,
            listener: None,
            metrics_listener: None,
            max_connections: 1024,
        }
    }
}

impl fmt::Debug for ServingSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ServingSpec")
            .field("engine", &self.engine)
            .field("backends", &self.backends)
            .field("tier_mix", &self.tier_mix)
            .field("tier_seed", &self.tier_seed)
            .field("shards", &self.shards)
            .field("shard_policy", &self.shard_policy)
            .field("batch_policy", &self.batch_policy)
            .field("workers", &self.workers)
            .field("engine_parallelism", &self.engine_parallelism)
            .field("batcher", &self.batcher)
            .field("queue_capacity", &self.queue_capacity)
            .field("source", &self.source)
            .field("completions", &self.completions)
            .field("completion_capacity", &self.completion_capacity)
            .field("listener", &self.listener)
            .field("metrics_listener", &self.metrics_listener)
            .field("max_connections", &self.max_connections)
            .finish_non_exhaustive()
    }
}

impl ServingSpec {
    pub fn with_engine(mut self, engine: BackendKind) -> Self {
        self.engine = engine;
        self
    }

    pub fn with_backends(mut self, backends: Vec<BackendKind>) -> Self {
        self.backends = backends;
        self
    }

    pub fn with_tier_mix(mut self, mix: TierMix) -> Self {
        self.tier_mix = Some(mix);
        self
    }

    pub fn with_tier_seed(mut self, seed: u64) -> Self {
        self.tier_seed = seed;
        self
    }

    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    pub fn with_shard_policy(mut self, policy: ShardPolicy) -> Self {
        self.shard_policy = policy;
        self
    }

    pub fn with_batch_policy(mut self, policy: TierPolicy) -> Self {
        self.batch_policy = Some(policy);
        self
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    pub fn with_engine_parallelism(mut self, parallelism: usize) -> Self {
        self.engine_parallelism = parallelism;
        self
    }

    pub fn with_batcher(mut self, max_batch: usize, max_wait: Duration) -> Self {
        self.batcher = BatcherConfig {
            max_batch,
            max_wait,
        };
        self
    }

    pub fn with_queue_capacity(mut self, capacity: usize) -> Self {
        self.queue_capacity = capacity;
        self
    }

    pub fn with_source(mut self, source: SourceConfig) -> Self {
        self.source = source;
        self
    }

    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.clock = clock;
        self
    }

    pub fn with_completions(mut self, on: bool) -> Self {
        self.completions = on;
        self
    }

    /// Pin the completion channel's capacity (`None` = automatic bound;
    /// `Some(0)` is rejected at [`Self::build`]).
    pub fn with_completion_capacity(mut self, capacity: usize) -> Self {
        self.completion_capacity = Some(capacity);
        self
    }

    /// Bind a TCP ingest listener at `addr` (port 0 = ephemeral); serve
    /// it with [`Session::serve_listener`].
    pub fn with_listener(mut self, addr: SocketAddr) -> Self {
        self.listener = Some(addr);
        self
    }

    /// Expose live snapshots as a line-oriented metrics endpoint on a
    /// second port.
    pub fn with_metrics_listener(mut self, addr: SocketAddr) -> Self {
        self.metrics_listener = Some(addr);
        self
    }

    /// Bound accepted-but-unfinished connections (`BUSY` beyond it).
    pub fn with_max_connections(mut self, max: usize) -> Self {
        self.max_connections = max;
        self
    }

    /// Validate the spec and resolve it into a [`ServingPlan`] — the one
    /// place every serving invariant is checked, with uniform error
    /// messages (the CLI and the library share it):
    ///
    /// * `shards >= 1`, `workers >= 1`, `queue_capacity >= 1`,
    ///   `engine_parallelism >= 1`;
    /// * `batcher.max_batch >= 1` (and every `batch_policy` entry —
    ///   enforced at `TierPolicy` parse time too);
    /// * `backends` names exactly one backend per shard, and mixing
    ///   kinds requires [`ShardPolicy::ModelKey`];
    /// * an explicit `tier_mix` requires `backends` and one fraction per
    ///   backend (the mix itself validates that fractions are positive
    ///   and sum to 1);
    /// * an explicit `batch_policy` names exactly one entry per shard;
    /// * shards sharing a backend label share one batching policy
    ///   (re-checked by [`Session::start`]).
    pub fn build(&self) -> anyhow::Result<ServingPlan> {
        // Fabric invariants (shards/workers/queue >= 1, batcher
        // validity, arities, label consistency) are checked once, in
        // `validate_config` on the assembled config below — one copy of
        // each message, shared with hand-built `Session::start_config`
        // callers.  Only spec-level knobs are checked here.
        anyhow::ensure!(
            self.engine_parallelism >= 1,
            "engine parallelism must be >= 1"
        );
        anyhow::ensure!(
            self.completion_capacity != Some(0),
            "completion channel capacity must be >= 1"
        );
        anyhow::ensure!(
            self.max_connections >= 1,
            "max connections must be >= 1"
        );

        if !self.backends.is_empty() {
            anyhow::ensure!(
                self.backends.len() == self.shards,
                "spec names {} backends for {} shards \
                 (one backend per shard)",
                self.backends.len(),
                self.shards
            );
            let mixed = self
                .backends
                .iter()
                .any(|kind| *kind != self.backends[0]);
            anyhow::ensure!(
                !mixed || self.shard_policy == ShardPolicy::ModelKey,
                "mixing backends requires the model-key shard policy \
                 (tier keys must reach their backend's shard; {} routing \
                 would scatter tiers across backends)",
                self.shard_policy.name()
            );
        }

        let tier_mix = match &self.tier_mix {
            Some(mix) => {
                anyhow::ensure!(
                    !self.backends.is_empty(),
                    "a tier mix requires backends (tiers name backends)"
                );
                anyhow::ensure!(
                    mix.tiers() == self.backends.len(),
                    "tier mix lists {} fractions for {} backends",
                    mix.tiers(),
                    self.backends.len()
                );
                mix.clone()
            }
            None if self.backends.len() > 1 => {
                TierMix::uniform(self.backends.len(), self.tier_seed)?
            }
            None => TierMix::single(),
        };

        let shard_backends: Vec<String> = self
            .backends
            .iter()
            .map(|kind| kind.name().to_string())
            .collect();
        let shard_batchers = match &self.batch_policy {
            Some(policy) => {
                anyhow::ensure!(
                    policy.entries.len() == self.shards,
                    "batch policy names {} tiers for {} shards \
                     (one name:max_batch:max_wait_us entry per shard)",
                    policy.entries.len(),
                    self.shards
                );
                policy.batchers()
            }
            // Heterogeneous sessions default to each backend's tier
            // class: trigger backends batch-1/zero-wait, offline deep.
            None if self.backends.len() > 1 => {
                TierPolicy::for_backends(&shard_backends).batchers()
            }
            None => Vec::new(),
        };

        let config = ShardedConfig {
            shards: self.shards,
            policy: self.shard_policy,
            tier_mix,
            shard_backends,
            shard_batchers,
            server: ServerConfig {
                workers: self.workers,
                queue_capacity: self.queue_capacity,
                batcher: self.batcher,
                source: self.source,
            },
        };
        validate_config(&config)?;
        Ok(ServingPlan {
            config,
            shard_kinds: self.backends.clone(),
            engine: self.engine,
            engine_parallelism: self.engine_parallelism,
            clock: self.clock.clone(),
            completions: self.completions,
            completion_capacity: self.completion_capacity,
            listener: self.listener.map(|addr| ListenerSpec {
                addr,
                metrics_addr: self.metrics_listener,
                max_connections: self.max_connections,
            }),
        })
    }
}

/// A validated spec, resolved to the fabric configuration plus the
/// engine-construction context a factory needs ([`Self::kind_for`],
/// [`Self::runner_cap`]).  Produced by [`ServingSpec::build`], consumed
/// by [`Session::start_plan`].
#[derive(Clone)]
pub struct ServingPlan {
    /// The fabric configuration the session spins up.
    pub config: ShardedConfig,
    /// Resolved engine kind per shard (empty = homogeneous `engine`).
    pub shard_kinds: Vec<BackendKind>,
    /// Homogeneous engine kind (used when `shard_kinds` is empty).
    pub engine: BackendKind,
    /// Per-batch worker threads inside each engine.
    pub engine_parallelism: usize,
    /// The serving clock.
    pub clock: Arc<dyn Clock>,
    /// Whether the session records per-request completions.
    pub completions: bool,
    /// Explicit completion-channel capacity (`None` = automatic bound).
    pub completion_capacity: Option<usize>,
    /// Resolved listener settings (`None` = in-process serving only).
    pub listener: Option<ListenerSpec>,
}

impl ServingPlan {
    /// Engine kind shard `shard` serves with.
    pub fn kind_for(&self, shard: usize) -> BackendKind {
        self.shard_kinds.get(shard).copied().unwrap_or(self.engine)
    }

    /// The engine-runner batch cap for `shard`: its (tier-resolved)
    /// batcher's `max_batch`, so a deep-batching shard is never clamped
    /// by the shared batcher.
    pub fn runner_cap(&self, shard: usize) -> usize {
        self.config.batcher_for(shard).max_batch
    }
}

// ------------------------------------------------------------ Completion

/// One request's output probabilities, shared out of its batch's packed
/// output buffer: the worker loop builds **one** `Arc<[f32]>` per batch
/// and every completion in the batch holds a `[start, end)` window into
/// it — replacing one `Vec` allocation per request with one shared
/// allocation per batch.  `Output` derefs to `[f32]`, so existing
/// slice-shaped call sites read through unchanged; use
/// [`Output::to_vec`] where an owned `Vec<f32>` is genuinely needed.
#[derive(Clone)]
pub struct Output {
    buf: Arc<[f32]>,
    start: usize,
    end: usize,
}

impl Output {
    /// A `[start, end)` window of a shared batch buffer.
    pub(crate) fn from_shared(
        buf: Arc<[f32]>,
        start: usize,
        end: usize,
    ) -> Self {
        debug_assert!(start <= end && end <= buf.len());
        Self { buf, start, end }
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.buf[self.start..self.end]
    }

    pub fn to_vec(&self) -> Vec<f32> {
        self.as_slice().to_vec()
    }

    pub fn len(&self) -> usize {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

impl std::ops::Deref for Output {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl fmt::Debug for Output {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.as_slice().fmt(f)
    }
}

impl PartialEq for Output {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Vec<f32>> for Output {
    fn eq(&self, other: &Vec<f32>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<[f32]> for Output {
    fn eq(&self, other: &[f32]) -> bool {
        self.as_slice() == other
    }
}

impl From<Vec<f32>> for Output {
    /// Wrap an owned row (tests, adapters); one window over its own
    /// buffer.
    fn from(row: Vec<f32>) -> Self {
        let buf: Arc<[f32]> = Arc::from(row);
        let end = buf.len();
        Self { buf, start: 0, end }
    }
}

/// One served request, as delivered on the session's completion channel.
#[derive(Debug, Clone)]
pub struct Completion {
    /// The request's id (caller-assigned via [`Session::submit`], or the
    /// source's sequence number in replay runs).
    pub id: u64,
    /// The engine's output probabilities for this request — a window of
    /// its batch's shared output buffer (see [`Output`]).
    pub output: Output,
    /// Shard that served the request.
    pub shard: usize,
    /// When the request entered the fabric (the latency anchor).
    pub enqueued_at: Instant,
    /// When its batch finished, on the serving clock.
    pub completed_at: Instant,
}

/// Per-worker handle the serving loop pushes completions through.  The
/// channel is *bounded* (sized from the session's aggregate queue
/// capacity), and a full channel drops the completion and counts it
/// ([`Session::completions_lost`]) instead of stalling the worker — an
/// undrained egress buffer must never block serving or grow without
/// bound.
pub(crate) struct CompletionSink {
    pub(crate) shard: usize,
    pub(crate) tx: SyncSender<Completion>,
    pub(crate) lost: Arc<AtomicU64>,
}

// ------------------------------------------------------------ SubmitError

/// Why a submission was not admitted.  Both variants hand the request
/// back so the caller can retry, redirect, or drop it knowingly.
#[derive(Debug)]
pub enum SubmitError {
    /// The target shard's bounded queue is full — trigger-style
    /// backpressure.  The drop has been counted in that shard's metrics
    /// (exactly what the replay source does with overflow).
    Full {
        /// Shard whose queue rejected the request.
        shard: usize,
        /// The rejected request, returned to the caller.
        request: Request,
    },
    /// The session is shutting down (or already shut down); nothing was
    /// counted.
    Closed {
        /// The rejected request, returned to the caller.
        request: Request,
    },
}

impl SubmitError {
    /// The request that was not admitted.
    pub fn request(&self) -> &Request {
        match self {
            Self::Full { request, .. } | Self::Closed { request } => request,
        }
    }

    /// Recover the request by value (for retry).
    pub fn into_request(self) -> Request {
        match self {
            Self::Full { request, .. } | Self::Closed { request } => request,
        }
    }
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Full { shard, request } => write!(
                f,
                "shard {shard} queue full: request {} dropped \
                 (backpressure)",
                request.id
            ),
            Self::Closed { request } => write!(
                f,
                "session closed: request {} not admitted",
                request.id
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

// --------------------------------------------------------------- Session

/// The shared state every submitter handle and the session itself point
/// at.  Admission (route → count → push) lives here so `Session` and
/// [`SessionHandle`] behave identically.
struct SessionShared {
    config: ShardedConfig,
    queues: Vec<Arc<BoundedQueue<Request>>>,
    metrics: Vec<Arc<ServerMetrics>>,
    router: Mutex<Router>,
    clock: Arc<dyn Clock>,
    closed: AtomicBool,
    next_id: AtomicU64,
    /// Recycled request feature buffers: workers return each served
    /// request's `features` Vec here; submitters draw refills via
    /// [`Session::recycled_features`].  Sized to the aggregate queue
    /// capacity (every in-flight request can have a parked twin) so the
    /// steady state allocates no feature buffers at all.
    feature_pool: Arc<BufferPool<Vec<f32>>>,
}

impl SessionShared {
    fn submit(&self, request: Request) -> Result<(), SubmitError> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(SubmitError::Closed { request });
        }
        // Route on the submitter's thread — the same cheap, deterministic
        // policies the replay source uses (no downstream inspection).
        // Hash and model-key routing are pure functions of the request,
        // so concurrent submitters take no lock on the hot path; only
        // round-robin (router state) serializes.
        let shard = match self
            .config
            .policy
            .route_stateless(&request, self.config.shards)
        {
            Some(shard) => shard,
            None => lock_or_recover(&self.router).route(&request),
        };
        // SeqCst on the accounting counters (here and below): the
        // `generated == completed + dropped` identity is checked across
        // threads, and the un-count on the shutdown race must never be
        // reorderable against the closed-queue observation that
        // justifies it.  (Enforced by `tools/lint`.)
        self.metrics[shard].generated.fetch_add(1, Ordering::SeqCst);
        match self.queues[shard].push(request) {
            Ok(()) => Ok(()),
            // A push failing on a *closed* queue means shutdown raced us
            // between the closed-flag check and the push: undo the
            // admission count (the request was never admitted) and
            // report Closed, not a spurious Full — the final report's
            // books must balance (generated = completed + dropped).
            Err(request) if self.queues[shard].is_closed() => {
                self.metrics[shard]
                    .generated
                    .fetch_sub(1, Ordering::SeqCst);
                Err(SubmitError::Closed { request })
            }
            Err(request) => {
                self.metrics[shard]
                    .dropped
                    .fetch_add(1, Ordering::SeqCst);
                Err(SubmitError::Full { shard, request })
            }
        }
    }

    /// Build a request the session way: fresh id, tier stamp from the
    /// session's mix, enqueue instant from the serving clock.
    fn next_request(&self, features: Vec<f32>, label: u32) -> Request {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Request {
            id,
            features,
            label,
            route_key: self.config.tier_mix.stamp(id),
            enqueued_at: self.clock.now(),
        }
    }

    fn snapshot(&self, started_at: Instant) -> ShardedReport {
        let wall = (self.clock.now() - started_at).as_secs_f64();
        roll_up(&self.config, &self.metrics, wall, self.feature_pool.stats())
    }
}

/// A clonable submitter handle: many sources, one fabric.  Cheap to
/// clone and `Send + Sync`, so each producer thread owns one.
#[derive(Clone)]
pub struct SessionHandle {
    shared: Arc<SessionShared>,
}

impl SessionHandle {
    /// Admit one request (see [`Session::submit`]).
    pub fn submit(&self, request: Request) -> Result<(), SubmitError> {
        self.shared.submit(request)
    }

    /// Build and admit a request from raw features, returning its
    /// session-assigned id.  On rejection the error carries the request
    /// (and its id) back.
    pub fn submit_event(
        &self,
        features: Vec<f32>,
        label: u32,
    ) -> Result<u64, SubmitError> {
        let request = self.shared.next_request(features, label);
        let id = request.id;
        self.shared.submit(request)?;
        Ok(id)
    }

    /// Build (but do not admit) a request with a session-assigned id —
    /// see [`Session::prepare_event`].
    pub fn prepare_event(&self, features: Vec<f32>, label: u32) -> Request {
        self.shared.next_request(features, label)
    }

    /// Draw a recycled feature buffer — see
    /// [`Session::recycled_features`].
    pub fn recycled_features(&self) -> Vec<f32> {
        self.shared.feature_pool.get_with(Vec::new)
    }

    /// Return a feature buffer to the pool — see
    /// [`Session::recycle_features`].
    pub fn recycle_features(&self, features: Vec<f32>) {
        recycle(&self.shared.feature_pool, features);
    }
}

/// Clear and park a feature buffer (shared by the session-level and
/// handle-level recycle entry points).
fn recycle(pool: &BufferPool<Vec<f32>>, mut features: Vec<f32>) {
    features.clear();
    pool.put(features);
}

type WorkerHandles = Vec<Vec<JoinHandle<anyhow::Result<()>>>>;

/// A live serving session: the sharded queue+batcher+worker fabric with
/// the tap open.  See the [module docs](crate::coordinator::session) for
/// the lifecycle.
pub struct Session {
    shared: Arc<SessionShared>,
    /// `workers[shard][worker]` join handles (the shutdown protocol
    /// needs the per-shard grouping for its settled check).  Behind a
    /// mutex so [`Self::begin_shutdown`] can run the drain protocol
    /// through a shared reference while [`Self::shutdown`] later takes
    /// the handles out to join them.
    workers: Mutex<WorkerHandles>,
    completions: Mutex<Receiver<Completion>>,
    /// Completions dropped because the bounded channel was full (the
    /// owner was not draining).  Serving itself is unaffected.
    completions_lost: Arc<AtomicU64>,
    started_at: Instant,
    /// Listener settings carried from the plan
    /// ([`Session::serve_listener`] consumes them); `None` when the
    /// spec named no listener or the session came from a raw config.
    pub(crate) listener_spec: Option<ListenerSpec>,
}

impl Session {
    /// Validate `spec` and start the fabric.  `factory` is invoked once
    /// per worker, *inside* that worker's thread (non-`Send` engines
    /// stay legal), receiving the worker's shard index; `start` returns
    /// once every worker has built its engine (or failed to — init
    /// errors surface at [`Self::shutdown`]).
    pub fn start<F>(spec: &ServingSpec, factory: F) -> anyhow::Result<Self>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn BatchRunner>>
            + Send
            + Sync
            + 'static,
    {
        Self::start_plan(spec.build()?, factory)
    }

    /// [`Self::start`] over an already-built plan (lets the caller read
    /// `plan.kind_for` / `plan.runner_cap` while constructing `factory`).
    pub fn start_plan<F>(plan: ServingPlan, factory: F) -> anyhow::Result<Self>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn BatchRunner>>
            + Send
            + Sync
            + 'static,
    {
        let mut session = Self::start_inner(
            plan.config,
            plan.clock,
            plan.completions,
            plan.completion_capacity,
            factory,
        )?;
        session.listener_spec = plan.listener;
        Ok(session)
    }

    /// Low-level entry over an assembled [`ShardedConfig`] — the path
    /// the replay wrappers (`Server::run`, `ShardedServer::run`) use.
    /// Re-validates the config, so hand-built configs get the same
    /// errors as spec-built ones.
    pub fn start_config<F>(
        config: ShardedConfig,
        clock: Arc<dyn Clock>,
        completions: bool,
        factory: F,
    ) -> anyhow::Result<Self>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn BatchRunner>>
            + Send
            + Sync
            + 'static,
    {
        Self::start_inner(config, clock, completions, None, factory)
    }

    fn start_inner<F>(
        config: ShardedConfig,
        clock: Arc<dyn Clock>,
        completions: bool,
        completion_capacity: Option<usize>,
        factory: F,
    ) -> anyhow::Result<Self>
    where
        F: Fn(usize) -> anyhow::Result<Box<dyn BatchRunner>>
            + Send
            + Sync
            + 'static,
    {
        validate_config(&config)?;
        let queues: Vec<Arc<BoundedQueue<Request>>> = (0..config.shards)
            .map(|_| Arc::new(BoundedQueue::new(config.server.queue_capacity)))
            .collect();
        let metrics: Vec<Arc<ServerMetrics>> = (0..config.shards)
            .map(|_| Arc::new(ServerMetrics::new()))
            .collect();
        let started_at = clock.now();
        // The completion channel is bounded — the egress buffer must
        // never grow without bound when the owner is slow to drain.  The
        // automatic bound is generous (4× the aggregate ingress
        // capacity, at least 4096) so a consumer that keeps up never
        // loses a completion; overflow is dropped and counted, never
        // blocking a worker.  An explicit capacity (already validated
        // nonzero at `build`) pins the bound instead.
        let completion_bound = match completion_capacity {
            Some(capacity) => capacity,
            None => config
                .server
                .queue_capacity
                .saturating_mul(config.shards)
                .saturating_mul(4)
                .max(4096),
        };
        let (tx, rx) = mpsc::sync_channel::<Completion>(completion_bound);
        let completions_lost = Arc::new(AtomicU64::new(0));

        // Feature-buffer pool: every in-flight request can have a parked
        // twin (aggregate queue capacity), with a hard ceiling so huge
        // configs don't pin memory in the free list.
        let feature_pool: Arc<BufferPool<Vec<f32>>> = Arc::new(
            BufferPool::new(
                config
                    .server
                    .queue_capacity
                    .saturating_mul(config.shards)
                    .min(16384),
            ),
        );

        // Readiness gate: the tap opens (start returns) only after every
        // worker on every shard has attempted engine construction, so
        // submitters cannot flood the queues while executables compile.
        let total_workers = config.shards * config.server.workers;
        let ready = Arc::new(AtomicUsize::new(0));
        let factory = Arc::new(factory);

        let mut workers: WorkerHandles = Vec::with_capacity(config.shards);
        for shard in 0..config.shards {
            let mut shard_handles =
                Vec::with_capacity(config.server.workers);
            // Tier-aware batching: each shard serves under its own
            // policy, falling back to the shared config.
            let batcher_cfg = config.batcher_for(shard);
            for worker in 0..config.server.workers {
                let queue = queues[shard].clone();
                let shard_metrics = metrics[shard].clone();
                let factory = factory.clone();
                let ready = ready.clone();
                let clock = clock.clone();
                let sink = completions.then(|| CompletionSink {
                    shard,
                    tx: tx.clone(),
                    lost: completions_lost.clone(),
                });
                let feature_pool = feature_pool.clone();
                shard_handles.push(thread::spawn(
                    move || -> anyhow::Result<()> {
                        // The readiness bump rides a drop guard so a
                        // factory that *panics* (not just errors) still
                        // counts: a dead worker must never wedge the
                        // start-time readiness gate.
                        struct ReadyGuard(Arc<AtomicUsize>);
                        impl Drop for ReadyGuard {
                            fn drop(&mut self) {
                                self.0.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                        let runner_or = {
                            let _ready = ReadyGuard(ready);
                            (*factory)(shard).map_err(|e| {
                                anyhow::anyhow!(
                                    "shard {shard} worker {worker}: \
                                     engine init: {e}"
                                )
                            })
                        };
                        let mut runner = runner_or?;
                        worker_loop_with_sink(
                            runner.as_mut(),
                            &queue,
                            &shard_metrics,
                            &batcher_cfg,
                            &*clock,
                            sink.as_ref(),
                            Some(&feature_pool),
                        )
                    },
                ));
            }
            workers.push(shard_handles);
        }
        // The workers own every live sender clone; dropping the original
        // lets `recv` observe end-of-stream once they exit.
        drop(tx);

        while ready.load(Ordering::SeqCst) < total_workers {
            thread::sleep(Duration::from_millis(1));
        }

        let shared = Arc::new(SessionShared {
            router: Mutex::new(Router::new(config.policy, config.shards)),
            config,
            queues,
            metrics,
            clock,
            closed: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            feature_pool,
        });
        Ok(Self {
            shared,
            workers: Mutex::new(workers),
            completions: Mutex::new(rx),
            completions_lost,
            started_at,
            listener_spec: None,
        })
    }

    /// Admit one request: route it to its shard, count it, push it.
    /// Backpressure and shutdown surface as typed [`SubmitError`]s with
    /// the request handed back — never a panic, never a silent drop.
    pub fn submit(&self, request: Request) -> Result<(), SubmitError> {
        self.shared.submit(request)
    }

    /// Build and admit a request from raw features (session-assigned id,
    /// tier stamp, enqueue instant), returning the id.
    pub fn submit_event(
        &self,
        features: Vec<f32>,
        label: u32,
    ) -> Result<u64, SubmitError> {
        let request = self.shared.next_request(features, label);
        let id = request.id;
        self.shared.submit(request)?;
        Ok(id)
    }

    /// Build (but do not admit) a request the session way: fresh
    /// session-assigned id, tier stamp, enqueue instant from the
    /// serving clock.  Lets a caller learn the id *before* submitting —
    /// the network dispatcher registers its reply route under the id
    /// first, so a completion can never arrive for an id it has not
    /// seen.  Pass the result to [`Self::submit`].
    pub fn prepare_event(&self, features: Vec<f32>, label: u32) -> Request {
        self.shared.next_request(features, label)
    }

    /// Draw a recycled feature buffer from the session's pool: cleared,
    /// with capacity retained from a previously served request.  Fill it
    /// and pass it to [`Self::submit_event`] / [`Self::prepare_event`];
    /// the worker loop recycles it automatically once the request is
    /// served, so a steady-state submit→recv loop allocates no feature
    /// buffers at all.  Pool hit/miss/occupancy counters surface in
    /// [`Self::snapshot`] and the metrics endpoint grammar.
    pub fn recycled_features(&self) -> Vec<f32> {
        self.shared.feature_pool.get_with(Vec::new)
    }

    /// Hand a feature buffer back to the pool without serving it — the
    /// path for buffers recovered from a [`SubmitError`]
    /// ([`SubmitError::into_request`]`.features`) or abandoned before
    /// submit.  The buffer is cleared here; only its capacity recycles.
    pub fn recycle_features(&self, features: Vec<f32>) {
        recycle(&self.shared.feature_pool, features);
    }

    /// A clonable submitter handle — hand one to each producer thread
    /// (many sources, one fabric).
    pub fn handle(&self) -> SessionHandle {
        SessionHandle {
            shared: self.shared.clone(),
        }
    }

    /// Blocking receive of the next completion.  `None` once the
    /// session is closed, every worker has exited, and the channel is
    /// drained.  Only meaningful when the spec enabled `completions`.
    /// Consumption is serialized, but the inner lock is released
    /// between waits so a concurrent [`Self::drain`] can make progress
    /// on an idle session.
    pub fn recv(&self) -> Option<Completion> {
        loop {
            let rx = lock_or_recover(&self.completions);
            match rx.try_recv() {
                Ok(completion) => return Some(completion),
                Err(TryRecvError::Disconnected) => return None,
                Err(TryRecvError::Empty) => {}
            }
            // Empty with the fabric closed and every worker gone: no
            // sender can ever push again, so report end-of-stream *now*
            // instead of waiting out the poll timeout — a listener
            // shutdown's dispatcher drains through here, and a 10 ms
            // stall per call would serialize into seconds of busy-wait.
            // One last look catches a completion that raced in between
            // the empty check and the workers finishing.
            if self.shared.closed.load(Ordering::SeqCst)
                && self.workers_finished()
            {
                return rx.try_recv().ok();
            }
            match rx.recv_timeout(Duration::from_millis(10)) {
                Ok(completion) => return Some(completion),
                Err(RecvTimeoutError::Disconnected) => return None,
                // Timed out with the fabric still up: drop the lock for
                // a beat so other consumers are not starved, then wait
                // again.
                Err(RecvTimeoutError::Timeout) => {}
            }
        }
    }

    /// True when every worker thread has exited (or the handles were
    /// already taken by [`Self::shutdown`]).
    fn workers_finished(&self) -> bool {
        let workers = lock_or_recover(&self.workers);
        workers
            .iter()
            .all(|shard| shard.iter().all(|worker| worker.is_finished()))
    }

    /// Completions dropped because the bounded completion channel was
    /// full (the session owner was not draining).  Serving and metrics
    /// are unaffected — only the egress notifications were shed.
    pub fn completions_lost(&self) -> u64 {
        self.completions_lost.load(Ordering::Relaxed)
    }

    /// Non-blocking drain of every completion currently queued.
    pub fn drain(&self) -> Vec<Completion> {
        let rx = lock_or_recover(&self.completions);
        let mut out = Vec::new();
        loop {
            match rx.try_recv() {
                Ok(completion) => out.push(completion),
                Err(TryRecvError::Empty | TryRecvError::Disconnected) => {
                    return out
                }
            }
        }
    }

    /// Live metrics roll-up: the same exact cross-shard merge as the
    /// final report (counters summed, histogram buckets merged
    /// bucket-wise), taken while the session serves.
    pub fn snapshot(&self) -> ShardedReport {
        self.shared.snapshot(self.started_at)
    }

    /// Replay the spec's synthetic source through [`Self::submit`] to
    /// completion — the paced stream the `Server::run` /
    /// `ShardedServer::run` wrappers drive.  Same source seed, tier
    /// stamp, and admission accounting as the pre-session servers, so
    /// replay runs are bitwise-equivalent by construction.  Returns the
    /// number of generated events.
    ///
    /// The source stamps ids `0..n`; do not run a replay *concurrently*
    /// with [`Self::submit_event`] on one session (the wrappers never
    /// do) — a replay advances the session's id counter past its range,
    /// so sequential mixing stays collision-free.
    pub fn replay(&self, generator: Box<dyn Generator>) -> usize {
        let generated = source::run_with(
            generator,
            self.shared.config.server.source,
            0xEE77,
            &self.shared.config.tier_mix,
            &*self.shared.clock,
            |request| {
                // Overflow is already counted inside submit — exactly
                // the drop-and-continue admission the source always had.
                let _ = self.shared.submit(request);
            },
        );
        // Keep later submit_event ids disjoint from the replayed range.
        self.shared
            .next_id
            .fetch_max(generated as u64, Ordering::SeqCst);
        generated
    }

    /// The drain half of the shutdown protocol, through a *shared*
    /// reference: stop admitting, wait for every shard's queue to empty
    /// (or for all its workers to have exited — one dead shard cannot
    /// wedge the rest), close the queues.  Workers then exit on their
    /// own; [`Self::shutdown`] joins them and reports.  Idempotent, and
    /// callable through an `Arc<Session>` — the network front-end's
    /// dispatcher thread holds the session shared while shutdown begins.
    pub fn begin_shutdown(&self) {
        self.shared.closed.store(true, Ordering::SeqCst);
        let settled = |shard: usize| {
            self.shared.queues[shard].is_empty() || {
                let workers = lock_or_recover(&self.workers);
                workers.is_empty()
                    || workers[shard].iter().all(|w| w.is_finished())
            }
        };
        while !(0..self.shared.config.shards).all(settled) {
            thread::sleep(Duration::from_micros(200));
        }
        for queue in &self.shared.queues {
            queue.close();
        }
    }

    /// Drain-then-close shutdown: [`Self::begin_shutdown`], then join
    /// every worker and return the final report.  Worker errors (engine
    /// init, runner failures) surface here.
    pub fn shutdown(self) -> anyhow::Result<ShardedReport> {
        self.begin_shutdown();
        let workers = std::mem::take(&mut *lock_or_recover(&self.workers));
        let mut first_err: Option<anyhow::Error> = None;
        for shard_handles in workers {
            for handle in shard_handles {
                // Join every worker even after a failure; report the
                // first error once the fabric is fully stopped.
                if let Err(e) = handle.join().expect("worker panicked") {
                    first_err.get_or_insert(e);
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }

        let wall = (self.shared.clock.now() - self.started_at).as_secs_f64();
        Ok(roll_up(
            &self.shared.config,
            &self.shared.metrics,
            wall,
            self.shared.feature_pool.stats(),
        ))
        // `self` drops here: its Drop re-closes the (already closed)
        // queues, a no-op.
    }
}

impl Drop for Session {
    /// A session dropped without [`Session::shutdown`] (early `?`
    /// return, panic unwind) must not strand its fabric: stop admitting
    /// and close every shard queue so the workers drain what is queued
    /// and exit on their own.  The threads are detached rather than
    /// joined — `Drop` must not block — so `shutdown` remains the
    /// orderly path (joined workers, surfaced errors, final report).
    fn drop(&mut self) {
        self.shared.closed.store(true, Ordering::SeqCst);
        for queue in &self.shared.queues {
            queue.close();
        }
    }
}

// ------------------------------------------------- validation + roll-up

/// The fabric invariants every entry point enforces (spec-built and
/// hand-built configs alike) — moved here from `ShardedServer::run` so
/// there is exactly one copy of each message.
pub(crate) fn validate_config(cfg: &ShardedConfig) -> anyhow::Result<()> {
    anyhow::ensure!(cfg.shards >= 1, "need at least one shard");
    anyhow::ensure!(
        cfg.server.workers >= 1,
        "need at least one worker per shard"
    );
    anyhow::ensure!(
        cfg.server.queue_capacity >= 1,
        "queue capacity must be >= 1"
    );
    anyhow::ensure!(
        cfg.shard_backends.is_empty()
            || cfg.shard_backends.len() == cfg.shards,
        "shard_backends names {} backends for {} shards \
         (need one label per shard, or none)",
        cfg.shard_backends.len(),
        cfg.shards
    );
    anyhow::ensure!(
        cfg.shard_batchers.is_empty()
            || cfg.shard_batchers.len() == cfg.shards,
        "shard_batchers names {} policies for {} shards \
         (need one batcher per shard, or none)",
        cfg.shard_batchers.len(),
        cfg.shards
    );
    cfg.server.batcher.validate()?;
    for (shard, batcher) in cfg.shard_batchers.iter().enumerate() {
        batcher
            .validate()
            .map_err(|e| anyhow::anyhow!("shard {shard}: {e}"))?;
    }
    // Shards sharing a backend label must share a batching policy: the
    // per-backend roll-up reports one batcher per label, and its
    // percentiles must not blend measurements taken under different
    // policies (the bench batcher columns would lie).
    for (shard, label) in cfg.shard_backends.iter().enumerate() {
        let first = cfg
            .shard_backends
            .iter()
            .position(|l| l == label)
            .expect("label exists at its own index");
        anyhow::ensure!(
            cfg.batcher_for(first) == cfg.batcher_for(shard),
            "backend {label:?}: shards {first} and {shard} serve \
             under different batchers (the per-backend roll-up \
             needs one policy per label)"
        );
    }
    Ok(())
}

/// Cross-shard metrics roll-up: counters summed, histogram buckets
/// merged bucket-wise (merged percentiles are exact, not averages of
/// percentiles), plus the per-shard breakdown and — for labelled
/// sessions — the per-backend tier split.  Shared by the live
/// [`Session::snapshot`] and the final [`Session::shutdown`] report.
pub(crate) fn roll_up(
    cfg: &ShardedConfig,
    metrics: &[Arc<ServerMetrics>],
    wall: f64,
    pool: PoolStats,
) -> ShardedReport {
    let merged = ServerMetrics::new();
    for shard_metrics in metrics {
        merged.merge(shard_metrics);
    }
    let per_shard = metrics
        .iter()
        .enumerate()
        .map(|(shard, m)| ShardStats {
            shard,
            backend: cfg
                .shard_backends
                .get(shard)
                .cloned()
                .unwrap_or_default(),
            batcher: cfg.batcher_for(shard),
            routed: m.generated.load(Ordering::Relaxed),
            dropped: m.dropped.load(Ordering::Relaxed),
            completed: m.completed.load(Ordering::Relaxed),
            batches: m.batches.load(Ordering::Relaxed),
            mean_batch: m.mean_batch_size(),
            p99_latency_us: m.total_latency.quantile_us(0.99),
        })
        .collect();

    // Per-backend split: group labelled shards (first-appearance order)
    // and merge each group's metrics exactly, so every tier reports its
    // own true percentiles.
    let mut groups: Vec<(String, Vec<usize>)> = Vec::new();
    for (shard, label) in cfg.shard_backends.iter().enumerate() {
        match groups.iter_mut().find(|(name, _)| name == label) {
            Some((_, shards)) => shards.push(shard),
            None => groups.push((label.clone(), vec![shard])),
        }
    }
    let per_backend = groups
        .into_iter()
        .map(|(backend, shard_ids)| {
            let tier_metrics = ServerMetrics::new();
            for &shard in &shard_ids {
                tier_metrics.merge(&metrics[shard]);
            }
            BackendTierStats {
                backend,
                batcher: cfg.batcher_for(shard_ids[0]),
                report: ServerReport::from_metrics(&tier_metrics, wall),
                shards: shard_ids,
            }
        })
        .collect();

    ShardedReport {
        shards: cfg.shards,
        policy: cfg.policy,
        merged: ServerReport::from_metrics(&merged, wall),
        per_shard,
        per_backend,
        pool,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request {
            id,
            features: vec![0.0; 4],
            label: 0,
            route_key: 0,
            enqueued_at: Instant::now(),
        }
    }

    /// Echo runner: output encodes the first feature, so tests can match
    /// completions back to requests.
    struct EchoRunner;
    impl BatchRunner for EchoRunner {
        fn max_batch(&self) -> usize {
            8
        }
        fn run(
            &mut self,
            xs: &[f32],
            n: usize,
        ) -> anyhow::Result<Vec<Vec<f32>>> {
            let stride = xs.len() / n.max(1);
            Ok((0..n).map(|i| vec![xs[i * stride]]).collect())
        }
    }

    #[test]
    fn backend_kind_mirrors_the_registry() {
        // The typed enum and the registry table must agree row for row.
        let names: Vec<&str> =
            [BackendKind::Fixed, BackendKind::Float, BackendKind::Pjrt]
                .iter()
                .map(|k| k.name())
                .collect();
        assert_eq!(names, BackendSpec::names());
        for name in BackendSpec::names() {
            let kind: BackendKind = name.parse().unwrap();
            assert_eq!(kind.name(), name);
            assert_eq!(kind.spec().name(), name);
            assert_eq!(kind.to_string(), name);
        }
        let err = "tpu".parse::<BackendKind>().unwrap_err().to_string();
        assert!(err.contains("unknown backend"), "{err}");
        assert!(err.contains("registered"), "{err}");
    }

    #[test]
    fn backend_kind_list_parses_and_validates() {
        let kinds = BackendKind::parse_list("fixed, float").unwrap();
        assert_eq!(kinds, vec![BackendKind::Fixed, BackendKind::Float]);
        assert!(BackendKind::parse_list("fixed,nope").is_err());
        assert_eq!(BackendKind::Fixed.tier_class(), TierClass::Trigger);
        assert_eq!(BackendKind::Float.tier_class(), TierClass::Offline);
    }

    #[test]
    fn default_spec_builds_the_single_coordinator_plan() {
        let plan = ServingSpec::default().build().unwrap();
        assert_eq!(plan.config.shards, 1);
        assert_eq!(plan.config.policy, ShardPolicy::HashId);
        assert!(plan.config.shard_backends.is_empty());
        assert!(plan.config.shard_batchers.is_empty());
        assert!(plan.config.tier_mix.is_single());
        assert_eq!(plan.config.server.workers, 2);
        assert_eq!(plan.config.server.queue_capacity, 4096);
        assert_eq!(plan.config.server.batcher.max_batch, 10);
        assert_eq!(plan.kind_for(0), BackendKind::Pjrt);
        assert_eq!(plan.runner_cap(0), 10);
    }

    #[test]
    fn heterogeneous_spec_resolves_tier_defaults() {
        let spec = ServingSpec::default()
            .with_backends(vec![BackendKind::Fixed, BackendKind::Float])
            .with_shards(2)
            .with_shard_policy(ShardPolicy::ModelKey);
        let plan = spec.build().unwrap();
        assert_eq!(plan.config.shard_backends, vec!["fixed", "float"]);
        // Tier defaults: trigger batch-1/zero-wait, offline deep.
        assert_eq!(plan.config.shard_batchers[0].max_batch, 1);
        assert!(plan.config.shard_batchers[0].max_wait.is_zero());
        assert_eq!(plan.config.shard_batchers[1].max_batch, 64);
        // Uniform mix across the two tiers.
        assert_eq!(plan.config.tier_mix.tiers(), 2);
        assert!((plan.config.tier_mix.fraction(0) - 0.5).abs() < 1e-12);
        assert_eq!(plan.kind_for(0), BackendKind::Fixed);
        assert_eq!(plan.kind_for(1), BackendKind::Float);
        assert_eq!(plan.runner_cap(1), 64);
    }

    /// The uniform validation layer: every mis-configuration is caught
    /// at `build`, with a stable message.
    #[test]
    fn spec_validation_errors_are_uniform() {
        let err = |spec: ServingSpec| -> String {
            format!("{:#}", spec.build().unwrap_err())
        };

        let e = err(ServingSpec::default().with_shards(0));
        assert!(e.contains("at least one shard"), "{e}");

        let e = err(ServingSpec::default().with_workers(0));
        assert!(e.contains("at least one worker"), "{e}");

        let e = err(ServingSpec::default().with_queue_capacity(0));
        assert!(e.contains("queue capacity"), "{e}");

        let e = err(ServingSpec::default().with_engine_parallelism(0));
        assert!(e.contains("engine parallelism"), "{e}");

        let e = err(ServingSpec::default().with_batcher(0, Duration::ZERO));
        assert!(e.contains("max_batch must be >= 1"), "{e}");

        // Backends arity vs shards.
        let e = err(ServingSpec::default()
            .with_backends(vec![BackendKind::Fixed, BackendKind::Float])
            .with_shards(3)
            .with_shard_policy(ShardPolicy::ModelKey));
        assert!(e.contains("2 backends for 3 shards"), "{e}");

        // Mixed kinds require model-key routing.
        let e = err(ServingSpec::default()
            .with_backends(vec![BackendKind::Fixed, BackendKind::Float])
            .with_shards(2)
            .with_shard_policy(ShardPolicy::RoundRobin));
        assert!(e.contains("model-key"), "{e}");

        // A tier mix without backends names tiers that map to nothing.
        let e = err(ServingSpec::default()
            .with_tier_mix(TierMix::new(&[0.9, 0.1], 7).unwrap()));
        assert!(e.contains("requires backends"), "{e}");

        // Mix arity vs backends arity.
        let e = err(ServingSpec::default()
            .with_backends(vec![BackendKind::Fixed, BackendKind::Float])
            .with_shards(2)
            .with_shard_policy(ShardPolicy::ModelKey)
            .with_tier_mix(TierMix::new(&[0.5, 0.3, 0.2], 7).unwrap()));
        assert!(e.contains("3 fractions for 2 backends"), "{e}");

        // Batch policy arity vs shards.
        let e = err(ServingSpec::default()
            .with_batch_policy(TierPolicy::parse("a:1:0,b:4:100").unwrap()));
        assert!(e.contains("2 tiers for 1 shards"), "{e}");

        // A zero-capacity completion channel would shed every
        // completion — rejected up front, same uniform style.
        let e = err(ServingSpec::default().with_completion_capacity(0));
        assert!(e.contains("completion channel capacity"), "{e}");

        // Listener admission control needs at least one slot.
        let e = err(ServingSpec::default().with_max_connections(0));
        assert!(e.contains("max connections"), "{e}");
    }

    /// A nonzero explicit completion capacity is honored: a 1-deep
    /// channel under a 64-request burst must shed (count
    /// `completions_lost`) instead of growing or blocking a worker.
    #[test]
    fn explicit_completion_capacity_bounds_the_channel() {
        let spec = live_spec().with_completion_capacity(1);
        assert_eq!(spec.build().unwrap().completion_capacity, Some(1));
        let session = Session::start(&spec, |_| {
            Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>)
        })
        .unwrap();
        for id in 0..64u64 {
            session.submit(req(id)).unwrap();
        }
        // Nothing drains while the burst is served, so at most one
        // completion can land in the channel; the rest must be shed.
        let deadline = Instant::now() + Duration::from_secs(10);
        while session.snapshot().merged.completed < 64 {
            assert!(Instant::now() < deadline, "fabric stalled");
            thread::sleep(Duration::from_millis(1));
        }
        assert!(
            session.completions_lost() >= 1,
            "a 1-deep channel must shed under a 64-request burst"
        );
        assert!(session.drain().len() <= 1);
        let report = session.shutdown().unwrap();
        assert_eq!(report.merged.completed, 64);
    }

    /// Satellite regression: `recv` on a closed, drained session must
    /// report end-of-stream promptly (the listener dispatcher's exit
    /// path), not wait out its 10 ms poll timeout per call.
    #[test]
    fn recv_returns_promptly_after_begin_shutdown() {
        let session = Session::start(&live_spec(), |_| {
            Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>)
        })
        .unwrap();
        session.submit(req(0)).unwrap();
        assert_eq!(session.recv().expect("served").id, 0);
        session.begin_shutdown();
        // Workers may take a beat to observe the closed queues; the
        // *sum* of 100 recv calls staying far under 100 × 10 ms is what
        // pins the promptness (the old loop paid the timeout each call).
        let t0 = Instant::now();
        for _ in 0..100 {
            assert!(session.recv().is_none());
        }
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "recv busy-waited {:?} on a closed session",
            t0.elapsed()
        );
        session.shutdown().unwrap();
    }

    /// Replicated same-kind backends do not need model-key routing
    /// (there is only one engine to reach).
    #[test]
    fn replicated_backends_allow_any_policy() {
        let spec = ServingSpec::default()
            .with_backends(vec![BackendKind::Fixed, BackendKind::Fixed])
            .with_shards(2)
            .with_shard_policy(ShardPolicy::RoundRobin);
        let plan = spec.build().unwrap();
        assert_eq!(plan.config.shard_backends, vec!["fixed", "fixed"]);
        // Same kind twice → same tier default on both shards, so the
        // per-label consistency check passes.
        assert_eq!(plan.config.shard_batchers[0], plan.config.shard_batchers[1]);
    }

    fn live_spec() -> ServingSpec {
        ServingSpec::default()
            .with_engine(BackendKind::Float)
            .with_workers(1)
            .with_batcher(4, Duration::from_micros(100))
            .with_queue_capacity(256)
    }

    #[test]
    fn session_serves_submitted_requests_end_to_end() {
        let session =
            Session::start(&live_spec(), |_| Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>))
                .unwrap();
        for id in 0..32u64 {
            let mut request = req(id);
            request.features[0] = id as f32;
            session.submit(request).unwrap();
        }
        let mut got = Vec::new();
        while got.len() < 32 {
            got.push(session.recv().expect("fabric alive"));
        }
        let mut ids: Vec<u64> = got.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..32).collect::<Vec<_>>());
        for completion in &got {
            assert_eq!(completion.output, vec![completion.id as f32]);
            assert_eq!(completion.shard, 0);
            assert!(completion.completed_at >= completion.enqueued_at);
        }
        // Live snapshot sees the served requests before shutdown.
        let snap = session.snapshot();
        assert_eq!(snap.merged.generated, 32);
        assert_eq!(snap.merged.completed, 32);
        // The bounded egress channel never overflowed (we drained it).
        assert_eq!(session.completions_lost(), 0);
        let report = session.shutdown().unwrap();
        assert_eq!(report.merged.completed, 32);
        assert_eq!(report.merged.dropped, 0);
    }

    #[test]
    fn submit_event_assigns_sequential_ids_and_stamps() {
        let session =
            Session::start(&live_spec(), |_| Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>))
                .unwrap();
        let a = session.submit_event(vec![7.0; 4], 1).unwrap();
        let b = session.submit_event(vec![8.0; 4], 0).unwrap();
        assert_eq!((a, b), (0, 1));
        let report = session.shutdown().unwrap();
        assert_eq!(report.merged.generated, 2);
        assert_eq!(report.merged.completed, 2);
    }

    #[test]
    fn handle_submit_after_shutdown_is_a_typed_error() {
        let session =
            Session::start(&live_spec(), |_| Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>))
                .unwrap();
        let handle = session.handle();
        session.shutdown().unwrap();
        let err = handle.submit(req(9)).unwrap_err();
        assert!(
            matches!(&err, SubmitError::Closed { request } if request.id == 9),
            "{err}"
        );
        assert!(err.to_string().contains("closed"), "{err}");
        assert_eq!(err.into_request().id, 9);
        let err = handle.submit_event(vec![0.0; 4], 0).unwrap_err();
        assert!(matches!(err, SubmitError::Closed { .. }), "{err}");
    }

    #[test]
    fn session_replay_matches_sharded_server_accounting() {
        use crate::coordinator::SourceConfig;
        use crate::data::generators::TopTagging;

        let spec = ServingSpec::default()
            .with_engine(BackendKind::Float)
            .with_workers(1)
            .with_queue_capacity(8192)
            .with_completions(false)
            .with_source(SourceConfig {
                rate_hz: 1_000_000.0,
                poisson: false,
                n_events: 500,
            });
        let session =
            Session::start(&spec, |_| Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>)).unwrap();
        assert_eq!(session.replay(Box::new(TopTagging::new(3))), 500);
        let report = session.shutdown().unwrap();
        assert_eq!(report.merged.generated, 500);
        assert_eq!(report.merged.completed + report.merged.dropped, 500);
    }

    /// Dropping a session without `shutdown` must not strand the
    /// fabric: Drop stops admissions (observable through a surviving
    /// handle) and closes the queues so workers exit on their own.
    #[test]
    fn dropping_a_session_stops_admissions() {
        let session = Session::start(&live_spec(), |_| {
            Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>)
        })
        .unwrap();
        let handle = session.handle();
        drop(session);
        let err = handle.submit(req(1)).unwrap_err();
        assert!(matches!(err, SubmitError::Closed { .. }), "{err}");
    }

    #[test]
    fn engine_init_failure_surfaces_at_shutdown() {
        let session = Session::start(&live_spec(), |shard| {
            anyhow::ensure!(shard != 0, "no engine");
            Ok(Box::new(EchoRunner) as Box<dyn BatchRunner>)
        })
        .unwrap();
        let err = format!("{:#}", session.shutdown().unwrap_err());
        assert!(err.contains("engine init"), "{err}");
    }
}
