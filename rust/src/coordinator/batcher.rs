//! Dynamic batching policy: flush on size OR deadline, whichever first.
//!
//! The paper's §5.2 throughput study is batch-sensitive (batch-1 FPGA vs
//! batched GPU); the batcher is where the serving system chooses its
//! point on that curve.  Policy: collect up to `max_batch` requests; if
//! the batch has been held `max_wait` since its first pop, flush what we
//! have.  `max_wait = 0` is the trigger regime and is **strict batch-1**:
//! every request is dispatched alone, immediately — never co-batched,
//! not even with requests already queued behind it (the paper's trigger
//! never trades a single event's latency for throughput).
//!
//! All time flows through a [`Clock`]: production passes
//! [`SystemClock`](super::clock::SystemClock), tests pass
//! [`VirtualClock`](super::clock::VirtualClock) and drive the deadline
//! step-by-step without sleeping (`tests/tier_batching.rs`).

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::clock::Clock;
use super::queue::BoundedQueue;
use super::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatcherConfig {
    /// Flush when the batch reaches this size.  Must be >= 1: a
    /// zero-size batch could never flush (enforce via [`Self::new`]).
    pub max_batch: usize,
    /// Longest a batch may be held open for co-batching.  Zero = strict
    /// batch-1 trigger serving.
    pub max_wait: Duration,
}

impl BatcherConfig {
    /// Validated constructor — the one every parsing path (CLI flags,
    /// `--batch-policy` entries) must go through.  `max_batch = 0` is a
    /// config that can never flush a batch, so it is rejected here with
    /// a clear error instead of degrading at serve time.
    pub fn new(max_batch: usize, max_wait: Duration) -> anyhow::Result<Self> {
        let cfg = Self {
            max_batch,
            max_wait,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The flushability invariant, for configs built as plain struct
    /// literals: the serving entry points (`Server::run`,
    /// `ShardedServer::run`) re-check it here before spawning workers.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.max_batch >= 1,
            "batcher max_batch must be >= 1 (got 0): a zero-size batch \
             can never flush"
        );
        Ok(())
    }
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 10,
            max_wait: Duration::from_micros(200),
        }
    }
}

/// A formed batch ready for an engine worker.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub formed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Pack features into one flat buffer (row-major, request order).
    pub fn packed_features(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.pack_features_into(&mut out);
        out
    }

    /// [`Batch::packed_features`] into a caller-recycled buffer — the
    /// worker loop reuses one packing buffer per worker, so steady-state
    /// batches never allocate here (capacity is retained across calls).
    pub fn pack_features_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.requests.iter().map(|r| r.features.len()).sum());
        for r in &self.requests {
            out.extend_from_slice(&r.features);
        }
    }
}

/// Pull one batch from the queue under the policy, on `clock`'s
/// timeline.  Returns `None` when the queue is closed and drained.
///
/// Flush guarantees (the batcher property suite asserts them for random
/// arrival sequences):
///
/// * a batch flushes because it reached `max_batch` (size), because it
///   was held `max_wait` since its first pop (deadline), or because the
///   queue closed mid-batch (shutdown drain) — never for any other
///   reason;
/// * a batch is never held *past* the deadline;
/// * `max_wait = 0` always yields batch size 1.
pub fn next_batch(
    queue: &Arc<BoundedQueue<Request>>,
    cfg: &BatcherConfig,
    clock: &dyn Clock,
) -> Option<Batch> {
    debug_assert!(cfg.max_batch >= 1, "BatcherConfig::new enforces this");
    // Block for the first request (no deadline: only shutdown ends it).
    let first = clock.pop_first(queue)?;
    let mut requests = vec![first];
    // The trigger regime: dispatch alone, immediately.  Not even
    // already-queued requests are co-batched — batch-1 is a *guarantee*
    // a trigger-tier policy makes, not a best-effort degenerate case.
    if cfg.max_wait.is_zero() {
        return Some(Batch {
            requests,
            formed_at: clock.now(),
        });
    }
    // Anchor the flush deadline to *pop* time, not the first request's
    // enqueue time: under backlog an aged request would otherwise carry
    // an already-expired deadline and force degenerate batch-1 flushes —
    // exactly when batching matters most.
    let deadline = clock.now() + cfg.max_wait;

    while requests.len() < cfg.max_batch {
        // Fast path: take whatever is already waiting.
        let more = queue.drain_up_to(cfg.max_batch - requests.len());
        if !more.is_empty() {
            requests.extend(more);
            continue;
        }
        if clock.now() >= deadline {
            break;
        }
        match clock.pop_until(queue, deadline) {
            Some(r) => requests.push(r),
            None => break, // deadline or close
        }
    }
    Some(Batch {
        requests,
        formed_at: clock.now(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::clock::SystemClock;

    fn req(id: u64) -> Request {
        Request {
            id,
            features: vec![id as f32; 4],
            label: 0,
            route_key: 0,
            enqueued_at: Instant::now(),
        }
    }

    fn queue_with(n: u64) -> Arc<BoundedQueue<Request>> {
        let q = Arc::new(BoundedQueue::new(1024));
        for i in 0..n {
            q.push(req(i)).unwrap();
        }
        q
    }

    #[test]
    fn zero_max_batch_rejected_at_construction() {
        let err = BatcherConfig::new(0, Duration::ZERO).unwrap_err();
        assert!(
            format!("{err:#}").contains("max_batch must be >= 1"),
            "{err:#}"
        );
        assert_eq!(BatcherConfig::new(1, Duration::ZERO).unwrap().max_batch, 1);
    }

    #[test]
    fn flushes_on_size() {
        let q = queue_with(25);
        let cfg = BatcherConfig {
            max_batch: 10,
            max_wait: Duration::from_secs(10),
        };
        let b = next_batch(&q, &cfg, &SystemClock).unwrap();
        assert_eq!(b.len(), 10);
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(q.len(), 15);
    }

    #[test]
    fn flushes_on_deadline_with_partial_batch() {
        let q = queue_with(3);
        let cfg = BatcherConfig {
            max_batch: 10,
            max_wait: Duration::from_millis(5),
        };
        let t0 = Instant::now();
        let b = next_batch(&q, &cfg, &SystemClock).unwrap();
        assert_eq!(b.len(), 3);
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    /// `max_wait = 0` is the trigger guarantee: strict batch-1, even
    /// with a deep backlog already queued.
    #[test]
    fn zero_wait_is_strict_batch_one() {
        let q = queue_with(3);
        let cfg = BatcherConfig {
            max_batch: 10,
            max_wait: Duration::ZERO,
        };
        for want in 0..3u64 {
            let b = next_batch(&q, &cfg, &SystemClock).unwrap();
            assert_eq!(b.len(), 1, "trigger regime must never co-batch");
            assert_eq!(b.requests[0].id, want);
        }
        assert!(q.is_empty());
    }

    /// Regression: the flush deadline must anchor to pop time.  A request
    /// that already sat in the queue longer than `max_wait` used to yield
    /// an expired deadline and a degenerate batch-1 flush under backlog.
    #[test]
    fn deadline_anchors_to_pop_time_not_enqueue_time() {
        let q = Arc::new(BoundedQueue::new(16));
        let mut stale = req(0);
        stale.enqueued_at = Instant::now() - Duration::from_millis(50);
        q.push(stale).unwrap();
        let cfg = BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(250),
        };
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.push(req(1)).unwrap();
        });
        let b = next_batch(&q, &cfg, &SystemClock).unwrap();
        producer.join().unwrap();
        assert_eq!(
            b.len(),
            2,
            "stale first request must not collapse the batching window"
        );
    }

    #[test]
    fn closed_and_drained_returns_none() {
        let q = queue_with(2);
        q.close();
        let cfg = BatcherConfig::default();
        assert_eq!(next_batch(&q, &cfg, &SystemClock).unwrap().len(), 2);
        assert!(next_batch(&q, &cfg, &SystemClock).is_none());
    }

    /// The batcher entry blocks across idle gaps instead of giving up:
    /// a worker must only exit on close, however slow the source is.
    #[test]
    fn idle_gap_longer_than_poll_slice_does_not_end_the_stream() {
        let q: Arc<BoundedQueue<Request>> = Arc::new(BoundedQueue::new(16));
        let cfg = BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_micros(50),
        };
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(70));
            q2.push(req(0)).unwrap();
            q2.close();
        });
        let b = next_batch(&q, &cfg, &SystemClock)
            .expect("batcher must wait out the idle gap");
        assert_eq!(b.len(), 1);
        assert!(next_batch(&q, &cfg, &SystemClock).is_none());
        producer.join().unwrap();
    }

    #[test]
    fn packed_features_concatenate_in_order() {
        let b = Batch {
            requests: vec![req(1), req(2)],
            formed_at: Instant::now(),
        };
        let packed = b.packed_features();
        assert_eq!(packed.len(), 8);
        assert_eq!(&packed[..4], &[1.0; 4]);
        assert_eq!(&packed[4..], &[2.0; 4]);
    }

    #[test]
    fn no_request_lost_under_concurrent_batching() {
        use crate::util::sync::{lock_or_recover, Mutex};
        use std::collections::HashSet;
        let q = Arc::new(BoundedQueue::new(4096));
        let seen = Arc::new(Mutex::new(HashSet::new()));
        let cfg = BatcherConfig {
            max_batch: 7,
            max_wait: Duration::from_micros(100),
        };
        std::thread::scope(|s| {
            for _ in 0..3 {
                let q = q.clone();
                let seen = seen.clone();
                let cfg = cfg;
                s.spawn(move || {
                    while let Some(b) = next_batch(&q, &cfg, &SystemClock) {
                        let mut set = lock_or_recover(&seen);
                        for r in &b.requests {
                            assert!(set.insert(r.id), "duplicate {}", r.id);
                        }
                    }
                });
            }
            for i in 0..2000u64 {
                while q.push(req(i)).is_err() {
                    std::thread::yield_now();
                }
            }
            q.close();
        });
        assert_eq!(lock_or_recover(&seen).len(), 2000);
    }
}
