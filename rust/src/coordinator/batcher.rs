//! Dynamic batching policy: flush on size OR deadline, whichever first.
//!
//! The paper's §5.2 throughput study is batch-sensitive (batch-1 FPGA vs
//! batched GPU); the batcher is where the serving system chooses its
//! point on that curve.  Policy: collect up to `max_batch` requests; if
//! the oldest waiting request has been held `max_wait`, flush what we
//! have.  `max_wait = 0` degenerates to batch-1 serving (the trigger
//! regime: never trade latency for throughput).

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::queue::BoundedQueue;
use super::Request;

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    /// Longest a request may wait for co-batching.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 10,
            max_wait: Duration::from_micros(200),
        }
    }
}

/// A formed batch ready for an engine worker.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub formed_at: Instant,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Pack features into one flat buffer (row-major, request order).
    pub fn packed_features(&self) -> Vec<f32> {
        let mut out =
            Vec::with_capacity(self.requests.iter().map(|r| r.features.len()).sum());
        for r in &self.requests {
            out.extend_from_slice(&r.features);
        }
        out
    }
}

/// Pull one batch from the queue under the policy.  Returns `None` when
/// the queue is closed and drained.
pub fn next_batch(
    queue: &Arc<BoundedQueue<Request>>,
    cfg: &BatcherConfig,
) -> Option<Batch> {
    // Block for the first request.
    let first = queue.pop_timeout(Duration::from_millis(50))?;
    let mut requests = vec![first];
    // Anchor the flush deadline to *pop* time, not the first request's
    // enqueue time: under backlog an aged request would otherwise carry
    // an already-expired deadline and force degenerate batch-1 flushes —
    // exactly when batching matters most.  `max_wait = 0` still means
    // the trigger regime: drain whatever is already queued, never wait.
    let deadline = Instant::now() + cfg.max_wait;

    while requests.len() < cfg.max_batch {
        // Fast path: take whatever is already waiting.
        let more = queue.drain_up_to(cfg.max_batch - requests.len());
        if !more.is_empty() {
            requests.extend(more);
            continue;
        }
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match queue.pop_timeout(deadline - now) {
            Some(r) => requests.push(r),
            None => break, // deadline or close
        }
    }
    Some(Batch {
        requests,
        formed_at: Instant::now(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request {
            id,
            features: vec![id as f32; 4],
            label: 0,
            route_key: 0,
            enqueued_at: Instant::now(),
        }
    }

    fn queue_with(n: u64) -> Arc<BoundedQueue<Request>> {
        let q = Arc::new(BoundedQueue::new(1024));
        for i in 0..n {
            q.push(req(i)).unwrap();
        }
        q
    }

    #[test]
    fn flushes_on_size() {
        let q = queue_with(25);
        let cfg = BatcherConfig {
            max_batch: 10,
            max_wait: Duration::from_secs(10),
        };
        let b = next_batch(&q, &cfg).unwrap();
        assert_eq!(b.len(), 10);
        let ids: Vec<u64> = b.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(q.len(), 15);
    }

    #[test]
    fn flushes_on_deadline_with_partial_batch() {
        let q = queue_with(3);
        let cfg = BatcherConfig {
            max_batch: 10,
            max_wait: Duration::from_millis(5),
        };
        let t0 = Instant::now();
        let b = next_batch(&q, &cfg).unwrap();
        assert_eq!(b.len(), 3);
        assert!(t0.elapsed() < Duration::from_millis(100));
    }

    #[test]
    fn zero_wait_gives_immediate_partial_batches() {
        let q = queue_with(3);
        let cfg = BatcherConfig {
            max_batch: 10,
            max_wait: Duration::ZERO,
        };
        // All three are already queued, so one drain picks them up.
        let b = next_batch(&q, &cfg).unwrap();
        assert_eq!(b.len(), 3);
        // But an empty queue + zero wait returns a singleton immediately.
        let q2 = queue_with(1);
        let b2 = next_batch(&q2, &cfg).unwrap();
        assert_eq!(b2.len(), 1);
    }

    /// Regression: the flush deadline must anchor to pop time.  A request
    /// that already sat in the queue longer than `max_wait` used to yield
    /// an expired deadline and a degenerate batch-1 flush under backlog.
    #[test]
    fn deadline_anchors_to_pop_time_not_enqueue_time() {
        let q = Arc::new(BoundedQueue::new(16));
        let mut stale = req(0);
        stale.enqueued_at = Instant::now() - Duration::from_millis(50);
        q.push(stale).unwrap();
        let cfg = BatcherConfig {
            max_batch: 2,
            max_wait: Duration::from_millis(250),
        };
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.push(req(1)).unwrap();
        });
        let b = next_batch(&q, &cfg).unwrap();
        producer.join().unwrap();
        assert_eq!(
            b.len(),
            2,
            "stale first request must not collapse the batching window"
        );
    }

    #[test]
    fn closed_and_drained_returns_none() {
        let q = queue_with(2);
        q.close();
        let cfg = BatcherConfig::default();
        assert_eq!(next_batch(&q, &cfg).unwrap().len(), 2);
        assert!(next_batch(&q, &cfg).is_none());
    }

    #[test]
    fn packed_features_concatenate_in_order() {
        let b = Batch {
            requests: vec![req(1), req(2)],
            formed_at: Instant::now(),
        };
        let packed = b.packed_features();
        assert_eq!(packed.len(), 8);
        assert_eq!(&packed[..4], &[1.0; 4]);
        assert_eq!(&packed[4..], &[2.0; 4]);
    }

    #[test]
    fn no_request_lost_under_concurrent_batching() {
        use std::collections::HashSet;
        use std::sync::Mutex;
        let q = Arc::new(BoundedQueue::new(4096));
        let seen = Arc::new(Mutex::new(HashSet::new()));
        let cfg = BatcherConfig {
            max_batch: 7,
            max_wait: Duration::from_micros(100),
        };
        std::thread::scope(|s| {
            for _ in 0..3 {
                let q = q.clone();
                let seen = seen.clone();
                let cfg = cfg;
                s.spawn(move || {
                    while let Some(b) = next_batch(&q, &cfg) {
                        let mut set = seen.lock().unwrap();
                        for r in &b.requests {
                            assert!(set.insert(r.id), "duplicate {}", r.id);
                        }
                    }
                });
            }
            for i in 0..2000u64 {
                while q.push(req(i)).is_err() {
                    std::thread::yield_now();
                }
            }
            q.close();
        });
        assert_eq!(seen.lock().unwrap().len(), 2000);
    }
}
