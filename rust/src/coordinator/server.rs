//! The serving loop: source → queue → batcher → engine workers → metrics.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::data::generators::Generator;
use crate::nn::PackedOut;
use crate::util::pool::BufferPool;

use super::batcher::{next_batch, BatcherConfig};
use super::clock::{Clock, SystemClock};
use super::metrics::ServerMetrics;
use super::queue::BoundedQueue;
use super::session::{Completion, CompletionSink, Output, Session};
use super::sharded::{ShardPolicy, ShardedConfig};
use super::source::SourceConfig;
use super::tier::TierMix;
use super::Request;

/// An engine that can run one packed batch.  Implemented by the PJRT
/// executor (`examples/trigger_serving.rs`), the fixed-point engine, and
/// mocks in tests.  NOT required to be `Send`: each worker thread builds
/// its own runner via the factory (the PJRT client is thread-local).
pub trait BatchRunner {
    /// Largest batch this runner accepts.
    fn max_batch(&self) -> usize;
    /// Run `n` samples packed in `xs`; returns per-sample probabilities.
    fn run(&mut self, xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>>;

    /// [`BatchRunner::run`], writing rows into a caller-recycled
    /// [`PackedOut`] — the worker loop's allocation-free entry point.
    /// The default packs whatever `run` returns (validating one uniform
    /// row width, since a packed buffer cannot represent ragged rows);
    /// engine-backed runners override it to write rows directly.
    fn run_into(
        &mut self,
        xs: &[f32],
        n: usize,
        out: &mut PackedOut,
    ) -> anyhow::Result<()> {
        let rows = self.run(xs, n)?;
        anyhow::ensure!(
            rows.len() == n,
            "runner returned {} rows for {n} samples",
            rows.len()
        );
        let width = rows.first().map(|r| r.len()).unwrap_or(0);
        out.reset(width);
        for row in &rows {
            anyhow::ensure!(
                row.len() == width,
                "runner row width {} != {width} (packed rows must be \
                 uniform)",
                row.len()
            );
            out.push_row(row);
        }
        Ok(())
    }
}

/// Adapter: any [`crate::nn::Engine`] as a [`BatchRunner`].  The
/// batcher's packed feature buffer feeds the engine's `forward_packed`,
/// so whole batches hit the engine's (possibly parallel) batched
/// datapath instead of a per-sample loop.  Nested parallelism is set on
/// the engine itself (`FloatEngine::with_parallelism` /
/// `FixedEngine::with_parallelism`, CLI `--engine-parallelism`).
pub struct EngineRunner {
    engine: Box<dyn crate::nn::Engine>,
    max_batch: usize,
}

impl EngineRunner {
    pub fn new(engine: Box<dyn crate::nn::Engine>, max_batch: usize) -> Self {
        Self {
            engine,
            max_batch: max_batch.max(1),
        }
    }
}

impl BatchRunner for EngineRunner {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn run(&mut self, xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let stride = self.engine.arch().seq_len * self.engine.arch().input_size;
        anyhow::ensure!(
            xs.len() == n * stride,
            "packed batch length {} != {n} × {stride}",
            xs.len()
        );
        Ok(self.engine.forward_packed(xs, n))
    }

    /// The serving hot path: straight into the engine's scratch-pooled
    /// `forward_packed_into` — no per-request `Vec`s on either side.
    fn run_into(
        &mut self,
        xs: &[f32],
        n: usize,
        out: &mut PackedOut,
    ) -> anyhow::Result<()> {
        let stride = self.engine.arch().seq_len * self.engine.arch().input_size;
        anyhow::ensure!(
            xs.len() == n * stride,
            "packed batch length {} != {n} × {stride}",
            xs.len()
        );
        self.engine.forward_packed_into(xs, n, out);
        Ok(())
    }
}

#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    pub workers: usize,
    pub queue_capacity: usize,
    pub batcher: BatcherConfig,
    pub source: SourceConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            queue_capacity: 4096,
            batcher: BatcherConfig::default(),
            source: SourceConfig::default(),
        }
    }
}

/// Final run report (what `examples/trigger_serving.rs` prints).
#[derive(Debug, Clone)]
pub struct ServerReport {
    pub generated: u64,
    pub dropped: u64,
    pub completed: u64,
    pub accuracy: f64,
    pub mean_batch: f64,
    pub p50_latency_us: f64,
    pub p99_latency_us: f64,
    pub p50_queue_us: f64,
    pub wall_seconds: f64,
    pub throughput_hz: f64,
}

impl ServerReport {
    /// Build a report from a (possibly merged) metrics block and the run's
    /// wall time.  Shared by [`Server`], the sharded roll-up, and the
    /// virtual-clock test harness (which hand-builds metrics blocks and
    /// asserts the derived percentiles exactly).
    pub fn from_metrics(metrics: &ServerMetrics, wall: f64) -> Self {
        let completed = metrics.completed.load(Ordering::Relaxed);
        Self {
            generated: metrics.generated.load(Ordering::Relaxed),
            dropped: metrics.dropped.load(Ordering::Relaxed),
            completed,
            accuracy: metrics.accuracy(),
            mean_batch: metrics.mean_batch_size(),
            p50_latency_us: metrics.total_latency.quantile_us(0.5),
            p99_latency_us: metrics.total_latency.quantile_us(0.99),
            p50_queue_us: metrics.queue_latency.quantile_us(0.5),
            wall_seconds: wall,
            // Guard the zero-wall case (a live `Session::snapshot`
            // under a virtual clock that has not advanced): report 0,
            // never NaN/Inf.
            throughput_hz: if wall > 0.0 {
                completed as f64 / wall
            } else {
                0.0
            },
        }
    }

    pub fn render(&self) -> String {
        format!(
            "events generated   {}\n\
             events dropped     {} ({:.2}%)\n\
             events completed   {}\n\
             online accuracy    {:.4}\n\
             mean batch size    {:.2}\n\
             latency p50 / p99  {:.1} µs / {:.1} µs (queue p50 {:.1} µs)\n\
             wall time          {:.3} s\n\
             throughput         {:.0} events/s",
            self.generated,
            self.dropped,
            100.0 * self.dropped as f64 / self.generated.max(1) as f64,
            self.completed,
            self.accuracy,
            self.mean_batch,
            self.p50_latency_us,
            self.p99_latency_us,
            self.p50_queue_us,
            self.wall_seconds,
            self.throughput_hz,
        )
    }
}

/// One engine worker's serving loop: pull batches off `queue` under the
/// batcher policy until the queue is closed and drained, run them on
/// `runner`, record per-request metrics.  Shared by [`Server`] and
/// [`super::ShardedServer`] — a shard's workers are exactly this loop on
/// the shard's own queue, metrics block, and (tier-resolved) batcher
/// policy.  Every time-dependent step — the flush deadline inside
/// [`next_batch`], the completion instant metrics are recorded at —
/// reads `clock`, so the whole loop runs deterministically under a
/// [`VirtualClock`](super::clock::VirtualClock) (public for exactly that
/// test harness).
pub fn worker_loop(
    runner: &mut dyn BatchRunner,
    queue: &Arc<BoundedQueue<Request>>,
    metrics: &ServerMetrics,
    batcher_cfg: &BatcherConfig,
    clock: &dyn Clock,
) -> anyhow::Result<()> {
    worker_loop_with_sink(
        runner, queue, metrics, batcher_cfg, clock, None, None,
    )
}

/// [`worker_loop`] with an optional completion sink and feature pool:
/// after a batch's metrics are recorded, each request's feature buffer
/// is recycled into `feature_pool` and its output is forwarded to the
/// session's completion channel with its enqueue/complete instants.
/// `None`/`None` (the replay wrappers, the plain `worker_loop`) skips
/// both — identical hot path, bit for bit.
///
/// Steady-state allocation contract: the packing buffer and the
/// [`PackedOut`] persist across batches (capacity is retained), request
/// feature buffers return to the pool, and completions share **one**
/// `Arc<[f32]>` per batch instead of materializing one `Vec` per
/// request.  Per request, nothing is allocated once the fabric is warm.
pub(crate) fn worker_loop_with_sink(
    runner: &mut dyn BatchRunner,
    queue: &Arc<BoundedQueue<Request>>,
    metrics: &ServerMetrics,
    batcher_cfg: &BatcherConfig,
    clock: &dyn Clock,
    sink: Option<&CompletionSink>,
    feature_pool: Option<&BufferPool<Vec<f32>>>,
) -> anyhow::Result<()> {
    let cap = runner.max_batch().min(batcher_cfg.max_batch).max(1);
    let local_cfg = BatcherConfig {
        max_batch: cap,
        max_wait: batcher_cfg.max_wait,
    };
    // Worker-lifetime buffers: packed inputs in, packed rows out.
    let mut packed: Vec<f32> = Vec::new();
    let mut out = PackedOut::new();
    while let Some(batch) = next_batch(queue, &local_cfg, clock) {
        let n = batch.len();
        batch.pack_features_into(&mut packed);
        runner.run_into(&packed, n, &mut out)?;
        anyhow::ensure!(
            out.rows() == n && out.as_flat().len() == n * out.width(),
            "runner output count: {} rows for {n} requests",
            out.rows()
        );
        let done = clock.now();
        metrics.observe_batch_packed(&batch, &out, done);
        // One shared buffer per batch backs every completion's output —
        // built only when someone will receive it.
        let width = out.width();
        let shared: Option<Arc<[f32]>> =
            sink.map(|_| Arc::from(out.as_flat()));
        for (i, request) in batch.requests.into_iter().enumerate() {
            let Request {
                id,
                features,
                enqueued_at,
                ..
            } = request;
            // Recycle the feature buffer *before* the completion becomes
            // visible: a submitter ping-ponging submit → recv → submit
            // must always find its buffer already pooled (the
            // zero-allocation regression test pins this order).
            if let Some(pool) = feature_pool {
                let mut buf = features;
                buf.clear();
                pool.put(buf);
            }
            if let (Some(sink), Some(shared)) = (sink, &shared) {
                // Completions are monitoring, not control flow: a full
                // channel (owner not draining) or a gone receiver
                // (session dropped mid-run) must never stall serving —
                // shed the notification and count it.
                let undelivered = sink
                    .tx
                    .try_send(Completion {
                        id,
                        output: Output::from_shared(
                            shared.clone(),
                            i * width,
                            (i + 1) * width,
                        ),
                        shard: sink.shard,
                        enqueued_at,
                        completed_at: done,
                    })
                    .is_err();
                if undelivered {
                    // SeqCst: `lost` closes the completion-channel
                    // accounting identity (sent == delivered + lost)
                    // that the model-check shed scenario asserts.
                    sink.lost.fetch_add(1, Ordering::SeqCst);
                }
            }
        }
    }
    Ok(())
}

pub struct Server;

impl Server {
    /// Run one serving session to completion — a thin wrapper over the
    /// live [`Session`] API: start a one-shard session, replay the
    /// configured synthetic source through `Session::submit`, shut down.
    ///
    /// `runner_factory` is invoked once *inside each worker thread* —
    /// this is what lets non-`Send` engines (PJRT) be used.
    pub fn run<F>(
        cfg: ServerConfig,
        generator: Box<dyn Generator>,
        runner_factory: F,
    ) -> anyhow::Result<ServerReport>
    where
        F: Fn() -> anyhow::Result<Box<dyn BatchRunner>>
            + Send
            + Sync
            + 'static,
    {
        Self::run_with_clock(
            cfg,
            generator,
            runner_factory,
            Arc::new(SystemClock),
        )
    }

    /// [`Server::run`] with an explicit serving [`Clock`].  Production
    /// callers use [`run`](Self::run) (system time); tests may pass a
    /// [`VirtualClock`](super::clock::VirtualClock) to make the batcher
    /// deadline and metrics path deterministic (arrival *pacing* stays
    /// real-time — the clock governs the deadline/latency path).
    pub fn run_with_clock<F>(
        cfg: ServerConfig,
        generator: Box<dyn Generator>,
        runner_factory: F,
        clock: Arc<dyn Clock>,
    ) -> anyhow::Result<ServerReport>
    where
        F: Fn() -> anyhow::Result<Box<dyn BatchRunner>>
            + Send
            + Sync
            + 'static,
    {
        // A one-shard session is exactly the classic single coordinator
        // (every routing policy degenerates to shard 0, the source seed
        // and tier stamp are identical) — asserted by the
        // shard-equivalence suite, so this wrapper has zero semantic
        // footprint.
        let session = Session::start_config(
            ShardedConfig {
                shards: 1,
                policy: ShardPolicy::HashId,
                tier_mix: TierMix::single(),
                shard_backends: Vec::new(),
                shard_batchers: Vec::new(),
                server: cfg,
            },
            clock,
            false,
            move |_shard| runner_factory(),
        )?;
        session.replay(generator);
        Ok(session.shutdown()?.merged)
    }
}

/// Binary (p > 0.5) or argmax label from output probabilities.
pub fn predicted_label(probs: &[f32]) -> u32 {
    if probs.len() == 1 {
        u32::from(probs[0] > 0.5)
    } else {
        probs
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite probs"))
            .map(|(i, _)| i as u32)
            .expect("non-empty probs")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::TopTagging;
    use std::time::Duration;

    /// Oracle runner: "classifies" using the mean dR feature, so online
    /// accuracy is well above chance — validates label plumbing.
    struct HeuristicRunner;

    impl BatchRunner for HeuristicRunner {
        fn max_batch(&self) -> usize {
            10
        }
        fn run(&mut self, xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
            let stride = 20 * 6;
            Ok((0..n)
                .map(|i| {
                    let x = &xs[i * stride..(i + 1) * stride];
                    let mut dr = 0.0f32;
                    let mut count = 0;
                    for p in 0..20 {
                        if x[p * 6] > 0.0 {
                            dr += x[p * 6 + 4];
                            count += 1;
                        }
                    }
                    let spread = dr / count.max(1) as f32;
                    vec![if spread > 0.3 { 0.9 } else { 0.1 }]
                })
                .collect())
        }
    }

    #[test]
    fn end_to_end_mock_serving() {
        let cfg = ServerConfig {
            workers: 2,
            queue_capacity: 8192,
            batcher: BatcherConfig {
                max_batch: 10,
                max_wait: Duration::from_micros(100),
            },
            source: SourceConfig {
                rate_hz: 200_000.0,
                poisson: true,
                n_events: 3000,
            },
        };
        let report = Server::run(cfg, Box::new(TopTagging::new(7)), || {
            Ok(Box::new(HeuristicRunner))
        })
        .unwrap();
        assert_eq!(report.generated, 3000);
        assert_eq!(report.completed + report.dropped, 3000);
        assert!(report.completed > 0);
        assert!(
            report.accuracy > 0.7,
            "heuristic accuracy {}",
            report.accuracy
        );
        assert!(report.mean_batch >= 1.0);
        assert!(report.throughput_hz > 0.0);
        assert!(report.render().contains("events completed"));
    }

    /// Full pipeline with the parallel batched FloatEngine as the backend
    /// (synthetic weights — no artifacts needed): every event accounted
    /// for, batches flow through `forward_packed`.
    #[test]
    fn end_to_end_with_parallel_float_engine() {
        use crate::model::{zoo, Cell, Weights};
        use crate::nn::FloatEngine;

        let arch = zoo::arch("top", Cell::Gru).unwrap();
        let weights = Weights::synthetic(&arch, 0x5EED);
        let cfg = ServerConfig {
            workers: 2,
            queue_capacity: 8192,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_micros(200),
            },
            source: SourceConfig {
                rate_hz: 150_000.0,
                poisson: true,
                n_events: 2000,
            },
        };
        let report = Server::run(cfg, Box::new(TopTagging::new(3)), move || {
            let engine = FloatEngine::new(&weights)?.with_parallelism(2);
            Ok(Box::new(EngineRunner::new(Box::new(engine), 32))
                as Box<dyn BatchRunner>)
        })
        .unwrap();
        assert_eq!(report.generated, 2000);
        assert_eq!(report.completed + report.dropped, 2000);
        assert!(report.completed > 0);
        assert!(report.mean_batch >= 1.0);
    }

    #[test]
    fn engine_init_failure_propagates() {
        let cfg = ServerConfig {
            source: SourceConfig {
                rate_hz: 1e6,
                poisson: false,
                n_events: 10,
            },
            ..Default::default()
        };
        let result = Server::run(cfg, Box::new(TopTagging::new(1)), || {
            anyhow::bail!("no engine")
        });
        assert!(result.is_err());
    }

    #[test]
    fn predicted_label_binary_and_argmax() {
        assert_eq!(predicted_label(&[0.7]), 1);
        assert_eq!(predicted_label(&[0.3]), 0);
        assert_eq!(predicted_label(&[0.1, 0.6, 0.3]), 1);
    }
}
