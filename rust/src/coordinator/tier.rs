//! Traffic classes: the tier mix stamped onto [`Request::route_key`].
//!
//! The paper's deployment story is two-tiered (§1, §5): bit-accurate
//! fixed-point designs serve the trigger path, full-precision models
//! serve everything that can tolerate latency.  One serving session
//! mixing both therefore needs a *traffic-class* layer: every request
//! carries a tier (trigger / offline / …) and the router steers each
//! tier to the shard owning the matching backend
//! ([`ShardPolicy::ModelKey`]).
//!
//! [`TierMix`] is that layer.  It is deliberately a **pure function of
//! `(seed, request id)`** — a hash, not a stateful RNG — so:
//!
//! * stamping never perturbs the source's arrival pacing or event
//!   generation (the stream replay contract of `source::run_with` is
//!   untouched);
//! * any sub-stream can be replayed independently: given the same seed,
//!   a standalone single-backend run serves exactly the requests its
//!   tier would have received in the mixed session, which is what makes
//!   the mixed-vs-standalone equivalence suite
//!   (`tests/backend_routing.rs`) possible.
//!
//! [`Request::route_key`]: super::Request::route_key
//! [`ShardPolicy::ModelKey`]: super::ShardPolicy::ModelKey

use crate::util::rng::splitmix64;

/// A configurable traffic-class mix: per-tier fractions that sum to 1.
/// `stamp(id)` assigns each request id a tier index in `0..tiers()`,
/// deterministically in `(seed, id)`.
#[derive(Debug, Clone)]
pub struct TierMix {
    /// Normalized per-tier traffic fractions (sum exactly 1 after
    /// normalization).
    fractions: Vec<f64>,
    /// Cumulative upper bounds; the last is forced to 1.0 so every
    /// hash value lands in some tier.
    cumulative: Vec<f64>,
    seed: u64,
}

impl TierMix {
    /// The single-class mix: every request is tier 0 (`route_key = 0`),
    /// reproducing the pre-multi-backend behavior bit for bit.
    pub fn single() -> Self {
        Self {
            fractions: vec![1.0],
            cumulative: vec![1.0],
            seed: 0,
        }
    }

    /// Build a mix from per-tier fractions.  Fractions must be finite,
    /// strictly positive, and sum to 1 within 1e-6 (they are then
    /// normalized exactly).
    pub fn new(fractions: &[f64], seed: u64) -> anyhow::Result<Self> {
        anyhow::ensure!(!fractions.is_empty(), "tier mix needs >= 1 fraction");
        for (i, &f) in fractions.iter().enumerate() {
            anyhow::ensure!(
                f.is_finite() && f > 0.0,
                "tier {i} fraction {f} must be a positive finite number"
            );
        }
        let sum: f64 = fractions.iter().sum();
        anyhow::ensure!(
            (sum - 1.0).abs() < 1e-6,
            "tier fractions sum to {sum}, expected 1"
        );
        let fractions: Vec<f64> = fractions.iter().map(|f| f / sum).collect();
        let mut cumulative = Vec::with_capacity(fractions.len());
        let mut acc = 0.0f64;
        for &f in &fractions {
            acc += f;
            cumulative.push(acc);
        }
        *cumulative.last_mut().expect("non-empty") = 1.0;
        Ok(Self {
            fractions,
            cumulative,
            seed,
        })
    }

    /// Parse a CLI spelling: comma-separated fractions (`"0.9,0.1"`).
    pub fn parse(csv: &str, seed: u64) -> anyhow::Result<Self> {
        let fractions: Vec<f64> = csv
            .split(',')
            .map(|part| {
                let part = part.trim();
                part.parse::<f64>().map_err(|e| {
                    anyhow::anyhow!("tier fraction {part:?}: {e}")
                })
            })
            .collect::<anyhow::Result<_>>()?;
        Self::new(&fractions, seed)
    }

    /// Equal share for each of `tiers` classes (the default when
    /// `--backends` is given without `--tier-mix`).
    pub fn uniform(tiers: usize, seed: u64) -> anyhow::Result<Self> {
        anyhow::ensure!(tiers >= 1, "tier mix needs >= 1 tier");
        Self::new(&vec![1.0 / tiers as f64; tiers], seed)
    }

    /// Number of traffic classes.
    pub fn tiers(&self) -> usize {
        self.fractions.len()
    }

    /// Configured traffic share of `tier`.
    pub fn fraction(&self, tier: usize) -> f64 {
        self.fractions[tier]
    }

    /// True for the degenerate one-class mix (every request keyed 0).
    pub fn is_single(&self) -> bool {
        self.fractions.len() == 1
    }

    /// Tier index for request `id`, in `0..tiers()`.  A pure function of
    /// `(seed, id)`: no internal state, no interaction with any other
    /// request — the property the replay/equivalence suites rely on.
    pub fn stamp(&self, id: u64) -> u64 {
        if self.fractions.len() == 1 {
            return 0;
        }
        // One splitmix64 step over a seed/id blend (the golden-ratio
        // multiply decorrelates sequential ids before the avalanche).
        let mut state = self.seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let u = (splitmix64(&mut state) >> 11) as f64
            * (1.0 / (1u64 << 53) as f64);
        self.cumulative
            .iter()
            .position(|&c| u < c)
            .unwrap_or(self.fractions.len() - 1) as u64
    }
}

impl Default for TierMix {
    fn default() -> Self {
        Self::single()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_mix_stamps_everything_zero() {
        let mix = TierMix::single();
        assert_eq!(mix.tiers(), 1);
        assert!(mix.is_single());
        for id in 0..512u64 {
            assert_eq!(mix.stamp(id), 0);
        }
    }

    #[test]
    fn invalid_fractions_rejected() {
        assert!(TierMix::new(&[], 0).is_err());
        assert!(TierMix::new(&[0.5, 0.6], 0).is_err(), "sum > 1");
        assert!(TierMix::new(&[0.5, 0.4], 0).is_err(), "sum < 1");
        assert!(TierMix::new(&[1.1, -0.1], 0).is_err(), "negative");
        assert!(TierMix::new(&[f64::NAN, 1.0], 0).is_err(), "nan");
        assert!(TierMix::new(&[0.0, 1.0], 0).is_err(), "zero share");
        assert!(TierMix::parse("0.9,0.2", 0).is_err());
        assert!(TierMix::parse("0.9,zebra", 0).is_err());
    }

    #[test]
    fn parse_and_uniform_roundtrip() {
        let mix = TierMix::parse("0.9, 0.1", 7).unwrap();
        assert_eq!(mix.tiers(), 2);
        assert!((mix.fraction(0) - 0.9).abs() < 1e-12);
        assert!((mix.fraction(1) - 0.1).abs() < 1e-12);
        assert!(!mix.is_single());

        let uni = TierMix::uniform(4, 7).unwrap();
        assert_eq!(uni.tiers(), 4);
        for t in 0..4 {
            assert!((uni.fraction(t) - 0.25).abs() < 1e-12);
        }
        assert!(TierMix::uniform(0, 7).is_err());
    }

    #[test]
    fn stamp_is_deterministic_in_seed_and_id() {
        let a = TierMix::new(&[0.9, 0.1], 42).unwrap();
        let b = TierMix::new(&[0.9, 0.1], 42).unwrap();
        for id in 0..4096u64 {
            assert_eq!(a.stamp(id), b.stamp(id), "id {id}");
            assert!(a.stamp(id) < 2);
        }
        // A different seed must produce a different partition (4096 ids:
        // the chance a correct hash agrees everywhere is ~0; only a stamp
        // that ignores the seed would pass).
        let c = TierMix::new(&[0.9, 0.1], 43).unwrap();
        assert!(
            (0..4096u64).any(|id| c.stamp(id) != a.stamp(id)),
            "seed must repartition the stream"
        );
    }

    #[test]
    fn stamp_respects_fractions() {
        let mix = TierMix::new(&[0.9, 0.1], 0xC1A5).unwrap();
        let n = 20_000u64;
        let tier0 = (0..n).filter(|&id| mix.stamp(id) == 0).count();
        let share = tier0 as f64 / n as f64;
        assert!((share - 0.9).abs() < 0.02, "tier-0 share {share}");

        let thirds = TierMix::uniform(3, 5).unwrap();
        let mut counts = [0usize; 3];
        for id in 0..n {
            counts[thirds.stamp(id) as usize] += 1;
        }
        for (t, &c) in counts.iter().enumerate() {
            let share = c as f64 / n as f64;
            assert!((share - 1.0 / 3.0).abs() < 0.02, "tier {t} share {share}");
        }
    }
}
