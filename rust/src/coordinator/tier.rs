//! Traffic classes: the tier mix stamped onto [`Request::route_key`].
//!
//! The paper's deployment story is two-tiered (§1, §5): bit-accurate
//! fixed-point designs serve the trigger path, full-precision models
//! serve everything that can tolerate latency.  One serving session
//! mixing both therefore needs a *traffic-class* layer: every request
//! carries a tier (trigger / offline / …) and the router steers each
//! tier to the shard owning the matching backend
//! ([`ShardPolicy::ModelKey`]).
//!
//! [`TierMix`] is that layer.  It is deliberately a **pure function of
//! `(seed, request id)`** — a hash, not a stateful RNG — so:
//!
//! * stamping never perturbs the source's arrival pacing or event
//!   generation (the stream replay contract of `source::run_with` is
//!   untouched);
//! * any sub-stream can be replayed independently: given the same seed,
//!   a standalone single-backend run serves exactly the requests its
//!   tier would have received in the mixed session, which is what makes
//!   the mixed-vs-standalone equivalence suite
//!   (`tests/backend_routing.rs`) possible.
//!
//! Tiers also carry a **batching policy** ([`TierPolicy`]): the trigger
//! tier is pinned at strict batch-1 (`max_wait = 0`) while the offline
//! tier batches deep, so one heterogeneous session holds both ends of
//! the latency/throughput curve at once (the paper's §5.2 trade).  The
//! CLI spells it `--batch-policy trigger:1:0,offline:64:2000`.
//!
//! [`Request::route_key`]: super::Request::route_key
//! [`ShardPolicy::ModelKey`]: super::ShardPolicy::ModelKey

use std::time::Duration;

use crate::util::rng::splitmix64;

use super::batcher::BatcherConfig;

/// A configurable traffic-class mix: per-tier fractions that sum to 1.
/// `stamp(id)` assigns each request id a tier index in `0..tiers()`,
/// deterministically in `(seed, id)`.
#[derive(Debug, Clone)]
pub struct TierMix {
    /// Normalized per-tier traffic fractions (sum exactly 1 after
    /// normalization).
    fractions: Vec<f64>,
    /// Cumulative upper bounds; the last is forced to 1.0 so every
    /// hash value lands in some tier.
    cumulative: Vec<f64>,
    seed: u64,
}

impl TierMix {
    /// The single-class mix: every request is tier 0 (`route_key = 0`),
    /// reproducing the pre-multi-backend behavior bit for bit.
    pub fn single() -> Self {
        Self {
            fractions: vec![1.0],
            cumulative: vec![1.0],
            seed: 0,
        }
    }

    /// Build a mix from per-tier fractions.  Fractions must be finite,
    /// strictly positive, and sum to 1 within 1e-6 (they are then
    /// normalized exactly).
    pub fn new(fractions: &[f64], seed: u64) -> anyhow::Result<Self> {
        anyhow::ensure!(!fractions.is_empty(), "tier mix needs >= 1 fraction");
        for (i, &f) in fractions.iter().enumerate() {
            anyhow::ensure!(
                f.is_finite() && f > 0.0,
                "tier {i} fraction {f} must be a positive finite number"
            );
        }
        let sum: f64 = fractions.iter().sum();
        anyhow::ensure!(
            (sum - 1.0).abs() < 1e-6,
            "tier fractions sum to {sum}, expected 1"
        );
        let fractions: Vec<f64> = fractions.iter().map(|f| f / sum).collect();
        let mut cumulative = Vec::with_capacity(fractions.len());
        let mut acc = 0.0f64;
        for &f in &fractions {
            acc += f;
            cumulative.push(acc);
        }
        *cumulative.last_mut().expect("non-empty") = 1.0;
        Ok(Self {
            fractions,
            cumulative,
            seed,
        })
    }

    /// Parse a CLI spelling: comma-separated fractions (`"0.9,0.1"`).
    pub fn parse(csv: &str, seed: u64) -> anyhow::Result<Self> {
        let fractions: Vec<f64> = csv
            .split(',')
            .map(|part| {
                let part = part.trim();
                part.parse::<f64>().map_err(|e| {
                    anyhow::anyhow!("tier fraction {part:?}: {e}")
                })
            })
            .collect::<anyhow::Result<_>>()?;
        Self::new(&fractions, seed)
    }

    /// Equal share for each of `tiers` classes (the default when
    /// `--backends` is given without `--tier-mix`).
    pub fn uniform(tiers: usize, seed: u64) -> anyhow::Result<Self> {
        anyhow::ensure!(tiers >= 1, "tier mix needs >= 1 tier");
        Self::new(&vec![1.0 / tiers as f64; tiers], seed)
    }

    /// Number of traffic classes.
    pub fn tiers(&self) -> usize {
        self.fractions.len()
    }

    /// Configured traffic share of `tier`.
    pub fn fraction(&self, tier: usize) -> f64 {
        self.fractions[tier]
    }

    /// True for the degenerate one-class mix (every request keyed 0).
    pub fn is_single(&self) -> bool {
        self.fractions.len() == 1
    }

    /// Tier index for request `id`, in `0..tiers()`.  A pure function of
    /// `(seed, id)`: no internal state, no interaction with any other
    /// request — the property the replay/equivalence suites rely on.
    pub fn stamp(&self, id: u64) -> u64 {
        if self.fractions.len() == 1 {
            return 0;
        }
        // One splitmix64 step over a seed/id blend (the golden-ratio
        // multiply decorrelates sequential ids before the avalanche).
        let mut state = self.seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let u = (splitmix64(&mut state) >> 11) as f64
            * (1.0 / (1u64 << 53) as f64);
        self.cumulative
            .iter()
            .position(|&c| u < c)
            .unwrap_or(self.fractions.len() - 1) as u64
    }
}

impl Default for TierMix {
    fn default() -> Self {
        Self::single()
    }
}

/// Latency class of a backend: which end of the paper's §5.2
/// batch-vs-latency curve its shard should hold.  This is what resolves
/// a backend name to a default per-shard [`BatcherConfig`] when the
/// operator does not pin one with `--batch-policy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierClass {
    /// The trigger path: strict batch-1, never wait — a trigger never
    /// trades one event's latency for throughput.
    Trigger,
    /// The offline path: batch deep, amortize dispatch — latency is
    /// negotiable, throughput is the budget.
    Offline,
}

impl TierClass {
    /// Class of a registered backend: the bit-accurate engines (`fixed`,
    /// and the reserved `pjrt` slot standing in for the FPGA design) are
    /// trigger-path; everything else serves offline traffic.
    pub fn for_backend(backend: &str) -> Self {
        match backend {
            "fixed" | "pjrt" => Self::Trigger,
            _ => Self::Offline,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Trigger => "trigger",
            Self::Offline => "offline",
        }
    }

    /// The class's default batcher: trigger is pinned at batch-1 /
    /// zero-wait; offline batches deep (64 requests or a 2 ms deadline,
    /// whichever first).
    pub fn default_batcher(self) -> BatcherConfig {
        match self {
            Self::Trigger => BatcherConfig {
                max_batch: 1,
                max_wait: Duration::ZERO,
            },
            Self::Offline => BatcherConfig {
                max_batch: 64,
                max_wait: Duration::from_micros(2_000),
            },
        }
    }
}

/// One named per-shard batching policy entry.
#[derive(Debug, Clone)]
pub struct TierBatch {
    /// Display label (`trigger`, `offline`, or any operator-chosen
    /// name); purely informational — position selects the shard.
    pub name: String,
    pub batcher: BatcherConfig,
}

/// Per-shard batching policy: entry *i* is shard *i*'s batcher, which
/// under [`ShardPolicy::ModelKey`](super::ShardPolicy::ModelKey) routing
/// is tier *i*'s batcher.  Parsed from the CLI grammar
///
/// ```text
/// --batch-policy <name>:<max_batch>:<max_wait_us>[,<name>:<max_batch>:<max_wait_us>...]
/// ```
///
/// e.g. `trigger:1:0,offline:64:2000` — shard 0 serves strict batch-1,
/// shard 1 batches up to 64 with a 2 ms deadline.  `max_batch` must be
/// >= 1 (a zero-size batch can never flush; rejected at parse time).
#[derive(Debug, Clone)]
pub struct TierPolicy {
    pub entries: Vec<TierBatch>,
}

impl TierPolicy {
    /// Parse the CLI spelling (see the type-level grammar).
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let mut entries = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            let fields: Vec<&str> = part.split(':').collect();
            anyhow::ensure!(
                fields.len() == 3,
                "batch-policy entry {part:?} is not \
                 <name>:<max_batch>:<max_wait_us>"
            );
            let name = fields[0].trim();
            anyhow::ensure!(
                !name.is_empty(),
                "batch-policy entry {part:?} has an empty tier name"
            );
            let max_batch: usize = fields[1].trim().parse().map_err(|e| {
                anyhow::anyhow!("batch-policy {name}: max_batch {:?}: {e}", fields[1])
            })?;
            let wait_us: u64 = fields[2].trim().parse().map_err(|e| {
                anyhow::anyhow!("batch-policy {name}: max_wait_us {:?}: {e}", fields[2])
            })?;
            let batcher = BatcherConfig::new(
                max_batch,
                Duration::from_micros(wait_us),
            )
            .map_err(|e| anyhow::anyhow!("batch-policy {name}: {e}"))?;
            entries.push(TierBatch {
                name: name.to_string(),
                batcher,
            });
        }
        anyhow::ensure!(!entries.is_empty(), "batch-policy needs >= 1 entry");
        Ok(Self { entries })
    }

    /// Default policy for a heterogeneous session: each backend's
    /// [`TierClass`] default, in shard order.
    pub fn for_backends<S: AsRef<str>>(backends: &[S]) -> Self {
        let entries = backends
            .iter()
            .map(|b| {
                let class = TierClass::for_backend(b.as_ref());
                TierBatch {
                    name: class.name().to_string(),
                    batcher: class.default_batcher(),
                }
            })
            .collect();
        Self { entries }
    }

    /// The per-shard batcher configs, in shard order (what
    /// `ShardedConfig::shard_batchers` takes).
    pub fn batchers(&self) -> Vec<BatcherConfig> {
        self.entries.iter().map(|e| e.batcher).collect()
    }

    /// Render back to the CLI grammar (for banners and reports).
    pub fn describe(&self) -> String {
        self.entries
            .iter()
            .map(|e| {
                format!(
                    "{}:{}:{}",
                    e.name,
                    e.batcher.max_batch,
                    e.batcher.max_wait.as_micros()
                )
            })
            .collect::<Vec<_>>()
            .join(",")
    }
}

impl std::str::FromStr for TierPolicy {
    type Err = anyhow::Error;

    /// [`TierPolicy::parse`] as `FromStr`, so the CLI reads batch
    /// policies with `.parse()` like every other typed `ServingSpec`
    /// field.
    fn from_str(spec: &str) -> anyhow::Result<Self> {
        Self::parse(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_mix_stamps_everything_zero() {
        let mix = TierMix::single();
        assert_eq!(mix.tiers(), 1);
        assert!(mix.is_single());
        for id in 0..512u64 {
            assert_eq!(mix.stamp(id), 0);
        }
    }

    #[test]
    fn invalid_fractions_rejected() {
        assert!(TierMix::new(&[], 0).is_err());
        assert!(TierMix::new(&[0.5, 0.6], 0).is_err(), "sum > 1");
        assert!(TierMix::new(&[0.5, 0.4], 0).is_err(), "sum < 1");
        assert!(TierMix::new(&[1.1, -0.1], 0).is_err(), "negative");
        assert!(TierMix::new(&[f64::NAN, 1.0], 0).is_err(), "nan");
        assert!(TierMix::new(&[0.0, 1.0], 0).is_err(), "zero share");
        assert!(TierMix::parse("0.9,0.2", 0).is_err());
        assert!(TierMix::parse("0.9,zebra", 0).is_err());
    }

    #[test]
    fn parse_and_uniform_roundtrip() {
        let mix = TierMix::parse("0.9, 0.1", 7).unwrap();
        assert_eq!(mix.tiers(), 2);
        assert!((mix.fraction(0) - 0.9).abs() < 1e-12);
        assert!((mix.fraction(1) - 0.1).abs() < 1e-12);
        assert!(!mix.is_single());

        let uni = TierMix::uniform(4, 7).unwrap();
        assert_eq!(uni.tiers(), 4);
        for t in 0..4 {
            assert!((uni.fraction(t) - 0.25).abs() < 1e-12);
        }
        assert!(TierMix::uniform(0, 7).is_err());
    }

    #[test]
    fn stamp_is_deterministic_in_seed_and_id() {
        let a = TierMix::new(&[0.9, 0.1], 42).unwrap();
        let b = TierMix::new(&[0.9, 0.1], 42).unwrap();
        for id in 0..4096u64 {
            assert_eq!(a.stamp(id), b.stamp(id), "id {id}");
            assert!(a.stamp(id) < 2);
        }
        // A different seed must produce a different partition (4096 ids:
        // the chance a correct hash agrees everywhere is ~0; only a stamp
        // that ignores the seed would pass).
        let c = TierMix::new(&[0.9, 0.1], 43).unwrap();
        assert!(
            (0..4096u64).any(|id| c.stamp(id) != a.stamp(id)),
            "seed must repartition the stream"
        );
    }

    /// A one-entry explicit mix must behave exactly like
    /// `TierMix::single()`: one tier, every request keyed 0.
    #[test]
    fn explicit_single_tier_mix_matches_single() {
        let mix = TierMix::new(&[1.0], 99).unwrap();
        assert_eq!(mix.tiers(), 1);
        assert!(mix.is_single());
        assert!((mix.fraction(0) - 1.0).abs() < 1e-12);
        for id in 0..1024u64 {
            assert_eq!(mix.stamp(id), 0, "id {id}");
        }
    }

    /// `1.0,0.0` names a tier that can never receive traffic — a config
    /// error (a backend would sit idle silently), not a valid mix.
    #[test]
    fn zero_share_tiers_rejected_even_when_sum_is_one() {
        for spec in ["1.0,0.0", "0.0,1.0", "0.5,0.0,0.5"] {
            let err = TierMix::parse(spec, 0).unwrap_err();
            assert!(
                format!("{err:#}").contains("positive"),
                "{spec}: {err:#}"
            );
        }
    }

    /// Near-boundary stamping: tiny-but-positive fractions, fractions
    /// whose float cumulative sum lands just shy of 1, and many-tier
    /// mixes must all keep every stamp strictly inside `0..tiers()` —
    /// the forced final cumulative bound of 1.0 guarantees it.
    #[test]
    fn stamp_stays_in_range_near_fraction_boundaries() {
        let cases: Vec<TierMix> = vec![
            TierMix::new(&[1e-9, 1.0 - 1e-9], 7).unwrap(),
            TierMix::new(&[1.0 - 1e-9, 1e-9], 7).unwrap(),
            // 10 × 0.1 accumulates float error near the top boundary.
            TierMix::new(&[0.1; 10], 3).unwrap(),
            TierMix::new(&[1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0], 1).unwrap(),
            TierMix::uniform(7, 5).unwrap(),
        ];
        for (case, mix) in cases.iter().enumerate() {
            let tiers = mix.tiers() as u64;
            for id in 0..8192u64 {
                let t = mix.stamp(id);
                assert!(t < tiers, "case {case} id {id}: tier {t}");
            }
        }
        // The dominant tier of a (1e-9, rest) mix takes essentially all
        // traffic; the starved tier keeps its index valid regardless.
        let skewed = TierMix::new(&[1e-9, 1.0 - 1e-9], 7).unwrap();
        let tier1 = (0..8192u64).filter(|&id| skewed.stamp(id) == 1).count();
        assert!(tier1 > 8000, "dominant tier got {tier1}/8192");
    }

    #[test]
    fn sums_away_from_one_rejected() {
        for bad in [&[0.2, 0.2][..], &[0.7, 0.7][..], &[0.9999, 0.0002][..]] {
            assert!(TierMix::new(bad, 0).is_err(), "{bad:?}");
        }
        // ... while 1e-7-level float noise around 1 is normalized away.
        assert!(TierMix::new(&[0.3000000499, 0.7], 0).is_ok());
    }

    #[test]
    fn tier_class_resolves_backends() {
        assert_eq!(TierClass::for_backend("fixed"), TierClass::Trigger);
        assert_eq!(TierClass::for_backend("pjrt"), TierClass::Trigger);
        assert_eq!(TierClass::for_backend("float"), TierClass::Offline);
        let trig = TierClass::Trigger.default_batcher();
        assert_eq!(trig.max_batch, 1);
        assert!(trig.max_wait.is_zero());
        let off = TierClass::Offline.default_batcher();
        assert!(off.max_batch > 1);
        assert!(!off.max_wait.is_zero());
    }

    #[test]
    fn tier_policy_parse_roundtrip() {
        let policy = TierPolicy::parse("trigger:1:0, offline:64:2000").unwrap();
        assert_eq!(policy.entries.len(), 2);
        assert_eq!(policy.entries[0].name, "trigger");
        assert_eq!(policy.entries[0].batcher.max_batch, 1);
        assert!(policy.entries[0].batcher.max_wait.is_zero());
        assert_eq!(policy.entries[1].batcher.max_batch, 64);
        assert_eq!(
            policy.entries[1].batcher.max_wait,
            Duration::from_micros(2000)
        );
        assert_eq!(policy.describe(), "trigger:1:0,offline:64:2000");
        assert_eq!(policy.batchers().len(), 2);
    }

    #[test]
    fn tier_policy_rejects_malformed_and_zero_batch_entries() {
        assert!(TierPolicy::parse("").is_err());
        assert!(TierPolicy::parse("trigger:1").is_err(), "missing field");
        assert!(TierPolicy::parse("trigger:1:0:9").is_err(), "extra field");
        assert!(TierPolicy::parse(":1:0").is_err(), "empty name");
        assert!(TierPolicy::parse("t:zebra:0").is_err(), "bad max_batch");
        assert!(TierPolicy::parse("t:1:zebra").is_err(), "bad wait");
        // The max_batch = 0 config that used to reach the batcher.
        let err = TierPolicy::parse("trigger:0:0").unwrap_err();
        assert!(
            format!("{err:#}").contains("max_batch must be >= 1"),
            "{err:#}"
        );
    }

    #[test]
    fn tier_policy_for_backends_matches_classes() {
        let policy =
            TierPolicy::for_backends(&["fixed".to_string(), "float".into()]);
        assert_eq!(policy.entries[0].name, "trigger");
        assert_eq!(policy.entries[0].batcher.max_batch, 1);
        assert_eq!(policy.entries[1].name, "offline");
        assert_eq!(policy.entries[1].batcher.max_batch, 64);
        assert_eq!(policy.describe(), "trigger:1:0,offline:64:2000");
    }

    #[test]
    fn stamp_respects_fractions() {
        let mix = TierMix::new(&[0.9, 0.1], 0xC1A5).unwrap();
        let n = 20_000u64;
        let tier0 = (0..n).filter(|&id| mix.stamp(id) == 0).count();
        let share = tier0 as f64 / n as f64;
        assert!((share - 0.9).abs() < 0.02, "tier-0 share {share}");

        let thirds = TierMix::uniform(3, 5).unwrap();
        let mut counts = [0usize; 3];
        for id in 0..n {
            counts[thirds.stamp(id) as usize] += 1;
        }
        for (t, &c) in counts.iter().enumerate() {
            let share = c as f64 / n as f64;
            assert!((share - 1.0 / 3.0).abs() < 0.02, "tier {t} share {share}");
        }
    }
}
