//! `rnn-hls` launcher: serve | report | sweep | golden | list.
//!
//! ```text
//! rnn-hls report all                    # regenerate every table + figure
//! rnn-hls report fig2 --samples 500
//! rnn-hls serve --model top_gru --engine pjrt --rate 20000
//! rnn-hls serve --engine float --shards 4 --shard-policy round-robin
//! rnn-hls serve --shards 2 --shard-policy model-key \
//!               --backends fixed,float --tier-mix 0.9,0.1
//! rnn-hls sweep --benchmark top --width 16
//! rnn-hls golden                        # PJRT vs python golden outputs
//! ```
//!
//! ## Serving knobs
//!
//! * `--shards N` — partition the request stream across N independent
//!   coordinator shards (own queue, batcher, and engine workers each);
//!   per-shard metrics are rolled up into one report.  `--shards 1`
//!   (default) is the classic single coordinator.
//! * `--shard-policy hash|round-robin|model-key` — the routing layer in
//!   front of the shards.  `hash` is sticky per request id, `round-robin`
//!   is perfectly balanced, `model-key` routes on `Request::route_key`
//!   (stamped from the tier mix in heterogeneous sessions).
//! * `--backends fixed,float` — heterogeneous session: one backend per
//!   shard (resolved by name through the `nn::BackendSpec` registry),
//!   with `--tier-mix 0.9,0.1` setting each tier's traffic share and the
//!   report splitting p50/p99 + throughput per backend.  Requires
//!   `--shard-policy model-key` so tiers reach their backends.
//! * `--batch-policy trigger:1:0,offline:64:2000` — per-shard batching
//!   (grammar: comma-separated `<name>:<max_batch>:<max_wait_us>`, one
//!   entry per shard).  Heterogeneous sessions default to each backend's
//!   tier class: trigger backends (`fixed`, `pjrt`) pinned at strict
//!   batch-1 / zero-wait, offline backends batching deep — one session
//!   holding both ends of the latency/throughput curve.
//! * `--workers` / `--engine-parallelism` — threads per shard and per
//!   batch; total budget is `shards × workers × engine-parallelism`.
//! * `--listen 127.0.0.1:7432` — network serving: instead of replaying a
//!   synthetic source, put the `ingest::wire` TCP front-end over the live
//!   session and accept typed request frames for `--serve-for-ms`
//!   milliseconds (then drain-then-close).  `--metrics-listen` adds the
//!   line-oriented metrics endpoint, `--max-connections` caps concurrent
//!   connections (beyond it new ones are answered `BUSY`).  Drive it
//!   with the `loadgen` binary (`loadgen --addr <addr>`).
//!
//! ## Bench smoke (CI)
//!
//! `./ci.sh --bench-smoke` runs a reduced-iteration
//! `benches/throughput_batch.rs` — including the shards × workers sweep —
//! and emits `BENCH_serving.json` (samples/s, p50/p99 µs per config),
//! which the `bench-smoke` CI job uploads as an artifact so the perf
//! trajectory is tracked per commit.

use std::path::PathBuf;
use std::time::Duration;

use rnn_hls::config::{Fig2Config, SweepConfig};
use rnn_hls::coordinator::{
    BackendKind, BatchRunner, BatcherConfig, EngineRunner, ServingSpec,
    Session, SourceConfig, TierMix, TierPolicy,
};
use rnn_hls::data::generators;
use rnn_hls::fixed::FixedSpec;
use rnn_hls::hls::{
    explore, paper, Device, HlsConfig, HlsDesign, ReuseFactor, RnnMode,
};
use rnn_hls::model::Weights;
use rnn_hls::nn::{BackendCtx, BackendSpec};
use rnn_hls::report::{
    accuracy, explore as explore_report, fig2, resources, tables, throughput,
};
use rnn_hls::runtime::{manifest, Runtime};
use rnn_hls::util::cli::Command;

fn main() {
    if let Err(err) = run() {
        eprintln!("error: {err:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match argv.split_first() {
        Some((s, rest)) => (s.as_str(), rest.to_vec()),
        None => {
            println!("{}", usage());
            return Ok(());
        }
    };
    match sub {
        "report" => cmd_report(&rest),
        "accuracy" => cmd_accuracy(&rest),
        "serve" => cmd_serve(&rest),
        "sweep" => cmd_sweep(&rest),
        "explore" => cmd_explore(&rest),
        "golden" => cmd_golden(&rest),
        "list" => cmd_list(&rest),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand {other:?}\n\n{}", usage()),
    }
}

fn usage() -> String {
    "rnn-hls — ultra-low-latency RNN inference (hls4ml paper reproduction)\n\
     \n\
     Subcommands:\n\
       report <what>   regenerate paper tables/figures\n\
                       what: table1|table2|table3|table4|table5|fig2|\n\
                             fig345|fig6|throughput|all\n\
       accuracy        float-vs-fixed AUC sweep over a real checkpoint\n\
                       (--weights <path.json|path.onnx>; defaults to the\n\
                       bundled trained top_gru fixture + test slice)\n\
       serve           run the trigger-style serving coordinator\n\
                       (--shards N partitions the stream across N\n\
                       coordinator shards; --shard-policy picks routing)\n\
       sweep           design-space sweep over the HLS model\n\
       explore         Pareto search over reuse x precision x strategy x\n\
                       clock (--budget-ns/--min-auc budget queries;\n\
                       --accuracy joins measured AUC from the checkpoint)\n\
       golden          cross-check PJRT outputs vs python goldens\n\
       list            list models available in the artifacts manifest\n\
     \n\
     Run `rnn-hls <subcommand> --help` for options."
        .to_string()
}

fn artifacts_from(args: &rnn_hls::util::cli::Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(manifest::default_artifacts_dir)
}

// ---------------------------------------------------------------- report

fn cmd_report(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("report", "regenerate paper tables/figures")
        .opt("artifacts", "artifacts directory", None)
        .opt("out", "directory for CSV output", Some("reports"))
        .opt("samples", "Fig.2 evaluation samples per model", Some("600"))
        .opt("only", "Fig.2: single model key", None)
        .flag("no-csv", "skip CSV files");
    let args = cmd.parse(rest)?;
    let what = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let artifacts = artifacts_from(&args);
    let out_dir = if args.has("no-csv") {
        None
    } else {
        Some(PathBuf::from(args.get_or("out", "reports")))
    };
    let out = out_dir.as_deref();

    let run_fig2 = |keys: Option<Vec<String>>| -> anyhow::Result<()> {
        let mut cfg = Fig2Config {
            samples: args.parse_num("samples", 600usize)?,
            ..Default::default()
        };
        if let Some(keys) = keys {
            cfg.keys = keys;
        }
        let points = fig2::run(&artifacts, &cfg, out)?;
        for key in &cfg.keys {
            match fig2::shape_check(&points, key) {
                Ok(()) => println!("fig2 shape check OK: {key}"),
                Err(e) => println!("fig2 shape check WARN: {e}"),
            }
        }
        Ok(())
    };

    match what {
        "table1" => {
            tables::table1(out)?;
        }
        "table2" => {
            tables::latency_tables("top", out)?;
        }
        "table3" => {
            tables::latency_tables("flavor", out)?;
        }
        "table4" => {
            tables::latency_tables("quickdraw", out)?;
        }
        "table5" => {
            tables::table5(out)?;
        }
        "fig2" => {
            let keys = args.get("only").map(|k| vec![k.to_string()]);
            run_fig2(keys)?;
        }
        "fig345" | "fig3" | "fig4" | "fig5" => {
            for benchmark in ["top", "flavor", "quickdraw"] {
                resources::figs345(&SweepConfig::paper(benchmark), out)?;
            }
        }
        "fig6" => {
            resources::fig6(out)?;
        }
        "throughput" => {
            let report = throughput::run(&artifacts, 2_000, out)?;
            match throughput::shape_check(&report) {
                Ok(()) => println!("throughput shape check OK"),
                Err(e) => println!("throughput shape check WARN: {e}"),
            }
        }
        "all" => {
            tables::table1(out)?;
            tables::latency_tables("top", out)?;
            tables::latency_tables("flavor", out)?;
            tables::latency_tables("quickdraw", out)?;
            tables::table5(out)?;
            for benchmark in ["top", "flavor", "quickdraw"] {
                resources::figs345(&SweepConfig::paper(benchmark), out)?;
            }
            resources::fig6(out)?;
            run_fig2(None)?;
            let report = throughput::run(&artifacts, 2_000, out)?;
            match throughput::shape_check(&report) {
                Ok(()) => println!("throughput shape check OK"),
                Err(e) => println!("throughput shape check WARN: {e}"),
            }
        }
        other => anyhow::bail!("unknown report {other:?}"),
    }
    Ok(())
}

// -------------------------------------------------------------- accuracy

/// Bundled fixture defaults: a real trained checkpoint plus a frozen
/// test-stream slice committed under `tests/fixtures/`, so
/// `rnn-hls accuracy` answers the paper's Fig. 2 question on a bare
/// checkout (no `make artifacts` needed).
const DEFAULT_WEIGHTS: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/top_gru.json");
const DEFAULT_DATASET: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/tests/fixtures/top_test_slice.bin"
);

fn cmd_accuracy(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "accuracy",
        "float-vs-fixed AUC sweep over a real checkpoint",
    )
    .opt(
        "weights",
        "checkpoint path, .json (interchange doc) or .onnx",
        Some(DEFAULT_WEIGHTS),
    )
    .opt("dataset", "RNNDAT01 evaluation set", Some(DEFAULT_DATASET))
    .opt(
        "model",
        "architecture hint for foreign .onnx files whose graph name is \
         not a zoo key (e.g. top_gru)",
        None,
    )
    .opt(
        "specs",
        "fixed-point ladder, comma-separated WIDTH:INTEGER",
        Some("8:4,12:6,16:6,20:8"),
    )
    .opt("samples", "cap evaluated events (0 = all)", Some("0"))
    .opt("workers", "evaluation threads", Some("4"))
    .opt("json", "write the BENCH_accuracy.json artifact here", None);
    let args = cmd.parse(rest)?;

    let hint = match args.get("model") {
        Some(key) => {
            let (benchmark, cell) = key.rsplit_once('_').ok_or_else(|| {
                anyhow::anyhow!("model key {key:?} is not <benchmark>_<cell>")
            })?;
            Some(rnn_hls::model::zoo::arch(benchmark, cell.parse()?)?)
        }
        None => None,
    };
    let weights_path = PathBuf::from(args.get_or("weights", DEFAULT_WEIGHTS));
    let weights = Weights::load_path(&weights_path, hint.as_ref())?;
    println!(
        "loaded {} ({} params) from {}",
        weights.arch.key(),
        weights.arch.param_count(),
        weights_path.display()
    );

    let ds = rnn_hls::data::Dataset::load(args.get_or("dataset", DEFAULT_DATASET))?;
    let samples: usize = args.parse_num("samples", 0usize)?;
    let ds = if samples > 0 { ds.truncated(samples) } else { ds };
    let specs =
        accuracy::parse_specs(args.get_or("specs", "8:4,12:6,16:6,20:8"))?;
    let workers: usize = args.parse_num("workers", 4usize)?;

    let report = accuracy::run(&weights, &ds, &specs, workers)?;
    println!("{}", accuracy::render(&report));
    match accuracy::shape_check(&report) {
        Ok(()) => println!("accuracy shape check OK: {}", report.key),
        Err(e) => println!("accuracy shape check WARN: {e}"),
    }
    if let Some(path) = args.get("json") {
        let path = accuracy::write_bench_json(
            std::path::Path::new(path),
            std::slice::from_ref(&report),
        )?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

// ----------------------------------------------------------------- serve

struct PjrtRunner {
    runtime: Runtime,
    key: String,
    buckets: Vec<usize>,
}

impl BatchRunner for PjrtRunner {
    fn max_batch(&self) -> usize {
        *self.buckets.last().expect("non-empty buckets")
    }
    fn run(&mut self, xs: &[f32], n: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let bucket = self
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or(self.max_batch());
        let model = self.runtime.model(&self.key, bucket)?;
        model.run_batch(xs, n)
    }
}

/// Load trained weights; when the artifact is absent *and the operator
/// did not point at an explicit artifacts dir*, fall back to
/// deterministic synthetic ones so bare checkouts (no `make artifacts`)
/// can still exercise the full serving path (same seed → same model).
/// An explicit `--artifacts` that lacks the file stays a hard error — a
/// typo'd path must not silently serve a random model.
///
/// An explicit `--weights <path>` (json or onnx, via the import layer)
/// supersedes the artifacts lookup entirely; the checkpoint's
/// architecture must match the requested model key so a tier-routed
/// session never serves the wrong network.
fn weights_or_synthetic(
    artifacts: &std::path::Path,
    key: &str,
    explicit_artifacts: bool,
    weights_path: Option<&std::path::Path>,
) -> anyhow::Result<Weights> {
    if let Some(p) = weights_path {
        let w = Weights::load_path(p, None)?;
        anyhow::ensure!(
            w.arch.key() == key,
            "--weights {} holds {} but --model is {key}",
            p.display(),
            w.arch.key()
        );
        return Ok(w);
    }
    let path = artifacts.join("weights").join(format!("{key}.json"));
    if path.exists() || explicit_artifacts {
        return Weights::load(path);
    }
    let (benchmark, cell) = key.rsplit_once('_').ok_or_else(|| {
        anyhow::anyhow!("model key {key:?} is not <benchmark>_<cell>")
    })?;
    let cell = match cell {
        "lstm" => rnn_hls::model::Cell::Lstm,
        "gru" => rnn_hls::model::Cell::Gru,
        other => anyhow::bail!("unknown cell {other:?} in model key {key:?}"),
    };
    let arch = rnn_hls::model::zoo::arch(benchmark, cell)?;
    println!(
        "WARNING: {} not found — serving SYNTHETIC weights for {key} \
         (accuracy is meaningless; run `make artifacts` or pass \
         --artifacts for the trained model)",
        path.display()
    );
    Ok(Weights::synthetic(&arch, 0x5EED))
}

fn cmd_serve(rest: &[String]) -> anyhow::Result<()> {
    // Help text follows the registry, so a new backend row shows up here
    // without touching the CLI (one short leak per `serve` invocation).
    let backends_help: &'static str = Box::leak(
        format!(
            "heterogeneous session: one backend per shard, comma-separated \
             ({}); empty = --engine everywhere",
            BackendSpec::names().join("|")
        )
        .into_boxed_str(),
    );
    let cmd = Command::new("serve", "trigger-style serving demo")
        .opt("artifacts", "artifacts directory", None)
        .opt("model", "model key", Some("top_gru"))
        .opt(
            "weights",
            "explicit checkpoint path (.json or .onnx) for the rust \
             engines; overrides the artifacts lookup and the synthetic \
             fallback (ignored by --engine pjrt, which loads compiled \
             artifacts)",
            None,
        )
        .opt("engine", "pjrt | fixed | float", Some("pjrt"))
        .opt("rate", "event rate (events/s)", Some("20000"))
        .opt("events", "number of events", Some("50000"))
        .opt(
            "shards",
            "coordinator shards (request-stream partitions)",
            Some("1"),
        )
        .opt(
            "shard-policy",
            "routing: hash | round-robin | model-key",
            Some("hash"),
        )
        .opt("backends", backends_help, Some(""))
        .opt(
            "tier-mix",
            "per-backend traffic fractions summing to 1 (e.g. 0.9,0.1); \
             empty = uniform across --backends",
            Some(""),
        )
        .opt(
            "tier-seed",
            "seed of the tier-stamping hash (same seed = same partition)",
            Some("0"),
        )
        .opt("workers", "engine worker threads per shard", Some("2"))
        .opt(
            "engine-parallelism",
            "per-batch threads inside each rust engine",
            Some("1"),
        )
        .opt("max-batch", "dynamic batcher size cap (>= 1)", Some("10"))
        .opt("max-wait-us", "batching deadline (µs; 0 = strict batch-1)", Some("200"))
        .opt(
            "batch-policy",
            "per-shard batching: comma-separated name:max_batch:max_wait_us \
             entries, one per shard (e.g. trigger:1:0,offline:64:2000); \
             empty = tier defaults with --backends (trigger backends \
             batch-1/zero-wait, offline deep), --max-batch/--max-wait-us \
             otherwise",
            Some(""),
        )
        .opt("queue", "per-shard queue capacity (drop beyond)", Some("4096"))
        .opt("width", "fixed engine: total bits", Some("16"))
        .opt("integer", "fixed engine: integer bits", Some("6"))
        .opt(
            "listen",
            "serve the ingest::wire protocol on this TCP address \
             (e.g. 127.0.0.1:7432) instead of replaying a synthetic \
             source; drive it with the `loadgen` binary",
            None,
        )
        .opt(
            "metrics-listen",
            "line-oriented metrics endpoint address (with --listen)",
            None,
        )
        .opt(
            "max-connections",
            "concurrent connection cap; beyond it new connections are \
             answered BUSY (with --listen)",
            Some("1024"),
        )
        .opt(
            "serve-for-ms",
            "how long to keep the listener up before the drain-then-close \
             shutdown (with --listen)",
            Some("10000"),
        )
        .flag("fixed-interval", "fixed (non-Poisson) arrivals");
    let args = cmd.parse(rest)?;
    let artifacts = artifacts_from(&args);
    // An operator who pointed anywhere — flag or env var — gets hard
    // errors for missing weights instead of the synthetic fallback.
    let explicit_artifacts = args.get("artifacts").is_some()
        || std::env::var_os("RNN_HLS_ARTIFACTS").is_some();
    let width: u32 = args.parse_num("width", 16)?;
    let integer: u32 = args.parse_num("integer", 6)?;
    let model_key = args.get_or("model", "top_gru").to_string();
    let listen: Option<std::net::SocketAddr> =
        args.get("listen").map(|s| s.parse()).transpose()?;
    let metrics_listen: Option<std::net::SocketAddr> =
        args.get("metrics-listen").map(|s| s.parse()).transpose()?;
    anyhow::ensure!(
        listen.is_some() || metrics_listen.is_none(),
        "--metrics-listen requires --listen"
    );

    // The CLI is a thin adapter over the typed session API: every flag
    // parses straight into a ServingSpec field (FromStr), and every
    // serving invariant — backend names, arities, mix sums to 1, zero
    // batch — is validated in one place, ServingSpec::build.
    let tier_seed: u64 = args.parse_num("tier-seed", 0u64)?;
    let backends = match args.get_or("backends", "") {
        "" => Vec::new(),
        csv => BackendKind::parse_list(csv)?,
    };
    let tier_mix = match args.get_or("tier-mix", "") {
        "" => None,
        csv => Some(TierMix::parse(csv, tier_seed)?),
    };
    let batch_policy = match args.get_or("batch-policy", "") {
        "" => None,
        grammar => Some(grammar.parse::<TierPolicy>()?),
    };
    // Tier defaults supersede the shared batcher knobs for mixed
    // sessions; an operator who spelled those knobs out explicitly must
    // hear that they were overridden (use --batch-policy to pin
    // per-shard values).  Args::parse folds defaults into the parsed
    // map, so explicitness is read off the raw arg list.
    if backends.len() > 1 && batch_policy.is_none() {
        let explicit_batch_flags = rest.iter().any(|a| {
            a == "--max-batch"
                || a == "--max-wait-us"
                || a.starts_with("--max-batch=")
                || a.starts_with("--max-wait-us=")
        });
        if explicit_batch_flags {
            println!(
                "WARNING: --max-batch/--max-wait-us are overridden by \
                 tier defaults in a multi-backend session; pass \
                 --batch-policy to pin per-shard batching explicitly"
            );
        }
    }
    // Single source of truth for serve defaults: ServingSpec::default
    // (the Command .opt defaults above are display strings; the typed
    // fallbacks come from the spec so the CLI can never drift from the
    // library defaults).
    let d = ServingSpec::default();
    let spec = ServingSpec {
        engine: args.get_or("engine", d.engine.name()).parse()?,
        backends,
        tier_mix,
        tier_seed,
        shards: args.parse_num("shards", d.shards)?,
        shard_policy: args
            .get_or("shard-policy", d.shard_policy.name())
            .parse()?,
        batch_policy,
        workers: args.parse_num("workers", d.workers)?,
        engine_parallelism: args
            .parse_num("engine-parallelism", d.engine_parallelism)?,
        batcher: BatcherConfig {
            max_batch: args.parse_num("max-batch", d.batcher.max_batch)?,
            max_wait: Duration::from_micros(args.parse_num(
                "max-wait-us",
                d.batcher.max_wait.as_micros() as u64,
            )?),
        },
        queue_capacity: args.parse_num("queue", d.queue_capacity)?,
        source: SourceConfig {
            rate_hz: args.parse_num("rate", d.source.rate_hz)?,
            poisson: !args.has("fixed-interval"),
            n_events: args.parse_num("events", d.source.n_events)?,
        },
        // Replay-to-completion runs drain no completion channel; the
        // network front-end's dispatcher needs one.
        completions: listen.is_some(),
        listener: listen,
        metrics_listener: metrics_listen,
        max_connections: args.parse_num("max-connections", d.max_connections)?,
        ..d
    };
    let plan = spec.build()?;

    let engine_desc = if plan.shard_kinds.is_empty() {
        format!("{} engine", spec.engine)
    } else {
        let mix: Vec<String> = (0..plan.config.tier_mix.tiers())
            .map(|t| format!("{:.2}", plan.config.tier_mix.fraction(t)))
            .collect();
        format!(
            "backends [{}] mix [{}]",
            plan.config.shard_backends.join(","),
            mix.join(",")
        )
    };
    // Describe the batchers the plan *actually resolved* (explicit
    // policy or tier defaults), never a re-derivation that could drift
    // from what the session serves under.
    let batching_desc = if plan.config.shard_batchers.is_empty() {
        format!(
            "batch<= {}, wait {} µs",
            plan.config.server.batcher.max_batch,
            plan.config.server.batcher.max_wait.as_micros()
        )
    } else {
        let entries: Vec<String> = plan
            .config
            .shard_batchers
            .iter()
            .enumerate()
            .map(|(shard, b)| {
                // Prefer the operator's tier names (explicit
                // --batch-policy), then the backend label, then a
                // generic placeholder.
                let label = spec
                    .batch_policy
                    .as_ref()
                    .and_then(|p| p.entries.get(shard))
                    .map(|e| e.name.as_str())
                    .or_else(|| {
                        plan.config
                            .shard_backends
                            .get(shard)
                            .map(String::as_str)
                    })
                    .unwrap_or("shard");
                format!(
                    "{label}:{}:{}",
                    b.max_batch,
                    b.max_wait.as_micros()
                )
            })
            .collect();
        format!("batch policy [{}]", entries.join(","))
    };
    println!(
        "serving {model_key} via {engine_desc}: rate {} ev/s, {} events, \
         {} shards ({} routing) × {} workers × {} engine \
         threads, {batching_desc}",
        plan.config.server.source.rate_hz,
        plan.config.server.source.n_events,
        plan.config.shards,
        plan.config.policy.name(),
        plan.config.server.workers,
        plan.engine_parallelism,
    );

    let benchmark = model_key
        .split('_')
        .next()
        .unwrap_or(&model_key)
        .to_string();
    let generator = generators::for_benchmark(&benchmark, 0xBEEF)?;
    let session = if plan.shard_kinds.is_empty()
        && spec.engine == BackendKind::Pjrt
    {
        // PJRT runtime path: the runner sizes itself from the AOT batch
        // buckets, and every bucket precompiles before the readiness
        // gate opens (§Perf: keeps lazy compilation out of the serving
        // percentiles).
        let artifacts = artifacts.clone();
        let key2 = model_key.clone();
        Session::start_plan(plan, move |_shard| {
            let runtime = Runtime::new(&artifacts)?;
            let buckets = runtime.manifest().batch_buckets(&key2)?;
            for &b in &buckets {
                runtime.model(&key2, b)?;
            }
            Ok(Box::new(PjrtRunner {
                runtime,
                key: key2.clone(),
                buckets,
            }) as Box<dyn BatchRunner>)
        })?
    } else {
        // Registry path (homogeneous or heterogeneous): each shard
        // builds its resolved BackendKind over the shared weights; an
        // unbuildable slot (the stubbed pjrt row) fails engine init
        // with the registry's clear error.  Each EngineRunner's cap
        // follows its shard's (tier-resolved) batcher, so a
        // deep-batching offline tier is never clamped to the shared
        // --max-batch.
        let weights_flag = args.get("weights").map(PathBuf::from);
        let weights = weights_or_synthetic(
            &artifacts,
            &model_key,
            explicit_artifacts,
            weights_flag.as_deref(),
        )?;
        let parallelism = plan.engine_parallelism;
        let shard_kinds: Vec<BackendKind> =
            (0..plan.config.shards).map(|s| plan.kind_for(s)).collect();
        let runner_caps: Vec<usize> =
            (0..plan.config.shards).map(|s| plan.runner_cap(s)).collect();
        Session::start_plan(plan, move |shard| {
            let engine = shard_kinds[shard].spec().build(&BackendCtx {
                weights: &weights,
                fixed_spec: FixedSpec::new(width, integer),
                parallelism,
            })?;
            Ok(Box::new(EngineRunner::new(engine, runner_caps[shard]))
                as Box<dyn BatchRunner>)
        })?
    };

    let report = if listen.is_some() {
        // Network run: put the wire front-end over the live session,
        // hold the listener open for the configured window, then
        // drain-then-close (same shutdown contract as in-process).
        let serve_for =
            Duration::from_millis(args.parse_num("serve-for-ms", 10_000u64)?);
        let server = session.serve_listener()?;
        match server.metrics_addr() {
            Some(m) => println!(
                "listening on {} (metrics on {m}) for {} ms — drive it \
                 with `loadgen --addr {}`",
                server.local_addr(),
                serve_for.as_millis(),
                server.local_addr(),
            ),
            None => println!(
                "listening on {} for {} ms — drive it with \
                 `loadgen --addr {}`",
                server.local_addr(),
                serve_for.as_millis(),
                server.local_addr(),
            ),
        }
        std::thread::sleep(serve_for);
        let net = server.shutdown()?;
        println!(
            "net: accepted {} refused {} requests {} replies {} \
             wire_errors {} malformed {} stranded {}",
            net.accepted,
            net.refused,
            net.requests,
            net.replies,
            net.wire_errors,
            net.malformed,
            net.stranded,
        );
        net.serving
    } else {
        session.replay(generator);
        session.shutdown()?
    };
    println!("{}", report.render());
    Ok(())
}

// ----------------------------------------------------------------- sweep

fn cmd_sweep(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("sweep", "HLS design-space sweep")
        .opt("benchmark", "top | flavor | quickdraw", Some("top"))
        .opt("cell", "lstm | gru | both", Some("both"))
        .opt("width", "total bits", Some("16"))
        .opt("integer", "integer bits", Some("6"))
        .opt("mode", "static | nonstatic | both", Some("static"));
    let args = cmd.parse(rest)?;
    let benchmark = args.get_or("benchmark", "top").to_string();
    let width: u32 = args.parse_num("width", 16)?;
    let integer: u32 = args.parse_num("integer", 6)?;
    let cells: Vec<rnn_hls::model::Cell> =
        match args.one_of("cell", "both", &["lstm", "gru", "both"])? {
            "lstm" => vec![rnn_hls::model::Cell::Lstm],
            "gru" => vec![rnn_hls::model::Cell::Gru],
            _ => vec![rnn_hls::model::Cell::Gru, rnn_hls::model::Cell::Lstm],
        };
    let modes: Vec<RnnMode> =
        match args.one_of("mode", "static", &["static", "nonstatic", "both"])? {
            "nonstatic" => vec![RnnMode::NonStatic],
            "both" => vec![RnnMode::Static, RnnMode::NonStatic],
            _ => vec![RnnMode::Static],
        };
    for cell in cells {
        let arch = rnn_hls::model::zoo::arch(&benchmark, cell)?;
        for mode in &modes {
            for reuse in paper::reuse_grid(&benchmark, cell) {
                let mut cfg = HlsConfig::paper_default(
                    FixedSpec::new(width, integer.min(width - 1)),
                    reuse,
                );
                cfg.mode = *mode;
                let report =
                    HlsDesign::new(arch.clone(), cfg)?.synthesize()?;
                println!("{}", report.summary());
            }
            // Latency strategy where synthesizable.
            let mut cfg = HlsConfig::paper_default(
                FixedSpec::new(width, integer.min(width - 1)),
                ReuseFactor::fully_parallel(),
            );
            cfg.strategy = rnn_hls::hls::Strategy::Latency;
            cfg.mode = *mode;
            match HlsDesign::new(arch.clone(), cfg)
                .map_err(anyhow::Error::from)
                .and_then(|d| d.synthesize())
            {
                Ok(report) => println!("{}", report.summary()),
                Err(e) => println!("{}: {e}", arch.key()),
            }
        }
    }
    Ok(())
}

// --------------------------------------------------------------- explore

/// Parse `--model` into architectures: a zoo key (`top_gru`) or `all`.
fn explore_archs(model: &str) -> anyhow::Result<Vec<rnn_hls::model::Arch>> {
    if model == "all" {
        return Ok(rnn_hls::model::zoo::all_archs());
    }
    let (benchmark, cell) = model.rsplit_once('_').ok_or_else(|| {
        anyhow::anyhow!("model key {model:?} is not <benchmark>_<cell> or all")
    })?;
    Ok(vec![rnn_hls::model::zoo::arch(benchmark, cell.parse()?)?])
}

fn parse_f64_list(csv: &str, what: &str) -> anyhow::Result<Vec<f64>> {
    let mut out = Vec::new();
    for part in csv.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        out.push(
            part.parse()
                .map_err(|_| anyhow::anyhow!("bad {what} value {part:?}"))?,
        );
    }
    anyhow::ensure!(!out.is_empty(), "no {what} values given");
    Ok(out)
}

fn cmd_explore(rest: &[String]) -> anyhow::Result<()> {
    use rnn_hls::nn::fixed_engine::MAX_WIDTH;

    let cmd = Command::new(
        "explore",
        "design-space Pareto search over the analytical HLS model",
    )
    .opt("model", "zoo key (e.g. top_gru) or 'all'", Some("all"))
    .opt(
        "device",
        "ku115 | u250 | vu9p_slr (default: the paper's per-benchmark part, \
         ku115 for --model all)",
        None,
    )
    .opt(
        "widths",
        "total-bit precision ladder, comma-separated",
        Some("8,12,14,16,18,20"),
    )
    .opt(
        "clock",
        "synthesis-clock ladder in MHz, comma-separated",
        Some("200,300,400"),
    )
    .opt("budget-ns", "admit only designs at or under this latency", None)
    .opt(
        "min-auc",
        "admit only designs with measured AUC at or above this \
         (requires --accuracy)",
        None,
    )
    .flag(
        "accuracy",
        "join measured fixed-point AUC from the checkpoint into the front",
    )
    .opt(
        "weights",
        "checkpoint for the accuracy join",
        Some(DEFAULT_WEIGHTS),
    )
    .opt(
        "dataset",
        "evaluation set for the accuracy join",
        Some(DEFAULT_DATASET),
    )
    .opt("samples", "cap accuracy-join events (0 = all)", Some("0"))
    .opt("workers", "accuracy-join threads", Some("4"))
    .opt("json", "write the BENCH_explore.json artifact here", None)
    .opt("csv", "write the Pareto front as CSV here", None);
    let args = cmd.parse(rest)?;

    let archs = explore_archs(args.get_or("model", "all"))?;
    let device = match args.get("device") {
        Some(name) => Device::by_name(name)?,
        None if archs.len() == 1 => Device::for_benchmark(&archs[0].name),
        None => Device::KU115,
    };
    let mut widths: Vec<u32> = Vec::new();
    for part in args
        .get_or("widths", "8,12,14,16,18,20")
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
    {
        let w: u32 = part
            .parse()
            .map_err(|_| anyhow::anyhow!("bad --widths value {part:?}"))?;
        anyhow::ensure!(
            (2..=48).contains(&w),
            "--widths: width {w} out of range 2..=48"
        );
        widths.push(w);
    }
    anyhow::ensure!(!widths.is_empty(), "no --widths values given");
    let clocks = parse_f64_list(args.get_or("clock", "200,300,400"), "clock")?;

    let budget_ns: Option<f64> = match args.get("budget-ns") {
        Some(text) => Some(
            text.parse()
                .map_err(|_| anyhow::anyhow!("bad --budget-ns {text:?}"))?,
        ),
        None => None,
    };
    let min_auc: Option<f64> = match args.get("min-auc") {
        Some(text) => Some(
            text.parse()
                .map_err(|_| anyhow::anyhow!("bad --min-auc {text:?}"))?,
        ),
        None => None,
    };
    anyhow::ensure!(
        min_auc.is_none() || args.has("accuracy"),
        "--min-auc filters on *measured* AUC — pass --accuracy to join it"
    );

    let mut ecfg = explore::ExploreConfig::new(archs, device);
    ecfg.widths = widths;
    ecfg.clocks_mhz = clocks;
    let mut candidates = explore::evaluate(&ecfg)?;
    println!(
        "evaluated {} candidates over {} model(s) on {}",
        candidates.len(),
        ecfg.archs.len(),
        device.name
    );

    if args.has("accuracy") {
        let weights_path =
            PathBuf::from(args.get_or("weights", DEFAULT_WEIGHTS));
        let weights = Weights::load_path(&weights_path, None)?;
        let ds = rnn_hls::data::Dataset::load(
            args.get_or("dataset", DEFAULT_DATASET),
        )?;
        let samples: usize = args.parse_num("samples", 0usize)?;
        let ds = if samples > 0 { ds.truncated(samples) } else { ds };
        let workers: usize = args.parse_num("workers", 4usize)?;
        let baseline = accuracy::FloatBaseline::new(&weights, &ds, workers)?;
        let key = baseline.key();
        let specs: Vec<FixedSpec> = explore::distinct_specs(&candidates, &key)
            .into_iter()
            .filter(|s| s.width <= MAX_WIDTH)
            .collect();
        anyhow::ensure!(
            !specs.is_empty(),
            "--accuracy: no explored precision of {key} is evaluable \
             (engine max width {MAX_WIDTH})"
        );
        let report = baseline.sweep(&specs, workers)?;
        let join = explore::AccuracyJoin {
            key: report.key.clone(),
            auc_float: report.auc_float,
            samples: report.samples,
            auc_by_spec: report
                .points
                .iter()
                .map(|p| (p.spec, p.auc_fixed))
                .collect(),
        };
        println!(
            "accuracy join: {} float AUC {:.4} over {} events, {} precisions",
            join.key,
            join.auc_float,
            join.samples,
            join.auc_by_spec.len()
        );
        explore::join_accuracy(&mut candidates, &join);
    }

    let filters = explore::Filters { budget_ns, min_auc };
    let result = explore::pareto(device, candidates, filters);
    println!("{}", explore_report::render(&result));

    if let Some(budget) = budget_ns {
        match result.cheapest_within(budget) {
            Some(c) => println!(
                "cheapest within {budget} ns: {} ({:.1} ns, {} DSP, {} LUT)",
                c.name(),
                c.latency_ns(),
                c.resources.dsp,
                c.resources.lut
            ),
            None => println!(
                "no admitted design meets the {budget} ns budget on {}",
                device.name
            ),
        }
    }
    anyhow::ensure!(
        !result.front.is_empty(),
        "no design on {} survives the filters — widen the grid or relax \
         --budget-ns/--min-auc",
        device.name
    );

    if let Some(path) = args.get("csv") {
        let path = explore_report::write_csv(path, &result)?;
        println!("wrote {}", path.display());
    }
    if let Some(path) = args.get("json") {
        let path = explore_report::write_bench_json(
            std::path::Path::new(path),
            &result,
        )?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

// ---------------------------------------------------------------- golden

fn cmd_golden(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("golden", "PJRT vs python golden outputs")
        .opt("artifacts", "artifacts directory", None)
        .opt("tol", "max abs deviation", Some("1e-4"));
    let args = cmd.parse(rest)?;
    let artifacts = artifacts_from(&args);
    let tol: f64 = args.parse_num("tol", 1e-4f64)?;
    let runtime = Runtime::new(&artifacts)?;

    let mut worst: f64 = 0.0;
    let entries = runtime.manifest().models.clone();
    for entry in &entries {
        let golden_text =
            std::fs::read_to_string(runtime.manifest().path(&entry.golden))?;
        let golden = rnn_hls::util::json::parse(&golden_text)?;
        let n = golden.req("n")?.as_usize()?;
        let expected: Vec<Vec<f32>> = golden
            .req("outputs")?
            .as_array()?
            .iter()
            .map(|row| row.as_f32_vec())
            .collect::<Result<_, _>>()?;
        let ds = rnn_hls::data::Dataset::load(
            runtime.manifest().path(&entry.dataset),
        )?;
        let model = runtime.model(&entry.key, 10)?;
        let mut xs = Vec::new();
        for i in 0..n {
            xs.extend_from_slice(ds.sample(i));
        }
        let got = model.run_batch(&xs, n)?;
        let mut max_dev: f64 = 0.0;
        for (g_row, e_row) in got.iter().zip(&expected) {
            for (g, e) in g_row.iter().zip(e_row) {
                max_dev = max_dev.max((g - e).abs() as f64);
            }
        }
        println!(
            "{:<16} max |pjrt - golden| = {max_dev:.2e} {}",
            entry.key,
            if max_dev < tol { "OK" } else { "FAIL" }
        );
        worst = worst.max(max_dev);
    }
    anyhow::ensure!(
        worst < tol,
        "golden check failed: worst deviation {worst:.2e} >= {tol:.2e}"
    );
    println!("golden check passed (worst {worst:.2e})");
    Ok(())
}

// ------------------------------------------------------------------ list

fn cmd_list(rest: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("list", "list models in the manifest")
        .opt("artifacts", "artifacts directory", None);
    let args = cmd.parse(rest)?;
    let m = rnn_hls::runtime::Manifest::load(artifacts_from(&args))?;
    for model in &m.models {
        println!(
            "{:<16} seq {:>3} in {:>2} hidden {:>3} out {} batches {:?}",
            model.key,
            model.seq_len,
            model.input_size,
            model.hidden_size,
            model.output_size,
            model.hlo.keys().collect::<Vec<_>>()
        );
    }
    Ok(())
}
