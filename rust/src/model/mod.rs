//! Model zoo: the six benchmark architectures of Table 1 and their
//! trained weights (loaded from `artifacts/weights/*.json`, written by
//! `python/compile/train.py`).

pub mod arch;
pub mod import;
pub mod weights;
pub mod zoo;

pub use arch::{Arch, Cell, OutputActivation};
pub use import::{ImportError, JsonSource, OnnxSource, TensorSource};
pub use weights::{Tensor, Weights};
pub use zoo::{all_archs, arch, BENCHMARKS};
