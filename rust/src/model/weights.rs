//! Trained-weight containers.  Loading lives in [`super::import`]: the
//! JSON interchange doc written by `python/compile/model.py::
//! params_to_json` and the in-tree ONNX reader both assemble a
//! [`Weights`] through the same validated constructor.

use std::collections::BTreeMap;
use std::path::Path;

use super::arch::Arch;

/// A dense tensor: row-major f32 data + shape.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// 2-D accessor (row-major).  The shape contract is a hard check —
    /// tensors arrive from untrusted checkpoint files, and a release-mode
    /// read through a mis-shaped tensor would return wrong-but-in-bounds
    /// data silently.
    #[inline]
    pub fn at2(&self, r: usize, c: usize) -> f32 {
        assert!(
            self.shape.len() == 2,
            "at2 on a {}-D tensor (shape {:?})",
            self.shape.len(),
            self.shape
        );
        assert!(
            r < self.shape[0] && c < self.shape[1],
            "at2({r}, {c}) out of bounds for shape {:?}",
            self.shape
        );
        self.data[r * self.shape[1] + c]
    }
}

/// A trained model: architecture + named weight tensors.
///
/// Layer names: `rnn` (tensors `w`, `u`, `b`), `dense0..N` (`w`, `b`),
/// `out` (`w`, `b`) — the layout asserted by `test_params_json_roundtrip`
/// on the python side.
#[derive(Debug, Clone)]
pub struct Weights {
    pub arch: Arch,
    layers: BTreeMap<String, BTreeMap<String, Tensor>>,
}

impl Weights {
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading weights {}: {e}", path.display()))?;
        Self::from_json(&text)
    }

    /// Parse the JSON interchange doc.  A thin wrapper over the import
    /// layer: [`super::import::JsonSource`] + [`Weights::from_source`].
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let mut src = super::import::JsonSource::parse(text)?;
        let arch = src.arch.clone();
        Self::from_source(&arch, &mut src)
    }

    /// Validated constructor shared by every import path: checks the
    /// assembled layer map against the architecture's parameter count
    /// and pinned tensor shapes.
    pub(crate) fn from_parts(
        arch: Arch,
        layers: BTreeMap<String, BTreeMap<String, Tensor>>,
    ) -> anyhow::Result<Self> {
        let w = Self { arch, layers };
        let counted = w.param_count();
        anyhow::ensure!(
            counted == w.arch.param_count(),
            "weights param count {counted} != arch {} count {}",
            w.arch.key(),
            w.arch.param_count()
        );
        w.validate_shapes()?;
        Ok(w)
    }

    /// Fetch one tensor; layer/tensor names are a typed API error if wrong.
    pub fn tensor(&self, layer: &str, name: &str) -> anyhow::Result<&Tensor> {
        self.layers
            .get(layer)
            .and_then(|l| l.get(name))
            .ok_or_else(|| anyhow::anyhow!("no tensor {layer}/{name}"))
    }

    pub fn param_count(&self) -> usize {
        self.layers
            .values()
            .flat_map(|l| l.values())
            .map(Tensor::numel)
            .sum()
    }

    fn validate_shapes(&self) -> anyhow::Result<()> {
        let a = &self.arch;
        let g = a.cell.gates();
        let (i, h) = (a.input_size, a.hidden_size);
        let w = self.tensor("rnn", "w")?;
        anyhow::ensure!(w.shape == [i, g * h], "rnn/w shape {:?}", w.shape);
        let u = self.tensor("rnn", "u")?;
        anyhow::ensure!(u.shape == [h, g * h], "rnn/u shape {:?}", u.shape);
        let b = self.tensor("rnn", "b")?;
        let want_b: &[usize] = match a.cell {
            super::arch::Cell::Lstm => &[4 * h],
            super::arch::Cell::Gru => &[2, 3 * h],
        };
        anyhow::ensure!(b.shape == want_b, "rnn/b shape {:?}", b.shape);

        let mut prev = h;
        for (idx, &size) in a.dense_sizes.iter().enumerate() {
            let w = self.tensor(&format!("dense{idx}"), "w")?;
            anyhow::ensure!(w.shape == [prev, size], "dense{idx}/w {:?}", w.shape);
            prev = size;
        }
        let ow = self.tensor("out", "w")?;
        anyhow::ensure!(
            ow.shape == [prev, a.output_size],
            "out/w shape {:?}",
            ow.shape
        );
        Ok(())
    }

    /// Deterministic pseudo-random weights for an architecture — for
    /// benches and tests that need a real-shaped model without the
    /// trained artifacts.  Xavier-style `N(0, 1/fan_in)` scaling keeps
    /// activations in range (LSTM forget-gate bias set to 1.0, the usual
    /// initialization); same seed → same model, on every platform.
    pub fn synthetic(arch: &Arch, seed: u64) -> Self {
        use crate::util::rng::Rng;

        fn tensor(rng: &mut Rng, shape: Vec<usize>, fan_in: usize) -> Tensor {
            let n: usize = shape.iter().product();
            let scale = (1.0 / fan_in.max(1) as f64).sqrt();
            Tensor {
                shape,
                data: (0..n).map(|_| rng.normal(0.0, scale) as f32).collect(),
            }
        }

        let mut rng = Rng::new(seed);
        let g = arch.cell.gates();
        let (i, h) = (arch.input_size, arch.hidden_size);
        let mut layers: BTreeMap<String, BTreeMap<String, Tensor>> =
            BTreeMap::new();

        let mut rnn = BTreeMap::new();
        rnn.insert("w".to_string(), tensor(&mut rng, vec![i, g * h], i));
        rnn.insert("u".to_string(), tensor(&mut rng, vec![h, g * h], h));
        let bias = match arch.cell {
            super::arch::Cell::Lstm => Tensor {
                shape: vec![4 * h],
                data: (0..4 * h)
                    .map(|j| if (h..2 * h).contains(&j) { 1.0 } else { 0.0 })
                    .collect(),
            },
            super::arch::Cell::Gru => Tensor {
                shape: vec![2, 3 * h],
                data: vec![0.0; 2 * 3 * h],
            },
        };
        rnn.insert("b".to_string(), bias);
        layers.insert("rnn".to_string(), rnn);

        let mut prev = h;
        for (idx, &size) in arch.dense_sizes.iter().enumerate() {
            let mut layer = BTreeMap::new();
            layer.insert(
                "w".to_string(),
                tensor(&mut rng, vec![prev, size], prev),
            );
            layer.insert(
                "b".to_string(),
                Tensor {
                    shape: vec![size],
                    data: vec![0.0; size],
                },
            );
            layers.insert(format!("dense{idx}"), layer);
            prev = size;
        }
        let mut out = BTreeMap::new();
        out.insert(
            "w".to_string(),
            tensor(&mut rng, vec![prev, arch.output_size], prev),
        );
        out.insert(
            "b".to_string(),
            Tensor {
                shape: vec![arch.output_size],
                data: vec![0.0; arch.output_size],
            },
        );
        layers.insert("out".to_string(), out);

        let w = Self {
            arch: arch.clone(),
            layers,
        };
        debug_assert_eq!(w.param_count(), arch.param_count());
        w
    }

    /// Dynamic range of all weights — drives Fig. 2 commentary (how many
    /// integer bits the weights themselves need).
    pub fn weight_range(&self) -> (f32, f32) {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for t in self.layers.values().flat_map(|l| l.values()) {
            for &v in &t.data {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
        (lo, hi)
    }
}

#[cfg(test)]
pub(crate) mod test_support {
    /// A hand-built consistent scaled-down model doc used across the nn /
    /// integration tests: I=2, H=1, dense [2], out 1.
    /// LSTM params: 4*(2+1+1)=16; head: 1*2+2 + 2*1+1 = 7 → 23.
    pub fn tiny_lstm_json() -> String {
        r#"{
            "arch": {
                "name": "top", "cell": "lstm", "seq_len": 3,
                "input_size": 2, "hidden_size": 1, "dense_sizes": [2],
                "output_size": 1, "output_activation": "sigmoid"
            },
            "param_count": 23,
            "layers": [
                {"name": "rnn",
                 "w": {"shape": [2, 4],
                       "data": [0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1]},
                 "u": {"shape": [1, 4], "data": [0.2, 0.2, 0.2, 0.2]},
                 "b": {"shape": [4], "data": [0.0, 1.0, 0.0, 0.0]}},
                {"name": "dense0",
                 "w": {"shape": [1, 2], "data": [0.3, -0.3]},
                 "b": {"shape": [2], "data": [0.0, 0.0]}},
                {"name": "out",
                 "w": {"shape": [2, 1], "data": [0.5, -0.5]},
                 "b": {"shape": [1], "data": [0.1]}}
            ]
        }"#
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::test_support::tiny_lstm_json;
    use super::*;

    #[test]
    fn loads_consistent_doc() {
        let w = Weights::from_json(&tiny_lstm_json()).unwrap();
        assert_eq!(w.param_count(), 23);
        assert_eq!(w.tensor("rnn", "b").unwrap().data[1], 1.0);
        assert_eq!(w.tensor("out", "w").unwrap().at2(1, 0), -0.5);
    }

    #[test]
    #[should_panic(expected = "at2 on a 1-D tensor")]
    fn at2_rejects_non_2d_tensor() {
        let t = Tensor { shape: vec![4], data: vec![0.0; 4] };
        t.at2(0, 0);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn at2_rejects_out_of_bounds_column() {
        // (0, 4) on a (2, 3) tensor computes flat index 4 — in bounds of
        // the data, so without the hard check this read returns row 1's
        // second element silently.
        let t = Tensor { shape: vec![2, 3], data: vec![0.0; 6] };
        t.at2(0, 4);
    }

    #[test]
    fn rejects_wrong_declared_count() {
        let bad = tiny_lstm_json().replace("\"param_count\": 23", "\"param_count\": 99");
        assert!(Weights::from_json(&bad).is_err());
    }

    #[test]
    fn rejects_shape_data_mismatch() {
        let bad = tiny_lstm_json().replace(
            "\"b\": {\"shape\": [1], \"data\": [0.1]}",
            "\"b\": {\"shape\": [2], \"data\": [0.1]}",
        );
        assert!(Weights::from_json(&bad).is_err());
    }

    #[test]
    fn rejects_missing_tensor() {
        let w = Weights::from_json(&tiny_lstm_json()).unwrap();
        assert!(w.tensor("rnn", "nope").is_err());
        assert!(w.tensor("dense7", "w").is_err());
    }

    #[test]
    fn synthetic_weights_are_consistent_and_deterministic() {
        use crate::model::zoo;
        for arch in zoo::all_archs() {
            let w = Weights::synthetic(&arch, 42);
            assert_eq!(w.param_count(), arch.param_count(), "{}", arch.key());
            w.validate_shapes().unwrap();
        }
        let arch = zoo::arch("top", crate::model::Cell::Gru).unwrap();
        let a = Weights::synthetic(&arch, 7);
        let b = Weights::synthetic(&arch, 7);
        assert_eq!(
            a.tensor("rnn", "w").unwrap().data,
            b.tensor("rnn", "w").unwrap().data
        );
        let c = Weights::synthetic(&arch, 8);
        assert_ne!(
            a.tensor("rnn", "w").unwrap().data,
            c.tensor("rnn", "w").unwrap().data
        );
    }

    #[test]
    fn weight_range_covers_extremes() {
        let w = Weights::from_json(&tiny_lstm_json()).unwrap();
        let (lo, hi) = w.weight_range();
        assert_eq!(lo, -0.5);
        assert_eq!(hi, 1.0);
    }
}
