//! Weight import: named-tensor loading from checkpoint files.
//!
//! A `VarBuilder`-style loader (after `candle-nn`'s `var_builder`): a
//! [`TensorSource`] yields named f32 tensors, a [`VarBuilder`] fetches
//! them shape-checked, and [`Weights::from_source`] assembles the
//! canonical layer map that `validate_shapes` pins.  Two concrete
//! sources exist:
//!
//! * [`JsonSource`] — the JSON interchange doc written by
//!   `python/compile/model.py::params_to_json` (Keras-layout tensors,
//!   already in the canonical naming).
//! * [`OnnxSource`] — a minimal in-tree ONNX graph reader (pure-std
//!   protobuf-subset decode, see [`onnx`]) that maps `LSTM`/`GRU`/`Gemm`
//!   initializers from ONNX's native layouts (`[num_dirs, G*H, I]`
//!   gate-blocked kernels, `iofc` LSTM gate order, `transB` Gemm
//!   weights) onto the same canonical names.
//!
//! Canonical tensor names are `<layer>.<tensor>` over the `Weights`
//! layer naming: `rnn.w`, `rnn.u`, `rnn.b`, `dense0.w`, `dense0.b`, …,
//! `out.w`, `out.b`.
//!
//! Every failure is a typed [`ImportError`] naming the offending tensor
//! — imported files are untrusted input, so nothing here panics on bad
//! bytes.

pub mod onnx;

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::json::parse;

use super::arch::Arch;
use super::weights::{Tensor, Weights};

pub use onnx::OnnxSource;

/// Typed import failure.  Variants name the offending tensor (by its
/// canonical or in-file name) so a mis-exported checkpoint is
/// diagnosable from the message alone.
#[derive(Debug, Clone, PartialEq)]
pub enum ImportError {
    /// A tensor the architecture requires is absent.
    MissingTensor { name: String },
    /// A tensor exists but with the wrong shape (after any layout
    /// conversion the reader applies).
    ShapeMismatch {
        name: String,
        want: Vec<usize>,
        got: Vec<usize>,
    },
    /// A tensor is not f32 (`data_type` for ONNX).
    BadDtype { name: String, got: String },
    /// The file decodes but uses a construct outside the supported
    /// subset (e.g. bidirectional RNNs, non-`reset_after` GRUs).
    Unsupported { what: String },
    /// The file contents contradict the requested architecture.
    ArchMismatch { detail: String },
    /// The container bytes themselves do not decode.
    Malformed { detail: String },
}

impl std::fmt::Display for ImportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ImportError::MissingTensor { name } => {
                write!(f, "missing tensor {name:?}")
            }
            ImportError::ShapeMismatch { name, want, got } => {
                write!(f, "tensor {name:?} has shape {got:?}, want {want:?}")
            }
            ImportError::BadDtype { name, got } => {
                write!(f, "tensor {name:?} has dtype {got} (want f32)")
            }
            ImportError::Unsupported { what } => {
                write!(f, "unsupported: {what}")
            }
            ImportError::ArchMismatch { detail } => {
                write!(f, "architecture mismatch: {detail}")
            }
            ImportError::Malformed { detail } => {
                write!(f, "malformed model file: {detail}")
            }
        }
    }
}

impl std::error::Error for ImportError {}

/// A container of named f32 tensors.  `take` transfers ownership so the
/// loader can detect tensors the architecture never asked for.
pub trait TensorSource {
    /// The architecture the container records, when it records one.
    fn arch(&self) -> Option<&Arch>;
    /// Remove and return the tensor with this canonical name.
    fn take(&mut self, name: &str) -> Option<Tensor>;
    /// Names of the tensors not yet taken.
    fn remaining(&self) -> Vec<String>;
}

/// Shape-checked fetches over a [`TensorSource`].
pub struct VarBuilder<'a> {
    source: &'a mut dyn TensorSource,
}

impl<'a> VarBuilder<'a> {
    pub fn new(source: &'a mut dyn TensorSource) -> Self {
        Self { source }
    }

    /// Fetch `name`, requiring exactly `shape`.
    pub fn get(
        &mut self,
        name: &str,
        shape: &[usize],
    ) -> Result<Tensor, ImportError> {
        let t = self.source.take(name).ok_or_else(|| {
            ImportError::MissingTensor { name: name.to_string() }
        })?;
        if t.shape != shape {
            return Err(ImportError::ShapeMismatch {
                name: name.to_string(),
                want: shape.to_vec(),
                got: t.shape,
            });
        }
        Ok(t)
    }
}

impl Weights {
    /// Assemble [`Weights`] for `arch` from any [`TensorSource`], taking
    /// every tensor the architecture requires at its pinned shape and
    /// rejecting leftovers.  Runs the same parameter-count and shape
    /// validation as the JSON path.
    pub fn from_source(
        arch: &Arch,
        source: &mut dyn TensorSource,
    ) -> anyhow::Result<Weights> {
        if let Some(sa) = source.arch() {
            if sa != arch {
                return Err(ImportError::ArchMismatch {
                    detail: format!(
                        "file describes {} but {} was requested",
                        sa.key(),
                        arch.key()
                    ),
                }
                .into());
            }
        }
        let g = arch.cell.gates();
        let (i, h) = (arch.input_size, arch.hidden_size);
        let rnn_b_shape: Vec<usize> = match arch.cell {
            super::arch::Cell::Lstm => vec![4 * h],
            super::arch::Cell::Gru => vec![2, 3 * h],
        };

        let mut vb = VarBuilder::new(source);
        let mut layers: BTreeMap<String, BTreeMap<String, Tensor>> =
            BTreeMap::new();
        let mut put = |vb: &mut VarBuilder,
                       layers: &mut BTreeMap<String, BTreeMap<String, Tensor>>,
                       layer: &str,
                       tensor: &str,
                       shape: &[usize]|
         -> Result<(), ImportError> {
            let t = vb.get(&format!("{layer}.{tensor}"), shape)?;
            layers
                .entry(layer.to_string())
                .or_default()
                .insert(tensor.to_string(), t);
            Ok(())
        };

        put(&mut vb, &mut layers, "rnn", "w", &[i, g * h])?;
        put(&mut vb, &mut layers, "rnn", "u", &[h, g * h])?;
        put(&mut vb, &mut layers, "rnn", "b", &rnn_b_shape)?;
        let mut prev = h;
        for (idx, &size) in arch.dense_sizes.iter().enumerate() {
            let layer = format!("dense{idx}");
            put(&mut vb, &mut layers, &layer, "w", &[prev, size])?;
            put(&mut vb, &mut layers, &layer, "b", &[size])?;
            prev = size;
        }
        put(&mut vb, &mut layers, "out", "w", &[prev, arch.output_size])?;
        put(&mut vb, &mut layers, "out", "b", &[arch.output_size])?;

        let leftover = source.remaining();
        if !leftover.is_empty() {
            return Err(ImportError::Unsupported {
                what: format!(
                    "checkpoint carries tensors {} has no use for: {leftover:?}",
                    arch.key()
                ),
            }
            .into());
        }
        Weights::from_parts(arch.clone(), layers)
    }

    /// Load a checkpoint by path, dispatching on the extension:
    /// `.json` (interchange doc) or `.onnx`.  `arch` is optional for
    /// both formats — the JSON doc embeds it, and the ONNX reader
    /// infers it when the graph name is a model-zoo key — but when
    /// given it is enforced against the file.
    pub fn load_path(
        path: impl AsRef<Path>,
        arch: Option<&Arch>,
    ) -> anyhow::Result<Weights> {
        let path = path.as_ref();
        let ext = path
            .extension()
            .and_then(|e| e.to_str())
            .unwrap_or("")
            .to_ascii_lowercase();
        match ext.as_str() {
            "json" => {
                let text = std::fs::read_to_string(path).map_err(|e| {
                    anyhow::anyhow!("reading weights {}: {e}", path.display())
                })?;
                let mut src = JsonSource::parse(&text)?;
                let a = match arch {
                    Some(a) => a.clone(),
                    None => src.arch.clone(),
                };
                Weights::from_source(&a, &mut src)
            }
            "onnx" => {
                let bytes = std::fs::read(path).map_err(|e| {
                    anyhow::anyhow!("reading weights {}: {e}", path.display())
                })?;
                let mut src = OnnxSource::parse(&bytes, arch)?;
                let a = src.arch.clone();
                Weights::from_source(&a, &mut src)
            }
            other => anyhow::bail!(
                "unsupported weights extension {other:?} for {} \
                 (want .json or .onnx)",
                path.display()
            ),
        }
    }
}

/// The JSON interchange doc (`params_to_json`) as a [`TensorSource`]:
/// tensors flatten to `<layer>.<tensor>` names, the embedded `arch` is
/// exposed, and the declared `param_count` is cross-checked against the
/// tensors actually present.
pub struct JsonSource {
    pub arch: Arch,
    tensors: BTreeMap<String, Tensor>,
}

impl JsonSource {
    pub fn parse(text: &str) -> Result<Self, ImportError> {
        let malformed = |detail: String| ImportError::Malformed { detail };
        let doc = parse(text).map_err(|e| malformed(format!("json: {e}")))?;
        let arch = doc
            .req("arch")
            .and_then(Arch::from_json)
            .map_err(|e| malformed(format!("arch: {e}")))?;
        let declared = doc
            .req("param_count")
            .and_then(|v| v.as_usize())
            .map_err(|e| malformed(format!("param_count: {e}")))?;
        let mut tensors: BTreeMap<String, Tensor> = BTreeMap::new();
        let mut total = 0usize;
        let layers = doc
            .req("layers")
            .and_then(|v| v.as_array().map(<[_]>::to_vec))
            .map_err(|e| malformed(format!("layers: {e}")))?;
        for entry in &layers {
            let lname = entry
                .req("name")
                .and_then(|v| v.as_str().map(str::to_string))
                .map_err(|e| malformed(format!("layer name: {e}")))?;
            let pairs = entry
                .as_object()
                .map_err(|e| malformed(format!("layer {lname:?}: {e}")))?;
            for (key, val) in pairs {
                if key == "name" {
                    continue;
                }
                let name = format!("{lname}.{key}");
                let shape = val
                    .req("shape")
                    .and_then(|v| v.as_usize_vec())
                    .map_err(|e| malformed(format!("{name}: {e}")))?;
                let data = val
                    .req("data")
                    .and_then(|v| v.as_f32_vec())
                    .map_err(|e| malformed(format!("{name}: {e}")))?;
                let numel: usize = shape.iter().product();
                if numel != data.len() {
                    return Err(ImportError::ShapeMismatch {
                        name,
                        want: shape,
                        got: vec![data.len()],
                    });
                }
                total += data.len();
                if tensors.insert(name.clone(), Tensor { shape, data }).is_some()
                {
                    return Err(malformed(format!("duplicate tensor {name:?}")));
                }
            }
        }
        if total != declared {
            return Err(malformed(format!(
                "declared param_count {declared} but tensors hold {total}"
            )));
        }
        Ok(Self { arch, tensors })
    }
}

impl TensorSource for JsonSource {
    fn arch(&self) -> Option<&Arch> {
        Some(&self.arch)
    }
    fn take(&mut self, name: &str) -> Option<Tensor> {
        self.tensors.remove(name)
    }
    fn remaining(&self) -> Vec<String> {
        self.tensors.keys().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::weights::test_support::tiny_lstm_json;
    use crate::model::{zoo, Cell};

    #[test]
    fn json_source_yields_canonical_names() {
        let mut src = JsonSource::parse(&tiny_lstm_json()).unwrap();
        assert_eq!(src.arch.key(), "top_lstm");
        let names = src.remaining();
        assert!(names.contains(&"rnn.w".to_string()), "{names:?}");
        assert!(names.contains(&"out.b".to_string()), "{names:?}");
        assert_eq!(names.len(), 7);
        let w = src.take("rnn.w").unwrap();
        assert_eq!(w.shape, vec![2, 4]);
        assert!(src.take("rnn.w").is_none(), "take transfers ownership");
    }

    #[test]
    fn from_source_matches_from_json() {
        let a = Weights::from_json(&tiny_lstm_json()).unwrap();
        let mut src = JsonSource::parse(&tiny_lstm_json()).unwrap();
        let arch = src.arch.clone();
        let b = Weights::from_source(&arch, &mut src).unwrap();
        assert_eq!(
            a.tensor("rnn", "w").unwrap().data,
            b.tensor("rnn", "w").unwrap().data
        );
    }

    #[test]
    fn missing_tensor_is_typed_and_named() {
        let doc = tiny_lstm_json().replace("\"u\"", "\"u_typo\"");
        let err = match JsonSource::parse(&doc) {
            Ok(mut src) => {
                let arch = src.arch.clone();
                Weights::from_source(&arch, &mut src).unwrap_err()
            }
            Err(e) => e.into(),
        };
        let imp = err.downcast_ref::<ImportError>().expect("typed error");
        match imp {
            ImportError::MissingTensor { name } => assert_eq!(name, "rnn.u"),
            other => panic!("want MissingTensor, got {other}"),
        }
    }

    #[test]
    fn leftover_tensor_is_rejected() {
        let doc = tiny_lstm_json().replace(
            "{\"name\": \"out\",",
            "{\"name\": \"out\",
                 \"extra\": {\"shape\": [1], \"data\": [0.0]},",
        );
        // Extra params break the declared count first; fix it up.
        let doc = doc.replace("\"param_count\": 23", "\"param_count\": 24");
        let mut src = JsonSource::parse(&doc).unwrap();
        let arch = src.arch.clone();
        let err = Weights::from_source(&arch, &mut src).unwrap_err();
        assert!(err.to_string().contains("out.extra"), "{err}");
    }

    #[test]
    fn shape_mismatch_is_typed_and_named() {
        let mut src = JsonSource::parse(&tiny_lstm_json()).unwrap();
        let err = VarBuilder::new(&mut src).get("rnn.w", &[4, 2]).unwrap_err();
        match err {
            ImportError::ShapeMismatch { name, want, got } => {
                assert_eq!(name, "rnn.w");
                assert_eq!(want, vec![4, 2]);
                assert_eq!(got, vec![2, 4]);
            }
            other => panic!("want ShapeMismatch, got {other}"),
        }
    }

    #[test]
    fn arch_mismatch_is_rejected() {
        let mut src = JsonSource::parse(&tiny_lstm_json()).unwrap();
        let gru = zoo::arch("top", Cell::Gru).unwrap();
        let err = Weights::from_source(&gru, &mut src).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<ImportError>(),
                Some(ImportError::ArchMismatch { .. })
            ),
            "{err}"
        );
    }

    #[test]
    fn load_path_rejects_unknown_extension() {
        let err = Weights::load_path("weights.safetensors", None).unwrap_err();
        assert!(err.to_string().contains("safetensors"), "{err}");
        assert!(err.to_string().contains(".onnx"), "{err}");
    }
}
